"""Quickstart: SplitQuant in 40 lines.

    PYTHONPATH=src python examples/quickstart.py

Takes one weight matrix with outliers, INT2-quantizes it three ways
(baseline min/max, percentile clip, SplitQuant), and shows:
  * the mathematical equivalence (Σ split layers == fused dequant),
  * outlier preservation vs percentile clipping,
  * the resolution (MSE) win.
"""
import jax
import jax.numpy as jnp

from repro.core import (QuantConfig, baseline_quant_tensor, quantize_tree,
                        splitquant_tensor, QuantPolicy)

key = jax.random.PRNGKey(0)

# a weight matrix whose bulk is small but carries a few strong signals
w = jax.random.normal(key, (256, 256)) * 0.04
w = w.at[0, 0].set(2.0).at[10, 20].set(-1.8).at[100, 7].set(2.2)

cfg = QuantConfig(bits=2)
sq = splitquant_tensor(key, w, cfg, k=3)                 # the paper
bl = baseline_quant_tensor(w, cfg)                       # plain min/max PTQ
pc = baseline_quant_tensor(w, QuantConfig(bits=2, percentile=0.99))

print("== INT2 quantization of a 256x256 weight with outliers ==")
for name, t in (("baseline", bl), ("percentile-clip", pc),
                ("splitquant", sq)):
    mse = float(jnp.mean((w - t.dequantize()) ** 2))
    out_err = abs(float(t.dequantize()[0, 0]) - 2.0)
    print(f"{name:16s} mse {mse:.6f}   outlier |ŵ-2.0| = {out_err:.3f}")

# the paper's Figure-2 equivalence: three split layers sum to the whole
parts = sq.split_layers()
err = float(jnp.abs(sum(parts) - sq.dequantize()).max())
print(f"\nsplit-layer equivalence: max|Σ Ŵ_c - Ŵ| = {err} (exact)")
sizes = [f"{float(jnp.mean(sq.cid == c)):.1%}" for c in range(3)]
print(f"cluster occupancy lower/middle/upper: {sizes}")
print(f"deployed size: {sq.nbytes_deployed()} bytes "
      f"({w.size * 4 / sq.nbytes_deployed():.1f}x smaller than fp32)")

# whole-model application in one call
params = {"layer": {"w": w, "b": jnp.zeros(256)},
          "norm_scale": jnp.ones(256)}
qparams_, report = quantize_tree(key, params,
                                 QuantPolicy(cfg=QuantConfig(bits=2)))
print(f"\nquantize_tree: quantized={report['quantized']} "
      f"skipped={report['skipped']}")
