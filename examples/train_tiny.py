"""End-to-end training driver: train a small LM for a few hundred steps
through the full framework stack (mesh → shardings → prefetching pipeline →
fault-tolerant loop → checkpoints), then SplitQuant-quantize the result and
compare INT4 serving logits against fp32.

Default is a ~5M-param model so CPU finishes in a couple of minutes;
``--full`` trains the ~100M-param variant (use on real accelerators).

    PYTHONPATH=src python examples/train_tiny.py --steps 200
"""
import argparse
import dataclasses
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.core import QuantConfig, QuantPolicy, quantize_tree  # noqa: E402
from repro.data import DataConfig, Prefetcher, synthetic_lm_batch  # noqa: E402
from repro.launch.mesh import make_local_mesh  # noqa: E402
from repro.launch.shardings import (batch_shardings, opt_shardings,  # noqa: E402
                                    param_shardings)
from repro.models import get_model  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.runtime import train_loop  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="~100M params instead of ~5M")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_arch("stablelm-1.6b").reduced()
    if args.full:
        cfg = dataclasses.replace(cfg, n_layers=12, d_model=768, n_heads=12,
                                  n_kv_heads=12, d_ff=2048, vocab=32768)
    model = get_model(cfg)
    mesh = make_local_mesh()
    key = jax.random.PRNGKey(0)
    opt_cfg = adamw.OptConfig(lr=1e-3, total_steps=args.steps,
                              warmup_steps=20)

    with mesh, tempfile.TemporaryDirectory() as ckpt_dir:
        params = model.init(key, cfg)
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"model: {n/1e6:.1f}M params, mesh {dict(mesh.shape)}")
        p_sh = param_shardings(params, mesh)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(
            adamw.init(opt_cfg, params),
            opt_shardings(adamw.init(opt_cfg, params), p_sh, mesh))
        dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                        global_batch=args.batch)
        b_sh = batch_shardings(synthetic_lm_batch(dc, 0), mesh)
        step_fn = jax.jit(
            train_loop.make_train_step(
                lambda p, b: model.loss_fn(p, cfg, b, remat=True), opt_cfg),
            in_shardings=(p_sh, opt_shardings(opt_state, p_sh, mesh), b_sh),
            donate_argnums=(0, 1))
        pre = Prefetcher(lambda s: jax.device_put(
            synthetic_lm_batch(dc, s), b_sh), 0)
        lc = train_loop.TrainLoopConfig(total_steps=args.steps,
                                        ckpt_dir=ckpt_dir, ckpt_every=50,
                                        log_every=25)
        params, opt_state, hist = train_loop.run(lc, step_fn, params,
                                                 opt_state, pre.get)
        pre.stop()
        print(f"\nloss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
              f"over {len(hist)} steps")

        # quantized serving comparison
        batch = synthetic_lm_batch(dc, 999)
        ref = model.forward(params, cfg, {"tokens": batch["tokens"]})[0]
        for method in ("baseline", "splitquant"):
            qp, rep = quantize_tree(key, params, QuantPolicy(
                cfg=QuantConfig(bits=4), method=method))
            q = model.forward(qp, cfg, {"tokens": batch["tokens"]})[0]
            agree = float(jnp.mean((jnp.argmax(q, -1) ==
                                    jnp.argmax(ref, -1)).astype(jnp.float32)))
            print(f"INT4 {method:11s}: top-1 agreement with fp32 = "
                  f"{agree:.1%} (deployed {rep['deployed_bytes']/2**20:.1f} "
                  f"MiB vs {rep['orig_bytes']/2**20:.1f} MiB)")


if __name__ == "__main__":
    main()
