"""Full paper Table 1 reproduction (BERT-Tiny × 2 datasets × INT2/4/8 ×
{baseline, SplitQuant}). ~15 min on CPU.

    PYTHONPATH=src python examples/reproduce_bert_tiny.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))
from table1 import run_table1  # noqa: E402

if __name__ == "__main__":
    results = run_table1(epochs=8, n_samples=4000)
    print("\n== markdown (paper Table 1 structure) ==")
    print("| dataset | FP32 | INT2 base | INT2 SQ | diff | INT4 base | "
          "INT4 SQ | diff | INT8 base | INT8 SQ | diff |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for ds, r in results.items():
        cells = [f"{r['fp32']:.1%}"]
        for b in (2, 4, 8):
            base, sq = r[f"int{b}_baseline"], r[f"int{b}_splitquant"]
            cells += [f"{base:.1%}", f"{sq:.1%}", f"{100*(sq-base):+.1f}%p"]
        print(f"| {ds} | " + " | ".join(cells) + " |")
