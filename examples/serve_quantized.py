"""Quantized batched serving (the paper's deployment scenario): SplitQuant-
preprocess + INT2 quantize a model, then serve a wave of requests and
compare generations against the fp32 model.

    PYTHONPATH=src python examples/serve_quantized.py --bits 2
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.core import QuantConfig, QuantPolicy, quantize_tree  # noqa: E402
from repro.models import get_model  # noqa: E402
from repro.runtime.serve_loop import Request, ServeConfig, Server  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    scfg = ServeConfig(max_batch=4, max_new_tokens=args.new_tokens,
                       max_len=128)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=rng.integers(4, 10))
               for _ in range(args.requests)]

    def generate(p, label):
        srv = Server(cfg, p, scfg)
        reqs = [Request(i, pr.copy()) for i, pr in enumerate(prompts)]
        out = srv.serve(reqs)
        print(f"-- {label}")
        for r in out[:3]:
            print(f"   req {r.uid}: {r.out}")
        return [tuple(r.out) for r in out]

    ref = generate(params, "fp32")
    for method in ("baseline", "splitquant"):
        qp, rep = quantize_tree(key, params, QuantPolicy(
            cfg=QuantConfig(bits=args.bits), method=method))
        outs = generate(qp, f"INT{args.bits} {method} "
                        f"({rep['deployed_bytes']/2**20:.1f} MiB deployed)")
        match = np.mean([
            np.mean([a == b for a, b in zip(o, r)])
            for o, r in zip(outs, ref)])
        print(f"   token agreement with fp32: {match:.1%}")


if __name__ == "__main__":
    main()
