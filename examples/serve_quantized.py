"""Quantized serving (the paper's deployment scenario), end to end on the
continuous-batching engine: SplitQuant-preprocess + INT2 quantize the
weights, serve the same requests with the fp32 and the quantized model,
and compare generations — optionally with the KV cache itself stored INT8
(SplitQuant §4.2 chunked ranges applied to activations-at-rest).

    PYTHONPATH=src python examples/serve_quantized.py --bits 2 --kv-mode int8
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.core import QuantConfig, QuantPolicy, quantize_tree  # noqa: E402
from repro.engine import Engine, EngineConfig  # noqa: E402
from repro.models import get_model  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--kv-mode", default="fp", choices=["fp", "int8"])
    ap.add_argument("--fused-attn", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="read decode attention straight off the slot "
                         "cache (dequant-in-kernel, no full-precision "
                         "cache copy). Default ON; --no-fused-attn "
                         "selects the legacy materializing oracle")
    ap.add_argument("--prefill-chunk", type=int,
                    default=EngineConfig.prefill_chunk,
                    help="chunked fused prefill: at most this many prompt "
                         "tokens per engine step, K/V quantized in-kernel "
                         "straight into the slot cache (default ON — the "
                         "engine default; 0 = one-shot opt-out)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="self-speculative decoding: serve the fp32 "
                         "target with its own SplitQuant low-bit copy as "
                         "the DRAFT — up to k proposed tokens per slot "
                         "per step, verified in one fused pass; output "
                         "stays token-identical to plain greedy")
    ap.add_argument("--recipe", default=None,
                    help="serve from a calibration recipe dir (see "
                         "`python -m repro.launch.serve --save-recipe`): "
                         "pre-quantized weights, static INT8 KV scales")
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    ecfg = EngineConfig(max_len=128, n_slots=4,
                        max_new_tokens=args.new_tokens,
                        kv_mode=args.kv_mode,
                        fused_attn=args.fused_attn,
                        prefill_chunk=args.prefill_chunk)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=rng.integers(4, 10))
               for _ in range(args.requests)]

    def generate(p, label, kv_scales=None, ecfg=ecfg, draft=None):
        eng = Engine(cfg, p, ecfg, kv_scales=kv_scales, draft_params=draft)
        for pr in prompts:
            eng.submit(pr.copy())
        out = eng.drain()
        m = eng.metrics()
        spec = ""
        if ecfg.spec_k and m["acceptance_rate"] is not None:
            spec = (f", spec k={ecfg.spec_k} acceptance "
                    f"{m['acceptance_rate']:.1%}")
        print(f"-- {label}  ({m['tokens_per_s']:.1f} tok/s, "
              f"kv={m['kv_mode']}"
              f"{'/static' if m['kv_static_scales'] else ''}{spec})")
        for r in out[:3]:
            print(f"   req {r.uid}: {r.out}")
        return [tuple(r.out) for r in out]

    ref = generate(params, "fp32")

    if args.spec_k:
        # the paper's faithfulness property cashed in for decode wall
        # clock: the SplitQuant low-bit copy of the SAME weights drafts
        # for the fp32 target; the lossless accept rule keeps the output
        # token-identical to the plain fp32 serve above
        import dataclasses
        dqp, drep = quantize_tree(key, params, QuantPolicy(
            cfg=QuantConfig(bits=args.bits), method="splitquant"))
        secfg = dataclasses.replace(ecfg, spec_k=args.spec_k)
        outs = generate(params, f"fp32 + INT{args.bits} splitquant draft "
                        f"({drep['deployed_bytes']/2**20:.1f} MiB)",
                        ecfg=secfg, draft=dqp)
        match = np.mean([np.mean([a == b for a, b in zip(o, r)])
                         for o, r in zip(outs, ref)])
        print(f"   speculative == plain greedy: {match:.1%}")

    if args.recipe:
        # calibrated path: weights restore pre-quantized (no k-means) and
        # an INT8 KV cache quantizes with the recipe's static scales
        import dataclasses
        from repro.launch.serve import load_recipe_params
        qp, rec, kv_scales = load_recipe_params(args.recipe, params,
                                                arch=args.arch)
        if args.kv_mode != "int8":
            kv_scales = None
        ecfg = dataclasses.replace(ecfg, kv_qchunks=rec.kv_qchunks)
        outs = generate(qp, f"recipe {rec.name}", kv_scales=kv_scales)
        match = np.mean([
            np.mean([a == b for a, b in zip(o, r)])
            for o, r in zip(outs, ref)])
        print(f"   token agreement with fp32: {match:.1%}")
        return

    for method in ("baseline", "splitquant"):
        qp, rep = quantize_tree(key, params, QuantPolicy(
            cfg=QuantConfig(bits=args.bits), method=method))
        outs = generate(qp, f"INT{args.bits} {method} "
                        f"({rep['deployed_bytes']/2**20:.1f} MiB deployed)")
        match = np.mean([
            np.mean([a == b for a, b in zip(o, r)])
            for o, r in zip(outs, ref)])
        print(f"   token agreement with fp32: {match:.1%}")


if __name__ == "__main__":
    main()
