"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free LM with data-dependent
decay. Matrix-valued per-head state ⇒ O(1) memory decode, which is why this
arch runs the long_500k cell.

Per-layer time-mix recurrence (head h, key-dim i, value-dim j):
    S_t[i,j] = w_t[i] · S_{t-1}[i,j] + k_t[i] · v_t[j]
    y_t[j]   = Σ_i r_t[i] · (S_{t-1}[i,j] + u[i]·k_t[i]·v_t[j])
with data-dependent decay w_t = exp(-exp(d + tanh(x_w W1) W2)) ∈ (0,1).

Projections for the whole sequence are batched matmuls (MXU work); only the
elementwise state update is scanned over time. Decay/μ/u parameters are
"semantically not weights" (paper §4.1) and are excluded from quantization
via the "time_" path fragment.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import (apply_norm, dense, dtype_of, embed_init, embed_lookup,
                     he_init, init_norm, stack_layer_init)

LORA_MU, LORA_DECAY = 32, 64


class RWKVState(NamedTuple):
    """Recurrent cache: token-shift carries + per-head matrix state."""
    att_xprev: jnp.ndarray   # (L, B, d)
    ffn_xprev: jnp.ndarray   # (L, B, d)
    wkv: jnp.ndarray         # (L, B, H, Dh, Dh) fp32


def _init_layer(key, cfg, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    H, Dh = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    ks = jax.random.split(key, 10)
    z = lambda *s: jnp.zeros(s, dtype)
    return {
        "ln1": init_norm(d, "rms", dtype),
        "ln2": init_norm(d, "rms", dtype),
        "att": {
            "time_mu_x": z(d), "time_mu_w": z(d), "time_mu_k": z(d),
            "time_mu_v": z(d), "time_mu_r": z(d), "time_mu_g": z(d),
            "time_w1": he_init(ks[0], (d, 5 * LORA_MU), dtype),
            "time_w2": he_init(ks[1], (5, LORA_MU, d), dtype, fan_in=LORA_MU),
            "time_decay": jnp.full((d,), -4.0, dtype),
            "time_decay_w1": he_init(ks[2], (d, LORA_DECAY), dtype),
            "time_decay_w2": he_init(ks[3], (LORA_DECAY, d), dtype,
                                     fan_in=LORA_DECAY),
            "time_faaaa": z(H, Dh),
            "wr": he_init(ks[4], (d, d), dtype),
            "wk": he_init(ks[5], (d, d), dtype),
            "wv": he_init(ks[6], (d, d), dtype),
            "wg": he_init(ks[7], (d, d), dtype),
            "wo": he_init(ks[8], (d, d), dtype),
            "ln_x_scale": jnp.ones((d,), dtype),
            "ln_x_bias": z(d),
        },
        "ffn": {
            "time_mu_k": z(d), "time_mu_r": z(d),
            "wr": he_init(ks[9], (d, d), dtype),
            "wk": he_init(jax.random.fold_in(key, 91), (d, ff), dtype),
            "wv": he_init(jax.random.fold_in(key, 92), (ff, d), dtype,
                          fan_in=ff),
        },
    }


def init(key, cfg):
    dtype = dtype_of(cfg.param_dtype)
    ke, kl, kh = jax.random.split(key, 3)
    return {
        "embed": embed_init(ke, (cfg.vocab, cfg.d_model), dtype),
        "layers": stack_layer_init(lambda k: _init_layer(k, cfg, dtype),
                                   kl, cfg.n_layers),
        "final_norm": init_norm(cfg.d_model, "rms", dtype),
        "lm_head": he_init(kh, (cfg.d_model, cfg.vocab), dtype),
    }


def _token_shift(x, x_prev):
    """(B, T, d) → x_{t-1} with carry-in x_prev (B, d)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _ddlerp(x, xx, mu, lora):
    return x + (xx - x) * (mu + lora)


def _time_mix(p, x, cfg, x_prev, wkv_state):
    """x: (B,T,d). Returns (out, new_x_prev, new_wkv_state)."""
    B, T, d = x.shape
    H, Dh = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    xx = _token_shift(x, x_prev)
    base = _ddlerp(x, xx, p["time_mu_x"], 0.0)
    m = jnp.tanh(dense(base, p["time_w1"])).reshape(B, T, 5, LORA_MU)
    lora = jnp.einsum("btfm,fmd->fbtd", m, p["time_w2"].astype(x.dtype))
    xw = _ddlerp(x, xx, p["time_mu_w"], lora[0])
    xk = _ddlerp(x, xx, p["time_mu_k"], lora[1])
    xv = _ddlerp(x, xx, p["time_mu_v"], lora[2])
    xr = _ddlerp(x, xx, p["time_mu_r"], lora[3])
    xg = _ddlerp(x, xx, p["time_mu_g"], lora[4])

    r = dense(xr, p["wr"]).reshape(B, T, H, Dh)
    k = dense(xk, p["wk"]).reshape(B, T, H, Dh)
    v = dense(xv, p["wv"]).reshape(B, T, H, Dh)
    g = jax.nn.silu(dense(xg, p["wg"]))
    dec = p["time_decay"].astype(jnp.float32) + dense(
        jnp.tanh(dense(xw, p["time_decay_w1"])), p["time_decay_w2"]
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec)).reshape(B, T, H, Dh)            # (0,1)
    u = p["time_faaaa"].astype(jnp.float32)                    # (H, Dh)

    if T > 1 and T % 16 == 0:
        # chunked linear-attention form (kernels/wkv_chunked.py): MXU
        # matmuls instead of T sequential VPU steps — the TPU adaptation
        # of RWKV-LM's CUDA WKV kernel. Exact (all decay exponents ≤ 0).
        # NOTE (§Perf): the chunked form adds ~0.2 TB/dev of resharding
        # collectives vs the step scan (the B·H fold), but removes
        # 4096×32 sequential VPU steps per train step — a latency cost the
        # byte-based roofline cannot see but which dominates on hardware.
        from repro.kernels.wkv_chunked import wkv_chunked_jnp
        fold = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, T, Dh)
        yf, Sf = wkv_chunked_jnp(
            fold(r), fold(k), fold(v), fold(w),
            jnp.broadcast_to(u, (B, H, Dh)).reshape(B * H, Dh),
            chunk=16, s0=wkv_state.reshape(B * H, Dh, Dh))
        y = yf.reshape(B, H, T, Dh).transpose(0, 2, 1, 3) \
            .reshape(B, T, d).astype(jnp.float32)
        S = Sf.reshape(B, H, Dh, Dh)
    else:
        rf, kf, vf, wf = (a.astype(jnp.float32).transpose(1, 0, 2, 3)
                          for a in (r, k, v, w))               # (T,B,H,Dh)

        def step(S, xs):
            r_t, k_t, v_t, w_t = xs
            kv = k_t[..., :, None] * v_t[..., None, :]         # (B,H,Dh,Dh)
            y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[..., None] * kv)
            S = w_t[..., None] * S + kv
            return S, y

        S, ys = jax.lax.scan(step, wkv_state, (rf, kf, vf, wf))
        y = ys.transpose(1, 0, 2, 3).reshape(B, T, d)          # (B,T,d)

    # per-head group norm
    yh = y.reshape(B, T, H, Dh)
    mu_ = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu_) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(B, T, d) * p["ln_x_scale"].astype(jnp.float32) + \
        p["ln_x_bias"].astype(jnp.float32)
    out = dense((y.astype(x.dtype)) * g, p["wo"])
    return out, x[:, -1, :], S


def _channel_mix(p, x, x_prev):
    xx = _token_shift(x, x_prev)
    xk = _ddlerp(x, xx, p["time_mu_k"], 0.0)
    xr = _ddlerp(x, xx, p["time_mu_r"], 0.0)
    r = jax.nn.sigmoid(dense(xr, p["wr"]))
    k = jnp.square(jax.nn.relu(dense(xk, p["wk"])))
    return r * dense(k, p["wv"]), x[:, -1, :]


def _layer(cfg, p, x, state_layer):
    from .common import shard_hint
    ax, fx, S = state_layer
    x = shard_hint(x, "dp", None, None)
    h = apply_norm(x, p["ln1"], "rms")
    att, ax, S = _time_mix(p["att"], h, cfg, ax, S)
    x = x + att
    h = apply_norm(x, p["ln2"], "rms")
    ffn, fx = _channel_mix(p["ffn"], h, fx)
    return x + ffn, (ax, fx, S)


def init_state(cfg, batch_size: int, dtype=jnp.bfloat16) -> RWKVState:
    d = cfg.d_model
    H, Dh = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    L = cfg.n_layers
    return RWKVState(
        att_xprev=jnp.zeros((L, batch_size, d), dtype),
        ffn_xprev=jnp.zeros((L, batch_size, d), dtype),
        wkv=jnp.zeros((L, batch_size, H, Dh, Dh), jnp.float32))


def forward(params, cfg, batch, state: RWKVState | None = None, *,
            remat=False):
    """Returns (logits, new_state)."""
    x = embed_lookup(params["embed"], batch["tokens"])
    B = x.shape[0]
    if state is None:
        state = init_state(cfg, B, x.dtype)

    fn = _layer
    if remat:
        fn = jax.checkpoint(fn, static_argnums=(0,))

    def step(x, xs):
        lp, ax, fx, S = xs
        x, (ax, fx, S) = fn(cfg, lp, x, (ax.astype(x.dtype),
                                         fx.astype(x.dtype), S))
        return x, (ax, fx, S)

    x, (ax, fx, S) = jax.lax.scan(
        step, x, (params["layers"], state.att_xprev, state.ffn_xprev,
                  state.wkv))
    x = apply_norm(x, params["final_norm"], "rms")
    logits = dense(x, params["lm_head"]).astype(jnp.float32)
    return logits, RWKVState(ax, fx, S)


def loss_fn(params, cfg, batch, *, remat=True, **_):
    logits, _ = forward(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss, {"loss": loss}


def decode_step(params, cfg, state: RWKVState, tokens, pos=None):
    logits, state = forward(params, cfg, {"tokens": tokens}, state)
    return logits, state


def prefill(params, cfg, batch, max_len=None, *, kv_chunk=None,
            pad_mask=None, moe_blocks=1):
    """Prefill = one forward from zero state. ``max_len`` is satisfied
    vacuously — the recurrent cache has no length axis, so there is
    nothing to pad or overflow (prompts of any length serve) — and
    ``kv_chunk`` has no KV cache to chunk (a pure perf hint). Kwargs
    whose silent swallowing would CORRUPT results fail loudly: a
    pad_mask cannot be honored because the recurrence folds every input
    token into the state in order — left-pad tokens would poison it."""
    if pad_mask is not None:
        raise NotImplementedError(
            "rwkv6 prefill cannot honor pad_mask: the recurrence "
            "integrates every token into the state in order, so pad "
            "tokens would corrupt it — feed unpadded (per-request) "
            "prompts instead")
    if moe_blocks != 1:
        raise NotImplementedError("rwkv6 has no MoE layers to block "
                                  f"(moe_blocks={moe_blocks})")
    return forward(params, cfg, batch)


def verify_step_slots(*args, **kwargs):
    """Speculative decoding (engine spec_k > 0) needs positional KV
    rollback; a recurrence cannot provide it — fail LOUDLY rather than
    silently serving non-speculative."""
    raise NotImplementedError(
        "rwkv6 cannot serve speculative decoding (spec_k > 0): rejecting "
        "draft tokens requires rolling the cache back to the accepted "
        "position, but the WKV state is a running recurrence with no "
        "per-position storage — once a draft token is folded in it "
        "cannot be unfolded. Serve this family with spec_k=0")
