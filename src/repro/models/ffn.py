"""Feed-forward layers: gated-linear-unit variants and the sort-based
dropping MoE (expert parallelism over the "model" mesh axis).

MoE dispatch: the classic one-hot einsum dispatch materializes a
(tokens × experts × capacity) tensor — O(10^15) elements at kimi-k2 scale —
so we use sort-based dispatch instead: token→expert pairs are scattered
into a dense (E, C, d) buffer by expert id with position-in-expert from a
cumulative count; tokens over capacity are dropped (GShard semantics,
capacity_factor configurable). The (E, C, d) buffer shards E over "model"
(expert parallelism) and C over "data", so GSPMD lowers the dispatch to an
all-to-all — the same schedule a hand-written EP implementation uses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense, he_init, materialize, shard_hint, tp_dense


def init_ffn(key, d_model: int, d_ff: int, ffn_type: str, dtype, bias=False):
    k1, k2, k3 = jax.random.split(key, 3)
    if ffn_type in ("swiglu", "geglu"):
        p = {"w_gate": he_init(k1, (d_model, d_ff), dtype),
             "w_up": he_init(k2, (d_model, d_ff), dtype),
             "w_down": he_init(k3, (d_ff, d_model), dtype, fan_in=d_ff)}
    else:  # gelu
        p = {"w_up": he_init(k2, (d_model, d_ff), dtype),
             "w_down": he_init(k3, (d_ff, d_model), dtype, fan_in=d_ff)}
        if bias:
            p["b_up"] = jnp.zeros((d_ff,), dtype)
            p["b_down"] = jnp.zeros((d_model,), dtype)
    return p


def apply_ffn(p, x, ffn_type: str):
    hint = lambda h: shard_hint(h, *(("dp",) + (None,) * (h.ndim - 2) +
                                     ("tp",)))
    # NOTE (§Perf cell A iter 3, refuted): routing the down-projection
    # through common.tp_dense (explicit shard_map psum) ADDED ~1 TB/dev of
    # backward-pass collectives vs GSPMD's native schedule — GSPMD is at
    # the Megatron row-parallel floor here already. The f32-wire artifact
    # it exposed is handled in hlo_analysis (TPU-adjusted accounting).
    if ffn_type == "swiglu":
        h = hint(jax.nn.silu(dense(x, p["w_gate"])) * dense(x, p["w_up"]))
        return shard_hint(dense(h, p["w_down"]), "dp", None, None)
    if ffn_type == "geglu":
        h = hint(jax.nn.gelu(dense(x, p["w_gate"])) * dense(x, p["w_up"]))
        return shard_hint(dense(h, p["w_down"]), "dp", None, None)
    h = hint(jax.nn.gelu(dense(x, p["w_up"], p.get("b_up"))))
    return dense(h, p["w_down"], p.get("b_down"))


# -------------------------------------------------------------------- MoE --
def init_moe(key, cfg, dtype):
    kr, ke, ks = jax.random.split(key, 3)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": he_init(kr, (d, E), jnp.float32),
        "w_gate": he_init(jax.random.fold_in(ke, 0), (E, d, f), dtype),
        "w_up": he_init(jax.random.fold_in(ke, 1), (E, d, f), dtype),
        "w_down": he_init(jax.random.fold_in(ke, 2), (E, f, d), dtype, fan_in=f),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_ffn(ks, d, f * cfg.n_shared_experts, "swiglu", dtype)
    return p


def _dispatch_block(xt, gate, eidx, E, K, C, dtype):
    """Sort-based dispatch of ONE token block (no cross-block indexing, so
    under GSPMD with the block axis sharded over the data axes every
    scatter/gather stays shard-local). xt: (Tb, d); returns
    (buf (E, C, d), flat_e, safe_pos, wsrc)."""
    Tb, d = xt.shape
    flat_e = eidx.reshape(-1)                                  # (Tb·K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))      # (E,)
    pos_sorted = jnp.arange(Tb * K) - seg_start[sorted_e]
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    keep = pos < C
    safe_pos = jnp.where(keep, pos, C - 1)
    src = jnp.repeat(xt, K, axis=0)                            # (Tb·K, d)
    # keep the gate in the activation dtype: a f32 literal here promotes
    # every downstream activation (and its collectives) to f32
    zero = jnp.zeros((), gate.dtype)
    wsrc = jnp.where(keep, gate.reshape(-1), zero)[:, None]
    buf = jnp.zeros((E, C, d), dtype)
    buf = buf.at[flat_e, safe_pos].add(jnp.where(keep[:, None], src, 0))
    return buf, flat_e, safe_pos, wsrc


def apply_moe(p, x, cfg, capacity_factor: float | None = None,
              n_blocks: int = 1):
    """x: (B, S, d) → (B, S, d); returns (out, aux_loss).

    ``n_blocks``: dispatch locality blocks. Set = the data-parallel degree
    so each block's sort/scatter is local to one data shard; the only
    cross-shard traffic is then the (block, E, C, d) → (E, block, C, d)
    transpose — the canonical EP dispatch all-to-all. (EXPERIMENTS.md §Perf
    kimi iter 2: the global-argsort dispatch made GSPMD replicate the
    whole buffer.)
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    if n_blocks > 1 and T % n_blocks != 0:
        n_blocks = 1
    Tb = T // n_blocks
    cf = capacity_factor or cfg.capacity_factor
    if Tb <= 512:
        # small-T (decode / tests): capacity = Tb ⇒ provably no drops, so
        # decode is bit-exact vs the full forward pass
        C = Tb
    else:
        C = max(1, int(Tb * K * cf) // E)

    xt = x.reshape(T, d)
    logits = dense(xt.astype(jnp.float32), p["router"])        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                       # (T, K)
    gate = (gate / jnp.sum(gate, axis=-1, keepdims=True)).astype(x.dtype)

    # load-balancing aux loss (Switch): E * Σ_e f_e · p_e
    me = jnp.mean(jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32), axis=0)
    pe = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(me * pe)

    # ---- block-local dispatch (vmapped over the dp-sharded block axis) --
    xb = shard_hint(xt.reshape(n_blocks, Tb, d), "dp", None, None)
    gb = gate.reshape(n_blocks, Tb, K)
    eb = eidx.reshape(n_blocks, Tb, K)
    buf, flat_e, safe_pos, wsrc = jax.vmap(
        lambda xx, gg, ee: _dispatch_block(xx, gg, ee, E, K, C, x.dtype)
    )(xb, gb, eb)                                # buf: (n_blocks, E, C, d)

    # ---- EP all-to-all: block-major → expert-major ----
    bufe = shard_hint(buf.transpose(1, 0, 2, 3), "tp", "dp", None, None)

    # ---- expert computation (E sharded over "model") ----
    wg = materialize(p["w_gate"], x.dtype)
    wu = materialize(p["w_up"], x.dtype)
    wd = materialize(p["w_down"], x.dtype)
    h = jnp.einsum("encd,edf->encf", bufe, wg)
    u = jnp.einsum("encd,edf->encf", bufe, wu)
    y = shard_hint(jnp.einsum("encf,efd->encd", jax.nn.silu(h) * u, wd),
                   "tp", "dp", None, None)

    # ---- combine: all-to-all back, block-local gather, gate-weight ----
    yb = shard_hint(y.transpose(1, 0, 2, 3), "dp", None, None, None)
    out_b = jax.vmap(
        lambda yy, ee, pp, ww: (yy[ee, pp] * ww.astype(x.dtype))
        .reshape(Tb, K, d).sum(axis=1)
    )(yb, flat_e, safe_pos, wsrc)                # (n_blocks, Tb, d)
    out = shard_hint(out_b, "dp", None, None).reshape(T, d)

    if "shared" in p:
        out = out + apply_ffn(p["shared"], xt, "swiglu")
    return out.reshape(B, S, d), aux
