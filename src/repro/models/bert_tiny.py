"""BERT-Tiny sequence classifier (Turc et al. 2019 — the paper's test
vehicle): 2 layers, d=128, 2 heads, learned positions, post-LN, GELU FFN
with biases, [CLS] pooler + classification head.

This is the model quantized in the paper's Table 1; examples/ fine-tunes it
on two synthetic text-classification datasets and reproduces the
baseline-vs-SplitQuant comparison at INT2/4/8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import attend
from .common import (dense, dtype_of, embed_init, embed_lookup, he_init,
                     layer_norm, stack_layer_init)


def _init_layer(key, cfg, dtype):
    d, H, D = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 7)
    z = lambda *s: jnp.zeros(s, dtype)
    return {
        "attn": {"wq": he_init(ks[0], (d, H * D), dtype), "bq": z(H * D),
                 "wk": he_init(ks[1], (d, H * D), dtype), "bk": z(H * D),
                 "wv": he_init(ks[2], (d, H * D), dtype), "bv": z(H * D),
                 "wo": he_init(ks[3], (H * D, d), dtype), "bo": z(d)},
        "ln1": {"norm_scale": jnp.ones((d,), dtype), "norm_bias": z(d)},
        "ffn": {"w_up": he_init(ks[4], (d, cfg.d_ff), dtype),
                "b_up": z(cfg.d_ff),
                "w_down": he_init(ks[5], (cfg.d_ff, d), dtype,
                                  fan_in=cfg.d_ff),
                "b_down": z(d)},
        "ln2": {"norm_scale": jnp.ones((d,), dtype), "norm_bias": z(d)},
    }


def init(key, cfg, n_classes: int, max_len: int = 128):
    dtype = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    return {
        "embed": embed_init(ks[0], (cfg.vocab, d), dtype),
        "pos_table": embed_init(ks[1], (max_len, d), dtype),
        "embed_ln": {"norm_scale": jnp.ones((d,), dtype),
                     "norm_bias": jnp.zeros((d,), dtype)},
        "layers": stack_layer_init(lambda k: _init_layer(k, cfg, dtype),
                                   ks[2], cfg.n_layers),
        "pooler": {"w": he_init(ks[3], (d, d), dtype),
                   "b": jnp.zeros((d,), dtype)},
        "classifier": {"w": he_init(ks[4], (d, n_classes), dtype),
                       "b": jnp.zeros((n_classes,), dtype)},
    }


#: activation tap sites instrumented for calibration (repro.calib.stats) —
#: exactly the §4.2 quantization points the ``aq()`` closure covers
ACT_SITES = ("attn_in", "attn_out", "ffn_in", "ffn_hidden")


def _site_stats(h, n_chunks: int, percentile: float):
    """Range statistics of one activation tensor: whole-tensor min/max,
    symmetric percentile clip points, and per-chunk (§4.2) min/max along
    the feature axis (uneven `array_split` chunks for any width)."""
    from repro.core import activation_chunk_bounds
    hf = h.astype(jnp.float32)
    bounds = activation_chunk_bounds(h.shape[-1], n_chunks)
    cmin = jnp.stack([jnp.min(hf[..., lo:hi])
                      for lo, hi in zip(bounds, bounds[1:])])
    cmax = jnp.stack([jnp.max(hf[..., lo:hi])
                      for lo, hi in zip(bounds, bounds[1:])])
    return {"min": jnp.min(hf), "max": jnp.max(hf),
            "p_lo": jnp.percentile(hf, (1 - percentile) * 100),
            "p_hi": jnp.percentile(hf, percentile * 100),
            "chunk_min": cmin, "chunk_max": cmax}


def forward(params, cfg, batch, *, act_quant=None, act_chunks: int = 1,
            collect_stats=None):
    """batch: {tokens (B,S), mask (B,S) 1=real} → logits (B, n_classes).

    ``act_quant``: optional QuantConfig for simulated ACTIVATION
    quantization (paper §4.2). ``act_chunks=3`` applies the SplitQuant
    activation split (per-chunk dynamic ranges); 1 = whole-tensor range
    (the baseline an int engine would use).

    ``collect_stats``: optional ``{"n_chunks": int, "percentile": float}``
    — the calibration instrumentation. Per-layer range statistics are
    emitted at every ``aq()`` tap site *through the layer scan* (each stat
    leaf gains a leading L axis) and the return value becomes
    ``(logits, {site: stats})``. See ``repro.calib.stats``.
    """
    from repro.core import split_activation_fake_quant

    def aq(h):
        if act_quant is None:
            return h
        return split_activation_fake_quant(h, act_quant, n_chunks=act_chunks)

    tokens = batch["tokens"]
    B, S = tokens.shape
    mask = batch.get("mask", jnp.ones_like(tokens))
    x = embed_lookup(params["embed"], tokens) + \
        params["pos_table"][None, :S]
    x = layer_norm(x, params["embed_ln"]["norm_scale"],
                   params["embed_ln"]["norm_bias"])
    positions = jnp.arange(S, dtype=jnp.int32)
    H, D = cfg.n_heads, cfg.head_dim
    # padding mask folded into kv positions: masked slots get pos -1
    kv_pos_b = jnp.where(mask > 0, positions[None, :], -1)     # (B, S)

    def layer(x, lp):
        a = lp["attn"]
        stats = {}

        def tap(site, h):
            if collect_stats is not None:
                stats[site] = _site_stats(h, collect_stats["n_chunks"],
                                          collect_stats["percentile"])
            return aq(h)

        x = tap("attn_in", x)
        q = dense(x, a["wq"], a["bq"]).reshape(B, S, H, D)
        k = dense(x, a["wk"], a["bk"]).reshape(B, S, H, D)
        v = dense(x, a["wv"], a["bv"]).reshape(B, S, H, D)
        # per-example padding: vmap attend over the batch
        o = jax.vmap(lambda qi, ki, vi, pi: attend(
            qi[None], ki[None], vi[None], positions, pi,
            causal=False)[0])(q, k, v, kv_pos_b)
        o = tap("attn_out", o.reshape(B, S, H * D))
        x = layer_norm(x + dense(o, a["wo"], a["bo"]),
                       lp["ln1"]["norm_scale"], lp["ln1"]["norm_bias"])
        h = jax.nn.gelu(dense(tap("ffn_in", x), lp["ffn"]["w_up"],
                              lp["ffn"]["b_up"]))
        h = dense(tap("ffn_hidden", h), lp["ffn"]["w_down"],
                  lp["ffn"]["b_down"])
        x = layer_norm(x + h, lp["ln2"]["norm_scale"],
                       lp["ln2"]["norm_bias"])
        return x, (stats if collect_stats is not None else None)

    x, layer_stats = jax.lax.scan(layer, x, params["layers"])
    cls = x[:, 0]
    pooled = jnp.tanh(dense(cls, params["pooler"]["w"], params["pooler"]["b"]))
    logits = dense(pooled, params["classifier"]["w"],
                   params["classifier"]["b"]).astype(jnp.float32)
    if collect_stats is not None:
        return logits, layer_stats
    return logits


def loss_fn(params, cfg, batch, **_):
    logits = forward(params, cfg, batch)
    labels = batch["labels"]                                   # (B,)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"loss": loss, "acc": acc}


def accuracy(params, cfg, batch):
    logits = forward(params, cfg, batch)
    return jnp.mean((jnp.argmax(logits, -1) == batch["labels"])
                    .astype(jnp.float32))
