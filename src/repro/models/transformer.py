"""Decoder-only LM covering the dense / MoE / VLM families.

Layers are stacked (leading L axis) and executed with ``jax.lax.scan`` +
``jax.checkpoint`` so HLO size and compile time are depth-independent (a
126-layer llama3-405b compiles as one scanned block). Heterogeneous stacks
(DeepSeek-style leading dense layers before MoE) are two scans.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .attention import KVCache, attention_block
from .common import (apply_norm, dense, dtype_of, embed_init, embed_lookup,
                     he_init, init_norm, shard_hint, stack_layer_init)
from .ffn import apply_ffn, apply_moe, init_ffn, init_moe

VLM_PATCH_DIM = 1152          # SigLIP-so400m embedding width (stub frontend)


def _init_layer(key, cfg, dtype, moe: bool):
    ka, kf = jax.random.split(key)
    d, Hq, Hkv, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(ka, 4)
    p = {
        "attn": {
            "wq": he_init(kq, (d, Hq * D), dtype),
            "wk": he_init(kk, (d, Hkv * D), dtype),
            "wv": he_init(kv, (d, Hkv * D), dtype),
            "wo": he_init(ko, (Hq * D, d), dtype, fan_in=Hq * D),
        },
        "ln1": init_norm(d, cfg.norm_type, dtype),
        "ln2": init_norm(d, cfg.norm_type, dtype),
    }
    if moe:
        p["moe"] = init_moe(kf, cfg, dtype)
    else:
        ff = cfg.dense_d_ff or cfg.d_ff
        if cfg.n_experts and not cfg.dense_d_ff:
            ff = cfg.d_ff * max(cfg.top_k, 1)   # dense prelude matches act. width
        p["ffn"] = init_ffn(kf, cfg.d_model, ff, cfg.ffn_type, dtype,
                            bias=cfg.bias)
    return p


def init(key, cfg):
    dtype = dtype_of(cfg.param_dtype)
    ke, kl, kd, kh, kp = jax.random.split(key, 5)
    n_moe = cfg.n_layers - cfg.first_k_dense if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe
    params = {"embed": embed_init(ke, (cfg.vocab, cfg.d_model), dtype),
              "final_norm": init_norm(cfg.d_model, cfg.norm_type, dtype)}
    if n_dense:
        params["layers"] = stack_layer_init(
            lambda k: _init_layer(k, cfg, dtype, moe=False), kl, n_dense)
    if n_moe:
        params["moe_layers"] = stack_layer_init(
            lambda k: _init_layer(k, cfg, dtype, moe=True), kd, n_moe)
    if not cfg.tie_embeddings:
        params["lm_head"] = he_init(kh, (cfg.d_model, cfg.vocab), dtype)
    if cfg.family == "vlm":
        params["patch_proj"] = he_init(kp, (VLM_PATCH_DIM, cfg.d_model), dtype)
    return params


def _layer_apply(cfg, p, x, positions, cache_layer, *, moe: bool,
                 kv_chunk, want_kv: bool, moe_blocks: int = 1,
                 tshard_decode: bool = False, kv_pos_override=None,
                 fused_attn: bool = False, slot_chunk=None,
                 spec_verify: bool = False):
    x = shard_hint(x, "dp", None, None)
    h = apply_norm(x, p["ln1"], cfg.norm_type)
    attn_out, kv = attention_block(
        p["attn"], h, cfg, positions, cache_layer,
        causal=cfg.family != "encoder", window=cfg.window,
        kv_chunk=kv_chunk, want_kv=want_kv, tshard_decode=tshard_decode,
        kv_pos_override=kv_pos_override, fused_attn=fused_attn,
        slot_chunk=slot_chunk, spec_verify=spec_verify)
    x = x + attn_out
    h = apply_norm(x, p["ln2"], cfg.norm_type)
    if moe:
        ffn_out, aux = apply_moe(p["moe"], h, cfg, n_blocks=moe_blocks)
    else:
        ffn_out, aux = apply_ffn(p["ffn"], h, cfg.ffn_type), jnp.float32(0)
    return x + ffn_out, kv, aux


def _scan_stack(cfg, stacked, x, positions, cache, *, moe, kv_chunk,
                want_kv, remat, moe_blocks=1, tshard_decode=False,
                kv_pos_override=None, fused_attn=False, slot_chunk=None,
                spec_verify=False):
    """Scan a homogeneous stacked layer group. cache: per-stack KVCache,
    engine SlotKVCache, or None. Returns (x, new_cache_or_kv, aux_sum)."""
    fn = functools.partial(_layer_apply, cfg, moe=moe, kv_chunk=kv_chunk,
                           want_kv=want_kv, moe_blocks=moe_blocks,
                           tshard_decode=tshard_decode,
                           kv_pos_override=kv_pos_override,
                           fused_attn=fused_attn, slot_chunk=slot_chunk,
                           spec_verify=spec_verify)
    if remat:
        fn = jax.checkpoint(fn, static_argnums=())

    if cache is not None and not isinstance(cache, KVCache):
        # engine slot cache: scan the dataclass itself — every data leaf
        # has leading L, so each step sees a per-layer SlotKVCache slice
        def step(carry, xs):
            x, aux = carry
            lp, cl = xs
            x, new_cl, a = fn(lp, x, positions, cl)
            return (x, aux + a), new_cl
        (x, aux), new_cache = jax.lax.scan(step, (x, jnp.float32(0)),
                                           (stacked, cache))
        return x, new_cache, aux

    if cache is not None:
        def step(carry, xs):
            x, aux = carry
            lp, ck, cv, sp = xs
            x, new_c, a = fn(lp, x, positions, (ck, cv, sp))
            return (x, aux + a), new_c
        (x, aux), ys = jax.lax.scan(step, (x, jnp.float32(0)),
                                    (stacked, cache.k, cache.v, cache.slot_pos))
        new_cache = KVCache(k=ys[0], v=ys[1], slot_pos=ys[2])
        return x, new_cache, aux

    def step(carry, lp):
        x, aux = carry
        x, kv, a = fn(lp, x, positions, None)
        return (x, aux + a), kv if want_kv else None
    (x, aux), ys = jax.lax.scan(step, (x, jnp.float32(0)), stacked)
    return x, ys, aux


def embed_inputs(params, cfg, batch):
    """tokens (+ optional VLM patch embeds) → (B, S, d), positions (S,)."""
    tokens = batch["tokens"]
    x = embed_lookup(params["embed"], tokens)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        patches = dense(batch["patch_embeds"].astype(x.dtype),
                        params["patch_proj"])
        x = jnp.concatenate([patches, x], axis=1)
    S = x.shape[1]
    return x, jnp.arange(S, dtype=jnp.int32)


def forward(params, cfg, batch, cache: Optional[KVCache] = None,
            positions=None, *, kv_chunk=None, want_cache=False, remat=False,
            cache_len: Optional[int] = None, moe_blocks: int = 1,
            tshard_decode: bool = False, pad_mask=None,
            fused_attn: bool = False, slot_chunk=None,
            spec_verify: bool = False):
    """Returns (logits, new_cache, aux). cache ⇒ decode step (a KVCache, or
    an engine SlotKVCache with per-request positions); want_cache ⇒ prefill
    (assembles a fresh cache from the computed K/V). pad_mask (B, S) marks
    True=padding tokens whose K/V must never be attended to (left- or
    right-padded batched prefill). fused_attn routes slot-cache decode
    through the fused dequant-in-kernel attention. slot_chunk (slot,
    pos_start, length) + a SlotKVCache ⇒ chunked prefill of one slot:
    `positions` are the chunk's absolute positions and each layer's K/V is
    quantized in-kernel and written straight into the slot cache instead
    of assembling a dense prefill cache. spec_verify (with slot_chunk) ⇒
    the chunk is a speculative DRAFT WINDOW: attention round-trips the
    window's own K/V through cache storage so each row scores like a plain
    decode step, and logits for EVERY window row are returned (the accept
    rule compares per-position argmax)."""
    if cache is not None:
        x = embed_lookup(params["embed"], batch["tokens"])     # (B, 1)
    else:
        x, positions = (embed_inputs(params, cfg, batch)
                        if positions is None else
                        (embed_lookup(params["embed"], batch["tokens"]), positions))

    kv_pos_override = None
    if pad_mask is not None and cache is None:
        kv_pos_override = jnp.where(pad_mask, jnp.int32(-1),
                                    positions[None, :].astype(jnp.int32))

    n_moe = (cfg.n_layers - cfg.first_k_dense) if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe
    aux = jnp.float32(0)
    caches, kvs = [], []
    want_kv = want_cache

    def split_cache(cache, lo, hi):
        if cache is None:
            return None
        # every cache leaf carries leading L (KVCache and SlotKVCache alike)
        return jax.tree_util.tree_map(lambda a: a[lo:hi], cache)

    if n_dense:
        x, c, a = _scan_stack(cfg, params["layers"], x, positions,
                              split_cache(cache, 0, n_dense), moe=False,
                              kv_chunk=kv_chunk, want_kv=want_kv, remat=remat,
                              tshard_decode=tshard_decode,
                              kv_pos_override=kv_pos_override,
                              fused_attn=fused_attn, slot_chunk=slot_chunk,
                              spec_verify=spec_verify)
        aux += a
        (caches if cache is not None else kvs).append(c)
    if n_moe:
        x, c, a = _scan_stack(cfg, params["moe_layers"], x, positions,
                              split_cache(cache, n_dense, cfg.n_layers),
                              moe=True, kv_chunk=kv_chunk, want_kv=want_kv,
                              remat=remat, moe_blocks=moe_blocks,
                              tshard_decode=tshard_decode,
                              kv_pos_override=kv_pos_override,
                              fused_attn=fused_attn, slot_chunk=slot_chunk,
                              spec_verify=spec_verify)
        aux += a
        (caches if cache is not None else kvs).append(c)

    if slot_chunk is not None and not spec_verify:
        # chunk prefill consumes ONLY the last valid token's logits (the
        # first-generated-token sample on the prompt's final chunk) —
        # slice before the head so the vocab projection is (1, 1, V)
        # instead of (1, Sc, V) per chunk. A verify window keeps every
        # row: the accept rule needs the target's argmax per position.
        x = jax.lax.dynamic_slice_in_dim(x, slot_chunk[2] - 1, 1, axis=1)
    x = apply_norm(x, params["final_norm"], cfg.norm_type)
    head = params.get("lm_head", None)
    if head is None:
        table = params["embed"]
        if hasattr(table, "dequantize"):
            table = table.dequantize()
        logits = jnp.dot(x, table.T.astype(x.dtype))
    else:
        logits = dense(x, head)
    logits = shard_hint(logits.astype(jnp.float32), "dp", None, "tp")

    new_cache = None
    if cache is not None:
        new_cache = (caches[0] if len(caches) == 1 else
                     jax.tree_util.tree_map(
                         lambda *xs: jnp.concatenate(xs, 0), *caches))
    elif want_cache:
        new_cache = assemble_cache(cfg, kvs, positions, max_len=cache_len,
                                   pad_mask=pad_mask)
    return logits, new_cache, aux


def assemble_cache(cfg, kvs, positions, max_len: Optional[int] = None,
                   pad_mask=None):
    """Build a decode cache from prefill K/V. Windowed attention keeps a
    ring of the last `window` positions; global keeps everything (padded to
    max_len if given). With pad_mask (B, S), slot_pos becomes per-request
    (L, B, T) and padded entries are marked -1 (never attended)."""
    k = jnp.concatenate([kv[0] for kv in kvs], axis=0)   # (L, B, S, Hkv, D)
    v = jnp.concatenate([kv[1] for kv in kvs], axis=0)
    L, B, S = k.shape[0], k.shape[1], k.shape[2]
    if cfg.window is not None and S > cfg.window:
        W = cfg.window
        k, v = k[:, :, -W:], v[:, :, -W:]
        pos = positions[-W:]
        # ring layout: slot = pos % W
        slot = pos % W
        inv = jnp.argsort(slot)
        k, v, pos = k[:, :, inv], v[:, :, inv], pos[inv]
        if pad_mask is not None:
            padb = pad_mask[:, -W:][:, inv]              # (B, W) ring order
            sp = jnp.where(padb, -1, pos[None, :]).astype(jnp.int32)
            return KVCache(k, v, jnp.broadcast_to(sp, (L, B, W)))
        slot_pos = jnp.broadcast_to(pos, (L, W)).astype(jnp.int32)
        return KVCache(k, v, slot_pos)
    T = max_len or S
    pad = T - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    sp = jnp.concatenate([positions.astype(jnp.int32),
                          jnp.full((pad,), -1, jnp.int32)])
    if pad_mask is not None:
        padb = jnp.pad(pad_mask, ((0, 0), (0, pad)), constant_values=True)
        sp = jnp.where(padb, -1, sp[None, :]).astype(jnp.int32)  # (B, T)
        return KVCache(k, v, jnp.broadcast_to(sp, (L, B, T)))
    return KVCache(k, v, jnp.broadcast_to(sp, (L, T)))


def init_cache(cfg, batch_size: int, max_len: int, dtype=jnp.bfloat16):
    T = min(cfg.window, max_len) if cfg.window else max_len
    shape = (cfg.n_layers, batch_size, T, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   slot_pos=jnp.full((cfg.n_layers, T), -1, jnp.int32))


def loss_fn(params, cfg, batch, *, kv_chunk=None, remat=True,
            aux_weight=0.01, moe_blocks=1):
    logits, _, aux = forward(params, cfg, batch, kv_chunk=kv_chunk,
                             remat=remat, moe_blocks=moe_blocks)
    labels = batch["labels"]
    if cfg.family == "vlm" and "patch_embeds" in batch:
        logits = logits[:, -labels.shape[1]:]          # loss on text tokens
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss + aux_weight * aux, {"loss": loss, "aux": aux}


def decode_step(params, cfg, cache: KVCache, tokens, pos, *, kv_chunk=None,
                tshard=False):
    """One decode step. tokens: (B, 1) int32; pos: scalar int32 position.
    ``tshard``: use the time-sharded ring decode attention (TP-resident
    cache when kv_heads < TP)."""
    positions = jnp.reshape(pos, (1,)).astype(jnp.int32)
    logits, cache, _ = forward(params, cfg, {"tokens": tokens}, cache=cache,
                               positions=positions, kv_chunk=kv_chunk,
                               tshard_decode=tshard)
    return logits, cache


def decode_step_slots(params, cfg, cache, tokens, pos, *, kv_chunk=None,
                      fused=False):
    """One decode step over an engine slot cache. tokens: (N, 1) int32;
    pos: (N,) int32 per-slot absolute positions (one per request — slots
    at different depths decode together). ``fused``: attention reads the
    (possibly INT8) cache through the fused dequant-in-kernel path instead
    of materializing a full-precision copy."""
    positions = jnp.reshape(pos, (-1, 1)).astype(jnp.int32)
    logits, cache, _ = forward(params, cfg, {"tokens": tokens}, cache=cache,
                               positions=positions, kv_chunk=kv_chunk,
                               fused_attn=fused)
    return logits, cache


def prefill_chunk_slots(params, cfg, cache, tokens, slot, pos_start,
                        length, *, kv_chunk=None):
    """CHUNKED prefill of ONE slot straight into the engine slot cache:
    process a chunk of prompt tokens at absolute positions
    [pos_start, pos_start + Sc), quantize each layer's K/V in-kernel and
    scatter the codes into the slot's rows — no dense (L, S, Hkv, D)
    prefill cache is ever assembled (contrast `prefill` +
    `engine.kvcache.write_prefill`, the legacy one-shot path).

    tokens: (1, Sc) int32 (right-padded to a chunk bucket); slot /
    pos_start / length are traced scalars, `length` <= Sc the number of
    real prompt tokens. Returns (last_logits (1, V), cache) where
    last_logits is the logits row of the chunk's FINAL valid token — the
    engine samples the first generated token from it on the prompt's last
    chunk and ignores it otherwise.
    """
    Sc = tokens.shape[1]
    positions = (jnp.asarray(pos_start, jnp.int32)
                 + jnp.arange(Sc, dtype=jnp.int32))
    logits, cache, _ = forward(
        params, cfg, {"tokens": tokens}, cache=cache, positions=positions,
        kv_chunk=kv_chunk, slot_chunk=(slot, pos_start, length))
    return logits[:, 0], cache                 # head already sliced to the
    # chunk's last valid token (see forward's slot_chunk branch)


def verify_step_slots(params, cfg, cache, tokens, slot, pos_start, length,
                      *, kv_chunk=None):
    """Speculative-decoding VERIFY: score a draft window of ONE slot in a
    single fused pass (DESIGN.md §9). A draft window *is* a prefill chunk
    — the window's queries attend the slot's already-committed (possibly
    INT8) prefix plus the window's own K/V, each layer's window K/V is
    quantized in-kernel and scattered into rows
    [pos_start, pos_start + Sq), and — unlike plain chunked prefill —
    every row attends the window THROUGH the storage round-trip and every
    row's logits are returned, so row j's argmax equals the token a plain
    decode step would have produced after window token j. The engine's
    accept rule then keeps the longest matching draft prefix plus the
    target's own correction token; rejected rows are undone with
    `engine.kvcache.rollback_slot`.

    tokens: (1, Sq) int32 — [last committed token, draft tokens...],
    right-padded to the spec window bucket; slot / pos_start / length are
    traced scalars, `length` <= Sq the real window size. Returns
    (logits (1, Sq, V), cache); rows at >= length are padding garbage the
    caller ignores.
    """
    Sq = tokens.shape[1]
    positions = (jnp.asarray(pos_start, jnp.int32)
                 + jnp.arange(Sq, dtype=jnp.int32))
    logits, cache, _ = forward(
        params, cfg, {"tokens": tokens}, cache=cache, positions=positions,
        kv_chunk=kv_chunk, slot_chunk=(slot, pos_start, length),
        spec_verify=True)
    return logits, cache


def prefill(params, cfg, batch, max_len: Optional[int] = None, *,
            kv_chunk=None, moe_blocks: int = 1, pad_mask=None):
    logits, cache, _ = forward(params, cfg, batch, kv_chunk=kv_chunk,
                               want_cache=True, cache_len=max_len,
                               moe_blocks=moe_blocks, pad_mask=pad_mask)
    return logits, cache
