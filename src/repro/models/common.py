"""Shared model building blocks: norms, RoPE (incl. GLM half/2-D variant),
initializers, and the quantization-transparent dense layer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.splitquant import SplitQuantTensor
from repro.kernels import ops


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ------------------------------------------------- activation sharding ----
#: axis aliases resolved against the active mesh: "dp" = the data-parallel
#: axes (("pod","data") or ("data",)), "tp" = "model".
import os as _os

_HINTS_ON = _os.environ.get("REPRO_SHARD_HINTS", "1") != "0"


def shard_hint(x, *spec):
    """Best-effort `with_sharding_constraint`: resolves "dp"/"tp" aliases
    against the active mesh, drops non-divisible axes, and is a no-op when
    no mesh is active (tests / single device) or REPRO_SHARD_HINTS=0.

    GSPMD's sharding propagation gives up inside scanned layers (it
    replicates q/k/v and re-gathers activations every layer — see
    EXPERIMENTS.md §Perf baseline); pinning the activation layout at block
    boundaries removes that redundancy.
    """
    if not _HINTS_ON:
        return x
    try:
        from jax._src import mesh as _mesh_lib
        mesh = _mesh_lib.thread_resources.env.physical_mesh
        if mesh.empty:
            mesh = jax.sharding.get_abstract_mesh()
        names = getattr(mesh, "axis_names", None)
        if not names:
            return x
        axis_size = dict(mesh.shape)
    except Exception:
        return x

    def resolve(ax):
        if ax is None:
            return None
        if ax == "dp":
            ax = tuple(a for a in ("pod", "data") if a in axis_size) or None
            if ax is None:
                return None
        elif ax == "tp":
            ax = "model" if "model" in axis_size else None
            if ax is None:
                return None
        return ax

    out = []
    for dim, ax in zip(x.shape, spec):
        ax = resolve(ax)
        if ax is None:
            out.append(None)
            continue
        n = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            n *= axis_size[a]
        out.append(ax if dim % n == 0 and dim >= n else None)
    from jax.sharding import PartitionSpec as _P
    try:
        return jax.lax.with_sharding_constraint(x, _P(*out))
    except Exception:
        return x


def tp_dense(x, w, b=None):
    """Row-parallel (Megatron-style) linear with an EXPLICIT shard_map
    reduction: local partial matmul over the TP shard of the contraction
    dim, then psum over "model" in the activation dtype.

    Why not let GSPMD insert it (EXPERIMENTS.md §Perf cell A iter 3):
      * GSPMD reduces the partials in the dot's accumulation dtype (f32 on
        the CPU-lowered dry-run) — 2× the wire bytes of a bf16 reduce;
      * GSPMD also emits dx all-reduces in backward, which row-parallel
        linear does not need (dy is replicated over "model"; dx_local =
        dy @ w_localᵀ is exact). shard_map's transpose gets this right.

    Falls back to `dense` when no mesh is active, dims don't divide, the
    weight is quantized/stacked oddly, or a bias is present.
    """
    from jax._src import mesh as _mesh_lib
    if (not _HINTS_ON or b is not None or
            isinstance(w, SplitQuantTensor) or w.ndim != 2 or x.ndim < 2):
        return dense(x, w, b)
    try:
        mesh = _mesh_lib.thread_resources.env.physical_mesh
    except Exception:
        return dense(x, w, b)
    if mesh.empty or "model" not in mesh.axis_names:
        return dense(x, w, b)
    import math
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as _P
    sizes = dict(mesh.shape)
    tp = sizes["model"]
    dp_axes = tuple(a for a in ("pod", "data") if a in sizes)
    fsdp = "data" if "data" in sizes else None
    dpn = math.prod(sizes[a] for a in dp_axes) if dp_axes else 1
    K, N = w.shape
    B0 = x.shape[0]
    if K % tp or (fsdp and N % sizes[fsdp]) or B0 % dpn or B0 < dpn or tp == 1:
        return dense(x, w, b)

    def body(xb, wb):
        if fsdp:
            wb = jax.lax.all_gather(wb, fsdp, axis=1, tiled=True)
        part = jnp.dot(xb, wb.astype(xb.dtype),
                       preferred_element_type=jnp.float32)
        return jax.lax.psum(part.astype(xb.dtype), "model")

    xspec = _P(*((dp_axes if dp_axes else None,) +
                 (None,) * (x.ndim - 2) + ("model",)))
    wspec = _P("model", fsdp)
    ospec = _P(*((dp_axes if dp_axes else None,) + (None,) * (x.ndim - 1)))
    fn = shard_map(body, mesh=mesh, in_specs=(xspec, wspec),
                   out_specs=ospec, check_rep=False)
    return fn(x, w)


def dense(x, w, b=None):
    """Linear layer; dispatches to the quantized path for SplitQuantTensor
    leaves (kernels/ops.py). Computation dtype follows x."""
    if isinstance(w, SplitQuantTensor):
        return ops.linear(x, w, b)
    y = jnp.dot(x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def materialize(w, dtype=None):
    """Dense view of a (possibly quantized) parameter, for ops that need the
    raw array (einsum over experts, depthwise conv taps, …)."""
    if isinstance(w, SplitQuantTensor):
        w = w.dequantize()
    return w.astype(dtype) if dtype is not None else w


def embed_lookup(table, ids):
    if isinstance(table, SplitQuantTensor):
        table = table.dequantize()
    return jnp.take(table, ids, axis=0)


# ---------------------------------------------------------------- norms ----
def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(x.dtype)


def apply_norm(x, p, norm_type: str):
    if norm_type == "rms":
        return rms_norm(x, p["norm_scale"])
    return layer_norm(x, p["norm_scale"], p["norm_bias"])


def init_norm(d, norm_type: str, dtype):
    if norm_type == "rms":
        return {"norm_scale": jnp.zeros((d,), dtype)}
    return {"norm_scale": jnp.ones((d,), dtype),
            "norm_bias": jnp.zeros((d,), dtype)}


# ----------------------------------------------------------------- rope ----
def rope_freqs(head_dim: int, theta: float, rotary_dim: int | None = None):
    rd = rotary_dim or head_dim
    inv = 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))
    return inv  # (rd/2,)


def apply_rope(x, positions, theta: float, variant: str = "full"):
    """x: (..., S, H, D). variant 'half' rotates only the first D/2 dims
    (GLM's 2-D RoPE uses half the channels for position)."""
    if variant == "none":
        return x
    D = x.shape[-1]
    rd = D // 2 if variant == "half" else D
    inv = rope_freqs(D, theta, rd)                       # (rd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, rd/2)
    cos = jnp.cos(ang)[..., None, :]                      # (..., S, 1, rd/2)
    sin = jnp.sin(ang)[..., None, :]
    xr = x[..., :rd].astype(jnp.float32)
    x1, x2 = xr[..., : rd // 2], xr[..., rd // 2:]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    out = jnp.concatenate([rot, x[..., rd:].astype(jnp.float32)], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ init ---
def he_init(key, shape, dtype, fan_in=None):
    fan = fan_in if fan_in is not None else shape[0]
    std = (2.0 / fan) ** 0.5
    return (jax.random.normal(key, shape) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


def stack_layer_init(init_fn, key, n_layers: int):
    """vmap an init over layer index → stacked (L, ...) params for scan."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_fn)(keys)
