"""Model zoo. `get_model(cfg)` returns the module implementing the family
protocol: init / forward / loss_fn / prefill / decode_step / init_cache."""
from __future__ import annotations

from . import bert_tiny, griffin, rwkv6, transformer, whisper
from .attention import KVCache
from .griffin import GriffinCache
from .rwkv6 import RWKVState
from .whisper import WhisperCache


def get_model(cfg):
    return {
        "dense": transformer,
        "moe": transformer,
        "vlm": transformer,
        "audio": whisper,
        "ssm": rwkv6,
        "hybrid": griffin,
        "encoder": bert_tiny,
    }[cfg.family]


def init_cache_for(cfg, batch_size: int, max_len: int, dtype=None):
    import jax.numpy as jnp
    dtype = dtype or jnp.bfloat16
    if cfg.family == "ssm":
        return rwkv6.init_state(cfg, batch_size, dtype)
    if cfg.family == "hybrid":
        return griffin.init_cache(cfg, batch_size, dtype)
    if cfg.family == "audio":
        return whisper.init_cache(cfg, batch_size, max_len, dtype)
    return transformer.init_cache(cfg, batch_size, max_len, dtype)


__all__ = ["get_model", "init_cache_for", "transformer", "rwkv6", "griffin",
           "whisper", "bert_tiny", "KVCache", "GriffinCache", "RWKVState",
           "WhisperCache"]
