"""Griffin / RecurrentGemma (arXiv:2402.19427): hybrid of RG-LRU recurrent
blocks and local (windowed, MQA) attention in a 1 attn : 2 recurrent ratio.

Layer pattern: groups of (rec, rec, attn) scanned together; remainder layers
(n_layers mod 3) are trailing recurrent layers. The RG-LRU is a linear
elementwise recurrence, so prefill/training uses `jax.lax.associative_scan`
(parallel scan — O(log T) depth) and decode keeps an O(1) state; the local
attention keeps a ring KV cache of `window` slots. Both properties make the
long_500k cell runnable (DESIGN.md §5).

Gate parameters (Λ, input/recurrence gates) are semantically-not-weights
(paper §4.1) → excluded from quantization via the "rg_lru" path fragment.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .attention import attention_block
from .common import (apply_norm, dense, dtype_of, embed_init, embed_lookup,
                     he_init, init_norm, stack_layer_init)
from .ffn import apply_ffn, init_ffn

LRU_C = 8.0   # Griffin's fixed gate sharpness


class GriffinCache(NamedTuple):
    rec_h: jnp.ndarray       # (Lr, B, lru)      RG-LRU hidden state, fp32
    rec_conv: jnp.ndarray    # (Lr, B, cw-1, lru) temporal-conv tail
    attn_k: jnp.ndarray      # (La, B, W, Hkv, D) ring buffer
    attn_v: jnp.ndarray
    attn_pos: jnp.ndarray    # (La, W) slot→absolute position (-1 empty)


def _lru_width(cfg):
    return cfg.lru_width or cfg.d_model


def _init_rec(key, cfg, dtype):
    d, r = cfg.d_model, _lru_width(cfg)
    ks = jax.random.split(key, 8)
    return {
        "ln": init_norm(d, cfg.norm_type, dtype),
        "w_x": he_init(ks[0], (d, r), dtype),          # recurrent branch in
        "w_gate_branch": he_init(ks[1], (d, r), dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, r)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((r,), dtype),
        "rg_lru_lambda": jnp.full((r,), 2.0, jnp.float32),   # a≈σ(Λ)
        "rg_lru_wa": he_init(ks[3], (r, r), jnp.float32) * 0.1,
        "rg_lru_ba": jnp.zeros((r,), jnp.float32),
        "rg_lru_wx": he_init(ks[4], (r, r), jnp.float32) * 0.1,
        "rg_lru_bx": jnp.zeros((r,), jnp.float32),
        "w_out": he_init(ks[5], (r, d), dtype, fan_in=r),
        "ln_mlp": init_norm(d, cfg.norm_type, dtype),
        "mlp": init_ffn(ks[6], d, cfg.d_ff, cfg.ffn_type, dtype),
    }


def _init_attn(key, cfg, dtype):
    d, Hq, Hkv, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    return {
        "ln": init_norm(d, cfg.norm_type, dtype),
        "attn": {"wq": he_init(ks[0], (d, Hq * D), dtype),
                 "wk": he_init(ks[1], (d, Hkv * D), dtype),
                 "wv": he_init(ks[2], (d, Hkv * D), dtype),
                 "wo": he_init(ks[3], (Hq * D, d), dtype, fan_in=Hq * D)},
        "ln_mlp": init_norm(d, cfg.norm_type, dtype),
        "mlp": init_ffn(ks[4], d, cfg.d_ff, cfg.ffn_type, dtype),
    }


def layout(cfg):
    """(n_groups, n_tail_rec): groups of (rec, rec, attn) + trailing recs."""
    n_groups = cfg.n_layers // 3
    return n_groups, cfg.n_layers - 3 * n_groups


def init(key, cfg):
    dtype = dtype_of(cfg.param_dtype)
    ke, kg, kt, kh = jax.random.split(key, 4)
    n_groups, n_tail = layout(cfg)
    params = {
        "embed": embed_init(ke, (cfg.vocab, cfg.d_model), dtype),
        "groups": stack_layer_init(
            lambda k: {
                "rec1": _init_rec(jax.random.fold_in(k, 0), cfg, dtype),
                "rec2": _init_rec(jax.random.fold_in(k, 1), cfg, dtype),
                "attn": _init_attn(jax.random.fold_in(k, 2), cfg, dtype),
            }, kg, n_groups),
        "final_norm": init_norm(cfg.d_model, cfg.norm_type, dtype),
        "lm_head": he_init(kh, (cfg.d_model, cfg.vocab), dtype),
    }
    if n_tail:
        params["tail"] = stack_layer_init(
            lambda k: _init_rec(k, cfg, dtype), kt, n_tail)
    return params


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise temporal conv, width cw. x: (B,T,r). conv_state: (B,cw-1,r)
    carry-in for decode. Returns (y, new_state)."""
    cw = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                 # (B, T+cw-1, r)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
            for i in range(cw))
    return y + b.astype(x.dtype), xp[:, -(cw - 1):, :]


def _rg_lru(p, x, h0):
    """x: (B,T,r) fp32 path. h_t = a_t·h_{t-1} + √(1-a_t²)·(i_t·x_t).
    Parallel associative scan over T; h0: (B, r) carry."""
    xf = x.astype(jnp.float32)
    rt = jax.nn.sigmoid(xf @ p["rg_lru_wa"] + p["rg_lru_ba"])
    it = jax.nn.sigmoid(xf @ p["rg_lru_wx"] + p["rg_lru_bx"])
    log_a = -LRU_C * jax.nn.softplus(p["rg_lru_lambda"]) * rt   # (B,T,r)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 0.0)) * (it * xf)
    # fold carry-in into the first step
    b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1, :].astype(jnp.float32)


def _rec_block(cfg, p, x, state):
    """Griffin recurrent block + its MLP. state: (h0, conv_state)."""
    h0, conv_state = state
    from .common import shard_hint
    x = shard_hint(x, "dp", None, None)
    h = apply_norm(x, p["ln"], cfg.norm_type)
    u = shard_hint(dense(h, p["w_x"]), "dp", None, "tp")
    from .common import materialize
    u, conv_state = _causal_conv(u, materialize(p["conv_w"]),
                                 materialize(p["conv_b"]), conv_state)
    u, h_last = _rg_lru(p, u, h0)
    g = jax.nn.gelu(dense(h, p["w_gate_branch"]))
    x = x + dense(u * g, p["w_out"])
    m = apply_norm(x, p["ln_mlp"], cfg.norm_type)
    x = x + apply_ffn(p["mlp"], m, cfg.ffn_type)
    return x, (h_last, conv_state)


def _attn_block(cfg, p, x, positions, cache_layer, kv_chunk, want_kv):
    h = apply_norm(x, p["ln"], cfg.norm_type)
    out, kv = attention_block(p["attn"], h, cfg, positions, cache_layer,
                              causal=True, window=cfg.window,
                              kv_chunk=kv_chunk, want_kv=want_kv)
    x = x + out
    m = apply_norm(x, p["ln_mlp"], cfg.norm_type)
    x = x + apply_ffn(p["mlp"], m, cfg.ffn_type)
    return x, kv


def init_cache(cfg, batch_size: int, dtype=jnp.bfloat16) -> GriffinCache:
    n_groups, n_tail = layout(cfg)
    Lr, La = 2 * n_groups + n_tail, n_groups
    r, W = _lru_width(cfg), cfg.window
    return GriffinCache(
        rec_h=jnp.zeros((Lr, batch_size, r), jnp.float32),
        rec_conv=jnp.zeros((Lr, batch_size, cfg.conv_width - 1, r), dtype),
        attn_k=jnp.zeros((La, batch_size, W, cfg.n_kv_heads, cfg.head_dim),
                         dtype),
        attn_v=jnp.zeros((La, batch_size, W, cfg.n_kv_heads, cfg.head_dim),
                         dtype),
        attn_pos=jnp.full((La, W), -1, jnp.int32))


def forward(params, cfg, batch, cache: GriffinCache | None = None,
            positions=None, *, kv_chunk=None, remat=False,
            want_cache=False):
    """Returns (logits, new_cache_or_None).

    S == 1 with a cache ⇒ decode (ring-buffer attention + O(1) rec states).
    Otherwise prefill/train: recurrent states start from the given cache (or
    zeros), attention runs windowed over the sequence, and with
    ``want_cache`` a fresh ring cache is assembled from the tail window.
    """
    from .transformer import assemble_cache  # shared ring assembly

    x = embed_lookup(params["embed"], batch["tokens"])
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    n_groups, n_tail = layout(cfg)
    decode = cache is not None and S == 1
    work = cache if cache is not None else init_cache(cfg, B, x.dtype)

    def group_fn(cfg, gp, x, gstate):
        (h1, c1), (h2, c2), attn_cl = gstate
        x, s1 = _rec_block(cfg, gp["rec1"], x, (h1, c1))
        x, s2 = _rec_block(cfg, gp["rec2"], x, (h2, c2))
        x, kv = _attn_block(cfg, gp["attn"], x, positions, attn_cl,
                            kv_chunk, want_kv=want_cache and not decode)
        return x, (s1, s2, kv)

    fn = group_fn
    if remat:
        fn = jax.checkpoint(group_fn, static_argnums=(0,))

    # group g uses rec-state rows 2g, 2g+1
    h1s, c1s = work.rec_h[0:2 * n_groups:2], work.rec_conv[0:2 * n_groups:2]
    h2s, c2s = work.rec_h[1:2 * n_groups:2], work.rec_conv[1:2 * n_groups:2]

    if decode:
        def step(x, xs):
            gp, h1, c1, h2, c2, ck, cv, sp = xs
            x, ((h1, c1), (h2, c2), (ck, cv, sp)) = fn(
                cfg, gp, x, ((h1, c1), (h2, c2), (ck, cv, sp)))
            return x, (h1, c1, h2, c2, ck, cv, sp)
        x, (h1s, c1s, h2s, c2s, cks, cvs, sps) = jax.lax.scan(
            step, x, (params["groups"], h1s, c1s, h2s, c2s,
                      work.attn_k, work.attn_v, work.attn_pos))
    else:
        def step(x, xs):
            gp, h1, c1, h2, c2 = xs
            x, ((h1, c1), (h2, c2), kv) = fn(
                cfg, gp, x, ((h1, c1), (h2, c2), None))
            return x, (h1, c1, h2, c2, kv)
        x, (h1s, c1s, h2s, c2s, kvs) = jax.lax.scan(
            step, x, (params["groups"], h1s, c1s, h2s, c2s))

    tail_states = []
    for i in range(n_tail):
        li = 2 * n_groups + i
        x, st = _rec_block(cfg, jax.tree_util.tree_map(lambda a: a[i],
                                                       params["tail"]),
                           x, (work.rec_h[li], work.rec_conv[li]))
        tail_states.append(st)

    x = apply_norm(x, params["final_norm"], cfg.norm_type)
    logits = dense(x, params["lm_head"]).astype(jnp.float32)

    if not decode and not want_cache and cache is None:
        return logits, None

    # reassemble recurrent states
    rec_h = work.rec_h.at[0:2 * n_groups:2].set(h1s.astype(jnp.float32)) \
        .at[1:2 * n_groups:2].set(h2s.astype(jnp.float32))
    rec_conv = work.rec_conv.at[0:2 * n_groups:2].set(
        c1s.astype(work.rec_conv.dtype)).at[1:2 * n_groups:2].set(
        c2s.astype(work.rec_conv.dtype))
    for i, (h, c) in enumerate(tail_states):
        li = 2 * n_groups + i
        rec_h = rec_h.at[li].set(h.astype(jnp.float32))
        rec_conv = rec_conv.at[li].set(c.astype(rec_conv.dtype))

    if decode:
        ak, av, ap = cks, cvs, sps
    elif want_cache:
        ring = assemble_cache(cfg, [kvs], positions, max_len=cfg.window)
        ak, av, ap = (ring.k.reshape(work.attn_k.shape),
                      ring.v.reshape(work.attn_v.shape), ring.slot_pos)
    else:
        ak, av, ap = work.attn_k, work.attn_v, work.attn_pos
    return logits, GriffinCache(rec_h, rec_conv, ak, av, ap)


def loss_fn(params, cfg, batch, *, kv_chunk=None, remat=True, **_):
    logits, _ = forward(params, cfg, batch, kv_chunk=kv_chunk, remat=remat)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss, {"loss": loss}


def decode_step(params, cfg, cache: GriffinCache, tokens, pos):
    positions = jnp.reshape(pos, (1,)).astype(jnp.int32)
    return forward(params, cfg, {"tokens": tokens}, cache=cache,
                   positions=positions)


def prefill(params, cfg, batch, max_len=None, *, kv_chunk=None,
            pad_mask=None, moe_blocks=1):
    """Prefill from zero state. The returned cache carries the recurrent
    states and a ring KV cache of the last `window` positions — so
    ``max_len`` is satisfied vacuously (a ring never overflows, prompts
    of any length serve). Kwargs whose silent swallowing would CORRUPT
    results fail loudly: a pad_mask cannot be honored because the RG-LRU
    recurrence folds every input token into its state in order."""
    if pad_mask is not None:
        raise NotImplementedError(
            "griffin prefill cannot honor pad_mask: the RG-LRU states "
            "integrate every token in order, so pad tokens would corrupt "
            "them — feed unpadded (per-request) prompts instead")
    if moe_blocks != 1:
        raise NotImplementedError("griffin has no MoE layers to block "
                                  f"(moe_blocks={moe_blocks})")
    return forward(params, cfg, batch, kv_chunk=kv_chunk, want_cache=True)


def verify_step_slots(*args, **kwargs):
    """Speculative decoding (engine spec_k > 0) needs positional KV
    rollback; the RG-LRU recurrence cannot provide it — fail LOUDLY
    rather than silently serving non-speculative."""
    raise NotImplementedError(
        "griffin cannot serve speculative decoding (spec_k > 0): "
        "rejecting draft tokens requires rolling the cache back to the "
        "accepted position, but the RG-LRU states integrate every token "
        "into a running recurrence with no per-position storage (the "
        "local-attention ring alone cannot restore them). Serve this "
        "family with spec_k=0")
