"""GQA attention with chunked (flash-style) online softmax, local windows,
RoPE, and ring-buffer KV caches for decode.

Memory note: full S×T score materialization at 32k prefill is ~O(S·T·H)
and would dominate the memory roofline, so prefill/training use an online
softmax scanned over KV chunks (O(S·chunk·H) transient) — the same scheme a
TPU flash kernel implements, expressed in jnp so the identical code path
lowers for the CPU dry-run and for TPU.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .common import apply_rope, dense, shard_hint, tp_dense

NEG_INF = -1e30


class KVCache(NamedTuple):
    """Per-layer-stack KV cache. For windowed attention the buffer is a ring
    of size `window`; `slot_pos[t]` records the absolute position stored in
    slot t (-1 = empty)."""
    k: jnp.ndarray          # (L, B, T, Hkv, D)
    v: jnp.ndarray          # (L, B, T, Hkv, D)
    slot_pos: jnp.ndarray   # (L, T) int32 — or (L, B, T) when positions
                            # are per-request (padded prefill)


def _is_slot_cache(cache_layer) -> bool:
    """Duck-typed check for a per-layer `engine.kvcache.SlotKVCache` slice
    (imported lazily in the hot path to keep models ← engine acyclic)."""
    return hasattr(cache_layer, "kv_pos") and hasattr(cache_layer, "mode")


def _mask(q_pos, kv_pos, causal: bool, window: Optional[int]):
    """Boolean validity, always (B|1, S, T). kv_pos may contain -1 (empty
    ring slots / padding). q_pos (S,) or (B, S); kv_pos (T,) or (B, T) —
    the batched forms carry per-request positions (engine slots, pad
    masks)."""
    q = jnp.atleast_2d(q_pos)            # (Bq, S)
    kv = jnp.atleast_2d(kv_pos)          # (Bk, T)
    m = kv[:, None, :] >= 0
    if causal:
        m = m & (kv[:, None, :] <= q[:, :, None])
    if window is not None:
        m = m & (kv[:, None, :] > q[:, :, None] - window)
    return m


def attend(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
           kv_chunk: Optional[int] = None):
    """q: (B, S, Hq, D); k, v: (B, T, Hkv, D). Returns (B, S, Hq, D).

    ``kv_chunk`` switches to the online-softmax scanned form (required for
    long T); None does a single dense pass.

    GQA layout (perf note, EXPERIMENTS.md §Perf iter 1): K/V are broadcast
    to Hq heads *before* the score einsum instead of reshaping Q into
    (Hkv, G) groups. With TP=16 and Hkv=8, neither the Hkv nor the G dim is
    divisible by the mesh axis, so the grouped form forces GSPMD to
    replicate the whole attention computation per device (~5-16× redundant
    FLOPs in the baseline). The broadcast form keeps a single Hq dim that
    shards cleanly; the expanded K/V tile per device is G× *smaller* than a
    fully-replicated K/V.
    """
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv

    def expand(t):
        """(B, c, Hkv, D) → (B, c, Hq, D) head broadcast (per chunk, so the
        expanded tile stays VMEM-sized and shards over the single Hq dim)."""
        if G == 1:
            return t
        Bc, c = t.shape[0], t.shape[1]
        t = jnp.broadcast_to(t[:, :, :, None, :], (Bc, c, Hkv, G, D))
        return t.reshape(Bc, c, Hq, D)

    q = shard_hint(q, "dp", None, "tp", None)
    qs = (q * (D ** -0.5)).astype(q.dtype)

    if kv_chunk is None or T <= kv_chunk:
        k = shard_hint(expand(k), "dp", None, "tp", None)
        v = shard_hint(expand(v), "dp", None, "tp", None)
        s = jnp.einsum("bshd,bthd->bsht", qs, k,
                       preferred_element_type=jnp.float32)
        m = _mask(q_pos, kv_pos, causal, window)           # (B|1, S, T)
        s = jnp.where(m[:, :, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        o = jnp.einsum("bsht,bthd->bshd", p, v,
                       preferred_element_type=jnp.float32)
        return shard_hint(o.astype(q.dtype), "dp", None, "tp", None)

    n_chunks = T // kv_chunk
    assert T % kv_chunk == 0, (T, kv_chunk)
    kc = k.reshape(B, n_chunks, kv_chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    if kv_pos.ndim == 1:
        pc = kv_pos.reshape(n_chunks, kv_chunk)
    else:                                # batched positions (B, T)
        pc = kv_pos.reshape(B, n_chunks, kv_chunk).transpose(1, 0, 2)

    def step(carry, xs):
        m_run, l_run, acc = carry
        k_i, v_i, p_i = xs
        k_i = shard_hint(expand(k_i), "dp", None, "tp", None)
        v_i = shard_hint(expand(v_i), "dp", None, "tp", None)
        s = jnp.einsum("bshd,bthd->bsht", qs, k_i,
                       preferred_element_type=jnp.float32)   # (B,S,Hq,c)
        s = shard_hint(s, "dp", None, "tp", None)
        msk = _mask(q_pos, p_i, causal, window)
        s = jnp.where(msk[:, :, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        corr = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bsht,bthd->bshd", p.astype(q.dtype), v_i,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = shard_hint(jnp.full((B, S, Hq), NEG_INF, jnp.float32),
                    "dp", None, "tp")
    l0 = shard_hint(jnp.zeros((B, S, Hq), jnp.float32), "dp", None, "tp")
    a0 = shard_hint(jnp.zeros((B, S, Hq, D), jnp.float32),
                    "dp", None, "tp", None)
    (m_f, l_f, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    o = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return shard_hint(o.astype(q.dtype), "dp", None, "tp", None)


def tshard_decode_attend(q, k, v, q_pos, kv_pos, *, window=None):
    """Decode attention over a TIME-sharded KV cache (ring-attention-style):
    each model shard attends over its local cache slice; shards merge via a
    log-sum-exp reduction of (m, l, acc) — per layer the cross-shard bytes
    are O(B·Hq·D), not O(cache). Used when kv_heads < TP so head-sharding
    the cache is impossible (EXPERIMENTS.md §Perf cell C iter 3).

    q: (B, 1, Hq, D) — heads REPLICATED over "model" (q is tiny at decode);
    k, v: (B, T, Hkv, D) with T sharded over "model"; kv_pos: (T,).
    """
    from jax._src import mesh as _mesh_lib
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = _mesh_lib.thread_resources.env.physical_mesh
    if mesh.empty or "model" not in mesh.axis_names or kv_pos.ndim > 1:
        # batched (per-request) kv_pos carries no single time shard; the
        # engine path never runs time-sharded, so fall back
        return attend(q, k, v, q_pos, kv_pos, causal=True, window=window)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    import math
    B, _, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    dpn = math.prod(dict(mesh.shape)[a] for a in dp) if dp else 1
    bspec = dp if (dp and B % dpn == 0 and B >= dpn) else None

    def body(qb, kb, vb, pb, qp):
        # qb: (Bl, 1, Hq, D); kb/vb: (Bl, Tl, Hkv, D); pb: (Tl,)
        if G > 1:
            Bl, Tl = kb.shape[0], kb.shape[1]
            kb = jnp.broadcast_to(kb[:, :, :, None, :],
                                  (Bl, Tl, Hkv, G, D)).reshape(Bl, Tl, Hq, D)
            vb = jnp.broadcast_to(vb[:, :, :, None, :],
                                  (Bl, Tl, Hkv, G, D)).reshape(Bl, Tl, Hq, D)
        s = jnp.einsum("bshd,bthd->bsht", (qb * D ** -0.5).astype(qb.dtype),
                       kb, preferred_element_type=jnp.float32)
        msk = _mask(qp, pb, True, window)                  # (1, 1, Tl)
        s = jnp.where(msk[:, :, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1)                            # (Bl,1,Hq)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        acc = jnp.einsum("bsht,bthd->bshd", p.astype(qb.dtype), vb,
                         preferred_element_type=jnp.float32)
        m_g = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, "model")
        acc_g = jax.lax.psum(acc * corr[..., None], "model")
        return (acc_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(qb.dtype)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(bspec, None, None, None),
                             P(bspec, "model", None, None),
                             P(bspec, "model", None, None),
                             P("model"), P(None)),
                   out_specs=P(bspec, None, None, None),
                   check_rep=False)
    return fn(q, k, v, kv_pos, q_pos)


def attention_block(p, x, cfg, positions, cache_layer=None, *,
                    causal=True, window=None, kv_chunk=None,
                    cross_kv=None, want_kv=False, tshard_decode=False,
                    kv_pos_override=None, fused_attn=False,
                    slot_chunk=None, spec_verify=False):
    """Full attention sub-layer: projections + RoPE + (cache) + attend + out.

    p: {"wq","wk","wv","wo"(,biases)}; x: (B, S, d).
    cache_layer: (k, v, slot_pos) for this layer (decode), a per-layer
    `engine.kvcache.SlotKVCache` slice (slot decode with per-request
    positions — `positions` is then (B, 1)), or None.
    cross_kv: precomputed (k, v, kv_pos) for encoder-decoder cross-attention
    (projections wk/wv already applied by the caller).
    want_kv: with no cache, also return this call's post-RoPE (k, v) so the
    caller can assemble a prefill cache.
    kv_pos_override: (B, S) per-request KV validity positions for prefill
    with padding (-1 = pad token; masked out of attention).
    fused_attn: slot-cache decode only — read attention straight off the
    (possibly INT8) cache via the fused Pallas/jnp kernel instead of
    materializing a full-precision copy for `attend`.
    slot_chunk: (slot, pos_start, length) traced scalars — CHUNKED PREFILL
    over a slot cache: x is one slot's prompt chunk (B=1, S=chunk),
    `positions` its absolute positions; the chunk's K/V are quantized
    in-kernel and written straight into the slot's rows (no dense prefill
    cache is assembled). Requires a slot cache, causal, no window.
    spec_verify: with slot_chunk — the chunk is a speculative DRAFT
    WINDOW; it attends its own K/V through the cache's storage round-trip
    so each row scores exactly like a plain decode step (DESIGN.md §9).
    Returns (out, new_cache_layer | (k, v) | None).
    """
    B, S, _ = x.shape
    Hq, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense(x, p["wq"], p.get("bq")).reshape(B, S, Hq, D)
    q = shard_hint(q, "dp", None, "tp", None)
    if cross_kv is None:
        k = dense(x, p["wk"], p.get("bk")).reshape(B, S, Hkv, D)
        v = dense(x, p["wv"], p.get("bv")).reshape(B, S, Hkv, D)
        k = shard_hint(k, "dp", None, "tp", None)
        v = shard_hint(v, "dp", None, "tp", None)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_variant)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_variant)

    new_cache = None
    if cross_kv is not None:
        k, v, kv_pos = cross_kv
    elif _is_slot_cache(cache_layer):
        # engine slot cache: per-request positions (B, 1), quant-aware
        from repro.engine.kvcache import (fused_slot_attention,
                                          slot_chunk_prefill,
                                          slot_layer_update,
                                          slot_layer_write)
        if slot_chunk is not None:
            # chunked prefill of ONE slot: fused attention over prior rows
            # + this chunk, codes scattered into the slot in one pass
            assert causal and window is None and B == 1, (causal, window, B)
            slot, pos_start, length = slot_chunk
            o, new_cache = slot_chunk_prefill(
                cache_layer, q[0], k[0], v[0], slot, pos_start, length,
                kv_chunk=kv_chunk, verify=spec_verify)
            o = o[None]
        elif fused_attn and S == 1 and causal and window is None:
            # fused decode read: write-only cache update, then dequant-in-
            # kernel attention — no full-precision cache copy exists
            new_cache = slot_layer_write(cache_layer, k, v, positions)
            o = fused_slot_attention(new_cache, q[:, 0], positions[:, 0],
                                     kv_chunk=kv_chunk)[:, None]
        else:
            k, v, kv_pos, new_cache = slot_layer_update(
                cache_layer, k, v, positions)
            o = attend(q, k, v, positions, kv_pos, causal=causal,
                       window=window, kv_chunk=kv_chunk)
        out = dense(o.reshape(B, S, Hq * D), p["wo"], p.get("bo"))
        return shard_hint(out, "dp", None, None), new_cache
    elif cache_layer is not None:
        ck, cv, slot_pos = cache_layer
        T = ck.shape[1]
        slot = positions[0] % T                     # ring slot (window) or abs
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
        if slot_pos.ndim == 1:                      # shared positions (T,)
            slot_pos = jax.lax.dynamic_update_slice(
                slot_pos, positions.astype(jnp.int32), (slot,))
        else:                                       # per-request (B, T)
            upd = jnp.broadcast_to(positions.astype(jnp.int32),
                                   (slot_pos.shape[0], 1))
            slot_pos = jax.lax.dynamic_update_slice(slot_pos, upd, (0, slot))
        k, v, kv_pos = ck.astype(x.dtype), cv.astype(x.dtype), slot_pos
        new_cache = (ck, cv, slot_pos)
        if tshard_decode and S == 1:
            o = tshard_decode_attend(q, k, v, positions, kv_pos,
                                     window=window)
            out = dense(o.reshape(B, S, Hq * D), p["wo"], p.get("bo"))
            return shard_hint(out, "dp", None, None), new_cache
    else:
        kv_pos = positions if kv_pos_override is None else kv_pos_override
        if want_kv:
            new_cache = (k, v)

    o = attend(q, k, v, positions, kv_pos, causal=causal, window=window,
               kv_chunk=kv_chunk)
    out = dense(o.reshape(B, S, Hq * D), p["wo"], p.get("bo"))
    return shard_hint(out, "dp", None, None), new_cache
