"""Whisper-tiny (arXiv:2212.04356): encoder-decoder with a conv audio
frontend. Per the assignment the frontend is a STUB — ``input_specs()``
supplies precomputed frame embeddings (B, enc_seq, d), i.e. the output the
two conv layers would produce. Everything downstream (sinusoidal/learned
positions, bidirectional encoder, causal decoder with cross-attention,
LayerNorm + biased linears) is real and quantizable.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .attention import attend, attention_block
from .common import (apply_norm, dense, dtype_of, embed_init, embed_lookup,
                     he_init, init_norm, stack_layer_init)
from .ffn import apply_ffn, init_ffn


class WhisperCache(NamedTuple):
    self_k: jnp.ndarray     # (Ld, B, T, H, D)
    self_v: jnp.ndarray
    slot_pos: jnp.ndarray   # (Ld, T)
    cross_k: jnp.ndarray    # (Ld, B, enc_seq, H, D) — fixed after prefill
    cross_v: jnp.ndarray


def _init_attn(key, cfg, dtype):
    d, Hq, Hkv, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    z = lambda *s: jnp.zeros(s, dtype)
    return {"wq": he_init(ks[0], (d, Hq * D), dtype), "bq": z(Hq * D),
            "wk": he_init(ks[1], (d, Hkv * D), dtype), "bk": z(Hkv * D),
            "wv": he_init(ks[2], (d, Hkv * D), dtype), "bv": z(Hkv * D),
            "wo": he_init(ks[3], (Hq * D, d), dtype, fan_in=Hq * D),
            "bo": z(d)}


def _init_enc_layer(key, cfg, dtype):
    ka, kf = jax.random.split(key)
    return {"ln1": init_norm(cfg.d_model, "layer", dtype),
            "attn": _init_attn(ka, cfg, dtype),
            "ln2": init_norm(cfg.d_model, "layer", dtype),
            "ffn": init_ffn(kf, cfg.d_model, cfg.d_ff, "gelu", dtype,
                            bias=True)}


def _init_dec_layer(key, cfg, dtype):
    ka, kx, kf = jax.random.split(key, 3)
    return {"ln1": init_norm(cfg.d_model, "layer", dtype),
            "attn": _init_attn(ka, cfg, dtype),
            "ln_cross": init_norm(cfg.d_model, "layer", dtype),
            "cross": _init_attn(kx, cfg, dtype),
            "ln2": init_norm(cfg.d_model, "layer", dtype),
            "ffn": init_ffn(kf, cfg.d_model, cfg.d_ff, "gelu", dtype,
                            bias=True)}


def init(key, cfg):
    dtype = dtype_of(cfg.param_dtype)
    ke, kp, kq, kenc, kdec = jax.random.split(key, 5)
    return {
        "embed": embed_init(ke, (cfg.vocab, cfg.d_model), dtype),
        "enc_pos": embed_init(kp, (cfg.enc_seq, cfg.d_model), dtype),
        "dec_pos": embed_init(kq, (4096, cfg.d_model), dtype),
        "enc_layers": stack_layer_init(
            lambda k: _init_enc_layer(k, cfg, dtype), kenc, cfg.n_enc_layers),
        "dec_layers": stack_layer_init(
            lambda k: _init_dec_layer(k, cfg, dtype), kdec, cfg.n_layers),
        "enc_final": init_norm(cfg.d_model, "layer", dtype),
        "final_norm": init_norm(cfg.d_model, "layer", dtype),
    }


def encode(params, cfg, frames):
    """frames: (B, enc_seq, d) stub conv output → encoder states."""
    x = frames.astype(params["enc_pos"].dtype) + params["enc_pos"][None]
    positions = jnp.arange(cfg.enc_seq, dtype=jnp.int32)

    def step(x, lp):
        h = apply_norm(x, lp["ln1"], "layer")
        out, _ = attention_block(lp["attn"], h, cfg, positions, causal=False)
        x = x + out
        h = apply_norm(x, lp["ln2"], "layer")
        return x + apply_ffn(lp["ffn"], h, "gelu"), None

    x, _ = jax.lax.scan(step, x, params["enc_layers"])
    return apply_norm(x, params["enc_final"], "layer")


def _cross_kv(lp, enc_out, cfg):
    B, T, _ = enc_out.shape
    k = dense(enc_out, lp["cross"]["wk"], lp["cross"]["bk"]).reshape(
        B, T, cfg.n_kv_heads, cfg.head_dim)
    v = dense(enc_out, lp["cross"]["wv"], lp["cross"]["bv"]).reshape(
        B, T, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def _dec_layer(cfg, lp, x, positions, self_cache, cross_k, cross_v,
               want_kv=False, kv_chunk=None):
    enc_pos = jnp.arange(cross_k.shape[1], dtype=jnp.int32)
    h = apply_norm(x, lp["ln1"], "layer")
    out, kv = attention_block(lp["attn"], h, cfg, positions, self_cache,
                              causal=True, want_kv=want_kv,
                              kv_chunk=kv_chunk)
    x = x + out
    h = apply_norm(x, lp["ln_cross"], "layer")
    out, _ = attention_block(lp["cross"], h, cfg, positions,
                             causal=False,
                             cross_kv=(cross_k, cross_v, enc_pos))
    x = x + out
    h = apply_norm(x, lp["ln2"], "layer")
    return x + apply_ffn(lp["ffn"], h, "gelu"), kv


def forward(params, cfg, batch, cache: WhisperCache | None = None,
            positions=None, *, want_cache=False, remat=False,
            kv_chunk=None, **_):
    """Train/prefill: batch = {frames, tokens}. Decode: batch = {tokens} +
    cache (cross K/V precomputed at prefill)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    decode = cache is not None and S == 1
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    x = embed_lookup(params["embed"], tokens) + \
        jnp.take(params["dec_pos"], positions, axis=0)[None]

    if decode:
        cross_ks, cross_vs = cache.cross_k, cache.cross_v
    else:
        enc_out = encode(params, cfg, batch["frames"])
        cross_ks, cross_vs = jax.vmap(
            lambda lp: _cross_kv(lp, enc_out, cfg))(params["dec_layers"])

    import functools
    fn = functools.partial(_dec_layer, want_kv=want_cache and not decode,
                           kv_chunk=kv_chunk)
    if remat:
        fn = jax.checkpoint(fn, static_argnums=(0,))

    if decode:
        def step(x, xs):
            lp, ck, cv, sp, xk, xv = xs
            x, (ck, cv, sp) = fn(cfg, lp, x, positions, (ck, cv, sp), xk, xv)
            return x, (ck, cv, sp)
        x, (sk, sv, sp) = jax.lax.scan(
            step, x, (params["dec_layers"], cache.self_k, cache.self_v,
                      cache.slot_pos, cross_ks, cross_vs))
        new_cache = WhisperCache(sk, sv, sp, cache.cross_k, cache.cross_v)
    else:
        def step(x, xs):
            lp, xk, xv = xs
            x, kv = fn(cfg, lp, x, positions, None, xk, xv)
            return x, kv
        x, kvs = jax.lax.scan(step, x, (params["dec_layers"], cross_ks,
                                        cross_vs))
        new_cache = None
        if want_cache:
            from .transformer import assemble_cache
            ring = assemble_cache(cfg, [kvs], positions)
            new_cache = WhisperCache(ring.k, ring.v, ring.slot_pos,
                                     cross_ks, cross_vs)

    x = apply_norm(x, params["final_norm"], "layer")
    table = params["embed"]
    if hasattr(table, "dequantize"):
        table = table.dequantize()
    logits = jnp.dot(x, table.T.astype(x.dtype)).astype(jnp.float32)
    return logits, new_cache


def init_cache(cfg, batch_size: int, max_len: int, dtype=jnp.bfloat16):
    Ld = cfg.n_layers
    shp = (Ld, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim)
    xshp = (Ld, batch_size, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim)
    return WhisperCache(jnp.zeros(shp, dtype), jnp.zeros(shp, dtype),
                        jnp.full((Ld, max_len), -1, jnp.int32),
                        jnp.zeros(xshp, dtype), jnp.zeros(xshp, dtype))


def loss_fn(params, cfg, batch, *, remat=True, kv_chunk=None, **_):
    logits, _ = forward(params, cfg, batch, remat=remat, kv_chunk=kv_chunk)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss, {"loss": loss}


def decode_step(params, cfg, cache: WhisperCache, tokens, pos):
    positions = jnp.reshape(pos, (1,)).astype(jnp.int32)
    return forward(params, cfg, {"tokens": tokens}, cache=cache,
                   positions=positions)


def prefill(params, cfg, batch, max_len=None, *, kv_chunk=None,
            pad_mask=None, moe_blocks=1):
    """Prefill the decoder self-cache (+ encoder cross K/V). Kwargs this
    family cannot honor fail LOUDLY instead of being swallowed: silently
    ignoring a caller's pad_mask would leave left-pad K/V attendable."""
    if pad_mask is not None:
        raise NotImplementedError(
            "whisper prefill cannot honor pad_mask: WhisperCache keeps no "
            "per-request KV validity, so left-padded batches would attend "
            "to pad K/V — serve whisper with unpadded (per-request) "
            "prompts instead")
    if moe_blocks != 1:
        raise NotImplementedError("whisper has no MoE layers to block "
                                  f"(moe_blocks={moe_blocks})")
    logits, cache = forward(params, cfg, batch, want_cache=True,
                            kv_chunk=kv_chunk)
    if max_len and max_len > batch["tokens"].shape[1]:
        pad = max_len - batch["tokens"].shape[1]
        cache = WhisperCache(
            jnp.pad(cache.self_k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(cache.self_v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(cache.slot_pos, ((0, 0), (0, pad)), constant_values=-1),
            cache.cross_k, cache.cross_v)
    return logits, cache


def verify_step_slots(*args, **kwargs):
    """Speculative decoding (engine spec_k > 0) runs over the engine's
    slot cache, which this family does not have — fail LOUDLY rather
    than silently serving non-speculative."""
    raise NotImplementedError(
        "whisper cannot serve speculative decoding (spec_k > 0): the "
        "engine's draft/verify/rollback contract needs a slot-indexed "
        "cache with per-position validity, but WhisperCache is a "
        "wave-loop cache with no slot layout (and no rollback of the "
        "encoder cross-attention state). Serve this family with "
        "spec_k=0")
