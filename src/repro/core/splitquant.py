"""SplitQuant (paper §4): split each quantizable tensor into k=3
mathematically-equivalent parts with separate quantization parameters.

TPU-native representation (see DESIGN.md §2): instead of materializing the
three mostly-zero split layers, we store

  * ``q``     — low-bit codes, one per weight element (int8 storage; the
                logical width is ``bits``; the Pallas path packs them),
  * ``cid``   — the k-means cluster id per element (2 bits logically),
  * ``scale``/``zero`` — per-cluster (optionally × per-output-channel)
                quantization parameters.

Dequantization selects scale[cid] per element, so

    Ŵ = Σ_c  mask_c · dequant(q; scale_c, zero_c)

is *exactly* the paper's sum of three split layers, fused into one dense
tensor. ``split_layers`` materializes the literal paper form for the
equivalence tests.

Stacked quantization (``stack_dims``): scan-over-layers models carry
parameters with leading (L,) or (L, E) axes. Each trailing matrix is
quantized independently (vmap), giving leaves ``q/cid: (L, ..., *mat)`` and
``scale/zero: (L, ..., k[, out])`` whose *leading axes slice consistently
under jax.lax.scan* — the meta ``orig_shape`` stays the per-matrix shape.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kmeans import kmeans_1d
from .quantize import QuantConfig, dequantize, qparams, quantize


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("q", "cid", "scale", "zero"),
                   meta_fields=("bits", "k", "orig_shape", "orig_dtype"))
@dataclasses.dataclass
class SplitQuantTensor:
    """A tensor quantized with per-cluster scales (k=1 ⇒ plain baseline PTQ).

    orig_shape is the PER-MATRIX shape; leading stack axes (q.ndim -
    len(orig_shape) of them) are batch dims shared by q/cid/scale/zero.
    """

    q: jnp.ndarray        # int8 codes, (*stack, *orig_shape)
    cid: jnp.ndarray      # uint8 cluster ids, same shape as q
    scale: jnp.ndarray    # (*stack, k) or (*stack, k, out) fp32
    zero: jnp.ndarray     # like scale
    bits: int
    k: int
    orig_shape: tuple
    orig_dtype: object

    @property
    def shape(self):
        return self.q.shape

    @property
    def ndim(self):
        return self.q.ndim

    @property
    def stack_dims(self) -> int:
        return self.q.ndim - len(self.orig_shape)

    @property
    def per_channel(self) -> bool:
        return self.scale.ndim - self.stack_dims == 2

    def _select(self, vals: jnp.ndarray) -> jnp.ndarray:
        """vals: (*stack, k[, out]) → per-element (*stack, *orig_shape)."""
        b = self.stack_dims
        m = len(self.orig_shape)
        stack = vals.shape[:b]
        if self.per_channel:
            v = jnp.moveaxis(vals, -2, -1)                 # (*stack, out, k)
            v = v.reshape(stack + (1,) * (m - 1) + v.shape[-2:])
        else:
            v = vals.reshape(stack + (1,) * m + (self.k,))
        idx = self.cid[..., None].astype(jnp.int32)
        return jnp.take_along_axis(v, idx, axis=-1)[..., 0]

    def dequantize(self) -> jnp.ndarray:
        s = self._select(self.scale)
        z = self._select(self.zero)
        return dequantize(self.q, s, z, self.orig_dtype)

    def split_layers(self) -> list[jnp.ndarray]:
        """The paper's literal k split tensors: Ŵ_c = Ŵ ⊙ [cid == c]."""
        w_hat = self.dequantize()
        return [jnp.where(self.cid == c, w_hat, 0).astype(self.orig_dtype)
                for c in range(self.k)]

    def nbytes_deployed(self) -> int:
        """Deployed footprint: packed codes + 2-bit cids + scales."""
        n = self.q.size
        code_bits = self.bits * n
        cid_bits = (2 * n) if self.k > 1 else 0
        return (code_bits + cid_bits) // 8 + self.scale.nbytes + self.zero.nbytes


def _masked_range(x: jnp.ndarray, mask: jnp.ndarray, axis=None):
    """min/max of x over elements where mask, else a degenerate [0,0] range."""
    big = jnp.asarray(jnp.finfo(jnp.float32).max, jnp.float32)
    lo = jnp.min(jnp.where(mask, x, big), axis=axis)
    hi = jnp.max(jnp.where(mask, x, -big), axis=axis)
    empty = ~jnp.any(mask, axis=axis)
    beta = jnp.where(empty, 0.0, lo)
    alpha = jnp.where(empty, 0.0, hi)
    return beta, alpha


def _splitquant_single(key, w, cfg: QuantConfig, k: int, sample_size: int,
                       kmeans_iters: int):
    """Quantize ONE matrix/vector. Returns (q, cid, scale, zero)."""
    wf = w.astype(jnp.float32)
    flat = wf.reshape(-1)

    if k == 1:
        if cfg.percentile is not None:
            # percentile clipping: range from the clipped distribution
            if cfg.per_channel and w.ndim >= 2:
                red = tuple(range(w.ndim - 1))
                beta = jnp.percentile(wf, (1 - cfg.percentile) * 100, axis=red)
                alpha = jnp.percentile(wf, cfg.percentile * 100, axis=red)
                beta, alpha = beta[None], alpha[None]          # (1, out)
            else:
                beta = jnp.percentile(wf, (1 - cfg.percentile) * 100).reshape(1)
                alpha = jnp.percentile(wf, cfg.percentile * 100).reshape(1)
            scale, zero = qparams(beta, alpha, cfg)
            cid = jnp.zeros(w.shape, jnp.uint8)
            q = quantize(wf, scale[0], zero[0], cfg)
            return q, cid, scale, zero
        cid = jnp.zeros(w.shape, jnp.uint8)
    else:
        n = flat.shape[0]
        if n > sample_size:
            stride = n // sample_size
            sample = flat[::stride][:sample_size]
        else:
            sample = flat
        centroids, _, _ = kmeans_1d(key, sample, k=k, iters=kmeans_iters)
        cid = jnp.argmin((wf[..., None] - centroids) ** 2,
                         axis=-1).astype(jnp.uint8)

    if cfg.per_channel and w.ndim >= 2:
        red = tuple(range(w.ndim - 1))
        beta, alpha = jax.vmap(
            lambda c: _masked_range(wf, cid == c, axis=red))(jnp.arange(k))
    else:
        beta, alpha = jax.vmap(
            lambda c: _masked_range(flat, cid.reshape(-1) == c))(jnp.arange(k))
    scale, zero = qparams(beta, alpha, cfg)                 # (k,) or (k, out)

    if scale.ndim == 1:
        s_el, z_el = scale[cid], zero[cid]
    else:
        out_idx = jnp.arange(w.shape[-1])
        s_el = scale[cid, out_idx]
        z_el = zero[cid, out_idx]
    q = quantize(wf, s_el, z_el, cfg)
    return q, cid, scale, zero


def splitquant_tensor(key: jax.Array, w: jnp.ndarray, cfg: QuantConfig,
                      k: int = 3, sample_size: int = 1 << 18,
                      kmeans_iters: int = 25,
                      stack_dims: int = 0) -> SplitQuantTensor:
    """Cluster ``w``'s values into k groups and quantize each with its own
    scale (paper §4.1). ``k=1`` degenerates to baseline per-tensor PTQ.

    ``stack_dims``: number of leading axes to quantize independently (vmap)
    — one matrix per layer / per expert, see class docstring.

    Large matrices: centroids are fit on ≤``sample_size`` strided samples,
    then every element is assigned to its nearest centroid — assignment (not
    the centroid fit) is what the mathematical equivalence relies on.
    """
    fn = functools.partial(_splitquant_single, cfg=cfg, k=k,
                           sample_size=sample_size, kmeans_iters=kmeans_iters)
    for _ in range(stack_dims):
        fn = jax.vmap(fn)
    lead = w.shape[:stack_dims]
    keys = jax.random.split(key, lead) if stack_dims else key
    q, cid, scale, zero = fn(keys, w)
    return SplitQuantTensor(q=q, cid=cid, scale=scale, zero=zero,
                            bits=cfg.bits, k=k,
                            orig_shape=tuple(w.shape[stack_dims:]),
                            orig_dtype=w.dtype)


def baseline_quant_tensor(w: jnp.ndarray, cfg: QuantConfig,
                          stack_dims: int = 0) -> SplitQuantTensor:
    """Plain PTQ (one scale set; percentile clip if cfg.percentile) as k=1."""
    return splitquant_tensor(jax.random.PRNGKey(0), w, cfg, k=1,
                             stack_dims=stack_dims)


def activation_chunk_bounds(n: int, n_chunks: int) -> list[int]:
    """§4.2 chunk boundaries along an axis of width ``n``: the
    ``jnp.array_split`` partition (first ``n % n_chunks`` chunks one element
    wider), so indivisible widths still split into ``n_chunks`` parts."""
    n_chunks = max(1, min(n_chunks, n))
    base, rem = divmod(n, n_chunks)
    bounds = [0]
    for c in range(n_chunks):
        bounds.append(bounds[-1] + base + (1 if c < rem else 0))
    return bounds


def split_activation_fake_quant(x: jnp.ndarray, cfg: QuantConfig,
                                n_chunks: int = 3, axis: int = -1) -> jnp.ndarray:
    """Paper §4.2: split an activation vector into ``n_chunks`` chunks,
    quantize each with its own dynamic range, concatenate. Indivisible
    widths use uneven chunks (``jnp.array_split`` semantics) so the split
    never silently degrades to a single range.

    This is simulated (fake) quantization — ranges are computed at runtime,
    exactly as an int inference engine would calibrate dynamic activations.
    """
    axis = axis % x.ndim
    parts = jnp.array_split(x, max(1, min(n_chunks, x.shape[axis])),
                            axis=axis)
    outs = []
    for p in parts:
        beta = jnp.min(p)
        alpha = jnp.max(p)
        scale, zero = qparams(beta, alpha, cfg)
        outs.append(dequantize(quantize(p, scale, zero, cfg), scale, zero,
                               x.dtype))
    return jnp.concatenate(outs, axis=axis)


def effective_scales(sqt: SplitQuantTensor) -> jnp.ndarray:
    """Per-cluster scale factors — the paper's resolution metric (§4: larger
    S ⇒ finer resolution). Useful for the range-narrowing benchmark."""
    return sqt.scale
