"""1-D k-means with greedy k-means++ initialization (paper §4.1).

SplitQuant clusters the scalar values of a weight/bias tensor into k=3
(lower / middle / upper) clusters. Values are 1-D here by construction
(we cluster the flattened tensor), which keeps everything exact and cheap:
distance is (x - c)^2 and Lloyd iterations are segment means.

Greedy k-means++ (Grunau et al., SODA 2023 — the paper's [6]): each new
center is chosen from ℓ candidate samples drawn ∝ D²(x), keeping the
candidate that minimizes the total cost. With a fixed PRNG key the whole
procedure is deterministic and jit-compatible (static k, ℓ, iters).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class KMeansResult(NamedTuple):
    centroids: jnp.ndarray    # (k,) sorted ascending
    assignments: jnp.ndarray  # (n,) int32 in [0, k)
    cost: jnp.ndarray         # scalar: sum of squared distances


def _dist2(x: jnp.ndarray, centers: jnp.ndarray) -> jnp.ndarray:
    """(n, m) squared distances between 1-D points and m centers."""
    return (x[:, None] - centers[None, :]) ** 2


def _greedy_kmeanspp_init(key: jax.Array, x: jnp.ndarray, k: int,
                          num_candidates: int) -> jnp.ndarray:
    """Greedy k-means++ seeding over 1-D points ``x`` (n,)."""
    n = x.shape[0]
    k0, key = jax.random.split(key)
    first = x[jax.random.randint(k0, (), 0, n)]
    centers = jnp.full((k,), first, dtype=x.dtype)
    # squared distance to the nearest chosen center so far
    d2 = (x - first) ** 2

    def pick_one(carry, key_i):
        centers, d2, i = carry
        # sample ℓ candidates ∝ D²; guard the all-zero case (all points equal)
        total = jnp.sum(d2)
        logits = jnp.where(total > 0, jnp.log(jnp.maximum(d2, 1e-30)), jnp.zeros_like(d2))
        idx = jax.random.categorical(key_i, logits, shape=(num_candidates,))
        cand = x[idx]                                        # (ℓ,)
        # cost if candidate j were added = Σ min(d2, (x-cand_j)²)
        cand_d2 = _dist2(x, cand)                            # (n, ℓ)
        new_cost = jnp.sum(jnp.minimum(d2[:, None], cand_d2), axis=0)  # (ℓ,)
        best = jnp.argmin(new_cost)
        chosen = cand[best]
        centers = centers.at[i].set(chosen)
        d2 = jnp.minimum(d2, (x - chosen) ** 2)
        return (centers, d2, i + 1), None

    keys = jax.random.split(key, k - 1)
    (centers, _, _), _ = jax.lax.scan(pick_one, (centers, d2, 1), keys)
    return centers


@functools.partial(jax.jit, static_argnames=("k", "iters", "num_candidates"))
def kmeans_1d(key: jax.Array, x: jnp.ndarray, k: int = 3, iters: int = 25,
              num_candidates: int = 4) -> KMeansResult:
    """Lloyd's algorithm on 1-D data with greedy k-means++ init.

    Returns centroids sorted ascending (lower/middle/upper for k=3) and the
    matching assignments. Empty clusters keep their previous centroid.
    """
    x = x.reshape(-1).astype(jnp.float32)
    centers = _greedy_kmeanspp_init(key, x, k, num_candidates)

    def lloyd(centers, _):
        assign = jnp.argmin(_dist2(x, centers), axis=1)
        one_hot = jax.nn.one_hot(assign, k, dtype=jnp.float32)   # (n, k)
        counts = one_hot.sum(axis=0)
        sums = one_hot.T @ x
        new_centers = jnp.where(counts > 0, sums / jnp.maximum(counts, 1), centers)
        return new_centers, None

    centers, _ = jax.lax.scan(lloyd, centers, None, length=iters)
    order = jnp.argsort(centers)
    centers = centers[order]
    assign = jnp.argmin(_dist2(x, centers), axis=1).astype(jnp.int32)
    cost = jnp.sum(jnp.min(_dist2(x, centers), axis=1))
    return KMeansResult(centers, assign, cost)
