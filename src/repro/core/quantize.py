"""Uniform affine quantization (paper §3).

Q(x)  = INT(S·x) + Z                      (eq. 1)
S     = (2^b - 1) / (α - β)               (eq. 2)
Z     = -2^(b-1) - INT(S·β)               (eq. 3)
x̂     = (Q(x) - Z) / S                    (eq. 4-6)

``b`` is the bit-width; codes live in [-2^(b-1), 2^(b-1) - 1].
Symmetric quantization is the special case α = -β ⇒ Z = 0.

All functions are pure jnp and jit/vmap-safe. Ranges may carry leading
"group" axes (per-channel / per-cluster quantization): ``beta``/``alpha``
broadcast against ``x``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static configuration of a uniform quantizer."""

    bits: int = 8
    symmetric: bool = False
    #: keep values within this percentile when computing the range
    #: (paper §1: "often 99% is used in practice"). None = min/max (no clip).
    percentile: Optional[float] = None
    #: quantize per output channel (axis 0 groups) instead of per tensor.
    #: Beyond-paper option; the paper uses per-tensor scales per split layer.
    per_channel: bool = False

    def __post_init__(self):
        if not (2 <= self.bits <= 8):
            raise ValueError(f"bits must be in [2, 8], got {self.bits}")
        if self.percentile is not None and not (0.5 < self.percentile <= 1.0):
            raise ValueError(f"percentile must be in (0.5, 1], got {self.percentile}")

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1))

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1

    @property
    def levels(self) -> int:
        return 2**self.bits


def value_range(x: jnp.ndarray, percentile: Optional[float] = None,
                axis=None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(β, α) of ``x``; optionally the symmetric percentile range."""
    x = x.astype(jnp.float32)
    if percentile is None:
        beta = jnp.min(x, axis=axis)
        alpha = jnp.max(x, axis=axis)
    else:
        lo = (1.0 - percentile) * 100.0
        hi = percentile * 100.0
        beta = jnp.percentile(x, lo, axis=axis)
        alpha = jnp.percentile(x, hi, axis=axis)
    return beta, alpha


def qparams(beta: jnp.ndarray, alpha: jnp.ndarray, cfg: QuantConfig
            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Scale S and zero-point Z per eqs. (2)-(3).

    Degenerate ranges (α == β, e.g. an all-zero or single-valued cluster)
    get S = 1 so quantize/dequantize stay finite.
    """
    beta = jnp.asarray(beta, jnp.float32)
    alpha = jnp.asarray(alpha, jnp.float32)
    if cfg.symmetric:
        amax = jnp.maximum(jnp.abs(beta), jnp.abs(alpha))
        beta, alpha = -amax, amax
    span = alpha - beta
    # Degenerate range (all-equal cluster): pick S = 1/|v| so the single
    # value v maps to code ±1 and dequantizes EXACTLY (rint(S·v)/S = v).
    amax = jnp.maximum(jnp.abs(beta), jnp.abs(alpha))
    degenerate_scale = jnp.where(amax > 0, 1.0 / jnp.where(amax > 0, amax, 1.0), 1.0)
    scale = jnp.where(span > 0,
                      (cfg.levels - 1) / jnp.where(span > 0, span, 1.0),
                      degenerate_scale)
    if cfg.symmetric:
        zero = jnp.zeros_like(scale)
    else:
        zero = -(2 ** (cfg.bits - 1)) - jnp.rint(scale * beta)
    return scale, zero


def quantize(x: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray,
             cfg: QuantConfig) -> jnp.ndarray:
    """x → int8 codes in [qmin, qmax] (eq. 1, clipped to the code range)."""
    q = jnp.rint(scale * x.astype(jnp.float32)) + zero
    return jnp.clip(q, cfg.qmin, cfg.qmax).astype(jnp.int8)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray,
               dtype=jnp.float32) -> jnp.ndarray:
    """Codes → x̂ per eq. (4)."""
    return ((q.astype(jnp.float32) - zero) / scale).astype(dtype)


def fake_quant(x: jnp.ndarray, cfg: QuantConfig, axis=None) -> jnp.ndarray:
    """Simulated quantization: dequantize(quantize(x)) with ranges from x.

    ``axis``: reduction axes for the range (None = per-tensor). For
    per-channel weights pass ``axis=tuple(range(1, x.ndim))`` and keep dims.
    """
    if axis is None and cfg.per_channel and x.ndim >= 2:
        axis = tuple(range(1, x.ndim))
    if axis is not None:
        beta, alpha = value_range(x, cfg.percentile, axis=axis)
        beta = jnp.expand_dims(beta, axis)
        alpha = jnp.expand_dims(alpha, axis)
    else:
        beta, alpha = value_range(x, cfg.percentile)
    scale, zero = qparams(beta, alpha, cfg)
    return dequantize(quantize(x, scale, zero, cfg), scale, zero, x.dtype)


def quant_error(x: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """Mean squared quantization error of the per-tensor quantizer on x."""
    return jnp.mean((x - fake_quant(x, cfg)) ** 2)
