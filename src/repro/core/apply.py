"""Model-level SplitQuant application: walk a parameter pytree, replace
quantizable weight leaves with :class:`SplitQuantTensor`s.

Paper §4.1 rules honored:
  * normalization γ/β are "semantically not weights" → never quantized;
  * gate/decay parameters of recurrent layers (RWKV decay, RG-LRU gates)
    are treated the same way;
  * biases are clustered+quantized like weights (1-D);
  * batch-norm folding is a no-op for the archs here (none use BN), but the
    hook exists for conv frontends.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .quantize import QuantConfig
from .splitquant import SplitQuantTensor, baseline_quant_tensor, splitquant_tensor

#: parameter-path fragments that are never quantized (semantically not weights)
DEFAULT_EXCLUDE = (
    "norm", "ln_", "layernorm", "rmsnorm", "scale_param",
    "decay", "gate_a", "rg_lru", "time_", "alibi", "rope",
    # MoE routers stay fp32: top-k selection flips discretely under
    # quantization noise, destroying accuracy for ~0 memory savings
    "router",
)


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """What to quantize and how."""

    cfg: QuantConfig = QuantConfig(bits=8)
    method: str = "splitquant"          # "splitquant" | "baseline" | "percentile"
    k: int = 3                          # number of split layers (paper: 3)
    quantize_biases: bool = True        # paper quantizes biases too
    quantize_embeddings: bool = False
    min_size: int = 64                  # leave tiny params alone
    exclude: tuple = DEFAULT_EXCLUDE
    act_chunks: int = 3                 # §4.2 activation split (0/1 disables)
    sample_size: int = 1 << 18

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts).lower()


#: path fragments marking stacked (scan-over-layers) parameter groups
STACK_FRAGMENTS = ("layers", "moe_layers", "groups", "tail",
                   "enc_layers", "dec_layers")


def infer_stack_dims(path_s: str, leaf) -> int:
    """Leading axes quantized independently: 1 under a layer stack, 2 for
    per-expert MoE weights (L, E, d, f) — DESIGN.md §5 (per-expert
    clustering)."""
    in_stack = any(f"/{f}/" in f"/{path_s}/" or path_s.startswith(f + "/")
                   for f in STACK_FRAGMENTS)
    if not in_stack:
        return 0
    if leaf.ndim >= 4:
        return 2
    return 1


def _quantizable(path_s: str, leaf, policy: QuantPolicy) -> bool:
    if not isinstance(leaf, jnp.ndarray) or not jnp.issubdtype(leaf.dtype, jnp.floating):
        return False
    if leaf.size < policy.min_size:
        return False
    if any(frag in path_s for frag in policy.exclude):
        return False
    is_table = any(f in path_s for f in ("embed", "pos_table", "enc_pos",
                                         "dec_pos"))
    if is_table and not policy.quantize_embeddings:
        return False
    if leaf.ndim == 0:
        return False
    sd = infer_stack_dims(path_s, leaf)
    if leaf.ndim - sd < 1:
        return False
    if leaf.ndim - sd == 1 and not policy.quantize_biases:
        return False
    return True


#: fallback clip when method="percentile" is asked for without an explicit
#: percentile (paper §1: "often 99% is used in practice")
DEFAULT_PERCENTILE = 0.99

#: per-path override keys a recipe may carry (see repro.calib.recipe)
OVERRIDE_KEYS = ("bits", "k", "method", "percentile")


def resolve_policy(policy: QuantPolicy, override: Optional[dict] = None
                   ) -> QuantPolicy:
    """Effective policy for one leaf: apply a per-path override (bits / k /
    method / percentile) and normalize method-dependent percentile handling
    in ONE place:

    * ``baseline``    — never clips (percentile forced to None);
    * ``percentile``  — always clips (an unset/None percentile falls back
                        to :data:`DEFAULT_PERCENTILE`);
    * ``splitquant``  — uses cfg.percentile as given (normally None).
    """
    if override:
        unknown = set(override) - set(OVERRIDE_KEYS)
        if unknown:
            raise ValueError(f"unknown override keys {sorted(unknown)}")
        cfg_kw = {kk: override[kk] for kk in ("bits", "percentile")
                  if kk in override}
        pol_kw = {kk: override[kk] for kk in ("method", "k")
                  if kk in override}
        policy = policy.replace(
            cfg=dataclasses.replace(policy.cfg, **cfg_kw), **pol_kw)
    if policy.method == "baseline":
        policy = policy.replace(
            cfg=dataclasses.replace(policy.cfg, percentile=None))
    elif policy.method == "percentile":
        pct = (policy.cfg.percentile if policy.cfg.percentile is not None
               else DEFAULT_PERCENTILE)
        policy = policy.replace(
            cfg=dataclasses.replace(policy.cfg, percentile=pct))
    return policy


def quantize_tree(key: jax.Array, params, policy: QuantPolicy,
                  is_quantizable: Optional[Callable] = None,
                  overrides: Optional[dict] = None):
    """Return a copy of ``params`` with quantizable leaves replaced by
    SplitQuantTensors (method-dependent), plus a report dict.

    * ``splitquant``  — k-means split, per-cluster scales (the paper).
    * ``baseline``    — one scale set from full min/max range.
    * ``percentile``  — one scale set from the clipped range (de-facto
                        outlier treatment the paper argues against).
    * ``none``        — leave the leaf in floating point (only meaningful
                        as a per-path override).

    ``overrides``: optional ``{path: {bits|k|method|percentile: ...}}`` map
    (exact lowercase "a/b/c" paths as reported in ``report["quantized"]``)
    applied on top of ``policy`` — the mechanism a calibration
    :class:`~repro.calib.recipe.QuantRecipe` uses for mixed-precision
    deployment. Unmatched override paths raise (a silently ignored
    override would serve the wrong bit-widths).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    report = {"quantized": [], "skipped": [], "deployed_bytes": 0,
              "orig_bytes": 0, "per_path": {}}
    overrides = dict(overrides or {})
    unused = set(overrides)
    out_leaves = []
    keys = jax.random.split(key, max(len(flat), 1))
    for (path, leaf), k_i in zip(flat, keys):
        path_s = _path_str(path)
        ok = (is_quantizable or _quantizable)(path_s, leaf, policy)
        if not ok:
            out_leaves.append(leaf)
            report["skipped"].append(path_s)
            continue
        eff = resolve_policy(policy, overrides.get(path_s))
        unused.discard(path_s)
        if eff.method == "none":
            out_leaves.append(leaf)
            report["skipped"].append(path_s)
            continue
        sd = infer_stack_dims(path_s, leaf)
        if eff.method == "splitquant":
            sq = splitquant_tensor(k_i, leaf, eff.cfg, k=eff.k,
                                   sample_size=eff.sample_size,
                                   stack_dims=sd)
        elif eff.method in ("baseline", "percentile"):
            sq = baseline_quant_tensor(leaf, eff.cfg, stack_dims=sd)
        else:
            raise ValueError(f"unknown method {eff.method!r}")
        out_leaves.append(sq)
        report["quantized"].append(path_s)
        report["per_path"][path_s] = {"bits": eff.cfg.bits, "k": sq.k,
                                      "method": eff.method,
                                      "bytes": sq.nbytes_deployed()}
        report["deployed_bytes"] += sq.nbytes_deployed()
        report["orig_bytes"] += leaf.size * 4
    if unused:
        raise ValueError(f"overrides matched no quantizable leaf: "
                         f"{sorted(unused)}")
    return jax.tree_util.tree_unflatten(treedef, out_leaves), report


def dequantize_tree(params):
    """Replace every SplitQuantTensor leaf with its dequantized dense array
    (simulated-quantization evaluation path)."""
    return jax.tree_util.tree_map(
        lambda l: l.dequantize() if isinstance(l, SplitQuantTensor) else l,
        params, is_leaf=lambda l: isinstance(l, SplitQuantTensor))
