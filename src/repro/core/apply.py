"""Model-level SplitQuant application: walk a parameter pytree, replace
quantizable weight leaves with :class:`SplitQuantTensor`s.

Paper §4.1 rules honored:
  * normalization γ/β are "semantically not weights" → never quantized;
  * gate/decay parameters of recurrent layers (RWKV decay, RG-LRU gates)
    are treated the same way;
  * biases are clustered+quantized like weights (1-D);
  * batch-norm folding is a no-op for the archs here (none use BN), but the
    hook exists for conv frontends.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .quantize import QuantConfig
from .splitquant import SplitQuantTensor, baseline_quant_tensor, splitquant_tensor

#: parameter-path fragments that are never quantized (semantically not weights)
DEFAULT_EXCLUDE = (
    "norm", "ln_", "layernorm", "rmsnorm", "scale_param",
    "decay", "gate_a", "rg_lru", "time_", "alibi", "rope",
    # MoE routers stay fp32: top-k selection flips discretely under
    # quantization noise, destroying accuracy for ~0 memory savings
    "router",
)


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """What to quantize and how."""

    cfg: QuantConfig = QuantConfig(bits=8)
    method: str = "splitquant"          # "splitquant" | "baseline" | "percentile"
    k: int = 3                          # number of split layers (paper: 3)
    quantize_biases: bool = True        # paper quantizes biases too
    quantize_embeddings: bool = False
    min_size: int = 64                  # leave tiny params alone
    exclude: tuple = DEFAULT_EXCLUDE
    act_chunks: int = 3                 # §4.2 activation split (0/1 disables)
    sample_size: int = 1 << 18

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts).lower()


#: path fragments marking stacked (scan-over-layers) parameter groups
STACK_FRAGMENTS = ("layers", "moe_layers", "groups", "tail",
                   "enc_layers", "dec_layers")


def infer_stack_dims(path_s: str, leaf) -> int:
    """Leading axes quantized independently: 1 under a layer stack, 2 for
    per-expert MoE weights (L, E, d, f) — DESIGN.md §5 (per-expert
    clustering)."""
    in_stack = any(f"/{f}/" in f"/{path_s}/" or path_s.startswith(f + "/")
                   for f in STACK_FRAGMENTS)
    if not in_stack:
        return 0
    if leaf.ndim >= 4:
        return 2
    return 1


def _quantizable(path_s: str, leaf, policy: QuantPolicy) -> bool:
    if not isinstance(leaf, jnp.ndarray) or not jnp.issubdtype(leaf.dtype, jnp.floating):
        return False
    if leaf.size < policy.min_size:
        return False
    if any(frag in path_s for frag in policy.exclude):
        return False
    is_table = any(f in path_s for f in ("embed", "pos_table", "enc_pos",
                                         "dec_pos"))
    if is_table and not policy.quantize_embeddings:
        return False
    if leaf.ndim == 0:
        return False
    sd = infer_stack_dims(path_s, leaf)
    if leaf.ndim - sd < 1:
        return False
    if leaf.ndim - sd == 1 and not policy.quantize_biases:
        return False
    return True


def quantize_tree(key: jax.Array, params, policy: QuantPolicy,
                  is_quantizable: Optional[Callable] = None):
    """Return a copy of ``params`` with quantizable leaves replaced by
    SplitQuantTensors (method-dependent), plus a report dict.

    * ``splitquant``  — k-means split, per-cluster scales (the paper).
    * ``baseline``    — one scale set from full min/max range.
    * ``percentile``  — one scale set from the clipped range (de-facto
                        outlier treatment the paper argues against).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    report = {"quantized": [], "skipped": [], "deployed_bytes": 0,
              "orig_bytes": 0}
    out_leaves = []
    keys = jax.random.split(key, max(len(flat), 1))
    for (path, leaf), k_i in zip(flat, keys):
        path_s = _path_str(path)
        ok = (is_quantizable or _quantizable)(path_s, leaf, policy)
        if not ok:
            out_leaves.append(leaf)
            report["skipped"].append(path_s)
            continue
        sd = infer_stack_dims(path_s, leaf)
        if policy.method == "splitquant":
            sq = splitquant_tensor(k_i, leaf, policy.cfg, k=policy.k,
                                   sample_size=policy.sample_size,
                                   stack_dims=sd)
        elif policy.method == "baseline":
            cfg = dataclasses.replace(policy.cfg, percentile=None)
            sq = baseline_quant_tensor(leaf, cfg, stack_dims=sd)
        elif policy.method == "percentile":
            cfg = policy.cfg if policy.cfg.percentile else dataclasses.replace(
                policy.cfg, percentile=0.99)
            sq = baseline_quant_tensor(leaf, cfg, stack_dims=sd)
        else:
            raise ValueError(f"unknown method {policy.method!r}")
        out_leaves.append(sq)
        report["quantized"].append(path_s)
        report["deployed_bytes"] += sq.nbytes_deployed()
        report["orig_bytes"] += leaf.size * 4
    return jax.tree_util.tree_unflatten(treedef, out_leaves), report


def dequantize_tree(params):
    """Replace every SplitQuantTensor leaf with its dequantized dense array
    (simulated-quantization evaluation path)."""
    return jax.tree_util.tree_map(
        lambda l: l.dequantize() if isinstance(l, SplitQuantTensor) else l,
        params, is_leaf=lambda l: isinstance(l, SplitQuantTensor))
