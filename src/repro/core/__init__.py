"""SplitQuant core: the paper's contribution as composable JAX transforms."""
from .quantize import QuantConfig, fake_quant, qparams, quantize, dequantize, value_range
from .kmeans import kmeans_1d, KMeansResult
from .splitquant import (
    SplitQuantTensor,
    splitquant_tensor,
    baseline_quant_tensor,
    split_activation_fake_quant,
    activation_chunk_bounds,
    effective_scales,
)
from .apply import (QuantPolicy, quantize_tree, dequantize_tree,
                    resolve_policy, DEFAULT_EXCLUDE, DEFAULT_PERCENTILE)

__all__ = [
    "QuantConfig", "fake_quant", "qparams", "quantize", "dequantize",
    "value_range", "kmeans_1d", "KMeansResult", "SplitQuantTensor",
    "splitquant_tensor", "baseline_quant_tensor", "split_activation_fake_quant",
    "activation_chunk_bounds", "effective_scales", "QuantPolicy",
    "quantize_tree", "dequantize_tree", "resolve_policy", "DEFAULT_EXCLUDE",
    "DEFAULT_PERCENTILE",
]
