"""AdamW with configurable state dtypes + global-norm clipping + optional
int8 gradient compression with error feedback.

State-dtype control matters at scale: fp32 m/v for a 405B model is 3.2 TB;
bf16 states + stochastic-rounding-free update keeps the dry-run memory
budget honest (DESIGN.md §8). Gradient compression halves (int8: quarters)
the all-reduce bytes on the data axis — the collective roofline term.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: str = "float32"      # "float32" | "bfloat16"
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    grad_compress: Optional[str] = None   # None | "int8"


class OptState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict
    err: Optional[dict]               # error-feedback residual (compression)


def _state_dtype(cfg):
    return jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32


def init(cfg: OptConfig, params) -> OptState:
    dt = _state_dtype(cfg)
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, dt), p)
    err = zeros(params) if cfg.grad_compress else None
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros(params),
                    v=zeros(params), err=err)


def schedule(cfg: OptConfig, step):
    """Linear warmup → cosine decay to min_lr_frac·lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def compress_int8(g, err):
    """Symmetric per-tensor int8 quantization with error feedback. Returns
    (decompressed_g, new_err). Applied BEFORE the data-axis all-reduce —
    under GSPMD the psum then moves int-width bytes... in this jnp-level
    simulation we model the value error while XLA still reduces fp; the
    byte saving is realized in the serve/train launch path via
    shard_map-wrapped int reductions (launch/collectives.py)."""
    gf = g.astype(jnp.float32) + err.astype(jnp.float32)
    amax = jnp.max(jnp.abs(gf)) + 1e-12
    scale = 127.0 / amax
    q = jnp.clip(jnp.rint(gf * scale), -127, 127)
    deq = q / scale
    return deq.astype(g.dtype), (gf - deq).astype(err.dtype)


def update(cfg: OptConfig, state: OptState, params, grads):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm:
        factor = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * factor, grads)

    new_err = state.err
    if cfg.grad_compress == "int8":
        pairs = jax.tree.map(compress_int8, grads, state.err)
        grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda p: p[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))

    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step, new_m, new_v, new_err), \
        {"grad_norm": gnorm, "lr": lr}
