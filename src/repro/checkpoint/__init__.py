from . import ckpt
