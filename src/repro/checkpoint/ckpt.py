"""Sharding-aware, atomic, async checkpointing.

Format: one .npz per host holding that host's addressable shards, keyed by
flattened param path, plus a JSON manifest (step, tree structure, shapes,
dtypes). Writes go to a temp dir and are atomically renamed after fsync —
a killed writer can never corrupt the latest checkpoint (fault-tolerance
requirement). `retain` old steps are kept for rollback. Mesh-independent:
restore re-shards to whatever mesh the restoring process uses.

Quantized trees: `SplitQuantTensor` leaves flatten into their q/cid/scale/
zero arrays (saved like any other), and the manifest records each leaf's
static meta (bits / k / orig_shape / orig_dtype) under ``quant_meta``.
`restore` rebuilds the SplitQuantTensors from the manifest — including
into a plain fp32 `like` tree, which is how a serving process loads an
offline-quantized checkpoint without re-running k-means.

Integrity (DESIGN.md §13): `save` records a per-array CRC32 under the
manifest's ``checksums``; `restore` recomputes and compares before any
array reaches the caller, and validates the SplitQuant invariants
(codes within the bits-range, finite scale/zero) — corruption raises
``engine.recovery.IntegrityError`` instead of serving garbage. Manifests
predating the checksum field restore as before (no silent tightening on
old artifacts).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.splitquant import SplitQuantTensor

SQT_FIELDS = ("q", "cid", "scale", "zero")


def _is_sqt(x) -> bool:
    return isinstance(x, SplitQuantTensor)


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(p): v for p, v in flat}, treedef


def _quant_meta(tree) -> dict:
    """{path: {bits, k, orig_shape, orig_dtype}} for SplitQuantTensor
    subtrees — the meta that lives in the treedef, not in any array."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree, is_leaf=_is_sqt)
    meta = {}
    for p, v in flat:
        if _is_sqt(v):
            meta[jax.tree_util.keystr(p)] = {
                "bits": int(v.bits), "k": int(v.k),
                "orig_shape": list(v.orig_shape),
                "orig_dtype": str(jnp.dtype(v.orig_dtype)),
            }
    return meta


def _build_sqt(data, key: str, meta: Optional[dict],
               fallback: Optional[SplitQuantTensor]) -> SplitQuantTensor:
    """Reassemble one SplitQuantTensor from saved arrays + manifest meta
    (meta falls back to the `like` leaf for pre-quant_meta checkpoints)."""
    arrs = {f: data[f"{key}.{f}"] for f in SQT_FIELDS}
    if meta is not None:
        bits, k = int(meta["bits"]), int(meta["k"])
        orig_shape = tuple(meta["orig_shape"])
        orig_dtype = jnp.dtype(meta["orig_dtype"])
    elif fallback is not None:
        bits, k = fallback.bits, fallback.k
        orig_shape, orig_dtype = fallback.orig_shape, fallback.orig_dtype
    else:
        raise ValueError(
            f"checkpoint has quantized arrays for {key!r} but no "
            f"quant_meta and no quantized `like` leaf to borrow meta from")
    return SplitQuantTensor(
        q=jnp.asarray(arrs["q"], jnp.int8),
        cid=jnp.asarray(arrs["cid"], jnp.uint8),
        scale=jnp.asarray(arrs["scale"], jnp.float32),
        zero=jnp.asarray(arrs["zero"], jnp.float32),
        bits=bits, k=k, orig_shape=orig_shape, orig_dtype=orig_dtype)


def save(ckpt_dir: str, step: int, tree: Any, *, retain: int = 3,
         blocking: bool = True) -> str:
    """Atomically write `tree` under ckpt_dir/step_<N>. Returns the path."""
    flat, treedef = _flatten(tree)
    host_arrays = {}
    orig_dtypes = {}
    for k, v in flat.items():
        a = jax.device_get(v)
        orig_dtypes[k] = str(jnp.asarray(v).dtype) if hasattr(v, "dtype") \
            else str(np.asarray(a).dtype)
        a = np.asarray(a)
        if a.dtype not in (np.float64, np.float32, np.float16, np.int64,
                           np.int32, np.int16, np.int8, np.uint8, np.uint16,
                           np.uint32, np.uint64, np.bool_):
            a = a.astype(np.float32)      # bf16 etc: widen for npz storage
        host_arrays[k] = a
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"

    quant_meta = _quant_meta(tree)

    def _write():
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host_arrays)
        from repro.engine.recovery import checksum_arrays
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "keys": list(host_arrays.keys()),
            "shapes": {k: list(v.shape) for k, v in host_arrays.items()},
            "dtypes": orig_dtypes,
            "quant_meta": quant_meta,
            "checksums": checksum_arrays(host_arrays),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic commit
        _gc(ckpt_dir, retain)

    if blocking:
        _write()
    else:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        save._last_async = t            # joinable by tests/shutdown
    return final


def _gc(ckpt_dir: str, retain: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-retain]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of `like` (values replaced). If
    `shardings` (matching pytree of NamedSharding) is given, arrays are
    placed sharded — mesh-independent restore.

    Quantized checkpoints: positions recorded in the manifest's
    ``quant_meta`` come back as `SplitQuantTensor`s with their saved
    bits/k/orig_shape/orig_dtype — whether the matching `like` leaf is a
    SplitQuantTensor (meta is overridden from the manifest) or a plain
    dense array (the quantized leaf replaces it, so serving can restore
    an offline-quantized tree into freshly-initialized fp32 params).
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    quant_meta = manifest.get("quant_meta", {})
    # integrity gate (engine/recovery.py, DESIGN.md §13): checksums when
    # the manifest has them (older checkpoints predate the field), quant
    # invariants always — both are exact, so any trip is real corruption
    from repro.engine.recovery import (check_code_range, check_finite,
                                       verify_checksums)
    if "checksums" in manifest:
        verify_checksums({k: data[k] for k in data.files},
                         manifest["checksums"], context=path)
    for key, meta in quant_meta.items():
        check_code_range(f"{key}.q", data[f"{key}.q"],
                         int(meta["bits"]), context=path)
        for f_ in ("scale", "zero"):
            check_finite(f"{key}.{f_}", data[f"{key}.{f_}"], context=path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like,
                                                         is_leaf=_is_sqt)
    has_quant = quant_meta or any(_is_sqt(leaf) for _, leaf in flat)
    if shardings is not None and has_quant:
        raise NotImplementedError(
            "sharded restore of quantized trees is not supported")
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    out = []
    for (p, leaf), sh in zip(flat, shard_flat):
        key = jax.tree_util.keystr(p)
        if key in quant_meta or _is_sqt(leaf):
            out.append(_build_sqt(data, key, quant_meta.get(key),
                                  leaf if _is_sqt(leaf) else None))
            continue
        arr = data[key]
        dt = manifest.get("dtypes", {}).get(key)
        if dt is not None:
            arr = jnp.asarray(arr).astype(dt)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr, dtype=leaf.dtype)
                       if hasattr(leaf, "dtype") else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


def wait_for_async():
    t = getattr(save, "_last_async", None)
    if t is not None:
        t.join()
