"""Sharding-aware, atomic, async checkpointing.

Format: one .npz per host holding that host's addressable shards, keyed by
flattened param path, plus a JSON manifest (step, tree structure, shapes,
dtypes). Writes go to a temp dir and are atomically renamed after fsync —
a killed writer can never corrupt the latest checkpoint (fault-tolerance
requirement). `retain` old steps are kept for rollback. Mesh-independent:
restore re-shards to whatever mesh the restoring process uses.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(p): v for p, v in flat}, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, retain: int = 3,
         blocking: bool = True) -> str:
    """Atomically write `tree` under ckpt_dir/step_<N>. Returns the path."""
    flat, treedef = _flatten(tree)
    host_arrays = {}
    orig_dtypes = {}
    for k, v in flat.items():
        a = jax.device_get(v)
        orig_dtypes[k] = str(jnp.asarray(v).dtype) if hasattr(v, "dtype") \
            else str(np.asarray(a).dtype)
        a = np.asarray(a)
        if a.dtype not in (np.float64, np.float32, np.float16, np.int64,
                           np.int32, np.int16, np.int8, np.uint8, np.uint16,
                           np.uint32, np.uint64, np.bool_):
            a = a.astype(np.float32)      # bf16 etc: widen for npz storage
        host_arrays[k] = a
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"

    def _write():
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host_arrays)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "keys": list(host_arrays.keys()),
            "shapes": {k: list(v.shape) for k, v in host_arrays.items()},
            "dtypes": orig_dtypes,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic commit
        _gc(ckpt_dir, retain)

    if blocking:
        _write()
    else:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        save._last_async = t            # joinable by tests/shutdown
    return final


def _gc(ckpt_dir: str, retain: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-retain]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of `like` (values replaced). If
    `shardings` (matching pytree of NamedSharding) is given, arrays are
    placed sharded — mesh-independent restore."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    out = []
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    for (p, leaf), sh in zip(flat, shard_flat):
        key = jax.tree_util.keystr(p)
        arr = data[key]
        dt = manifest.get("dtypes", {}).get(key)
        if dt is not None:
            arr = jnp.asarray(arr).astype(dt)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr, dtype=leaf.dtype)
                       if hasattr(leaf, "dtype") else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


def wait_for_async():
    t = getattr(save, "_last_async", None)
    if t is not None:
        t.join()
