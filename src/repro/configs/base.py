"""Architecture configuration schema + the shape grid assigned to this paper."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | ssm | hybrid | encoder
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0           # leading dense layers (DeepSeek-style)
    dense_d_ff: int = 0              # ffn width of those dense layers
    capacity_factor: float = 1.25

    # --- attention / positional ---
    rope_variant: str = "full"       # full | half (GLM 2d-RoPE) | none | learned
    rope_theta: float = 1e4
    window: Optional[int] = None     # local-attention window (None = global)
    head_dim_override: int = 0

    # --- ffn ---
    ffn_type: str = "swiglu"         # swiglu | geglu | gelu

    # --- hybrid (Griffin / RecurrentGemma) ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    conv_width: int = 4
    lru_width: int = 0               # RG-LRU recurrent width (0 ⇒ d_model)

    # --- ssm (RWKV6) ---
    rwkv_head_dim: int = 64

    # --- encoder-decoder (Whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 1500              # audio frames after the conv stub

    # --- modality frontend stubs (vlm / audio) ---
    stub_frontend: bool = False
    n_prefix_embeds: int = 0         # vlm: image patch tokens prepended

    # --- misc ---
    tie_embeddings: bool = False
    norm_type: str = "rms"           # rms | layer
    param_dtype: str = "bfloat16"
    bias: bool = False               # linear biases (BERT/whisper style)
    source: str = ""

    @property
    def head_dim(self) -> int:
        if self.head_dim_override:
            return self.head_dim_override
        return self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing ⇒ long_500k cell runs."""
        return self.family in ("ssm", "hybrid")

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2 * max(1, len(self.block_pattern) or 1)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads else 0,
            d_ff=256,
            dense_d_ff=256 if self.dense_d_ff else 0,
            vocab=512,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            first_k_dense=min(self.first_k_dense, 1),
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=32,
            n_prefix_embeds=min(self.n_prefix_embeds, 8),
            lru_width=128 if self.lru_width else 0,
            head_dim_override=32 if self.head_dim_override else 0,
            rwkv_head_dim=32,
            window=min(self.window, 16) if self.window else None,
            param_dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_is_runnable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch × shape) cell runs, else the documented skip reason."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("long_500k requires sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (DESIGN.md §5)")
    return True, ""
