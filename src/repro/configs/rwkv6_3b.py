"""rwkv6-3b (Finch) — attention-free, data-dependent decay
[arXiv:2404.05892; hf]. 40 heads × 64 head_dim."""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=8960, vocab=65536,
    rwkv_head_dim=64, rope_variant="none",
    source="arXiv:2404.05892",
))
