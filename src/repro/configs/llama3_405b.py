"""llama3-405b — GQA, 128k vocab [arXiv:2407.21783; unverified]"""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab=128256,
    rope_variant="full", rope_theta=5e5, ffn_type="swiglu",
    source="arXiv:2407.21783",
))
