"""Assigned architecture registry (10 archs; exact specs from the
assignment table, sources inline)."""
from __future__ import annotations

from .base import ArchConfig

REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    return REGISTRY[name]


def all_archs():
    return dict(REGISTRY)
