"""recurrentgemma-9b (Griffin) — RG-LRU + local attn 1:2, MQA kv=1,
window 2048 [arXiv:2402.19427; unverified]. 38 layers = 12×(rec,rec,attn)
groups + 2 trailing recurrent layers."""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000,
    window=2048, block_pattern=("rec", "rec", "attn"), conv_width=4,
    lru_width=4096, rope_variant="full", rope_theta=1e4, ffn_type="geglu",
    source="arXiv:2402.19427",
))
