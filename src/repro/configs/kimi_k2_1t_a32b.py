"""kimi-k2-1t-a32b — trillion-param MoE, 384e top-8 [arXiv:2501.kimi2;
unverified]. head_dim 7168/64 = 112."""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840,
    n_experts=384, top_k=8, n_shared_experts=1, first_k_dense=1,
    dense_d_ff=18432, capacity_factor=1.25,
    rope_variant="full", rope_theta=5e4, ffn_type="swiglu",
    source="arXiv:2501.kimi2",
))
