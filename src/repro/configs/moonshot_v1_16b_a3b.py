"""moonshot-v1-16b-a3b (Moonlight) — MoE 64e top-6
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840,
    n_experts=64, top_k=6, n_shared_experts=2, first_k_dense=1,
    dense_d_ff=11264, capacity_factor=1.25,
    rope_variant="full", rope_theta=5e4, ffn_type="swiglu",
    source="hf:moonshotai/Moonlight-16B-A3B",
))
