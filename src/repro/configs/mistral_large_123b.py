"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=28672, vocab=32768,
    rope_variant="full", rope_theta=1e6, ffn_type="swiglu",
    source="hf:mistralai/Mistral-Large-Instruct-2407",
))
