"""BERT-Tiny (Turc et al. 2019) — the paper's Table 1 test vehicle:
2L, d=128, 2 heads, d_ff=512, WordPiece vocab 30522."""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="bert-tiny", family="encoder",
    n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
    d_ff=512, vocab=30522,
    rope_variant="none", norm_type="layer", ffn_type="gelu", bias=True,
    param_dtype="float32",
    source="arXiv:1908.08962",
))
