"""Architecture configs. Importing this package populates the registry."""
from .base import ArchConfig, ShapeConfig, SHAPES, cell_is_runnable
from .registry import REGISTRY, all_archs, get_arch

# register all assigned architectures (+ the paper's own BERT-Tiny)
from . import (  # noqa: F401
    mistral_large_123b, chatglm3_6b, llama3_405b, stablelm_1_6b,
    moonshot_v1_16b_a3b, kimi_k2_1t_a32b, paligemma_3b, whisper_tiny,
    rwkv6_3b, recurrentgemma_9b, bert_tiny,
)

ASSIGNED = [
    "mistral-large-123b", "chatglm3-6b", "llama3-405b", "stablelm-1.6b",
    "moonshot-v1-16b-a3b", "kimi-k2-1t-a32b", "paligemma-3b", "whisper-tiny",
    "rwkv6-3b", "recurrentgemma-9b",
]

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "cell_is_runnable",
           "REGISTRY", "all_archs", "get_arch", "ASSIGNED"]
