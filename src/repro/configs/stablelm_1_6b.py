"""stablelm-1.6b — MHA (kv=32) [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=5632, vocab=100352,
    rope_variant="half", rope_theta=1e4, ffn_type="swiglu",
    source="hf:stabilityai/stablelm-2-1_6b",
))
