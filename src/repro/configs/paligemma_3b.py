"""paligemma-3b — SigLIP(stub) + gemma backbone, MQA kv=1
[arXiv:2407.07726; hf]. 256 image patch tokens prepended."""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab=257216,
    rope_variant="full", rope_theta=1e4, ffn_type="geglu",
    stub_frontend=True, n_prefix_embeds=256, tie_embeddings=True,
    source="arXiv:2407.07726",
))
