"""whisper-tiny — enc-dec, conv frontend stub [arXiv:2212.04356;
unverified]. 4 encoder + 4 decoder layers, learned positions, LayerNorm."""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865,
    n_enc_layers=4, enc_seq=1500,
    rope_variant="none", norm_type="layer", ffn_type="gelu", bias=True,
    stub_frontend=True, tie_embeddings=True,
    source="arXiv:2212.04356",
))
