"""chatglm3-6b — RoPE 2d (half-rotary), GQA kv=2 [arXiv:2406.12793; hf]"""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=65024,
    rope_variant="half", rope_theta=1e4, ffn_type="swiglu", bias=False,
    source="arXiv:2406.12793",
))
