"""repro: SplitQuant — layer splitting for low-bit quantization, as a
production JAX/TPU training + quantized-serving framework."""

__version__ = "1.0.0"
