"""Token-level continuous-batching scheduler: FCFS admission into a fixed
pool of N slots, per-step retire/refill.

The scheduler is pure-Python bookkeeping — it never touches device arrays.
The engine asks it three questions per step: which queued requests can be
admitted into free slots (`admit`), which slots are active (`active_slots`),
and it reports terminations back (`retire`). Replacing the wave-synchronous
loop, a finished request frees its slot immediately, so one long generation
no longer stalls the short requests batched with it.

Admission control (DESIGN.md §12): with ``max_queue > 0`` the submit
queue is bounded and an arrival into a full queue invokes the
``overload_policy`` — "reject-new" sheds the arrival itself,
"shed-oldest" sheds the queue head (the request that has already waited
longest and is least likely to meet any deadline), "shed-by-class"
sheds the oldest queued batch-class request first (interactive traffic
keeps its slot chances; the loadgen classes carry much looser batch
SLOs) and falls back to the arrival. Shed requests finish immediately
with reason "shed" — every submission still retires exactly once, just
without ever holding a slot. The set point for ``max_queue`` defaults
from the measured open-loop saturation knee (`admission_set_point`).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Optional

#: Bounded-queue overload policies (Scheduler(max_queue=...)).
OVERLOAD_POLICIES = ("reject-new", "shed-oldest", "shed-by-class")

#: Classes shed first under "shed-by-class" and deferred by the
#: degradation ladder — the loadgen batch class (loose SLO, long
#: prompts): dropping one frees the most work for the least SLO damage.
SHED_CLASSES = ("batch",)


class SubmitError(ValueError):
    """Structured rejection at `Engine.submit` time: a malformed request
    fails fast at the API surface instead of deep inside admission.
    ``code`` ∈ {"empty_prompt", "too_long", "bad_budget"}."""

    def __init__(self, code: str, msg: str):
        super().__init__(msg)
        self.code = code


@dataclasses.dataclass
class EngineRequest:
    """One generation request and its lifecycle metrics."""

    uid: int
    prompt: "object"                    # (S,) int array-like
    max_new_tokens: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # metrics (perf_counter seconds; None until the event happens)
    t_submit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    # why the request retired: one of obs.schema.RETIRE_REASONS
    # (normal: "eos" | "budget" | "max_len" | "zero_budget"; lifecycle
    # policy: "cancelled" | "deadline_exceeded" | "shed" | "failed");
    # None while running
    finish_reason: Optional[str] = None
    # loadgen request class ("interactive" | "batch" | None): the
    # shed-by-class victim key and the ladder's admission-defer key
    cls: Optional[str] = None
    # wall-clock deadlines, seconds relative to t_submit (None = no
    # deadline). Enforced by the engine at step boundaries: ttft for
    # requests still awaiting their first token, total for everyone.
    ttft_deadline_s: Optional[float] = None
    deadline_s: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        if self.t_submit is None or self.t_first_token is None:
            return None
        return self.t_first_token - self.t_submit

    @property
    def tokens_per_s(self) -> Optional[float]:
        if self.t_submit is None or self.t_done is None or not self.out:
            return None
        dt = self.t_done - self.t_submit
        return len(self.out) / dt if dt > 0 else None


class Scheduler:
    """FCFS queue + fixed slot pool."""

    def __init__(self, n_slots: int, clock=time.perf_counter, tracer=None,
                 registry=None, max_queue: int = 0,
                 overload_policy: str = "reject-new", journal=None):
        self.n_slots = n_slots
        self.clock = clock
        # admission control: 0 = unbounded queue (the historical
        # behavior); > 0 bounds the queue and overload_policy picks the
        # shed victim when an arrival would exceed it
        if overload_policy not in OVERLOAD_POLICIES:
            raise ValueError(f"overload_policy {overload_policy!r} not in "
                             f"{OVERLOAD_POLICIES}")
        self.max_queue = int(max_queue or 0)
        self.overload_policy = overload_policy
        # lifecycle-event sink (obs.Tracer); the scheduler owns the
        # submit/admit/retire transitions so it emits those events.
        # Falsy tracers normalize to None — one branch per site disabled.
        self.tracer = tracer if tracer else None
        # durable request journal (engine/recovery.py, DESIGN.md §13):
        # the scheduler owns the submit/admit/retire transitions, so it
        # writes their WAL records too. Unlike the tracer (a ring-buffer
        # profiling mode) journal appends are buffered then fsync'd by
        # the engine once per step — the crash-recovery replay source
        self.journal = journal if journal else None
        self.queue: collections.deque[EngineRequest] = collections.deque()
        self.slots: list[Optional[EngineRequest]] = [None] * n_slots
        self.finished: list[EngineRequest] = []
        # always-on queueing signals (recorded with or without a tracer:
        # admission control and the open-loop SLO bench need them on
        # every run, and they are O(1) appends at submit/admit time —
        # the tracer only cannot provide them when it is off)
        self.admit_latency_s: list[float] = []   # submit -> slot placement
        self.queue_depth_submit: list[int] = []  # depth seen by each submit
        # optional always-on metrics registry (obs.metrics): queueing
        # gauges + admit-latency histogram, shared with the engine
        self._mx = None
        if registry is not None:
            from repro.obs.metrics import DEPTH_BUCKETS
            self._mx = {
                "submitted": registry.counter(
                    "sched_requests_submitted",
                    "requests entering the FCFS queue"),
                "admitted": registry.counter(
                    "sched_requests_admitted",
                    "requests placed into a slot"),
                "retired": registry.counter(
                    "sched_requests_retired", "requests finished"),
                "depth": registry.gauge(
                    "sched_queue_depth",
                    "requests waiting for a slot"),
                "depth_hist": registry.histogram(
                    "sched_queue_depth_at_submit",
                    "queue depth seen by each arriving request",
                    buckets=DEPTH_BUCKETS),
                "admit_latency": registry.histogram(
                    "sched_admit_latency_seconds",
                    "submit -> slot placement wait"),
                "shed": registry.counter(
                    "sched_requests_shed",
                    "requests shed by admission control or the "
                    "degradation ladder (retire reason \"shed\")"),
                "cancelled": registry.counter(
                    "sched_requests_cancelled",
                    "requests cancelled mid-flight or while queued"),
            }
        # slots admitted but not fully prefilled yet (chunked-prefill
        # engines): they hold their request (the slot is occupied) but are
        # NOT active for decode — a mid-prefill slot must stay invisible
        # to the decode batch until its whole prompt is written
        self._prefilling: list[int] = []        # FCFS begin order
        # counters for the engine's metrics snapshot
        self.n_submitted = 0
        self.n_admitted = 0
        self.n_shed = 0
        self.n_cancelled = 0
        self.queue_depth_hist: list[int] = []
        # speculative-decoding accounting (spec_k > 0 engines): totals,
        # the per-verify accepted-length histogram, and per-slot
        # [proposed, accepted] pairs — mixed spec/non-spec steps mean
        # slots verify different window lengths in the same step, so the
        # rate must be tracked per verify call, not per step
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.accept_hist: list[int] = []
        self.spec_by_slot: list[list[int]] = [[0, 0] for _ in range(n_slots)]
        # EWMA of the per-verify acceptance fraction — the live gauge a
        # dashboard watches (the cumulative rate hides recent drift);
        # None until the first verify with proposed > 0
        self.accept_ewma: Optional[float] = None
        self.accept_ewma_alpha = 0.1

    # ------------------------------------------------------------ intake --
    def submit(self, req: EngineRequest) -> EngineRequest:
        req.t_submit = self.clock()
        self.n_submitted += 1
        victim = None
        if self.max_queue and len(self.queue) >= self.max_queue:
            victim = self._overload_victim(req)
        if victim is not req:
            self.queue.append(req)
        self.queue_depth_submit.append(len(self.queue))
        if self._mx:
            self._mx["submitted"].inc()
            self._mx["depth"].set(len(self.queue))
            self._mx["depth_hist"].observe(len(self.queue))
        if self.tracer:
            self.tracer.event("submit", uid=req.uid,
                              prompt_len=int(len(req.prompt)),
                              budget=req.max_new_tokens,
                              queue_depth=len(self.queue))
        if self.journal:
            # the WAL submit record carries everything replay needs to
            # re-enqueue the request from scratch (prompt included —
            # the one place the full token list is persisted)
            self.journal.event("submit", uid=req.uid,
                              prompt=[int(t) for t in req.prompt],
                              budget=req.max_new_tokens, cls=req.cls,
                              ttft_deadline_s=req.ttft_deadline_s,
                              deadline_s=req.deadline_s)
        if victim is not None:
            if victim is not req:
                self.queue.remove(victim)
                if self._mx:
                    self._mx["depth"].set(len(self.queue))
            self._finish(victim, "shed")
        return req

    def _overload_victim(self, incoming: EngineRequest) -> EngineRequest:
        """Pick the request to shed when ``incoming`` finds the queue
        full. Policies: see OVERLOAD_POLICIES / the module docstring."""
        if self.overload_policy == "shed-oldest" and self.queue:
            return self.queue[0]
        if self.overload_policy == "shed-by-class":
            for r in self.queue:                      # oldest batch first
                if r.cls in SHED_CLASSES:
                    return r
        return incoming                               # reject-new

    # ---------------------------------------------------------- stepping --
    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def active_slots(self) -> list[int]:
        """Slots decoding this step — occupied and NOT mid-prefill."""
        return [i for i, r in enumerate(self.slots)
                if r is not None and i not in self._prefilling]

    def occupied_uids(self) -> list[int]:
        """uids holding a slot right now, in slot order — the per-step
        active set the flight recorder stamps on every record (and the
        attribution set an incident bundle's request docs cover)."""
        return [r.uid for r in self.slots if r is not None]

    # ------------------------------------------- chunked-prefill states --
    def begin_prefill(self, slot: int) -> None:
        """Mark an admitted slot as mid-prefill (occupied, not decoding)."""
        assert self.slots[slot] is not None, f"prefill of empty slot {slot}"
        if slot not in self._prefilling:
            self._prefilling.append(slot)

    def finish_prefill(self, slot: int) -> None:
        """Prompt fully written — the slot joins the decode batch."""
        self._prefilling.remove(slot)

    def prefill_slots(self) -> list[int]:
        """Mid-prefill slots in FCFS begin order (the chunk-budget order)."""
        return list(self._prefilling)

    def admit(self, defer=()) -> list[tuple[int, EngineRequest]]:
        """Move queued requests into free slots (FCFS). Returns the
        (slot, request) pairs admitted this step; the engine prefills
        them. ``defer`` names request classes to skip over this step
        (the degradation ladder's rung-2 action): deferred requests
        keep their queue position and admit normally once the rung
        drops."""
        placed = []
        for slot in self.free_slots():
            if defer:
                req = next((r for r in self.queue if r.cls not in defer),
                           None)
                if req is None:
                    break
                self.queue.remove(req)
            elif self.queue:
                req = self.queue.popleft()
            else:
                break
            self.slots[slot] = req
            self.n_admitted += 1
            placed.append((slot, req))
            queued_s = self.clock() - req.t_submit
            self.admit_latency_s.append(queued_s)
            if self._mx:
                self._mx["admitted"].inc()
                self._mx["admit_latency"].observe(queued_s)
            if self.tracer:
                self.tracer.event(
                    "admit", uid=req.uid, slot=slot, queued_s=queued_s)
            if self.journal:
                self.journal.event("admit", uid=req.uid, slot=slot)
        self.queue_depth_hist.append(len(self.queue))
        if self._mx:
            self._mx["depth"].set(len(self.queue))
        return placed

    def retire(self, slot: int, reason: str = "eos") -> EngineRequest:
        """Free a slot whose request finished. ``reason`` is the
        lifecycle vocabulary (obs.schema.RETIRE_REASONS) — recorded on
        the request and in the trace."""
        req = self.slots[slot]
        assert req is not None, f"retire of empty slot {slot}"
        self.slots[slot] = None
        if slot in self._prefilling:            # retired mid-prefill (eos
            self._prefilling.remove(slot)       # on first token, 0 budget,
                                                # cancel, deadline)
        self._finish(req, reason, slot=slot)
        return req

    def _finish(self, req: EngineRequest, reason: str,
                slot: Optional[int] = None) -> None:
        """Shared terminal transition: slotted retires, queue drops, and
        shed-at-submit all funnel here, so every request finishes exactly
        once with exactly one reason. ``slot=None`` means the request
        never held a slot (trace records it as slot=-1)."""
        assert not req.done, f"double finish of uid {req.uid}"
        req.done = True
        req.t_done = self.clock()
        req.finish_reason = reason
        self.finished.append(req)
        if reason == "shed":
            self.n_shed += 1
        elif reason == "cancelled":
            self.n_cancelled += 1
        if self._mx:
            self._mx["retired"].inc()
            if reason in ("shed", "cancelled"):
                self._mx[reason].inc()
        if self.tracer:
            self.tracer.event("retire", uid=req.uid,
                              slot=-1 if slot is None else slot,
                              reason=reason, n_out=len(req.out))
        if self.journal:
            # retire records carry the OUTPUT tokens: after compaction
            # they are the only trace of a finished request, and a
            # recovering supervisor reports pre-crash finishers (and
            # proves their token identity) straight from the WAL
            self.journal.event("retire", uid=req.uid,
                              slot=-1 if slot is None else slot,
                              reason=reason, n_out=len(req.out),
                              out=[int(t) for t in req.out])

    def drop_queued(self, req: EngineRequest, reason: str) -> None:
        """Finish a request that is still waiting in the queue (cancel,
        deadline sweep, forced drain) without it ever holding a slot."""
        self.queue.remove(req)
        if self._mx:
            self._mx["depth"].set(len(self.queue))
        self._finish(req, reason)

    def shed_queued_to(self, target_depth: int,
                       prefer=SHED_CLASSES) -> int:
        """Shed queued requests (oldest ``prefer``-class first, then
        FCFS head) until the queue is at ``target_depth`` — the ladder's
        rung-3 action. Returns how many were shed."""
        n = 0
        while len(self.queue) > max(0, int(target_depth)):
            victim = next((r for r in self.queue if r.cls in prefer),
                          self.queue[0])
            self.drop_queued(victim, "shed")
            n += 1
        return n

    # ------------------------------------------------------------- state --
    @property
    def idle(self) -> bool:
        return not self.queue and all(r is None for r in self.slots)

    def utilization(self) -> float:
        """Mean fraction of slots active over recorded steps (set by the
        engine via `note_step`)."""
        if not getattr(self, "_active_hist", None):
            return 0.0
        return sum(self._active_hist) / (len(self._active_hist) * self.n_slots)

    def note_step(self, n_active: int):
        if not hasattr(self, "_active_hist"):
            self._active_hist = []
        self._active_hist.append(n_active)

    # ------------------------------------------- speculative decoding --
    def note_spec(self, slot: int, proposed: int, accepted: int):
        """Record one verify call's outcome: `proposed` draft tokens were
        scored for `slot`, the first `accepted` matched the target."""
        assert 0 <= accepted <= proposed, (slot, proposed, accepted)
        self.spec_proposed += proposed
        self.spec_accepted += accepted
        self.accept_hist.append(accepted)
        self.spec_by_slot[slot][0] += proposed
        self.spec_by_slot[slot][1] += accepted
        if proposed:                            # w=1 verifies propose 0 —
            rate = accepted / proposed          # no acceptance signal
            a = self.accept_ewma_alpha
            self.accept_ewma = rate if self.accept_ewma is None else \
                (1 - a) * self.accept_ewma + a * rate

    def acceptance_rate(self) -> Optional[float]:
        """Fraction of proposed draft tokens the target accepted."""
        if not self.spec_proposed:
            return None
        return self.spec_accepted / self.spec_proposed


# ----------------------------------------------- admission set point ----
def admission_set_point(open_loop: Optional[dict], slack: float = 2.0,
                        floor: int = 2) -> Optional[int]:
    """Derive the bounded-queue set point from a measured ``open_loop``
    BENCH_serve.json section (DESIGN.md §12).

    The policy: at the knee's last-OK offered rate the engine still met
    its SLOs, so the p95 queue depth arrivals saw THERE is the deepest
    backlog known to be survivable; bound the queue at ``slack`` × that
    depth (headroom for bursts the MMPP-2 process loves) with a small
    floor. Queued work beyond the bound would exit the measured-OK
    regime, so shedding it early converts doomed latency into goodput —
    the overload bench gates that this actually holds. Returns None when
    the section is missing, the sweep never saturated (no knee ⇒ no
    pressure ⇒ no bound needed), or the knee point lacks the depth
    signal (older BENCH files)."""
    if not open_loop:
        return None
    knee = open_loop.get("knee") or {}
    last_ok = knee.get("last_ok_offered_rps")
    if last_ok is None:
        return None
    pt = next((p for p in open_loop.get("points") or []
               if p.get("offered_rps") == last_ok), None)
    depth = (pt or {}).get("queue_depth_at_submit_p95")
    if depth is None:
        return None
    return max(int(floor), int(math.ceil(float(depth) * slack)))
