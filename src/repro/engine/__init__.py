"""Continuous-batching inference engine (DESIGN.md §6).

`kvcache` and `scheduler` are dependency-light and import eagerly;
`Engine` pulls in the model zoo, so it is resolved lazily to keep the
models ← engine.kvcache edge (attention's slot-cache branch) acyclic.
"""
from __future__ import annotations

from .faults import (DegradationLadder, FaultInjector, FaultSpec,
                     InjectedCrash, StepFailure)
from .kvcache import (SlotKVCache, clear_slot, dequantize_kv,
                      init_slot_cache, occupied_slots, quantize_kv,
                      quantize_kv_static, rollback_slot, write_prefill)
from .recovery import (IntegrityError, RequestJournal, compact_journal,
                       read_snapshot)
from .scheduler import (EngineRequest, Scheduler, SubmitError,
                        admission_set_point)

__all__ = ["Engine", "EngineConfig", "EngineRequest", "Scheduler",
           "SubmitError", "admission_set_point", "FaultSpec",
           "FaultInjector", "DegradationLadder", "StepFailure",
           "InjectedCrash", "IntegrityError", "RequestJournal",
           "compact_journal", "read_snapshot",
           "SlotKVCache", "SpecDecoder", "init_slot_cache", "write_prefill",
           "clear_slot", "rollback_slot", "occupied_slots", "quantize_kv",
           "quantize_kv_static", "dequantize_kv"]


def __getattr__(name):
    if name in ("Engine", "EngineConfig"):
        from . import engine as _engine
        return getattr(_engine, name)
    if name == "SpecDecoder":
        from . import spec as _spec
        return _spec.SpecDecoder
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
