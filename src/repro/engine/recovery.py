"""Crash-safe serving: journal, snapshot/restore, integrity validation.

Three cooperating pieces (DESIGN.md §13):

1. ``RequestJournal`` — an append-only JSONL write-ahead log of request
   lifecycle transitions (submit / admit / first_token / retire), written
   in the tracer's record format (``obs/tracer.py``) so the merged
   journal of a crashed run plus its recovery run validates under
   ``trace_report --validate`` unchanged.  Appends are buffered in
   memory and made durable once per engine step via ``sync()``
   (write + flush + fsync): the durability horizon is the last step
   boundary, which is exactly where the crash fault fires.

2. ``snapshot_engine`` / ``restore_engine`` — serialize the live engine
   (quantized slot cache, draft-twin cache, scheduler queue + slot
   table, host-side decode state, PRNG key) to a directory written
   atomically (tmp dir + ``os.rename``, same protocol as
   ``checkpoint/ckpt.py``) with a manifest carrying per-array CRC32
   checksums, the provenance header, and an engine-geometry fingerprint.

3. ``IntegrityError`` + the shared validators — one set of checks used
   by snapshot restore, ``checkpoint.ckpt.restore`` and
   ``QuantRecipe.load``: byte checksums, INT8 code-range invariants,
   finite (and positive, where required) scales, and the ``kv_pos``
   invariant (every entry is -1 or exactly its own time index — the
   engine only ever writes position t at row t).  SplitQuant's compact
   storage makes these checks *exact*: any drift is corruption, never
   quantization slop, so the validator fails loudly instead of serving
   garbage.

``recover_engine`` composes the pieces: restore the snapshot (if any),
then replay the journal against it — requests retired after the
snapshot are cleared (their output lives in the journal; exactly-once
holds across the crash), requests alive in the snapshot resume from
their quantized KV state, and requests submitted past the snapshot
horizon are re-enqueued from their journal submit record and re-prefill
from scratch.  Greedy decoding is a pure function of the committed
prefix, so resumed requests regenerate post-snapshot tokens
bit-identically (the same property PR 8's rollback-retry relies on).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import zlib
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

SNAPSHOT_SCHEMA = 1

# arrays.npz key prefixes
_CACHE = "cache/"
_DRAFT = "draft/"
_HOST = "host/"


# --------------------------------------------------------------------------
# integrity primitives (shared by snapshot restore, ckpt restore and
# QuantRecipe load)
# --------------------------------------------------------------------------

class IntegrityError(RuntimeError):
    """A loaded artifact failed validation and must not be served.

    ``reason`` is a stable machine-readable tag: one of ``checksum``,
    ``missing_array``, ``schema``, ``config_mismatch``, ``code_range``,
    ``nonfinite``, ``nonpositive_scale``, ``kv_pos_invalid``.
    """

    def __init__(self, reason: str, msg: str):
        super().__init__(f"[{reason}] {msg}")
        self.reason = reason


def array_checksum(a: np.ndarray) -> str:
    """CRC32 over dtype + shape + raw bytes, as ``crc32:xxxxxxxx``."""
    a = np.ascontiguousarray(a)
    h = zlib.crc32(repr((a.dtype.str, a.shape)).encode())
    h = zlib.crc32(a.tobytes(), h)
    return f"crc32:{h:08x}"


def checksum_arrays(arrays: Dict[str, np.ndarray]) -> Dict[str, str]:
    return {k: array_checksum(np.asarray(v)) for k, v in arrays.items()}


def verify_checksums(arrays: Dict[str, np.ndarray],
                     want: Dict[str, str], context: str = "") -> None:
    """Compare stored checksums against the loaded arrays; loud on drift."""
    ctx = f"{context}: " if context else ""
    for name, expect in want.items():
        if name not in arrays:
            raise IntegrityError("missing_array",
                                 f"{ctx}array {name!r} in manifest but "
                                 f"missing from archive")
        got = array_checksum(np.asarray(arrays[name]))
        if got != expect:
            raise IntegrityError("checksum",
                                 f"{ctx}{name}: stored {expect}, "
                                 f"recomputed {got} — artifact corrupt")


def check_finite(name: str, a: np.ndarray, context: str = "") -> None:
    a = np.asarray(a)
    if a.size and not np.all(np.isfinite(a)):
        ctx = f"{context}: " if context else ""
        n = int(np.sum(~np.isfinite(a)))
        raise IntegrityError("nonfinite",
                             f"{ctx}{name} has {n} non-finite entries")


def check_positive(name: str, a: np.ndarray, context: str = "") -> None:
    check_finite(name, a, context)
    a = np.asarray(a)
    if a.size and not np.all(a > 0):
        ctx = f"{context}: " if context else ""
        raise IntegrityError("nonpositive_scale",
                             f"{ctx}{name} has entries <= 0 "
                             f"(min {float(a.min())})")


def check_code_range(name: str, codes: np.ndarray, bits: int,
                     context: str = "") -> None:
    """Quantized codes must lie within the signed ``bits``-bit levels."""
    qmin, qmax = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    c = np.asarray(codes)
    if c.size == 0:
        return
    lo, hi = int(c.min()), int(c.max())
    if lo < qmin or hi > qmax:
        ctx = f"{context}: " if context else ""
        raise IntegrityError("code_range",
                             f"{ctx}{name} codes span [{lo}, {hi}], "
                             f"outside int{bits} range [{qmin}, {qmax}]")


def validate_cache_arrays(arrays: Dict[str, np.ndarray], mode: str,
                          prefix: str = _CACHE, context: str = "") -> None:
    """Invariant checks for a (snapshotted) SlotKVCache's arrays.

    - ``kv_pos[l, n, t]`` is either -1 (empty) or exactly ``t``: the
      engine writes position t at row t and never wraps, so any other
      value is corruption.
    - int8 modes: codes within the 8-bit levels, scales finite and
      positive, zero-points finite.
    """
    ctx = f"{context}: " if context else ""
    pos = np.asarray(arrays[prefix + "kv_pos"])
    T = pos.shape[-1]
    t = np.arange(T, dtype=pos.dtype)
    bad = ~((pos == -1) | (pos == t))
    if bad.any():
        l, n, tt = (int(x[0]) for x in np.nonzero(bad))
        raise IntegrityError("kv_pos_invalid",
                             f"{ctx}kv_pos[{l},{n},{tt}] = "
                             f"{int(pos[l, n, tt])}, expected -1 or {tt}")
    if mode == "int8":
        from .kvcache import KV_QCFG
        for kk in ("k", "v"):
            check_code_range(prefix + kk, arrays[prefix + kk],
                             KV_QCFG.bits, context)
        for kk in ("k_scale", "v_scale"):
            check_positive(prefix + kk, arrays[prefix + kk], context)
        for kk in ("k_zero", "v_zero"):
            check_finite(prefix + kk, arrays[prefix + kk], context)


# --------------------------------------------------------------------------
# durable request journal
# --------------------------------------------------------------------------

class RequestJournal:
    """Append-only JSONL WAL of request lifecycle transitions.

    Record format is the tracer's (``obs/tracer.py``): a single header
    line (``kind=header``, ``schema=1``) followed by event lines
    (``kind=event``, ``name`` in the ``obs/schema.py`` lifecycle
    taxonomy).  Journal events carry extra replay payload the schema
    validator permits: submit records hold the full prompt + budget +
    class + deadlines, retire records hold the output token list (so a
    supervisor can report pre-crash finishers without the engine).

    ``event()`` buffers; ``sync()`` writes + flushes + fsyncs — the
    engine calls it once per step, making the step boundary the
    durability horizon.  Opening an existing journal (``resume=True``)
    appends without a second header, so the merged crash+recovery file
    stays a single valid trace.
    """

    def __init__(self, path: str, clock=time.perf_counter,
                 meta: Optional[dict] = None, resume: bool = False):
        self.path = path
        self.clock = clock
        self.t0 = clock()
        self._buf: List[str] = []
        append = resume and _has_journal_header(path)
        self._f = open(path, "a" if append else "w")
        if not append:
            from ..obs.tracer import SCHEMA_VERSION
            header = {"kind": "header", "schema": SCHEMA_VERSION,
                      "journal": True, **(meta or {})}
            self._f.write(json.dumps(header) + "\n")
            self._flush_fsync()

    def event(self, name: str, **fields) -> None:
        rec = {"kind": "event", "name": name,
               "ts": self.clock() - self.t0, **fields}
        self._buf.append(json.dumps(rec))

    def sync(self) -> None:
        """Make every buffered record durable (write + flush + fsync)."""
        if self._buf:
            self._f.write("\n".join(self._buf) + "\n")
            self._buf.clear()
        self._flush_fsync()

    def _flush_fsync(self) -> None:
        self._f.flush()
        os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self.sync()
            self._f.close()

    def __del__(self):  # best effort; sync() per step is the real contract
        try:
            self.close()
        except Exception:
            pass


def _has_journal_header(path: str) -> bool:
    try:
        with open(path) as f:
            first = f.readline()
        rec = json.loads(first)
        return rec.get("kind") == "header"
    except (OSError, ValueError):
        return False


def load_journal(path: str) -> List[dict]:
    from ..obs.tracer import load_jsonl
    return load_jsonl(path)


def replay_journal(records: List[dict]) -> Tuple[Dict[int, dict],
                                                 Dict[int, dict]]:
    """Fold journal records into (submitted, retired) maps keyed by uid.

    ``submitted[uid]`` is the submit record (prompt/budget/class/
    deadlines — enough to re-enqueue); ``retired[uid]`` is the retire
    record (reason + output tokens).  A uid present in both finished
    before the crash and must not run again.
    """
    submitted: Dict[int, dict] = {}
    retired: Dict[int, dict] = {}
    for rec in records:
        if rec.get("kind") != "event":
            continue
        name, uid = rec.get("name"), rec.get("uid")
        if uid is None:
            continue
        if name == "submit":
            submitted[int(uid)] = rec
        elif name == "retire":
            retired[int(uid)] = rec
    return submitted, retired


def compact_journal(path: str) -> Tuple[int, int]:
    """Rewrite the journal dropping records made redundant by a retire.

    Keeps the header, every record of un-retired uids (still needed for
    replay), the retire records themselves (they carry the output and
    pin exactly-once across restarts), and engine-scoped records
    (snapshot/restore marks).  Atomic via tmp + ``os.replace``.
    Returns (n_records_before, n_records_after).
    """
    records = load_journal(path)
    _, retired = replay_journal(records)
    kept = []
    for rec in records:
        if rec.get("kind") != "event":
            kept.append(rec)
            continue
        uid = rec.get("uid")
        if uid is not None and int(uid) in retired \
                and rec.get("name") != "retire":
            continue
        kept.append(rec)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        for rec in kept:
            f.write(json.dumps(rec) + "\n")
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return len(records), len(kept)


# --------------------------------------------------------------------------
# snapshot / restore
# --------------------------------------------------------------------------

def _req_doc(req) -> dict:
    return {"uid": req.uid,
            "prompt": [int(t) for t in req.prompt],
            "max_new_tokens": int(req.max_new_tokens),
            "out": [int(t) for t in req.out],
            "cls": req.cls,
            "ttft_deadline_s": req.ttft_deadline_s,
            "deadline_s": req.deadline_s,
            "has_first_token": req.t_first_token is not None}


def _req_from_doc(doc: dict, clock) -> Any:
    from .scheduler import EngineRequest
    req = EngineRequest(uid=int(doc["uid"]),
                        prompt=list(doc["prompt"]),
                        max_new_tokens=int(doc["max_new_tokens"]),
                        cls=doc.get("cls", "interactive"),
                        ttft_deadline_s=doc.get("ttft_deadline_s"),
                        deadline_s=doc.get("deadline_s"))
    req.out = list(doc.get("out", []))
    # wall-clock state does not survive a process: deadlines restart at
    # restore time (documented in DESIGN.md §13)
    req.t_submit = clock()
    if doc.get("has_first_token"):
        req.t_first_token = req.t_submit
    return req


def _engine_fingerprint(eng) -> dict:
    ecfg = eng.ecfg
    return {"arch": eng.cfg.name,
            "n_slots": ecfg.n_slots,
            "max_len": ecfg.max_len,
            "kv_mode": eng.cache.mode,
            "kv_static": eng.cache.static,
            "kv_qchunks": eng.cache.qchunks,
            "spec_k": ecfg.spec_k,
            "draft_mode": (eng._spec.cache.mode
                           if eng._spec is not None else None),
            "vocab": eng.cfg.vocab}


def _store_cache(cache, prefix: str) -> Tuple[Dict[str, np.ndarray],
                                              Dict[str, str]]:
    """(arrays, original dtypes) — bf16 widened to fp32 for npz storage."""
    import jax.numpy as jnp
    from .kvcache import CACHE_DATA_FIELDS
    arrays, dtypes = {}, {}
    for name in CACHE_DATA_FIELDS:
        x = getattr(cache, name)
        dtypes[prefix + name] = str(x.dtype)
        if x.dtype == jnp.bfloat16:
            x = x.astype(jnp.float32)
        arrays[prefix + name] = np.asarray(x)
    return arrays, dtypes


def _load_cache(like, arrays: Dict[str, np.ndarray],
                dtypes: Dict[str, str], prefix: str):
    import jax.numpy as jnp
    from .kvcache import CACHE_DATA_FIELDS
    repl = {}
    for name in CACHE_DATA_FIELDS:
        key = prefix + name
        if key not in arrays:
            raise IntegrityError("missing_array",
                                 f"snapshot missing {key!r}")
        want = getattr(like, name)
        x = jnp.asarray(arrays[key], dtype=jnp.dtype(dtypes[key]))
        if x.shape != want.shape:
            raise IntegrityError("config_mismatch",
                                 f"{key}: snapshot shape {x.shape} != "
                                 f"engine shape {want.shape}")
        repl[name] = x
    return dataclasses.replace(like, **repl)


def snapshot_engine(eng, path: str) -> str:
    """Write the engine's full serving state to ``path``, atomically.

    Layout mirrors ``checkpoint/ckpt.py``: a tmp directory holding
    ``arrays.npz`` + ``manifest.json`` (fsync'd) is ``os.rename``d over
    ``path`` via the shared ``obs.atomic.atomic_dir`` protocol — a crash
    mid-write leaves either the old snapshot or none, never a torn one.  The manifest carries per-array checksums, the
    provenance header and an engine-geometry fingerprint that restore
    validates before touching any array.
    """
    from ..obs.provenance import provenance

    arrays, dtypes = _store_cache(eng.cache, _CACHE)
    if eng._spec is not None:
        d_arrays, d_dtypes = _store_cache(eng._spec.cache, _DRAFT)
        arrays.update(d_arrays)
        dtypes.update(d_dtypes)
    arrays[_HOST + "last_tok"] = np.asarray(eng._last_tok)
    arrays[_HOST + "pos"] = np.asarray(eng._pos)
    arrays[_HOST + "prefill_prog"] = np.asarray(eng._prefill_prog)
    arrays[_HOST + "fail_streak"] = np.asarray(eng._fail_streak)
    arrays[_HOST + "rng"] = np.asarray(eng.rng)
    for k in (_HOST + "last_tok", _HOST + "pos", _HOST + "prefill_prog",
              _HOST + "fail_streak", _HOST + "rng"):
        dtypes[k] = str(arrays[k].dtype)

    sched = eng.sched
    manifest = {
        "schema": SNAPSHOT_SCHEMA,
        "provenance": provenance(),
        "engine": _engine_fingerprint(eng),
        "checksums": checksum_arrays(arrays),
        "dtypes": dtypes,
        "step": len(eng.step_s),
        "uid_next": eng._uid,
        "any_deadlines": eng._any_deadlines,
        "n_submitted": sched.n_submitted,
        "n_admitted": sched.n_admitted,
        "queue": [_req_doc(r) for r in sched.queue],
        "slots": [None if r is None else _req_doc(r) for r in sched.slots],
        "prefilling": list(sched._prefilling),
    }

    from ..obs.atomic import atomic_dir

    final = os.path.abspath(path)
    with atomic_dir(final) as tmp:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
    return final


def read_snapshot(path: str) -> Tuple[dict, Dict[str, np.ndarray]]:
    """Load and integrity-check a snapshot directory (no engine needed).

    Validates schema version, per-array checksums and the cache
    invariants; raises ``IntegrityError`` before any array could reach
    an engine.
    """
    mpath = os.path.join(path, "manifest.json")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except FileNotFoundError:
        raise IntegrityError("schema", f"{path}: no manifest.json — "
                             f"not a snapshot directory")
    except ValueError as e:
        raise IntegrityError("schema", f"{mpath}: corrupt JSON ({e})")
    if manifest.get("schema") != SNAPSHOT_SCHEMA:
        raise IntegrityError("schema",
                             f"{mpath}: snapshot schema "
                             f"{manifest.get('schema')!r}, expected "
                             f"{SNAPSHOT_SCHEMA}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    verify_checksums(arrays, manifest["checksums"], context=path)
    eng_meta = manifest["engine"]
    validate_cache_arrays(arrays, eng_meta["kv_mode"],
                          prefix=_CACHE, context=path)
    if _DRAFT + "kv_pos" in arrays:
        validate_cache_arrays(arrays, eng_meta.get("draft_mode") or "fp",
                              prefix=_DRAFT, context=path)
    return manifest, arrays


def restore_engine(eng, path: str) -> dict:
    """Restore ``eng`` (freshly constructed, idle) from a snapshot.

    The caller constructs the engine with the same config the snapshot
    was taken under (the manifest fingerprint is cross-checked), then
    this replaces the cache(s), host decode state, PRNG key, scheduler
    queue + slot table and uid counter.  Returns the manifest.
    """
    import jax.numpy as jnp

    manifest, arrays = read_snapshot(path)
    want = _engine_fingerprint(eng)
    got = manifest["engine"]
    if got != want:
        diff = {k: (got.get(k), want[k]) for k in want
                if got.get(k) != want[k]}
        raise IntegrityError("config_mismatch",
                             f"{path}: snapshot engine geometry differs "
                             f"from this engine: {diff} "
                             f"(snapshot, engine)")
    has_draft = _DRAFT + "kv_pos" in arrays
    if has_draft != (eng._spec is not None):
        raise IntegrityError("config_mismatch",
                             f"{path}: snapshot draft-cache presence "
                             f"({has_draft}) does not match engine "
                             f"spec_k={eng.ecfg.spec_k}")

    dtypes = manifest["dtypes"]
    eng.cache = _load_cache(eng.cache, arrays, dtypes, _CACHE)
    if has_draft:
        eng._spec.cache = _load_cache(eng._spec.cache, arrays, dtypes,
                                      _DRAFT)

    eng._last_tok = np.array(arrays[_HOST + "last_tok"])
    eng._pos = np.array(arrays[_HOST + "pos"])
    eng._prefill_prog = np.array(arrays[_HOST + "prefill_prog"])
    eng._fail_streak = np.array(arrays[_HOST + "fail_streak"])
    eng.rng = jnp.asarray(arrays[_HOST + "rng"],
                          dtype=jnp.dtype(dtypes[_HOST + "rng"]))
    eng._uid = int(manifest["uid_next"])
    eng._any_deadlines = bool(manifest["any_deadlines"])

    sched = eng.sched
    sched.queue = deque(_req_from_doc(d, eng.clock)
                        for d in manifest["queue"])
    sched.slots = [None if d is None else _req_from_doc(d, eng.clock)
                   for d in manifest["slots"]]
    sched._prefilling = list(manifest["prefilling"])
    sched.n_submitted = int(manifest["n_submitted"])
    sched.n_admitted = int(manifest["n_admitted"])
    return manifest


def recover_engine(eng, snapshot_path: Optional[str],
                   journal_path: Optional[str]) -> dict:
    """Restore a snapshot and reconcile it against the journal.

    Reconciliation, per journal uid:
      - retired            -> finished before the crash: its output lives
                              in the retire record; if the snapshot still
                              holds it (retired after the snapshot was
                              taken), evict it so it cannot run twice.
      - alive in snapshot  -> resumes from its quantized KV state; tokens
                              generated between snapshot and crash are
                              regenerated identically (greedy decode is a
                              pure function of the committed prefix).
      - past the horizon   -> submitted after the snapshot: re-enqueued
                              from the journal submit record, re-prefills
                              from scratch.

    Returns ``{"manifest", "retired", "n_restored", "n_requeued"}`` —
    ``retired`` maps uid -> retire record so a supervisor can fold
    pre-crash finishers into its final report (exactly-once across the
    crash: those uids never re-enter the engine).
    """
    manifest = None
    if snapshot_path and os.path.isdir(snapshot_path):
        manifest = restore_engine(eng, snapshot_path)

    submitted: Dict[int, dict] = {}
    retired: Dict[int, dict] = {}
    if journal_path and os.path.exists(journal_path):
        submitted, retired = replay_journal(load_journal(journal_path))

    sched = eng.sched

    # evict anything the journal says already retired (exactly-once)
    for uid, rec in retired.items():
        for slot, req in enumerate(sched.slots):
            if req is not None and req.uid == uid:
                eng._evict_slot(slot)
        sched.queue = deque(r for r in sched.queue if r.uid != uid)

    n_restored = sum(1 for r in sched.slots if r is not None) \
        + len(sched.queue)

    # re-enqueue post-horizon submissions, in original uid order
    present = {r.uid for r in sched.slots if r is not None} \
        | {r.uid for r in sched.queue}
    n_requeued = 0
    for uid in sorted(submitted):
        if uid in retired or uid in present:
            continue
        rec = submitted[uid]
        req = _req_from_doc({"uid": uid, "prompt": rec["prompt"],
                             "max_new_tokens": rec["budget"],
                             "cls": rec.get("cls", "interactive"),
                             "ttft_deadline_s": rec.get("ttft_deadline_s"),
                             "deadline_s": rec.get("deadline_s")},
                            eng.clock)
        req.out = []
        req.t_first_token = None
        # straight onto the queue: already journaled at first submit, so
        # no second submit record, no overload policy re-applied
        sched.queue.append(req)
        if req.ttft_deadline_s is not None or req.deadline_s is not None:
            eng._any_deadlines = True
        n_requeued += 1

    # fresh uids must never collide with journaled ones
    top = max(submitted, default=-1)
    eng._uid = max(eng._uid, top + 1)

    if eng.journal is not None:
        eng.journal.event("restore",
                          snapshot_step=(manifest or {}).get("step"),
                          n_restored=n_restored, n_requeued=n_requeued,
                          n_retired_in_journal=len(retired))
        eng.journal.sync()
    return {"manifest": manifest, "retired": retired,
            "n_restored": n_restored, "n_requeued": n_requeued}
