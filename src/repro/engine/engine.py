"""Continuous-batching inference engine.

`Engine` owns the three serving pieces: a `Scheduler` (FCFS queue + slot
pool), a `SlotKVCache` (preallocated, optionally INT8), and the jitted
model entry points. The serving loop is token-level:

    eng = Engine(cfg, params, EngineConfig(n_slots=4))
    eng.submit(prompt_a); eng.submit(prompt_b)
    finished = eng.drain()

Each `step()` (1) admits queued requests into free slots — every admit is
a per-request prefill (batch 1, right-padded to a length bucket so jit
recompiles are bounded; padding never pollutes the cache because only the
true prompt positions are marked valid); (2) runs ONE batched decode step
over all slots at their own positions; (3) retires finished slots so the
next step can refill them. A long generation therefore occupies exactly
one slot instead of stalling a whole wave.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_model

from .kvcache import clear_slot, init_slot_cache, write_prefill
from .scheduler import EngineRequest, Scheduler

ENGINE_FAMILIES = ("dense", "moe", "vlm")


def bucket_len(n: int, bucket: int, max_len: int) -> int:
    """Round a prompt length up to its prefill bucket (bounded jit
    recompiles). Single definition — the serve benchmark warms exactly
    these shapes, so it must agree with the engine byte-for-byte."""
    return min(max_len, -(-n // bucket) * bucket)


@functools.lru_cache(maxsize=None)
def _jitted_prefill(cfg):
    """Prefill depends only on the arch — shared across fused/sampling
    variants so an engine flag flip never recompiles prefill buckets."""
    model = get_model(cfg)
    return jax.jit(lambda p, toks: model.prefill(p, cfg, {"tokens": toks}))


@functools.lru_cache(maxsize=None)
def _jitted_entry_points(cfg, fused: bool, greedy: bool):
    """Process-wide jitted (decode, prefill) per (arch config, fused flag,
    sampling mode).

    Jitting per Engine INSTANCE (the old scheme) meant every restart — and
    every benchmark repetition — recompiled the decode step and each
    prefill bucket from scratch; sharing the wrappers here makes engine
    spin-up O(cache lookup) after the first instance and lets benchmarks
    measure steady state instead of XLA compile time.

    The cache argument is DONATED: the serving loop always replaces its
    cache with the returned one, and donation lets XLA update the slot
    arrays in place instead of copying every (L, N, T, ...) leaf each
    decode step — an O(cache-size) saving per token for both the fused
    and the materializing read path.

    ``greedy`` folds argmax sampling into the decode executable: one
    dispatch and a (N,)-int host transfer per step instead of a separate
    argmax jit call plus the full logits pull."""
    from repro.models import transformer

    def step(p, c, t, pos):
        logits, cache = transformer.decode_step_slots(p, cfg, c, t, pos,
                                                      fused=fused)
        if greedy:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), \
                cache
        return logits, cache

    decode = jax.jit(step, donate_argnums=(1,))
    return decode, _jitted_prefill(cfg)


# slot/length stay traced: one compile per prefill bucket shape, shared by
# every engine in the process; the old cache is dead after each call, so
# its buffers are donated (in-place row writes)
_WRITE = jax.jit(write_prefill, donate_argnums=(0,))
_CLEAR = jax.jit(clear_slot, donate_argnums=(0,))


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 8
    max_len: int = 256
    max_new_tokens: int = 32            # default per-request token budget
    temperature: float = 0.0            # 0 ⇒ greedy
    eos_id: int = -1                    # -1 ⇒ never stop early
    kv_mode: str = "fp"                 # "fp" | "int8" (SplitQuant §4.2)
    kv_qchunks: int = 4                 # ranges per head-vector in int8 mode
    kv_dtype: str = "float32"           # fp-mode storage; "bfloat16" on TPU
    prefill_bucket: int = 16            # prompt lengths round up to a multiple
    fused_attn: bool = False            # decode reads via the fused dequant-
                                        # in-kernel attention (no full-
                                        # precision cache copy)


class Engine:
    """submit()/step()/drain() continuous-batching server.

    ``kv_scales``: optional static KV quantization constants from an
    offline calibration recipe (``repro.calib``) — dict of
    ``k_scale/k_zero/v_scale/v_zero`` (L, Hkv, C) arrays. Requires
    ``kv_mode="int8"``; decode writes then skip the per-step min/max
    reduce and scale storage amortizes to ~0 bytes/token (DESIGN.md §7).
    """

    def __init__(self, cfg, params, ecfg: EngineConfig,
                 rng: Optional[jax.Array] = None,
                 clock=time.perf_counter,
                 kv_scales: Optional[dict] = None):
        if cfg.family not in ENGINE_FAMILIES:
            raise NotImplementedError(
                f"engine serves transformer families {ENGINE_FAMILIES}, "
                f"got {cfg.family!r} (recurrent-state continuous batching "
                f"is a separate cache layout)")
        if cfg.window is not None and cfg.window < ecfg.max_len:
            raise NotImplementedError(
                "windowed (ring) slot caches not wired up yet; "
                f"window={cfg.window} < max_len={ecfg.max_len}")
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.model = get_model(cfg)
        self.clock = clock
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        from repro.models.common import dtype_of
        self.sched = Scheduler(ecfg.n_slots, clock=clock)
        self.cache = init_slot_cache(
            cfg, ecfg.n_slots, ecfg.max_len, mode=ecfg.kv_mode,
            dtype=dtype_of(ecfg.kv_dtype), qchunks=ecfg.kv_qchunks,
            kv_scales=kv_scales)
        self._greedy = ecfg.temperature <= 0
        self._decode, self._prefill = _jitted_entry_points(
            cfg, ecfg.fused_attn, self._greedy)
        self._write = _WRITE
        self._clear = _CLEAR
        # host-side slot state
        N = ecfg.n_slots
        self._last_tok = np.zeros(N, np.int32)
        self._pos = np.zeros(N, np.int32)
        self._uid = 0
        self.n_decode_steps = 0
        self.n_prefills = 0
        self.decode_step_s: list[float] = []
        self._t_start: Optional[float] = None

    def load_kv_scales(self, kv_scales: dict) -> None:
        """Hot-swap a freshly loaded calibration recipe's static KV scales
        into a DYNAMIC int8 cache without draining slots (ROADMAP item):
        in-flight codes are requantized under the new constants once, and
        every subsequent write skips both the min/max reduce and the
        per-entry scale scatter. No-op for requests already finished; new
        admissions quantize with the recipe constants from the start."""
        from .kvcache import hotswap_static_scales
        self.cache = jax.jit(hotswap_static_scales)(self.cache, {
            k: jnp.asarray(v, jnp.float32) for k, v in kv_scales.items()})
        # self._decode retraces automatically: the cache's static flag is
        # pytree metadata, so the jit cache keys on it

    # ------------------------------------------------------------ intake --
    def submit(self, prompt, max_new_tokens: Optional[int] = None) -> int:
        """Enqueue a request; returns its uid. Non-blocking — work happens
        in step()/drain(). An explicit max_new_tokens=0 means "no tokens"
        (the request completes at admission with empty output)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) > self.ecfg.max_len:
            raise ValueError(
                f"prompt length {len(prompt)} > max_len {self.ecfg.max_len}")
        budget = (self.ecfg.max_new_tokens if max_new_tokens is None
                  else max_new_tokens)
        if len(prompt) + budget > self.ecfg.max_len:
            budget = max(1, self.ecfg.max_len - len(prompt))
        req = EngineRequest(uid=self._uid, prompt=prompt,
                            max_new_tokens=budget)
        self._uid += 1
        self.sched.submit(req)
        return req.uid

    # ---------------------------------------------------------- sampling --
    def _sample(self, logits):
        """logits (..., V) → token ids."""
        if self.ecfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.rng, k = jax.random.split(self.rng)
        return jax.random.categorical(k, logits / self.ecfg.temperature)

    # ----------------------------------------------------------- serving --
    def _bucket(self, n: int) -> int:
        return bucket_len(n, self.ecfg.prefill_bucket, self.ecfg.max_len)

    def _retire(self, slot: int):
        """Free the slot everywhere: scheduler, cache row (kv_pos → -1),
        and host-side position/token state, so idle slots genuinely ride
        along at pos 0."""
        self.sched.retire(slot)
        self.cache = self._clear(self.cache, jnp.int32(slot))
        self._pos[slot] = 0
        self._last_tok[slot] = 0

    def _admit_one(self, slot: int, req: EngineRequest):
        if req.max_new_tokens <= 0:                   # explicit 0-token ask
            req.t_first_token = req.t_submit
            self.sched.retire(slot)
            return
        S = len(req.prompt)
        Sp = self._bucket(S)
        toks = np.zeros((1, Sp), np.int32)
        toks[0, :S] = req.prompt                      # right-pad
        logits, pcache = self._prefill(self.params, jnp.asarray(toks))
        self.n_prefills += 1
        # only [0, S) becomes visible; bucket padding stays masked forever
        self.cache = self._write(self.cache, jnp.int32(slot), pcache,
                                 jnp.int32(S))
        first = int(self._sample(logits[0, S - 1]))
        req.t_first_token = self.clock()
        if first == self.ecfg.eos_id:                 # eos is never emitted
            self._retire(slot)
            return
        req.out.append(first)
        self._last_tok[slot] = first
        self._pos[slot] = S
        if len(req.out) >= req.max_new_tokens or S >= self.ecfg.max_len:
            self._retire(slot)

    def step(self) -> list[EngineRequest]:
        """Admit + one batched decode step. Returns requests finishing now."""
        if self._t_start is None:
            self._t_start = self.clock()
        n_done_before = len(self.sched.finished)
        for slot, req in self.sched.admit():
            self._admit_one(slot, req)
        active = self.sched.active_slots()
        if active:
            # idle slots ride along at pos 0 with token 0 (fixed decode
            # shape == jit cache of exactly one entry); _retire cleared
            # their kv_pos rows, so each idle step re-marks only its own
            # t=0 entry, and the next admit rewrites the row wholesale
            tokens = jnp.asarray(self._last_tok[:, None])
            pos = jnp.asarray(self._pos)
            t0 = self.clock()
            if self._greedy:
                toks, self.cache = self._decode(self.params, self.cache,
                                                tokens, pos)
                toks = np.asarray(toks)
            else:
                logits, self.cache = self._decode(self.params, self.cache,
                                                  tokens, pos)
                toks = np.asarray(self._sample(logits[:, -1]))
            self.n_decode_steps += 1
            # toks is on host here, so this brackets the real per-step
            # decode latency (dispatch + device compute + sample)
            self.decode_step_s.append(self.clock() - t0)
            for slot in active:
                req = self.sched.slots[slot]
                t = int(toks[slot])
                self._pos[slot] += 1
                if t == self.ecfg.eos_id:
                    self._retire(slot)
                    continue
                req.out.append(t)
                self._last_tok[slot] = t
                if (len(req.out) >= req.max_new_tokens
                        or self._pos[slot] >= self.ecfg.max_len):
                    self._retire(slot)
            self.sched.note_step(len(active))
        return self.sched.finished[n_done_before:]

    def drain(self) -> list[EngineRequest]:
        """Run until queue and slots are empty; returns all finished
        requests in uid order."""
        while not self.sched.idle:
            self.step()
        return sorted(self.sched.finished, key=lambda r: r.uid)

    # ----------------------------------------------------------- metrics --
    def metrics(self) -> dict:
        fin = self.sched.finished
        ttfts = [r.ttft for r in fin if r.ttft is not None]
        tps = [r.tokens_per_s for r in fin if r.tokens_per_s is not None]
        total_tokens = sum(len(r.out) for r in fin)
        wall = (self.clock() - self._t_start) if self._t_start else 0.0
        steps = np.asarray(self.decode_step_s, np.float64)
        return {
            "n_finished": len(fin),
            "total_tokens": total_tokens,
            "wall_s": wall,
            "tokens_per_s": total_tokens / wall if wall > 0 else None,
            "decode_steps": self.n_decode_steps,
            "prefills": self.n_prefills,
            "slot_utilization": self.sched.utilization(),
            "queue_depth_max": max(self.sched.queue_depth_hist, default=0),
            "ttft_mean_s": float(np.mean(ttfts)) if ttfts else None,
            "ttft_p50_s": float(np.median(ttfts)) if ttfts else None,
            "request_tokens_per_s_mean": float(np.mean(tps)) if tps else None,
            "decode_step_p50_s": (float(np.percentile(steps, 50))
                                  if steps.size else None),
            "decode_step_p95_s": (float(np.percentile(steps, 95))
                                  if steps.size else None),
            "decode_step_mean_s": (float(steps.mean())
                                   if steps.size else None),
            "fused_attn": self.ecfg.fused_attn,
            "kv_mode": self.cache.mode,
            "kv_static_scales": self.cache.static,
            "kv_bytes_per_token": self.cache.bytes_per_token(),
        }
