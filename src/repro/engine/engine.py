"""Continuous-batching inference engine.

`Engine` owns the three serving pieces: a `Scheduler` (FCFS queue + slot
pool), a `SlotKVCache` (preallocated, optionally INT8), and the jitted
model entry points. The serving loop is token-level:

    eng = Engine(cfg, params, EngineConfig(n_slots=4))
    eng.submit(prompt_a); eng.submit(prompt_b)
    finished = eng.drain()

Each `step()` (1) admits queued requests into free slots; (2) prefills —
either ONE-SHOT (`prefill_chunk=0`: a per-request dense prefill whose fp
cache `write_prefill` re-quantizes into the slot, batch 1, right-padded
to a length bucket so jit recompiles are bounded) or CHUNKED
(`prefill_chunk>0`: at most that many prompt tokens per step stream
through `transformer.prefill_chunk_slots`, whose fused kernel quantizes
K/V in-kernel and writes codes straight into the slot cache — no fp
prefill cache exists and a long prompt no longer stalls decoding, see
DESIGN.md §6); (3) runs ONE batched decode step over all decoding slots
at their own positions; (4) retires finished slots so the next step can
refill them. A long generation therefore occupies exactly one slot
instead of stalling a whole wave, and with chunked prefill a long PROMPT
occupies at most `prefill_chunk` tokens of any step.

Mid-prefill slots are invisible to decode (`Scheduler.active_slots`
excludes them) but still ride along in the fixed-shape decode batch,
parked at their next-unwritten position: the parked step writes garbage
K/V at exactly the row the slot's NEXT prefill chunk overwrites (and the
chunk kernel masks cache rows at >= pos_start), so the parked write can
never leak into any attention result.

With ``spec_k > 0`` the decode step is SPECULATIVE (`engine/spec.py`,
DESIGN.md §9): a low-bit draft model proposes up to k greedy tokens per
slot over its own slot cache, the target verifies each slot's window in
one fused prefill-kernel pass, and 1..k+1 tokens commit per slot per
step — token-identical to plain greedy decoding by the lossless accept
rule.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_model

from .kvcache import clear_slot, init_slot_cache, rollback_slot, \
    write_prefill
from .scheduler import EngineRequest, Scheduler

ENGINE_FAMILIES = ("dense", "moe", "vlm")

#: Materialization-counter hook: incremented once per LEGACY one-shot
#: prefill dispatch — each one materializes a dense full-precision
#: (L, S, Hkv, D) cache that `write_prefill` then pads, re-quantizes and
#: copies into the slot cache. The fused chunked-prefill path must never
#: bump it (asserted in tests/test_prefill_attention.py).
FP_PREFILL_MATERIALIZATIONS = 0


def bucket_len(n: int, bucket: int, max_len: int) -> int:
    """Round a prompt length up to its prefill bucket (bounded jit
    recompiles). Single definition — the serve benchmark warms exactly
    these shapes, so it must agree with the engine byte-for-byte."""
    return min(max_len, -(-n // bucket) * bucket)


@functools.lru_cache(maxsize=None)
def _jitted_prefill(cfg):
    """Prefill depends only on the arch — shared across fused/sampling
    variants so an engine flag flip never recompiles prefill buckets."""
    model = get_model(cfg)
    return jax.jit(lambda p, toks: model.prefill(p, cfg, {"tokens": toks}))


@functools.lru_cache(maxsize=None)
def _jitted_entry_points(cfg, fused: bool, greedy: bool):
    """Process-wide jitted (decode, prefill) per (arch config, fused flag,
    sampling mode).

    Jitting per Engine INSTANCE (the old scheme) meant every restart — and
    every benchmark repetition — recompiled the decode step and each
    prefill bucket from scratch; sharing the wrappers here makes engine
    spin-up O(cache lookup) after the first instance and lets benchmarks
    measure steady state instead of XLA compile time.

    The cache argument is DONATED: the serving loop always replaces its
    cache with the returned one, and donation lets XLA update the slot
    arrays in place instead of copying every (L, N, T, ...) leaf each
    decode step — an O(cache-size) saving per token for both the fused
    and the materializing read path.

    ``greedy`` folds argmax sampling into the decode executable: one
    dispatch and a (N,)-int host transfer per step instead of a separate
    argmax jit call plus the full logits pull."""
    from repro.models import transformer

    def step(p, c, t, pos):
        logits, cache = transformer.decode_step_slots(p, cfg, c, t, pos,
                                                      fused=fused)
        if greedy:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), \
                cache
        return logits, cache

    decode = jax.jit(step, donate_argnums=(1,))
    return decode, _jitted_prefill(cfg)


@functools.lru_cache(maxsize=None)
def _jitted_chunk_prefill(cfg):
    """Process-wide jitted chunked-prefill entry point. One compile per
    CHUNK BUCKET shape (the (1, Sc) tokens arg); slot / pos_start / length
    are traced scalars, so slots and chunk offsets never recompile. The
    cache is donated — chunk writes update the slot arrays in place."""
    from repro.models import transformer

    def chunk(p, c, toks, slot, pos_start, length):
        return transformer.prefill_chunk_slots(p, cfg, c, toks, slot,
                                               pos_start, length)

    return jax.jit(chunk, donate_argnums=(1,))


# slot/length stay traced: one compile per prefill bucket shape, shared by
# every engine in the process; the old cache is dead after each call, so
# its buffers are donated (in-place row writes)
_WRITE = jax.jit(write_prefill, donate_argnums=(0,))
_CLEAR = jax.jit(clear_slot, donate_argnums=(0,))
_ROLLBACK = jax.jit(rollback_slot, donate_argnums=(0,))


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 8
    max_len: int = 256
    max_new_tokens: int = 32            # default per-request token budget
    temperature: float = 0.0            # 0 ⇒ greedy
    eos_id: int = -1                    # -1 ⇒ never stop early
    kv_mode: str = "fp"                 # "fp" | "int8" (SplitQuant §4.2)
    kv_qchunks: int = 4                 # ranges per head-vector in int8 mode
    kv_dtype: str = "float32"           # fp-mode storage; "bfloat16" on TPU
    prefill_bucket: int = 16            # prompt lengths round up to a multiple
    fused_attn: bool = True             # decode reads via the fused dequant-
                                        # in-kernel attention (no full-
                                        # precision cache copy). False =
                                        # legacy materialize-then-attend,
                                        # kept as the cross-checked oracle
    prefill_chunk: int = 96             # chunked fused prefill — admit at
                                        # most this many prompt tokens per
                                        # step, quantize-in-kernel slot
                                        # writes, decode keeps running while
                                        # long prompts stream in. Default ON
                                        # (~4x prefill_bucket, the serve-
                                        # bench soak sweet spot) now that
                                        # soak + verify coverage has
                                        # accumulated; prefill_chunk=0 is
                                        # the legacy one-shot opt-out
                                        # (serve_bench pins it for its
                                        # stall baseline)
    spec_k: int = 0                     # >0: self-speculative decoding — a
                                        # low-bit draft proposes up to k
                                        # greedy tokens per slot per step,
                                        # the target verifies the window in
                                        # ONE fused pass (engine/spec.py,
                                        # DESIGN.md §9). Output is token-
                                        # identical to spec_k=0 greedy.
                                        # Requires temperature <= 0
    draft_recipe: Optional[str] = None  # QuantRecipe dir the draft weights
                                        # are minted from (spec_k > 0);
                                        # None = draft with the target's
                                        # own weights (acceptance ~1, no
                                        # draft cost win — mostly a test
                                        # and bring-up configuration)
    draft_dequantize: bool = True       # expand the draft's packed low-
                                        # bit weights to the compute dtype
                                        # ONCE at engine start: the low-
                                        # bit recipe buys draft
                                        # faithfulness + storage, and a
                                        # packed draft would otherwise pay
                                        # a full dequant per draft step on
                                        # backends without the fused
                                        # dequant-matmul. False keeps the
                                        # draft packed (memory-bound
                                        # deployments with the kernel)
    metrics: bool = True                # always-ON metrics registry
                                        # (repro.obs.metrics, DESIGN.md
                                        # §11): monotonic counters /
                                        # gauges / fixed-bucket
                                        # histograms over the queueing
                                        # signals (queue depth, admit
                                        # latency, slot occupancy,
                                        # prefill backlog, tokens in
                                        # flight, spec-acceptance EWMA).
                                        # Unlike trace, this is bounded-
                                        # memory and cheap enough to
                                        # never turn off — overhead is
                                        # asserted within the serve-
                                        # bench noise floor (≤1%).
                                        # False exists for that
                                        # overhead measurement
    metrics_kv_every: int = 0           # >0: sample KV clip-fraction /
                                        # occupancy gauges from live
                                        # int8 cache rows every N steps
                                        # (kvcache.kv_quality_counters —
                                        # a bounded host transfer, so
                                        # NOT free; keep the period
                                        # coarse in production)
    trace: bool = False                 # default-OFF observability
                                        # (repro.obs, DESIGN.md §10):
                                        # lifecycle events + per-step
                                        # phase spans with dispatch-vs-
                                        # device-wait attribution. Traced
                                        # mode inserts block_until_ready
                                        # sync points to attribute async
                                        # dispatch — it is a PROFILING
                                        # mode, not free; disabled, every
                                        # site pays one branch
    trace_capacity: int = 1 << 16       # tracer ring-buffer records;
                                        # oldest drop first on overflow
    trace_kv_every: int = 0             # >0: sample KV quantization-
                                        # quality counters (clip fraction,
                                        # code occupancy, outlier-chunk
                                        # histogram) every N steps — a
                                        # host transfer of live cache
                                        # rows, traced-mode cost only


class Engine:
    """submit()/step()/drain() continuous-batching server.

    ``kv_scales``: optional static KV quantization constants from an
    offline calibration recipe (``repro.calib``) — dict of
    ``k_scale/k_zero/v_scale/v_zero`` (L, Hkv, C) arrays. Requires
    ``kv_mode="int8"``; decode writes then skip the per-step min/max
    reduce and scale storage amortizes to ~0 bytes/token (DESIGN.md §7).

    ``draft_params``: optional pre-built draft weight tree for
    ``spec_k > 0`` (same architecture as ``params`` — typically the
    low-bit quantized copy). Overrides ``ecfg.draft_recipe``; when both
    are absent the target drafts for itself (acceptance ~1, no draft
    cost win — a bring-up configuration).
    """

    def __init__(self, cfg, params, ecfg: EngineConfig,
                 rng: Optional[jax.Array] = None,
                 clock=time.perf_counter,
                 kv_scales: Optional[dict] = None,
                 draft_params=None, tracer=None, registry=None):
        if cfg.family not in ENGINE_FAMILIES:
            raise NotImplementedError(
                f"engine serves transformer families {ENGINE_FAMILIES}, "
                f"got {cfg.family!r} (recurrent-state continuous batching "
                f"is a separate cache layout"
                + (" — and spec_k > 0 additionally needs positional KV "
                   "rollback, which recurrent state cannot provide)"
                   if ecfg.spec_k else ")"))
        if cfg.window is not None and cfg.window < ecfg.max_len:
            raise NotImplementedError(
                "windowed (ring) slot caches not wired up yet; "
                f"window={cfg.window} < max_len={ecfg.max_len}")
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.model = get_model(cfg)
        self.clock = clock
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        from repro.models.common import dtype_of
        # --- observability (repro.obs, DESIGN.md §10) -------------------
        # an explicit tracer wins; else ecfg.trace mints one on the
        # engine's own clock (trace time and metrics share one axis).
        # Falsy tracers normalize to None so every hot-path site guards
        # with a single `if tr:` branch — the whole disabled-mode cost.
        if tracer is None and ecfg.trace:
            from repro.obs import Tracer
            tracer = Tracer(capacity=ecfg.trace_capacity, clock=clock,
                            meta={"arch": cfg.name, "n_slots": ecfg.n_slots,
                                  "spec_k": ecfg.spec_k,
                                  "kv_mode": ecfg.kv_mode,
                                  "prefill_chunk": ecfg.prefill_chunk})
        self.tracer = tracer if tracer else None
        # --- always-on metrics registry (obs.metrics, DESIGN.md §11) ----
        # an explicit registry wins (shared across engines / exported by
        # a server); else ecfg.metrics mints a private one. Instruments
        # resolve ONCE here so the hot path is attribute ops behind a
        # single `if mx:` branch; ecfg.metrics=False leaves mx None —
        # the configuration the overhead assertion measures against.
        self.registry = None
        self._mx = None
        if registry is not None or ecfg.metrics:
            from repro.obs.metrics import MetricsRegistry
            self.registry = registry if registry is not None \
                else MetricsRegistry()
            r = self.registry
            self._mx = {
                "steps": r.counter("engine_steps", "Engine.step() calls"),
                "decode_steps": r.counter(
                    "engine_decode_steps", "batched plain-decode steps"),
                "spec_steps": r.counter(
                    "engine_spec_steps", "speculative decode steps"),
                "tokens": r.counter(
                    "engine_tokens_generated", "committed output tokens"),
                "prefill_tokens": r.counter(
                    "engine_prefill_tokens", "prompt tokens prefilled"),
                "prefill_chunks": r.counter(
                    "engine_prefill_chunks", "fused prefill chunks run"),
                "step_s": r.histogram(
                    "engine_step_seconds", "full Engine.step() wall"),
                "decode_s": r.histogram(
                    "engine_decode_step_seconds",
                    "batched decode dispatch + device + sample"),
                "occupancy": r.gauge(
                    "engine_slot_occupancy",
                    "occupied slots (decoding + mid-prefill) / n_slots"),
                "decoding": r.gauge(
                    "engine_slots_decoding", "slots in the decode batch"),
                "backlog": r.gauge(
                    "engine_prefill_backlog_chunks",
                    "prompt chunks still to stream for mid-prefill slots"),
                "in_flight": r.gauge(
                    "engine_tokens_in_flight",
                    "unexhausted generation budget across occupied slots"),
            }
            if ecfg.spec_k:
                self._mx["accept_ewma"] = r.gauge(
                    "spec_accept_ewma",
                    "EWMA of per-verify draft-token acceptance fraction")
            if ecfg.metrics_kv_every:
                for side in ("k", "v"):
                    self._mx[f"kv_{side}_clip"] = r.gauge(
                        f"kv_{side}_clip_frac",
                        f"sampled {side.upper()}-cache code saturation "
                        f"(static scale drifted narrow when trending up)")
                    self._mx[f"kv_{side}_occ"] = r.gauge(
                        f"kv_{side}_occupancy",
                        f"sampled {side.upper()}-cache code-range use "
                        f"(scale drifted wide when trending down)")
        self.sched = Scheduler(ecfg.n_slots, clock=clock,
                               tracer=self.tracer, registry=self.registry)
        self.cache = init_slot_cache(
            cfg, ecfg.n_slots, ecfg.max_len, mode=ecfg.kv_mode,
            dtype=dtype_of(ecfg.kv_dtype), qchunks=ecfg.kv_qchunks,
            kv_scales=kv_scales)
        self._greedy = ecfg.temperature <= 0
        self._decode, self._prefill = _jitted_entry_points(
            cfg, ecfg.fused_attn, self._greedy)
        self._chunk_prefill = (_jitted_chunk_prefill(cfg)
                               if ecfg.prefill_chunk else None)
        self._write = _WRITE
        self._clear = _CLEAR
        # --- self-speculative decoding (engine/spec.py, DESIGN.md §9) ---
        self._spec = None
        if ecfg.spec_k:
            if not self._greedy:
                raise NotImplementedError(
                    "spec_k > 0 requires greedy decoding (temperature <= "
                    "0): the lossless accept rule compares argmax tokens; "
                    "temperature sampling needs speculative rejection "
                    "sampling, which is not wired up")
            from . import spec as spec_mod
            if draft_params is None:
                draft_params = (
                    spec_mod.load_draft_params(ecfg.draft_recipe, params,
                                               cfg)
                    if ecfg.draft_recipe else params)
            self._spec = spec_mod.SpecDecoder(cfg, ecfg, draft_params,
                                              tracer=self.tracer,
                                              registry=self.registry)
            self._verify = spec_mod.jitted_verify(cfg)
        # host-side slot state
        N = ecfg.n_slots
        self._last_tok = np.zeros(N, np.int32)
        self._pos = np.zeros(N, np.int32)
        self._prefill_prog = np.zeros(N, np.int64)   # prompt tokens written
        self._uid = 0
        self.n_decode_steps = 0
        self.n_prefills = 0
        self.n_prefill_chunks = 0
        self.n_spec_steps = 0
        self.n_verify_calls = 0
        self.n_verify_tokens = 0
        self.n_spec_commit_tokens = 0   # tokens actually appended by spec
                                        # steps (eos/budget truncation can
                                        # commit fewer than accepted+1)
        self.decode_step_s: list[float] = []
        self.spec_step_s: list[float] = []
        # full step() wall + prompt tokens prefilled + decoders already
        # mid-generation at step start: the admission-stall telemetry
        # (serve_bench's soak reports the p95 of step latency among steps
        # whose prefill work ran while OTHER requests were decoding —
        # prefill with an idle decode batch stalls nobody)
        self.step_s: list[float] = []
        self.step_prefill_tokens: list[int] = []
        self.step_decode_slots: list[int] = []
        self._t_start: Optional[float] = None

    def load_kv_scales(self, kv_scales: dict) -> None:
        """Hot-swap a freshly loaded calibration recipe's static KV scales
        into a DYNAMIC int8 cache without draining slots (ROADMAP item):
        in-flight codes are requantized under the new constants once, and
        every subsequent write skips both the min/max reduce and the
        per-entry scale scatter. No-op for requests already finished; new
        admissions quantize with the recipe constants from the start."""
        from .kvcache import hotswap_static_scales
        self.cache = jax.jit(hotswap_static_scales)(self.cache, {
            k: jnp.asarray(v, jnp.float32) for k, v in kv_scales.items()})
        # self._decode retraces automatically: the cache's static flag is
        # pytree metadata, so the jit cache keys on it

    # ------------------------------------------------------------ intake --
    def submit(self, prompt, max_new_tokens: Optional[int] = None) -> int:
        """Enqueue a request; returns its uid. Non-blocking — work happens
        in step()/drain(). An explicit max_new_tokens=0 means "no tokens"
        (the request completes at admission with empty output)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) > self.ecfg.max_len:
            raise ValueError(
                f"prompt length {len(prompt)} > max_len {self.ecfg.max_len}")
        budget = (self.ecfg.max_new_tokens if max_new_tokens is None
                  else max_new_tokens)
        if len(prompt) + budget > self.ecfg.max_len:
            budget = max(1, self.ecfg.max_len - len(prompt))
        req = EngineRequest(uid=self._uid, prompt=prompt,
                            max_new_tokens=budget)
        self._uid += 1
        self.sched.submit(req)
        return req.uid

    # ---------------------------------------------------------- sampling --
    def _sample(self, logits):
        """logits (..., V) → token ids."""
        if self.ecfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.rng, k = jax.random.split(self.rng)
        return jax.random.categorical(k, logits / self.ecfg.temperature)

    # ----------------------------------------------------------- serving --
    def _bucket(self, n: int) -> int:
        return bucket_len(n, self.ecfg.prefill_bucket, self.ecfg.max_len)

    def _retire(self, slot: int, reason: str = "eos"):
        """Free the slot everywhere: scheduler, cache row (kv_pos → -1),
        and host-side position/token state, so idle slots genuinely ride
        along at pos 0. A speculative engine clears the draft's mirror
        row too. ``reason`` ∈ obs.schema.RETIRE_REASONS."""
        self.sched.retire(slot, reason=reason)
        self.cache = self._clear(self.cache, jnp.int32(slot))
        if self._spec is not None:
            self._spec.clear(slot)
        self._pos[slot] = 0
        self._last_tok[slot] = 0

    def _start_decoding(self, slot: int, req: EngineRequest, logits_row,
                        S: int):
        """Shared admission tail: sample the FIRST generated token from the
        prompt's final logits row and move the slot into decode (or retire
        it on eos / exhausted budget)."""
        first = int(self._sample(logits_row))
        req.t_first_token = self.clock()
        if self.tracer:
            self.tracer.event("first_token", uid=req.uid, slot=slot)
        if first == self.ecfg.eos_id:                 # eos is never emitted
            self._retire(slot, "eos")
            return
        req.out.append(first)
        if self._mx:
            self._mx["tokens"].inc()
        self._last_tok[slot] = first
        self._pos[slot] = S
        if len(req.out) >= req.max_new_tokens:
            self._retire(slot, "budget")
        elif S >= self.ecfg.max_len:
            self._retire(slot, "max_len")

    def _admit_one(self, slot: int, req: EngineRequest) -> int:
        """Legacy ONE-SHOT admission: dense per-request prefill (this is
        the fp (L, S, Hkv, D) materialization) + write_prefill's
        pad/requantize/copy. Returns prompt tokens prefilled."""
        global FP_PREFILL_MATERIALIZATIONS
        if req.max_new_tokens <= 0:                   # explicit 0-token ask
            req.t_first_token = req.t_submit
            self.sched.retire(slot, reason="zero_budget")
            return 0
        tr = self.tracer
        t_span = tr.begin() if tr else 0.0
        S = len(req.prompt)
        Sp = self._bucket(S)
        toks = np.zeros((1, Sp), np.int32)
        toks[0, :S] = req.prompt                      # right-pad
        t_d = tr.now() if tr else 0.0
        logits, pcache = self._prefill(self.params, jnp.asarray(toks))
        dispatch_s = (tr.now() - t_d) if tr else 0.0
        self.n_prefills += 1
        FP_PREFILL_MATERIALIZATIONS += 1
        # only [0, S) becomes visible; bucket padding stays masked forever
        self.cache = self._write(self.cache, jnp.int32(slot), pcache,
                                 jnp.int32(S))
        if self._spec is not None:
            # mirror the prompt into the draft cache (its own one-shot
            # dense materialization — count it honestly)
            self._spec.prefill_oneshot(jnp.asarray(toks), slot, S)
            FP_PREFILL_MATERIALIZATIONS += 1
        # _start_decoding's sample blocks on the prefill logits, so the
        # span's tail (dur - dispatch_s) is device wait + first-token work
        self._start_decoding(slot, req, logits[0, S - 1], S)
        if tr:
            tr.span_end("prefill_oneshot", t_span, slot=slot, uid=req.uid,
                        tokens=S, dispatch_s=dispatch_s)
        return S

    # --------------------------------------------------- chunked prefill --
    def _admit_chunked(self, slot: int, req: EngineRequest):
        """Chunked admission: mark the slot mid-prefill; `_prefill_work`
        streams its prompt in over the next step(s)."""
        if req.max_new_tokens <= 0:
            req.t_first_token = req.t_submit
            self.sched.retire(slot, reason="zero_budget")
            return
        self.sched.begin_prefill(slot)
        self._prefill_prog[slot] = 0
        self._pos[slot] = 0                           # parked (see below)
        self._last_tok[slot] = 0

    def _prefill_work(self) -> int:
        """Spend this step's `prefill_chunk`-token budget on mid-prefill
        slots (FCFS). Each dispatched chunk streams through the fused
        kernel: K/V quantized in-kernel, codes written straight into the
        slot rows. A slot whose prompt completes samples its first token
        from the chunk's last logits row and joins the decode batch; a
        slot still mid-prefill stays parked at its next-unwritten position
        (`_pos` = progress), so the decode batch's fixed-shape ride-along
        write lands exactly where the NEXT chunk will overwrite it.

        Chunks are NEVER split to fit leftover budget: a slot's next chunk
        is always min(prefill_chunk, remaining prompt), and if the step's
        remaining budget cannot cover it the work waits for the next step.
        Chunk boundaries are therefore a pure function of (prompt length,
        prefill_chunk) — independent of concurrent load — so a request
        generates the exact same tokens whether it prefilled alone or
        under contention (an int8 cache makes boundary placement visible:
        tokens after a boundary attend the QUANTIZED prefix, so
        load-dependent boundaries would make generations irreproducible).
        Returns prompt tokens processed."""
        budget = self.ecfg.prefill_chunk
        spent = 0
        tr = self.tracer
        for slot in self.sched.prefill_slots():
            req = self.sched.slots[slot]
            S = len(req.prompt)
            done = int(self._prefill_prog[slot])
            n = min(self.ecfg.prefill_chunk, S - done)
            if n > budget:          # whole chunk or nothing (FCFS head
                break               # waits; boundaries stay load-free)
            t_span = tr.begin() if tr else 0.0
            pos_start = done
            Sc = bucket_len(n, self.ecfg.prefill_bucket,
                            self.ecfg.prefill_chunk)
            toks = np.zeros((1, Sc), np.int32)
            toks[0, :n] = req.prompt[done:done + n]   # right-pad the chunk
            t_d = tr.now() if tr else 0.0
            logits, self.cache = self._chunk_prefill(
                self.params, self.cache, jnp.asarray(toks), jnp.int32(slot),
                jnp.int32(done), jnp.int32(n))
            dispatch_s = (tr.now() - t_d) if tr else 0.0
            if self._spec is not None:     # mirror the chunk to the draft
                self._spec.prefill_chunk(jnp.asarray(toks), slot, done, n)
            wait_s = 0.0
            if tr:
                # traced-mode sync: dispatch is async, so without this
                # the chunk's device time would surface as somebody
                # else's wait. A deliberate profiling cost.
                t_w = tr.now()
                jax.block_until_ready(logits)
                wait_s = tr.now() - t_w
            self.n_prefill_chunks += 1
            if self._mx:
                self._mx["prefill_chunks"].inc()
            budget -= n
            spent += n
            done += n
            self._prefill_prog[slot] = done
            self._pos[slot] = done                    # parked position
            if done >= S:                             # prompt complete
                self.sched.finish_prefill(slot)
                self._start_decoding(slot, req, logits[0], S)
            if tr:
                tr.span_end("prefill_chunk", t_span, slot=slot,
                            uid=req.uid, pos_start=pos_start, n=n,
                            dispatch_s=dispatch_s, wait_s=wait_s)
        return spent

    # ------------------------------------------- speculative decoding --
    def _spec_step(self, active: list[int]) -> None:
        """One SPECULATIVE decode step (DESIGN.md §9): the low-bit draft
        proposes up to `spec_k` greedy tokens per active slot in batched
        decode steps over its own cache, then the target scores each
        slot's whole window in ONE fused verify pass and commits the
        longest matching draft prefix plus its own correction token —
        between 1 and spec_k+1 tokens per slot per step, always exactly
        the tokens plain greedy decoding would have produced.

        Windows are per-slot (`w = min(spec_k+1, cache headroom,
        remaining budget)`), so budget-capped slots degrade to w=1 —
        an ordinary decode step expressed through the verify path — and
        spec/non-spec slots mix freely in one step. Verify writes the
        window's K/V codes in-kernel; rejected rows are undone by
        `rollback_slot` on both caches (kv_pos → -1 is the whole
        rollback), leaving slot bytes bit-identical to a never-speculated
        engine once overwritten."""
        k = self.ecfg.spec_k
        Sq = k + 1
        N = self.ecfg.n_slots
        pos0 = self._pos.copy()
        commit0 = self.n_spec_commit_tokens
        t0 = self.clock()
        # per-slot window lengths: 0 parks the slot through the draft
        # pass (idle / mid-prefill), w >= 1 for decoding slots
        w = np.zeros(N, np.int64)
        for s in active:
            req = self.sched.slots[s]
            rem = req.max_new_tokens - len(req.out)
            w[s] = max(1, min(Sq, self.ecfg.max_len - int(pos0[s]), rem))
        drafts = self._spec.draft(self._last_tok, pos0, w)     # (k, N)
        from .spec import accept_length
        tr = self.tracer
        for s in active:
            req = self.sched.slots[s]
            ws = int(w[s])
            t_span = tr.begin() if tr else 0.0
            toks = np.zeros((1, Sq), np.int32)
            toks[0, 0] = self._last_tok[s]
            toks[0, 1:ws] = drafts[:ws - 1, s]
            t_d = tr.now() if tr else 0.0
            garg, self.cache = self._verify(
                self.params, self.cache, jnp.asarray(toks), jnp.int32(s),
                jnp.int32(pos0[s]), jnp.int32(ws))
            t_w = tr.now() if tr else 0.0
            garg = np.asarray(garg)            # (Sq,) target argmax rows
                                               # — the device wait
            wait_s = (tr.now() - t_w) if tr else 0.0
            self.n_verify_calls += 1
            self.n_verify_tokens += ws
            a = accept_length(drafts[:, s], garg, ws)
            self.sched.note_spec(s, proposed=ws - 1, accepted=a)
            if tr:
                tr.span_end("verify", t_span, slot=s, uid=req.uid,
                            tokens=ws, accepted=a,
                            dispatch_s=t_w - t_d, wait_s=wait_s)
            new_pos = int(pos0[s]) + a + 1
            if a + 1 < ws:                     # rejected rows to undo
                t_rb = tr.begin() if tr else 0.0
                self.cache = _ROLLBACK(self.cache, jnp.int32(s),
                                       jnp.int32(new_pos))
                self._spec.rollback(s, new_pos)
                if tr:
                    tr.span_end("rollback", t_rb, slot=s, uid=req.uid,
                                accept_len=new_pos)
                    tr.event("rollback", uid=req.uid, slot=s,
                             accept_len=new_pos,
                             rejected=ws - (a + 1))
            # commit g_1..g_{a+1} with the same eos/budget/max_len
            # semantics as sequential decode steps
            t_c = tr.begin() if tr else 0.0
            for t in (int(x) for x in garg[:a + 1]):
                if t == self.ecfg.eos_id:      # eos is never emitted
                    self._retire(s, "eos")
                    break
                req.out.append(t)
                self.n_spec_commit_tokens += 1
                self._last_tok[s] = t
                self._pos[s] += 1
                if len(req.out) >= req.max_new_tokens:
                    self._retire(s, "budget")
                    break
                if self._pos[s] >= self.ecfg.max_len:
                    self._retire(s, "max_len")
                    break
            if tr:
                tr.span_end("accept_commit", t_c, slot=s, uid=req.uid,
                            committed=a + 1)
        self.n_spec_steps += 1
        self.spec_step_s.append(self.clock() - t0)
        self.sched.note_step(len(active))
        if self._mx:
            self._mx["spec_steps"].inc()
            self._mx["tokens"].inc(self.n_spec_commit_tokens - commit0)
            if self.sched.accept_ewma is not None:
                self._mx["accept_ewma"].set(self.sched.accept_ewma)

    def step(self) -> list[EngineRequest]:
        """Admit + (chunk-budgeted) prefill + one batched decode step.
        Returns requests finishing now."""
        if self._t_start is None:
            self._t_start = self.clock()
        t_step0 = self.clock()
        n_done_before = len(self.sched.finished)
        # decoders that were ALREADY mid-generation when this step's
        # prefill work ran — the requests a prefill stall actually delays
        # (a slot admitted and first-decoded in the same step was not
        # waiting on anything; counting it would inflate the one-shot
        # stall baseline with the idle-engine admission burst)
        n_decoding_before = len(self.sched.active_slots())
        prefill_tokens = 0
        for slot, req in self.sched.admit():
            if self.ecfg.prefill_chunk:
                self._admit_chunked(slot, req)
            else:
                prefill_tokens += self._admit_one(slot, req)
        if self.ecfg.prefill_chunk:
            prefill_tokens = self._prefill_work()
            # nobody is decoding ⇒ nobody can be stalled: keep spending
            # whole-chunk budgets until a slot finishes its prompt and
            # joins the decode batch (the chunk budget only throttles
            # prefill that would delay CONCURRENT decode steps; a
            # decode-idle engine prefills at one-shot speed)
            while not self.sched.active_slots() and \
                    self.sched.prefill_slots():
                prefill_tokens += self._prefill_work()
        active = self.sched.active_slots()
        if active and self._spec is not None:
            # speculative step: draft k tokens batched over the draft
            # cache, verify each slot's window in one fused pass, commit
            # 1..spec_k+1 tokens per slot (token-identical to the plain
            # decode branch below)
            self._spec_step(active)
        elif active:
            # idle slots ride along at pos 0 with token 0 (fixed decode
            # shape == jit cache of exactly one entry); _retire cleared
            # their kv_pos rows, so each idle step re-marks only its own
            # t=0 entry, and the next admit rewrites the row wholesale.
            # Mid-prefill slots ride along the same way, parked at their
            # next-unwritten position: the garbage row the ride-along
            # write marks valid is overwritten by the slot's next chunk,
            # and the chunk kernel masks cache rows at >= pos_start, so
            # it can never be attended (per-slot attention shields every
            # other request)
            tr = self.tracer
            # the decode SPAN opens before staging: the two host->device
            # puts below are real per-step decode cost (on small models
            # they rival the matmuls) and must attribute to the phase,
            # not leak into the step span's uncovered remainder. The
            # tracked decode_step_s metric keeps its historical bracket
            # (post-staging t0) so its trend stays comparable across PRs.
            t_span = tr.begin() if tr else 0.0
            tokens = jnp.asarray(self._last_tok[:, None])
            pos = jnp.asarray(self._pos)
            t0 = self.clock()
            if self._greedy:
                toks, self.cache = self._decode(self.params, self.cache,
                                                tokens, pos)
                t_w = tr.now() if tr else 0.0
                toks = np.asarray(toks)
            else:
                logits, self.cache = self._decode(self.params, self.cache,
                                                  tokens, pos)
                t_w = tr.now() if tr else 0.0
                toks = np.asarray(self._sample(logits[:, -1]))
            self.n_decode_steps += 1
            # toks is on host here, so this brackets the real per-step
            # decode latency (dispatch + device compute + sample)
            dt = self.clock() - t0
            self.decode_step_s.append(dt)
            if self._mx:
                self._mx["decode_steps"].inc()
                self._mx["decode_s"].observe(dt)
            if tr:
                tr.span_end("decode", t_span, slots=len(active),
                            dispatch_s=t_w - t0, wait_s=tr.now() - t_w)
            t_c = tr.begin() if tr else 0.0
            emitted = 0
            for slot in active:
                req = self.sched.slots[slot]
                t = int(toks[slot])
                self._pos[slot] += 1
                if t == self.ecfg.eos_id:
                    self._retire(slot, "eos")
                    continue
                req.out.append(t)
                emitted += 1
                self._last_tok[slot] = t
                if len(req.out) >= req.max_new_tokens:
                    self._retire(slot, "budget")
                elif self._pos[slot] >= self.ecfg.max_len:
                    self._retire(slot, "max_len")
            self.sched.note_step(len(active))
            if self._mx:
                self._mx["tokens"].inc(emitted)
            if tr:
                tr.span_end("accept_commit", t_c, slots=len(active))
        tr = self.tracer
        if tr and self.ecfg.trace_kv_every and self.cache.mode == "int8" \
                and len(self.step_s) % self.ecfg.trace_kv_every == 0:
            # periodic KV quantization-quality sample: a host transfer of
            # live cache rows — traced-mode-only cost, span-attributed
            from .kvcache import kv_quality_counters
            t_q = tr.begin()
            tr.counter("kv_quality", kv_quality_counters(self.cache))
            tr.span_end("kv_sample", t_q)
        self.step_s.append(self.clock() - t_step0)
        self.step_prefill_tokens.append(prefill_tokens)
        self.step_decode_slots.append(n_decoding_before)
        mx = self._mx
        if mx:
            # end-of-step queueing gauges: O(n_slots) host bookkeeping,
            # no device traffic — the always-on cost the ≤1% overhead
            # bound covers
            mx["steps"].inc()
            mx["step_s"].observe(self.step_s[-1])
            if prefill_tokens:
                mx["prefill_tokens"].inc(prefill_tokens)
            occupied = in_flight = 0
            for r in self.sched.slots:
                if r is not None:
                    occupied += 1
                    in_flight += max(0, r.max_new_tokens - len(r.out))
            backlog = 0
            if self.ecfg.prefill_chunk:
                for s in self.sched.prefill_slots():
                    rem = len(self.sched.slots[s].prompt) \
                        - int(self._prefill_prog[s])
                    backlog += -(-rem // self.ecfg.prefill_chunk)
            mx["occupancy"].set(occupied / self.ecfg.n_slots)
            mx["decoding"].set(len(self.sched.active_slots()))
            mx["backlog"].set(backlog)
            mx["in_flight"].set(in_flight)
            if self.ecfg.metrics_kv_every and self.cache.mode == "int8" \
                    and len(self.step_s) % self.ecfg.metrics_kv_every == 0:
                # periodic KV quality gauges: bounded host transfer of
                # live cache rows (kvcache.kv_quality_counters) — the
                # one metrics signal that is NOT free, which is why it
                # has its own period and defaults off
                from .kvcache import kv_quality_counters
                kc = kv_quality_counters(self.cache)
                for side in ("k", "v"):
                    if kc.get(f"{side}_clip_frac") is not None:
                        mx[f"kv_{side}_clip"].set(kc[f"{side}_clip_frac"])
                        mx[f"kv_{side}_occ"].set(kc[f"{side}_occupancy"])
        if tr:
            tr.span_end("step", t_step0,
                        prefill_tokens=prefill_tokens,
                        decode_slots=n_decoding_before)
        return self.sched.finished[n_done_before:]

    def drain(self) -> list[EngineRequest]:
        """Run until queue and slots are empty; returns all finished
        requests in uid order."""
        while not self.sched.idle:
            self.step()
        return sorted(self.sched.finished, key=lambda r: r.uid)

    # ----------------------------------------------------------- metrics --
    def metrics(self) -> dict:
        from repro.obs import mean, pct as p, phase_breakdown
        fin = self.sched.finished
        ttfts = [r.ttft for r in fin if r.ttft is not None]
        tps = [r.tokens_per_s for r in fin if r.tokens_per_s is not None]
        total_tokens = sum(len(r.out) for r in fin)
        wall = (self.clock() - self._t_start) if self._t_start else 0.0
        steps = np.asarray(self.decode_step_s, np.float64)
        full = np.asarray(self.step_s, np.float64)
        pmask = (np.asarray(self.step_prefill_tokens, np.int64) > 0) \
            & (np.asarray(self.step_decode_slots, np.int64) > 0)
        withp = full[pmask[:full.size]] if full.size else full
        spec = {}
        if self.ecfg.spec_k:
            hist = np.bincount(np.asarray(self.sched.accept_hist,
                                          np.int64),
                               minlength=self.ecfg.spec_k + 1) \
                if self.sched.accept_hist else np.zeros(0, np.int64)
            sstep = np.asarray(self.spec_step_s, np.float64)
            spec = {
                "spec_k": self.ecfg.spec_k,
                "spec_steps": self.n_spec_steps,
                "verify_calls": self.n_verify_calls,
                "verify_tokens": self.n_verify_tokens,
                "draft_steps": (self._spec.n_draft_steps
                                if self._spec else 0),
                "draft_proposed": self.sched.spec_proposed,
                "draft_accepted": self.sched.spec_accepted,
                "acceptance_rate": self.sched.acceptance_rate(),
                # accept_hist[a] = verify calls that accepted exactly a
                # draft tokens (a in [0, spec_k])
                "accept_hist": hist.tolist(),
                # tokens actually COMMITTED per verify (eos/budget can
                # truncate below accepted+1, so this is computed from
                # appended tokens, not from the accept histogram)
                "tokens_per_verify_mean": (
                    self.n_spec_commit_tokens / self.n_verify_calls
                    if self.n_verify_calls else None),
                "spec_step_p50_s": p(sstep, 50),
                "spec_step_p95_s": p(sstep, 95),
                "spec_by_slot": [list(x) for x in self.sched.spec_by_slot],
                # live acceptance gauge: EWMA over per-verify fractions —
                # tracks recent drift the cumulative rate smooths away
                "acceptance_ewma": self.sched.accept_ewma,
            }
        out = {
            "n_finished": len(fin),
            "total_tokens": total_tokens,
            "wall_s": wall,
            "tokens_per_s": total_tokens / wall if wall > 0 else None,
            "decode_steps": self.n_decode_steps,
            "prefills": self.n_prefills,
            "prefill_chunks": self.n_prefill_chunks,
            "prefill_chunk": self.ecfg.prefill_chunk,
            "slot_utilization": self.sched.utilization(),
            "queue_depth_max": max(self.sched.queue_depth_hist, default=0),
            # always-on queueing signals (scheduler records these at
            # submit/admit time with or without a tracer — obs.summary
            # keeps the None-on-empty convention)
            "queue_depth_at_submit_p50": p(self.sched.queue_depth_submit,
                                           50),
            "queue_depth_at_submit_p95": p(self.sched.queue_depth_submit,
                                           95),
            "admit_latency_mean_s": mean(self.sched.admit_latency_s),
            "admit_latency_p50_s": p(self.sched.admit_latency_s, 50),
            "admit_latency_p95_s": p(self.sched.admit_latency_s, 95),
            "ttft_mean_s": mean(ttfts),
            "ttft_p50_s": p(ttfts, 50),
            "ttft_p95_s": p(ttfts, 95),
            "request_tokens_per_s_mean": mean(tps),
            "decode_step_p50_s": p(steps, 50),
            "decode_step_p95_s": p(steps, 95),
            "decode_step_mean_s": mean(steps),
            # full-step latency: the admission-stall telemetry — a step
            # that prefilled a whole prompt one-shot blocks every decoding
            # slot for that long; chunked prefill bounds it by the budget
            "step_p50_s": p(full, 50),
            "step_p95_s": p(full, 95),
            "step_with_prefill_p95_s": p(withp, 95),
            "steps_with_prefill": int(pmask.sum()),
            "fused_attn": self.ecfg.fused_attn,
            "kv_mode": self.cache.mode,
            "kv_static_scales": self.cache.static,
            "kv_bytes_per_token": self.cache.bytes_per_token(),
            **spec,
        }
        if self.registry is not None:
            # the always-on registry snapshot rides along so one
            # metrics() call is the full observability surface (the
            # same dict SnapshotWriter streams and to_prometheus
            # renders)
            out["registry"] = self.registry.snapshot()
        if self.tracer:
            # traced engines embed the phase-attribution summary so every
            # metrics consumer (serve.py --metrics-json, the benchmarks)
            # gets the step-time breakdown without reparsing the trace
            out["phase_attribution"] = phase_breakdown(self.tracer.events)
            out["trace_records"] = len(self.tracer.events)
            out["trace_dropped"] = self.tracer.dropped
        return out
