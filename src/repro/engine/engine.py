"""Continuous-batching inference engine.

`Engine` owns the three serving pieces: a `Scheduler` (FCFS queue + slot
pool), a `SlotKVCache` (preallocated, optionally INT8), and the jitted
model entry points. The serving loop is token-level:

    eng = Engine(cfg, params, EngineConfig(n_slots=4))
    eng.submit(prompt_a); eng.submit(prompt_b)
    finished = eng.drain()

Each `step()` (1) admits queued requests into free slots; (2) prefills —
either ONE-SHOT (`prefill_chunk=0`: a per-request dense prefill whose fp
cache `write_prefill` re-quantizes into the slot, batch 1, right-padded
to a length bucket so jit recompiles are bounded) or CHUNKED
(`prefill_chunk>0`: at most that many prompt tokens per step stream
through `transformer.prefill_chunk_slots`, whose fused kernel quantizes
K/V in-kernel and writes codes straight into the slot cache — no fp
prefill cache exists and a long prompt no longer stalls decoding, see
DESIGN.md §6); (3) runs ONE batched decode step over all decoding slots
at their own positions; (4) retires finished slots so the next step can
refill them. A long generation therefore occupies exactly one slot
instead of stalling a whole wave, and with chunked prefill a long PROMPT
occupies at most `prefill_chunk` tokens of any step.

Mid-prefill slots are invisible to decode (`Scheduler.active_slots`
excludes them) but still ride along in the fixed-shape decode batch,
parked at their next-unwritten position: the parked step writes garbage
K/V at exactly the row the slot's NEXT prefill chunk overwrites (and the
chunk kernel masks cache rows at >= pos_start), so the parked write can
never leak into any attention result.

With ``spec_k > 0`` the decode step is SPECULATIVE (`engine/spec.py`,
DESIGN.md §9): a low-bit draft model proposes up to k greedy tokens per
slot over its own slot cache, the target verifies each slot's window in
one fused prefill-kernel pass, and 1..k+1 tokens commit per slot per
step — token-identical to plain greedy decoding by the lossless accept
rule.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_model

from .faults import DegradationLadder, FaultInjector, StepFailure
from .kvcache import clear_slot, init_slot_cache, rollback_slot, \
    write_prefill
from .scheduler import EngineRequest, Scheduler, SubmitError

ENGINE_FAMILIES = ("dense", "moe", "vlm")

#: Materialization-counter hook: incremented once per LEGACY one-shot
#: prefill dispatch — each one materializes a dense full-precision
#: (L, S, Hkv, D) cache that `write_prefill` then pads, re-quantizes and
#: copies into the slot cache. The fused chunked-prefill path must never
#: bump it (asserted in tests/test_prefill_attention.py).
FP_PREFILL_MATERIALIZATIONS = 0


def bucket_len(n: int, bucket: int, max_len: int) -> int:
    """Round a prompt length up to its prefill bucket (bounded jit
    recompiles). Single definition — the serve benchmark warms exactly
    these shapes, so it must agree with the engine byte-for-byte."""
    return min(max_len, -(-n // bucket) * bucket)


@functools.lru_cache(maxsize=None)
def _jitted_prefill(cfg):
    """Prefill depends only on the arch — shared across fused/sampling
    variants so an engine flag flip never recompiles prefill buckets."""
    model = get_model(cfg)
    return jax.jit(lambda p, toks: model.prefill(p, cfg, {"tokens": toks}))


@functools.lru_cache(maxsize=None)
def _jitted_entry_points(cfg, fused: bool, greedy: bool):
    """Process-wide jitted (decode, prefill) per (arch config, fused flag,
    sampling mode).

    Jitting per Engine INSTANCE (the old scheme) meant every restart — and
    every benchmark repetition — recompiled the decode step and each
    prefill bucket from scratch; sharing the wrappers here makes engine
    spin-up O(cache lookup) after the first instance and lets benchmarks
    measure steady state instead of XLA compile time.

    The cache argument is DONATED: the serving loop always replaces its
    cache with the returned one, and donation lets XLA update the slot
    arrays in place instead of copying every (L, N, T, ...) leaf each
    decode step — an O(cache-size) saving per token for both the fused
    and the materializing read path.

    ``greedy`` folds argmax sampling into the decode executable: one
    dispatch and a (N,)-int host transfer per step instead of a separate
    argmax jit call plus the full logits pull."""
    from repro.models import transformer

    def step(p, c, t, pos):
        logits, cache = transformer.decode_step_slots(p, cfg, c, t, pos,
                                                      fused=fused)
        if greedy:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), \
                cache
        return logits, cache

    decode = jax.jit(step, donate_argnums=(1,))
    return decode, _jitted_prefill(cfg)


@functools.lru_cache(maxsize=None)
def _jitted_chunk_prefill(cfg):
    """Process-wide jitted chunked-prefill entry point. One compile per
    CHUNK BUCKET shape (the (1, Sc) tokens arg); slot / pos_start / length
    are traced scalars, so slots and chunk offsets never recompile. The
    cache is donated — chunk writes update the slot arrays in place."""
    from repro.models import transformer

    def chunk(p, c, toks, slot, pos_start, length):
        return transformer.prefill_chunk_slots(p, cfg, c, toks, slot,
                                               pos_start, length)

    return jax.jit(chunk, donate_argnums=(1,))


# slot/length stay traced: one compile per prefill bucket shape, shared by
# every engine in the process; the old cache is dead after each call, so
# its buffers are donated (in-place row writes)
_WRITE = jax.jit(write_prefill, donate_argnums=(0,))
_CLEAR = jax.jit(clear_slot, donate_argnums=(0,))
_ROLLBACK = jax.jit(rollback_slot, donate_argnums=(0,))


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 8
    max_len: int = 256
    max_new_tokens: int = 32            # default per-request token budget
    temperature: float = 0.0            # 0 ⇒ greedy
    eos_id: int = -1                    # -1 ⇒ never stop early
    kv_mode: str = "fp"                 # "fp" | "int8" (SplitQuant §4.2)
    kv_qchunks: int = 4                 # ranges per head-vector in int8 mode
    kv_dtype: str = "float32"           # fp-mode storage; "bfloat16" on TPU
    prefill_bucket: int = 16            # prompt lengths round up to a multiple
    fused_attn: bool = True             # decode reads via the fused dequant-
                                        # in-kernel attention (no full-
                                        # precision cache copy). False =
                                        # legacy materialize-then-attend,
                                        # kept as the cross-checked oracle
    prefill_chunk: int = 96             # chunked fused prefill — admit at
                                        # most this many prompt tokens per
                                        # step, quantize-in-kernel slot
                                        # writes, decode keeps running while
                                        # long prompts stream in. Default ON
                                        # (~4x prefill_bucket, the serve-
                                        # bench soak sweet spot) now that
                                        # soak + verify coverage has
                                        # accumulated; prefill_chunk=0 is
                                        # the legacy one-shot opt-out
                                        # (serve_bench pins it for its
                                        # stall baseline)
    spec_k: int = 0                     # >0: self-speculative decoding — a
                                        # low-bit draft proposes up to k
                                        # greedy tokens per slot per step,
                                        # the target verifies the window in
                                        # ONE fused pass (engine/spec.py,
                                        # DESIGN.md §9). Output is token-
                                        # identical to spec_k=0 greedy.
                                        # Requires temperature <= 0
    draft_recipe: Optional[str] = None  # QuantRecipe dir the draft weights
                                        # are minted from (spec_k > 0);
                                        # None = draft with the target's
                                        # own weights (acceptance ~1, no
                                        # draft cost win — mostly a test
                                        # and bring-up configuration)
    draft_dequantize: bool = True       # expand the draft's packed low-
                                        # bit weights to the compute dtype
                                        # ONCE at engine start: the low-
                                        # bit recipe buys draft
                                        # faithfulness + storage, and a
                                        # packed draft would otherwise pay
                                        # a full dequant per draft step on
                                        # backends without the fused
                                        # dequant-matmul. False keeps the
                                        # draft packed (memory-bound
                                        # deployments with the kernel)
    metrics: bool = True                # always-ON metrics registry
                                        # (repro.obs.metrics, DESIGN.md
                                        # §11): monotonic counters /
                                        # gauges / fixed-bucket
                                        # histograms over the queueing
                                        # signals (queue depth, admit
                                        # latency, slot occupancy,
                                        # prefill backlog, tokens in
                                        # flight, spec-acceptance EWMA).
                                        # Unlike trace, this is bounded-
                                        # memory and cheap enough to
                                        # never turn off — overhead is
                                        # asserted within the serve-
                                        # bench noise floor (≤1%).
                                        # False exists for that
                                        # overhead measurement
    metrics_kv_every: int = 0           # >0: sample KV clip-fraction /
                                        # occupancy gauges from live
                                        # int8 cache rows every N steps
                                        # (kvcache.kv_quality_counters —
                                        # a bounded host transfer, so
                                        # NOT free; keep the period
                                        # coarse in production)
    trace: bool = False                 # default-OFF observability
                                        # (repro.obs, DESIGN.md §10):
                                        # lifecycle events + per-step
                                        # phase spans with dispatch-vs-
                                        # device-wait attribution. Traced
                                        # mode inserts block_until_ready
                                        # sync points to attribute async
                                        # dispatch — it is a PROFILING
                                        # mode, not free; disabled, every
                                        # site pays one branch
    trace_capacity: int = 1 << 16       # tracer ring-buffer records;
                                        # oldest drop first on overflow
    trace_kv_every: int = 0             # >0: sample KV quantization-
                                        # quality counters (clip fraction,
                                        # code occupancy, outlier-chunk
                                        # histogram) every N steps — a
                                        # host transfer of live cache
                                        # rows, traced-mode cost only
    # --- fault tolerance (DESIGN.md §12) -------------------------------
    max_queue: int = 0                  # >0: bounded submit queue; an
                                        # arrival into a full queue
                                        # triggers overload_policy. 0 =
                                        # unbounded (historical behavior).
                                        # The production set point comes
                                        # from the measured saturation
                                        # knee (scheduler.
                                        # admission_set_point)
    overload_policy: str = "reject-new" # full-queue victim choice:
                                        # "reject-new" | "shed-oldest" |
                                        # "shed-by-class" (oldest queued
                                        # batch-class request first)
    degrade: bool = False               # graceful-degradation ladder:
                                        # under sustained backlog disable
                                        # speculation (rung 1, output-
                                        # identical), defer batch-class
                                        # admissions (rung 2), shed
                                        # queued load (rung 3); each rung
                                        # change is a metrics event
    degrade_thresholds: tuple = ()      # 3 ascending pressure bounds
                                        # (queue depth + prefill backlog
                                        # chunks) for rungs 1..3; () →
                                        # (N, 2N, 4N) slots-scaled default
    degrade_patience: int = 2           # consecutive steps a threshold
                                        # crossing must persist before
                                        # the rung moves (hysteresis;
                                        # descent takes 2x)
    max_retries: int = 2                # per-slot consecutive-failure
                                        # budget for step retry; one more
                                        # failure quarantines the slot's
                                        # request as "failed"
    retry_backoff_s: float = 0.0005     # base for the bounded exponential
                                        # backoff between retry attempts
                                        # (doubles per attempt, capped)
    fault_spec: Optional[object] = None # faults.FaultSpec: seeded
                                        # synthetic fault injection (chaos
                                        # testing). None = no injection;
                                        # the retry/quarantine machinery
                                        # is always on regardless
    # --- crash safety (engine/recovery.py, DESIGN.md §13) --------------
    journal_path: Optional[str] = None  # append-only JSONL WAL of request
                                        # lifecycle transitions, fsync'd
                                        # once per step — the replay
                                        # source for crash recovery.
                                        # None = no journal
    journal_resume: bool = False        # append to an existing journal
                                        # (recovery/supervisor restart)
                                        # instead of starting a fresh one
    snapshot_path: Optional[str] = None # directory Engine.snapshot()
                                        # writes (atomic tmp + rename);
                                        # with snapshot_every, the engine
                                        # auto-snapshots here
    snapshot_every: int = 0             # >0: snapshot every N steps at
                                        # the end-of-step boundary (after
                                        # the journal fsync, so snapshot
                                        # state ⊆ journal horizon)
    # --- flight recorder + incident capture (obs/flight.py, §14) --------
    flight: bool = True                 # always-on bounded ring of coarse
                                        # per-step records (the black
                                        # box); overhead gated <= max(1%,
                                        # noise) by serve_bench like the
                                        # metrics registry
    flight_capacity: int = 512          # ring size in steps
    incident_dir: Optional[str] = None  # arm the anomaly-detector sweep
                                        # and write incident bundles
                                        # under this directory (atomic
                                        # tmp+fsync+rename). None = sweep
                                        # off, recorder still on
    incident_cooldown: int = 50         # steps: per-detector refire
                                        # cooldown AND global min gap
                                        # between bundles — a fault storm
                                        # produces one bundle, not one
                                        # per step


class Engine:
    """submit()/step()/drain() continuous-batching server.

    ``kv_scales``: optional static KV quantization constants from an
    offline calibration recipe (``repro.calib``) — dict of
    ``k_scale/k_zero/v_scale/v_zero`` (L, Hkv, C) arrays. Requires
    ``kv_mode="int8"``; decode writes then skip the per-step min/max
    reduce and scale storage amortizes to ~0 bytes/token (DESIGN.md §7).

    ``draft_params``: optional pre-built draft weight tree for
    ``spec_k > 0`` (same architecture as ``params`` — typically the
    low-bit quantized copy). Overrides ``ecfg.draft_recipe``; when both
    are absent the target drafts for itself (acceptance ~1, no draft
    cost win — a bring-up configuration).
    """

    def __init__(self, cfg, params, ecfg: EngineConfig,
                 rng: Optional[jax.Array] = None,
                 clock=time.perf_counter,
                 kv_scales: Optional[dict] = None,
                 draft_params=None, tracer=None, registry=None):
        if cfg.family not in ENGINE_FAMILIES:
            raise NotImplementedError(
                f"engine serves transformer families {ENGINE_FAMILIES}, "
                f"got {cfg.family!r} (recurrent-state continuous batching "
                f"is a separate cache layout"
                + (" — and spec_k > 0 additionally needs positional KV "
                   "rollback, which recurrent state cannot provide)"
                   if ecfg.spec_k else ")"))
        if cfg.window is not None and cfg.window < ecfg.max_len:
            raise NotImplementedError(
                "windowed (ring) slot caches not wired up yet; "
                f"window={cfg.window} < max_len={ecfg.max_len}")
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.model = get_model(cfg)
        self.clock = clock
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        from repro.models.common import dtype_of
        # --- observability (repro.obs, DESIGN.md §10) -------------------
        # an explicit tracer wins; else ecfg.trace mints one on the
        # engine's own clock (trace time and metrics share one axis).
        # Falsy tracers normalize to None so every hot-path site guards
        # with a single `if tr:` branch — the whole disabled-mode cost.
        if tracer is None and ecfg.trace:
            from repro.obs import Tracer
            tracer = Tracer(capacity=ecfg.trace_capacity, clock=clock,
                            meta={"arch": cfg.name, "n_slots": ecfg.n_slots,
                                  "spec_k": ecfg.spec_k,
                                  "kv_mode": ecfg.kv_mode,
                                  "prefill_chunk": ecfg.prefill_chunk})
        self.tracer = tracer if tracer else None
        # --- always-on metrics registry (obs.metrics, DESIGN.md §11) ----
        # an explicit registry wins (shared across engines / exported by
        # a server); else ecfg.metrics mints a private one. Instruments
        # resolve ONCE here so the hot path is attribute ops behind a
        # single `if mx:` branch; ecfg.metrics=False leaves mx None —
        # the configuration the overhead assertion measures against.
        self.registry = None
        self._mx = None
        if registry is not None or ecfg.metrics:
            from repro.obs.metrics import MetricsRegistry, RESTORE_BUCKETS_S
            self.registry = registry if registry is not None \
                else MetricsRegistry()
            r = self.registry
            self._mx = {
                "steps": r.counter("engine_steps", "Engine.step() calls"),
                "decode_steps": r.counter(
                    "engine_decode_steps", "batched plain-decode steps"),
                "spec_steps": r.counter(
                    "engine_spec_steps", "speculative decode steps"),
                "tokens": r.counter(
                    "engine_tokens_generated", "committed output tokens"),
                "prefill_tokens": r.counter(
                    "engine_prefill_tokens", "prompt tokens prefilled"),
                "prefill_chunks": r.counter(
                    "engine_prefill_chunks", "fused prefill chunks run"),
                "step_s": r.histogram(
                    "engine_step_seconds", "full Engine.step() wall"),
                "decode_s": r.histogram(
                    "engine_decode_step_seconds",
                    "batched decode dispatch + device + sample"),
                "occupancy": r.gauge(
                    "engine_slot_occupancy",
                    "occupied slots (decoding + mid-prefill) / n_slots"),
                "decoding": r.gauge(
                    "engine_slots_decoding", "slots in the decode batch"),
                "backlog": r.gauge(
                    "engine_prefill_backlog_chunks",
                    "prompt chunks still to stream for mid-prefill slots"),
                "in_flight": r.gauge(
                    "engine_tokens_in_flight",
                    "unexhausted generation budget across occupied slots"),
                "deadline": r.counter(
                    "engine_deadline_exceeded",
                    "requests retired by the step-boundary deadline "
                    "sweep (TTFT or total-wall)"),
                "retries": r.counter(
                    "engine_step_retries",
                    "decode step re-executions after rollback (injected "
                    "or detected failures)"),
                "rung": r.gauge(
                    "engine_degradation_rung",
                    "current degradation-ladder rung (0 normal, 1 spec "
                    "off, 2 defer batch, 3 shed)"),
                "degr_transitions": r.counter(
                    "engine_degradation_transitions",
                    "degradation-ladder rung changes"),
                # crash safety (engine/recovery.py, DESIGN.md §13) —
                # registered unconditionally so a box that never crashes
                # still exports the zeros an alert can sit on
                "snapshots": r.counter(
                    "engine_snapshots",
                    "engine state snapshots written (atomic tmp+rename)"),
                "restores": r.counter(
                    "engine_restore",
                    "engine state restores from a snapshot"),
                "replayed": r.counter(
                    "engine_journal_replayed_requests",
                    "un-retired requests resumed or re-enqueued by "
                    "journal replay after a restore"),
                "restore_s": r.histogram(
                    "engine_restore_duration_s",
                    "snapshot restore + journal replay wall time",
                    buckets=RESTORE_BUCKETS_S),
            }
            # rung 0 is a real state, not "unset" — render it from the
            # start (to_prometheus omits unset gauges)
            self._mx["rung"].set(0)
            if ecfg.spec_k:
                self._mx["accept_ewma"] = r.gauge(
                    "spec_accept_ewma",
                    "EWMA of per-verify draft-token acceptance fraction")
            if ecfg.metrics_kv_every:
                for side in ("k", "v"):
                    self._mx[f"kv_{side}_clip"] = r.gauge(
                        f"kv_{side}_clip_frac",
                        f"sampled {side.upper()}-cache code saturation "
                        f"(static scale drifted narrow when trending up)")
                    self._mx[f"kv_{side}_occ"] = r.gauge(
                        f"kv_{side}_occupancy",
                        f"sampled {side.upper()}-cache code-range use "
                        f"(scale drifted wide when trending down)")
        # --- crash safety (engine/recovery.py, DESIGN.md §13) -----------
        # the journal is a WAL, not a trace: always written when
        # configured, fsync'd once per step boundary in step()
        self.journal = None
        if ecfg.journal_path:
            from .recovery import RequestJournal
            self.journal = RequestJournal(
                ecfg.journal_path, clock=clock,
                meta={"arch": cfg.name, "n_slots": ecfg.n_slots,
                      "kv_mode": ecfg.kv_mode, "spec_k": ecfg.spec_k},
                resume=ecfg.journal_resume)
        self.sched = Scheduler(ecfg.n_slots, clock=clock,
                               tracer=self.tracer, registry=self.registry,
                               max_queue=ecfg.max_queue,
                               overload_policy=ecfg.overload_policy,
                               journal=self.journal)
        # --- fault tolerance (engine/faults.py, DESIGN.md §12) ----------
        self._faults = (FaultInjector(ecfg.fault_spec)
                        if ecfg.fault_spec else None)
        if self._faults is not None and ecfg.spec_k:
            raise NotImplementedError(
                "fault injection targets the plain decode path; the "
                "speculative path's verify/rollback already exercises "
                "mid-step recovery and injecting there would need "
                "draft-cache-aware retry bookkeeping that is not wired "
                "up — run chaos with spec_k=0 (the ladder's rung-1 "
                "configuration)")
        self._ladder = None
        self._rung = 0
        if ecfg.degrade:
            N_ = ecfg.n_slots
            self._ladder = DegradationLadder(
                ecfg.degrade_thresholds or (N_, 2 * N_, 4 * N_),
                patience=ecfg.degrade_patience)
        # --- flight recorder + incident capture (obs/flight.py, §14) ----
        # the recorder is the black box: always on (like the registry)
        # unless explicitly disabled; the detector sweep only runs when
        # an incident_dir is armed, so a plain run pays one ring append
        self._flight = None
        if ecfg.flight:
            from ..obs.flight import FlightRecorder
            self._flight = FlightRecorder(
                capacity=ecfg.flight_capacity, clock=clock,
                meta={"arch": cfg.name, "n_slots": ecfg.n_slots,
                      "kv_mode": ecfg.kv_mode, "spec_k": ecfg.spec_k})
        self._detect = None
        if ecfg.incident_dir:
            from ..obs.detect import AnomalyDetector
            self._detect = AnomalyDetector(
                cooldown_steps=ecfg.incident_cooldown,
                queue_set_point=(ecfg.max_queue or None))
        self.incidents: list = []        # bundle paths written this run
        self._last_bundle_step = None
        # latest sampled KV quality signals (fed by the periodic
        # kv_quality_counters pull; None until the first sample)
        self._last_clip_frac = None
        self._last_span_frac = None
        self.cache = init_slot_cache(
            cfg, ecfg.n_slots, ecfg.max_len, mode=ecfg.kv_mode,
            dtype=dtype_of(ecfg.kv_dtype), qchunks=ecfg.kv_qchunks,
            kv_scales=kv_scales)
        self._greedy = ecfg.temperature <= 0
        self._decode, self._prefill = _jitted_entry_points(
            cfg, ecfg.fused_attn, self._greedy)
        self._chunk_prefill = (_jitted_chunk_prefill(cfg)
                               if ecfg.prefill_chunk else None)
        self._write = _WRITE
        self._clear = _CLEAR
        # --- self-speculative decoding (engine/spec.py, DESIGN.md §9) ---
        self._spec = None
        if ecfg.spec_k:
            if not self._greedy:
                raise NotImplementedError(
                    "spec_k > 0 requires greedy decoding (temperature <= "
                    "0): the lossless accept rule compares argmax tokens; "
                    "temperature sampling needs speculative rejection "
                    "sampling, which is not wired up")
            from . import spec as spec_mod
            if draft_params is None:
                draft_params = (
                    spec_mod.load_draft_params(ecfg.draft_recipe, params,
                                               cfg)
                    if ecfg.draft_recipe else params)
            self._spec = spec_mod.SpecDecoder(cfg, ecfg, draft_params,
                                              tracer=self.tracer,
                                              registry=self.registry)
            self._verify = spec_mod.jitted_verify(cfg)
        # host-side slot state
        N = ecfg.n_slots
        self._last_tok = np.zeros(N, np.int32)
        self._pos = np.zeros(N, np.int32)
        self._prefill_prog = np.zeros(N, np.int64)   # prompt tokens written
        # consecutive corrupt-output attempts per slot (step retry);
        # crossing max_retries quarantines the slot's request as "failed"
        self._fail_streak = np.zeros(N, np.int64)
        self._uid = 0
        self._any_deadlines = False      # skip the per-step sweep until
                                         # a submit carries a deadline
        self.n_step_retries = 0
        self.n_quarantined = 0
        self.n_decode_steps = 0
        self.n_prefills = 0
        self.n_prefill_chunks = 0
        self.n_spec_steps = 0
        self.n_verify_calls = 0
        self.n_verify_tokens = 0
        self.n_spec_commit_tokens = 0   # tokens actually appended by spec
                                        # steps (eos/budget truncation can
                                        # commit fewer than accepted+1)
        self.decode_step_s: list[float] = []
        self.spec_step_s: list[float] = []
        # full step() wall + prompt tokens prefilled + decoders already
        # mid-generation at step start: the admission-stall telemetry
        # (serve_bench's soak reports the p95 of step latency among steps
        # whose prefill work ran while OTHER requests were decoding —
        # prefill with an idle decode batch stalls nobody)
        self.step_s: list[float] = []
        self.step_prefill_tokens: list[int] = []
        self.step_decode_slots: list[int] = []
        self._t_start: Optional[float] = None

    def load_kv_scales(self, kv_scales: dict) -> None:
        """Hot-swap a freshly loaded calibration recipe's static KV scales
        into a DYNAMIC int8 cache without draining slots (ROADMAP item):
        in-flight codes are requantized under the new constants once, and
        every subsequent write skips both the min/max reduce and the
        per-entry scale scatter. No-op for requests already finished; new
        admissions quantize with the recipe constants from the start."""
        from .kvcache import hotswap_static_scales
        self.cache = jax.jit(hotswap_static_scales)(self.cache, {
            k: jnp.asarray(v, jnp.float32) for k, v in kv_scales.items()})
        # self._decode retraces automatically: the cache's static flag is
        # pytree metadata, so the jit cache keys on it

    # ------------------------------------------------------------ intake --
    def submit(self, prompt, max_new_tokens: Optional[int] = None, *,
               cls: Optional[str] = None,
               ttft_deadline_s: Optional[float] = None,
               deadline_s: Optional[float] = None) -> int:
        """Enqueue a request; returns its uid. Non-blocking — work happens
        in step()/drain(). An explicit max_new_tokens=0 means "no tokens"
        (the request completes at admission with empty output).

        Validation happens HERE, not deep inside admission: a malformed
        request raises a structured `SubmitError` (a ValueError) before
        it consumes queue space — empty prompts, negative budgets, and
        prompt+budget combinations that cannot fit ``max_len`` (the old
        behavior silently truncated the budget, which made a request's
        output length depend on a config it never saw). ``cls`` is the
        loadgen request class (admission-policy key); the deadlines are
        wall-clock seconds from submit, enforced at step boundaries.

        Note the bounded queue (ecfg.max_queue) can shed on submit: the
        uid is still returned and the request lands in ``finished`` with
        reason "shed" — same lifecycle, it just never held a slot."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) == 0:
            raise SubmitError("empty_prompt",
                              "empty prompt (no tokens to prefill)")
        budget = (self.ecfg.max_new_tokens if max_new_tokens is None
                  else max_new_tokens)
        if budget < 0:
            raise SubmitError("bad_budget",
                              f"max_new_tokens must be >= 0, got {budget}")
        if len(prompt) + budget > self.ecfg.max_len:
            raise SubmitError(
                "too_long",
                f"prompt ({len(prompt)}) + max_new_tokens ({budget}) "
                f"exceeds max_len {self.ecfg.max_len}")
        req = EngineRequest(uid=self._uid, prompt=prompt,
                            max_new_tokens=budget, cls=cls,
                            ttft_deadline_s=ttft_deadline_s,
                            deadline_s=deadline_s)
        self._uid += 1
        if ttft_deadline_s is not None or deadline_s is not None:
            self._any_deadlines = True
        if self._faults is not None:
            self._faults.note_submit(req.uid)
        self.sched.submit(req)
        return req.uid

    def cancel(self, uid: int) -> bool:
        """Cancel a request mid-flight: queued requests finish
        immediately ("cancelled", never held a slot); slotted requests —
        including MID-CHUNKED-PREFILL ones — retire through the full
        slot-release path, so the cache row, draft-cache twin, and
        prefill bookkeeping all free together. Returns False when the
        uid is unknown or already finished (cancel is idempotent and
        racing a natural finish is not an error)."""
        for req in self.sched.queue:
            if req.uid == uid:
                if self.tracer:
                    self.tracer.event("cancel", uid=uid, slot=-1)
                self.sched.drop_queued(req, "cancelled")
                return True
        for slot, req in enumerate(self.sched.slots):
            if req is not None and req.uid == uid:
                if self.tracer:
                    self.tracer.event("cancel", uid=uid, slot=slot)
                self._retire(slot, "cancelled")
                return True
        return False

    def _deadline_expired(self, req: EngineRequest, now: float) -> bool:
        if req.t_submit is None:
            return False
        waited = now - req.t_submit
        if req.deadline_s is not None and waited > req.deadline_s:
            return True
        return (req.ttft_deadline_s is not None
                and req.t_first_token is None
                and waited > req.ttft_deadline_s)

    def _enforce_deadlines(self) -> None:
        """Step-boundary deadline sweep (DESIGN.md §12): queued requests
        whose TTFT/total-wall deadline already passed retire as
        "deadline_exceeded" without ever consuming a slot, and slotted
        ones (including mid-prefill) free their slot for work that can
        still make its SLO. Step-boundary granularity is deliberate —
        mid-step preemption would tear the batched decode dispatch."""
        now = self.clock()
        for req in [r for r in self.sched.queue
                    if self._deadline_expired(r, now)]:
            self.sched.drop_queued(req, "deadline_exceeded")
            if self._mx:
                self._mx["deadline"].inc()
        for slot, req in enumerate(self.sched.slots):
            if req is not None and self._deadline_expired(req, now):
                self._retire(slot, "deadline_exceeded")
                if self._mx:
                    self._mx["deadline"].inc()

    # ---------------------------------------------------------- sampling --
    def _sample(self, logits):
        """logits (..., V) → token ids."""
        if self.ecfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.rng, k = jax.random.split(self.rng)
        return jax.random.categorical(k, logits / self.ecfg.temperature)

    # ----------------------------------------------------------- serving --
    def _bucket(self, n: int) -> int:
        return bucket_len(n, self.ecfg.prefill_bucket, self.ecfg.max_len)

    def _retire(self, slot: int, reason: str = "eos"):
        """Free the slot everywhere: scheduler, cache row (kv_pos → -1),
        and host-side position/token state, so idle slots genuinely ride
        along at pos 0. A speculative engine clears the draft's mirror
        row too. ``reason`` ∈ obs.schema.RETIRE_REASONS."""
        self.sched.retire(slot, reason=reason)
        self.cache = self._clear(self.cache, jnp.int32(slot))
        if self._spec is not None:
            self._spec.clear(slot)
        self._pos[slot] = 0
        self._last_tok[slot] = 0

    def _evict_slot(self, slot: int):
        """Recovery-only (engine/recovery.py): drop a restored slot whose
        request the journal proves already retired after the snapshot was
        taken — clear the cache row and host state WITHOUT a second
        retire, so exactly-once holds across the crash."""
        if slot in self.sched._prefilling:
            self.sched._prefilling.remove(slot)
        self.sched.slots[slot] = None
        self.cache = self._clear(self.cache, jnp.int32(slot))
        if self._spec is not None:
            self._spec.clear(slot)
        self._pos[slot] = 0
        self._last_tok[slot] = 0
        self._prefill_prog[slot] = 0
        self._fail_streak[slot] = 0

    def _start_decoding(self, slot: int, req: EngineRequest, logits_row,
                        S: int):
        """Shared admission tail: sample the FIRST generated token from the
        prompt's final logits row and move the slot into decode (or retire
        it on eos / exhausted budget)."""
        first = int(self._sample(logits_row))
        req.t_first_token = self.clock()
        if self.tracer:
            self.tracer.event("first_token", uid=req.uid, slot=slot)
        if self.journal:
            self.journal.event("first_token", uid=req.uid, slot=slot)
        if first == self.ecfg.eos_id:                 # eos is never emitted
            self._retire(slot, "eos")
            return
        req.out.append(first)
        if self._mx:
            self._mx["tokens"].inc()
        self._last_tok[slot] = first
        self._pos[slot] = S
        if len(req.out) >= req.max_new_tokens:
            self._retire(slot, "budget")
        elif S >= self.ecfg.max_len:
            self._retire(slot, "max_len")

    def _admit_one(self, slot: int, req: EngineRequest) -> int:
        """Legacy ONE-SHOT admission: dense per-request prefill (this is
        the fp (L, S, Hkv, D) materialization) + write_prefill's
        pad/requantize/copy. Returns prompt tokens prefilled."""
        global FP_PREFILL_MATERIALIZATIONS
        if req.max_new_tokens <= 0:                   # explicit 0-token ask
            req.t_first_token = req.t_submit
            self.sched.retire(slot, reason="zero_budget")
            return 0
        tr = self.tracer
        t_span = tr.begin() if tr else 0.0
        S = len(req.prompt)
        Sp = self._bucket(S)
        toks = np.zeros((1, Sp), np.int32)
        toks[0, :S] = req.prompt                      # right-pad
        t_d = tr.now() if tr else 0.0
        logits, pcache = self._prefill(self.params, jnp.asarray(toks))
        dispatch_s = (tr.now() - t_d) if tr else 0.0
        self.n_prefills += 1
        FP_PREFILL_MATERIALIZATIONS += 1
        # only [0, S) becomes visible; bucket padding stays masked forever
        self.cache = self._write(self.cache, jnp.int32(slot), pcache,
                                 jnp.int32(S))
        if self._spec is not None:
            # mirror the prompt into the draft cache (its own one-shot
            # dense materialization — count it honestly)
            self._spec.prefill_oneshot(jnp.asarray(toks), slot, S)
            FP_PREFILL_MATERIALIZATIONS += 1
        # _start_decoding's sample blocks on the prefill logits, so the
        # span's tail (dur - dispatch_s) is device wait + first-token work
        self._start_decoding(slot, req, logits[0, S - 1], S)
        if tr:
            tr.span_end("prefill_oneshot", t_span, slot=slot, uid=req.uid,
                        tokens=S, dispatch_s=dispatch_s)
        return S

    # --------------------------------------------------- chunked prefill --
    def _admit_chunked(self, slot: int, req: EngineRequest):
        """Chunked admission: mark the slot mid-prefill; `_prefill_work`
        streams its prompt in over the next step(s)."""
        if req.max_new_tokens <= 0:
            req.t_first_token = req.t_submit
            self.sched.retire(slot, reason="zero_budget")
            return
        self.sched.begin_prefill(slot)
        self._prefill_prog[slot] = 0
        self._pos[slot] = 0                           # parked (see below)
        self._last_tok[slot] = 0

    def _prefill_work(self) -> int:
        """Spend this step's `prefill_chunk`-token budget on mid-prefill
        slots (FCFS). Each dispatched chunk streams through the fused
        kernel: K/V quantized in-kernel, codes written straight into the
        slot rows. A slot whose prompt completes samples its first token
        from the chunk's last logits row and joins the decode batch; a
        slot still mid-prefill stays parked at its next-unwritten position
        (`_pos` = progress), so the decode batch's fixed-shape ride-along
        write lands exactly where the NEXT chunk will overwrite it.

        Chunks are NEVER split to fit leftover budget: a slot's next chunk
        is always min(prefill_chunk, remaining prompt), and if the step's
        remaining budget cannot cover it the work waits for the next step.
        Chunk boundaries are therefore a pure function of (prompt length,
        prefill_chunk) — independent of concurrent load — so a request
        generates the exact same tokens whether it prefilled alone or
        under contention (an int8 cache makes boundary placement visible:
        tokens after a boundary attend the QUANTIZED prefix, so
        load-dependent boundaries would make generations irreproducible).
        Returns prompt tokens processed."""
        budget = self.ecfg.prefill_chunk
        spent = 0
        tr = self.tracer
        for slot in self.sched.prefill_slots():
            req = self.sched.slots[slot]
            S = len(req.prompt)
            done = int(self._prefill_prog[slot])
            n = min(self.ecfg.prefill_chunk, S - done)
            if n > budget:          # whole chunk or nothing (FCFS head
                break               # waits; boundaries stay load-free)
            t_span = tr.begin() if tr else 0.0
            pos_start = done
            Sc = bucket_len(n, self.ecfg.prefill_bucket,
                            self.ecfg.prefill_chunk)
            toks = np.zeros((1, Sc), np.int32)
            toks[0, :n] = req.prompt[done:done + n]   # right-pad the chunk
            t_d = tr.now() if tr else 0.0
            logits, self.cache = self._chunk_prefill(
                self.params, self.cache, jnp.asarray(toks), jnp.int32(slot),
                jnp.int32(done), jnp.int32(n))
            dispatch_s = (tr.now() - t_d) if tr else 0.0
            if self._spec is not None:     # mirror the chunk to the draft
                self._spec.prefill_chunk(jnp.asarray(toks), slot, done, n)
            wait_s = 0.0
            if tr:
                # traced-mode sync: dispatch is async, so without this
                # the chunk's device time would surface as somebody
                # else's wait. A deliberate profiling cost.
                t_w = tr.now()
                jax.block_until_ready(logits)
                wait_s = tr.now() - t_w
            self.n_prefill_chunks += 1
            if self._mx:
                self._mx["prefill_chunks"].inc()
            budget -= n
            spent += n
            done += n
            self._prefill_prog[slot] = done
            self._pos[slot] = done                    # parked position
            if done >= S:                             # prompt complete
                self.sched.finish_prefill(slot)
                self._start_decoding(slot, req, logits[0], S)
            if tr:
                tr.span_end("prefill_chunk", t_span, slot=slot,
                            uid=req.uid, pos_start=pos_start, n=n,
                            dispatch_s=dispatch_s, wait_s=wait_s)
        return spent

    # ------------------------------------------- speculative decoding --
    def _spec_step(self, active: list[int]) -> None:
        """One SPECULATIVE decode step (DESIGN.md §9): the low-bit draft
        proposes up to `spec_k` greedy tokens per active slot in batched
        decode steps over its own cache, then the target scores each
        slot's whole window in ONE fused verify pass and commits the
        longest matching draft prefix plus its own correction token —
        between 1 and spec_k+1 tokens per slot per step, always exactly
        the tokens plain greedy decoding would have produced.

        Windows are per-slot (`w = min(spec_k+1, cache headroom,
        remaining budget)`), so budget-capped slots degrade to w=1 —
        an ordinary decode step expressed through the verify path — and
        spec/non-spec slots mix freely in one step. Verify writes the
        window's K/V codes in-kernel; rejected rows are undone by
        `rollback_slot` on both caches (kv_pos → -1 is the whole
        rollback), leaving slot bytes bit-identical to a never-speculated
        engine once overwritten."""
        k = self.ecfg.spec_k
        Sq = k + 1
        N = self.ecfg.n_slots
        pos0 = self._pos.copy()
        commit0 = self.n_spec_commit_tokens
        t0 = self.clock()
        # per-slot window lengths: 0 parks the slot through the draft
        # pass (idle / mid-prefill), w >= 1 for decoding slots
        w = np.zeros(N, np.int64)
        for s in active:
            req = self.sched.slots[s]
            rem = req.max_new_tokens - len(req.out)
            w[s] = max(1, min(Sq, self.ecfg.max_len - int(pos0[s]), rem))
        drafts = self._spec.draft(self._last_tok, pos0, w)     # (k, N)
        from .spec import accept_length
        tr = self.tracer
        for s in active:
            req = self.sched.slots[s]
            ws = int(w[s])
            t_span = tr.begin() if tr else 0.0
            toks = np.zeros((1, Sq), np.int32)
            toks[0, 0] = self._last_tok[s]
            toks[0, 1:ws] = drafts[:ws - 1, s]
            t_d = tr.now() if tr else 0.0
            garg, self.cache = self._verify(
                self.params, self.cache, jnp.asarray(toks), jnp.int32(s),
                jnp.int32(pos0[s]), jnp.int32(ws))
            t_w = tr.now() if tr else 0.0
            garg = np.asarray(garg)            # (Sq,) target argmax rows
                                               # — the device wait
            wait_s = (tr.now() - t_w) if tr else 0.0
            self.n_verify_calls += 1
            self.n_verify_tokens += ws
            a = accept_length(drafts[:, s], garg, ws)
            self.sched.note_spec(s, proposed=ws - 1, accepted=a)
            if tr:
                tr.span_end("verify", t_span, slot=s, uid=req.uid,
                            tokens=ws, accepted=a,
                            dispatch_s=t_w - t_d, wait_s=wait_s)
            new_pos = int(pos0[s]) + a + 1
            if a + 1 < ws:                     # rejected rows to undo
                t_rb = tr.begin() if tr else 0.0
                self.cache = _ROLLBACK(self.cache, jnp.int32(s),
                                       jnp.int32(new_pos))
                self._spec.rollback(s, new_pos)
                if tr:
                    tr.span_end("rollback", t_rb, slot=s, uid=req.uid,
                                accept_len=new_pos)
                    tr.event("rollback", uid=req.uid, slot=s,
                             accept_len=new_pos,
                             rejected=ws - (a + 1))
            # commit g_1..g_{a+1} with the same eos/budget/max_len
            # semantics as sequential decode steps
            t_c = tr.begin() if tr else 0.0
            for t in (int(x) for x in garg[:a + 1]):
                if t == self.ecfg.eos_id:      # eos is never emitted
                    self._retire(s, "eos")
                    break
                req.out.append(t)
                self.n_spec_commit_tokens += 1
                self._last_tok[s] = t
                self._pos[s] += 1
                if len(req.out) >= req.max_new_tokens:
                    self._retire(s, "budget")
                    break
                if self._pos[s] >= self.ecfg.max_len:
                    self._retire(s, "max_len")
                    break
            if tr:
                tr.span_end("accept_commit", t_c, slot=s, uid=req.uid,
                            committed=a + 1)
        self.n_spec_steps += 1
        self.spec_step_s.append(self.clock() - t0)
        self.sched.note_step(len(active))
        if self._mx:
            self._mx["spec_steps"].inc()
            self._mx["tokens"].inc(self.n_spec_commit_tokens - commit0)
            if self.sched.accept_ewma is not None:
                self._mx["accept_ewma"].set(self.sched.accept_ewma)

    # --------------------------------------- plain decode with retry --
    def _dispatch_decode(self, n_active: int) -> np.ndarray:
        """One batched plain-decode dispatch over all N slots; returns
        the per-slot sampled tokens on host. The decode SPAN opens before
        staging: the two host->device puts are real per-step decode cost
        (on small models they rival the matmuls) and must attribute to
        the phase, not leak into the step span's uncovered remainder. The
        tracked decode_step_s metric keeps its historical bracket
        (post-staging t0) so its trend stays comparable across PRs."""
        tr = self.tracer
        t_span = tr.begin() if tr else 0.0
        tokens = jnp.asarray(self._last_tok[:, None])
        pos = jnp.asarray(self._pos)
        t0 = self.clock()
        if self._greedy:
            toks, self.cache = self._decode(self.params, self.cache,
                                            tokens, pos)
            t_w = tr.now() if tr else 0.0
            toks = np.asarray(toks)
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              tokens, pos)
            t_w = tr.now() if tr else 0.0
            toks = np.asarray(self._sample(logits[:, -1]))
        self.n_decode_steps += 1
        # toks is on host here, so this brackets the real per-step
        # decode latency (dispatch + device compute + sample)
        dt = self.clock() - t0
        self.decode_step_s.append(dt)
        if self._mx:
            self._mx["decode_steps"].inc()
            self._mx["decode_s"].observe(dt)
        if tr:
            tr.span_end("decode", t_span, slots=n_active,
                        dispatch_s=t_w - t0, wait_s=tr.now() - t_w)
        return toks

    def _decode_with_retry(self, active: list) \
            -> tuple[Optional[np.ndarray], list]:
        """Plain decode step with bounded retry-on-failure (§12).

        Failure sources: injected faults (ecfg.fault_spec) and the
        always-on sanity check that every sampled token id is in-vocab —
        the host-side detector for corrupted logits (greedy sampling is
        folded into the jitted executable, so NaN logits are observable
        only as a garbage argmax; an out-of-range id is the symptom, and
        unlike a raised exception it is per-SLOT attributable).

        Recovery contract: a failed attempt may already have written this
        step's K/V row for every decoding slot, so ALL active slots roll
        back to their pre-step positions — `rollback_slot`'s kv_pos→-1
        positional invalidation, the same primitive speculative decoding
        rolls rejected windows back with — and the step re-executes.
        Greedy decode re-derives bit-identical tokens from the unchanged
        committed prefix (the spec-path hypothesis property of
        tests/test_spec.py, re-asserted end-to-end under fault storms in
        tests/test_faults.py). A slot whose token stays corrupt for
        ``max_retries + 1`` consecutive attempts is quarantined — retired
        as "failed" and dropped from the batch — so one poison request
        can never wedge everyone else. Unattributable failures (raised
        exceptions) share the attempt budget and fail the WHOLE batch
        when it exhausts: the loud backstop for a deterministically
        crashing step, loud because silently spinning would be worse.

        Returns (tokens, surviving_active); tokens is None when every
        slot was quarantined."""
        pos0 = self._pos.copy()
        attempt = 0
        while active:
            inj = self._faults
            kind = inj.draw_step() if inj else None
            try:
                if kind == "exception":
                    raise StepFailure("injected transient step exception")
                if kind == "slow":
                    inj.sleep()
                toks = self._dispatch_decode(len(active))
                if inj is not None:
                    toks = inj.corrupt_tokens(
                        toks, active,
                        {s: self.sched.slots[s].uid for s in active})
                bad = [s for s in active
                       if not 0 <= int(toks[s]) < self.cfg.vocab]
                if bad:
                    raise StepFailure(
                        f"out-of-vocab decode token(s): "
                        f"{[(s, int(toks[s])) for s in bad]}", slots=bad)
                self._fail_streak[active] = 0
                return toks, active
            except StepFailure as e:
                attempt += 1
                self.n_step_retries += 1
                if self._mx:
                    self._mx["retries"].inc()
                if self._detect is not None:
                    # attributable failures carry the victim slots — name
                    # the first victim's uid in the incident trigger
                    uid = (self.sched.slots[e.slots[0]].uid
                           if e.slots and self.sched.slots[e.slots[0]]
                           is not None else None)
                    self._detect.note("step_retry", reason=str(e), uid=uid)
                # undo any K/V the failed dispatch wrote: every active
                # slot back to its pre-step position (host _pos has not
                # advanced, so re-execution is bit-identical)
                for s in active:
                    self.cache = _ROLLBACK(self.cache, jnp.int32(s),
                                           jnp.int32(pos0[s]))
                if e.slots:
                    for s in e.slots:
                        self._fail_streak[s] += 1
                        if self._fail_streak[s] > self.ecfg.max_retries:
                            print(f"[engine] quarantining slot {s} (uid "
                                  f"{self.sched.slots[s].uid}): corrupt "
                                  f"decode output {self._fail_streak[s]} "
                                  f"attempts running", file=sys.stderr)
                            self.n_quarantined += 1
                            if self._detect is not None:
                                self._detect.note(
                                    "quarantine",
                                    uid=self.sched.slots[s].uid,
                                    reason=f"slot {s}: corrupt output "
                                           f"{int(self._fail_streak[s])} "
                                           f"attempts running")
                            self._retire(s, "failed")
                            self._fail_streak[s] = 0
                            active = [a for a in active if a != s]
                elif attempt > self.ecfg.max_retries:
                    print(f"[engine] decode failed {attempt} attempts "
                          f"with no attributable slot — failing the "
                          f"whole batch: {e}", file=sys.stderr)
                    for s in list(active):
                        self._fail_streak[s] = 0
                        self.n_quarantined += 1
                        if self._detect is not None:
                            self._detect.note(
                                "quarantine",
                                uid=self.sched.slots[s].uid,
                                reason=f"slot {s}: whole-batch failure "
                                       f"after {attempt} attempts")
                        self._retire(s, "failed")
                    active = []
                if active and self.ecfg.retry_backoff_s > 0:
                    time.sleep(min(0.05, self.ecfg.retry_backoff_s
                                   * (2.0 ** (attempt - 1))))
        return None, []

    def _prefill_backlog(self) -> int:
        """Prompt chunks still to stream for mid-prefill slots — the
        prefill half of the ladder's pressure signal and the end-of-step
        backlog gauge."""
        if not self.ecfg.prefill_chunk:
            return 0
        backlog = 0
        for s in self.sched.prefill_slots():
            rem = len(self.sched.slots[s].prompt) \
                - int(self._prefill_prog[s])
            backlog += -(-rem // self.ecfg.prefill_chunk)
        return backlog

    def step(self) -> list[EngineRequest]:
        """Admit + (chunk-budgeted) prefill + one batched decode step.
        Returns requests finishing now."""
        if self._t_start is None:
            self._t_start = self.clock()
        t_step0 = self.clock()
        # --- injected process death (faults.crash_rate, §13) -----------
        # drawn before ANY step work: the journal's durability horizon is
        # the step boundary, so flush whatever arrived since the last
        # step's fsync (client submits land between steps) and die —
        # recovery then sees exactly the pre-step state
        if self._faults is not None and self._faults.draw_crash():
            if self.journal:
                self.journal.sync()
            self._faults.crash()
        n_done_before = len(self.sched.finished)
        # decoders that were ALREADY mid-generation when this step's
        # prefill work ran — the requests a prefill stall actually delays
        # (a slot admitted and first-decoded in the same step was not
        # waiting on anything; counting it would inflate the one-shot
        # stall baseline with the idle-engine admission burst)
        n_decoding_before = len(self.sched.active_slots())
        # dispatch-wall ring lengths at step start: whichever ring grew
        # this step holds the step's decode/verify dispatch wall (the
        # coarse dispatch split in the flight record)
        n_dec0, n_spec0 = len(self.decode_step_s), len(self.spec_step_s)
        if self._any_deadlines:
            self._enforce_deadlines()
        # --- degradation ladder (faults.DegradationLadder, §12) --------
        # pressure = queue depth + prefill backlog chunks, fed BEFORE
        # admission so this step's policy reflects the load it is about
        # to admit under
        defer = ()
        if self._ladder is not None:
            pressure = len(self.sched.queue) + self._prefill_backlog()
            rung = self._ladder.update(pressure)
            if rung != self._rung:
                if self._mx:
                    self._mx["degr_transitions"].inc()
                if self.tracer:
                    self.tracer.event("degrade", rung=rung,
                                      prev=self._rung, pressure=pressure)
                self._rung = rung
            if self._mx:
                self._mx["rung"].set(rung)
            if rung >= 3:
                # shed queued load (batch class first) back down to the
                # rung-2 threshold — enough relief to stop climbing
                self.sched.shed_queued_to(int(self._ladder.thresholds[1]))
            if rung >= 2:
                defer = ("batch",)
        prefill_tokens = 0
        for slot, req in self.sched.admit(defer=defer):
            if self.ecfg.prefill_chunk:
                self._admit_chunked(slot, req)
            else:
                prefill_tokens += self._admit_one(slot, req)
        if self.ecfg.prefill_chunk:
            prefill_tokens = self._prefill_work()
            # nobody is decoding ⇒ nobody can be stalled: keep spending
            # whole-chunk budgets until a slot finishes its prompt and
            # joins the decode batch (the chunk budget only throttles
            # prefill that would delay CONCURRENT decode steps; a
            # decode-idle engine prefills at one-shot speed)
            while not self.sched.active_slots() and \
                    self.sched.prefill_slots():
                prefill_tokens += self._prefill_work()
        active = self.sched.active_slots()
        if active and self._spec is not None and self._rung < 1:
            # speculative step: draft k tokens batched over the draft
            # cache, verify each slot's window in one fused pass, commit
            # 1..spec_k+1 tokens per slot (token-identical to the plain
            # decode branch below)
            self._spec_step(active)
        elif active:
            # idle slots ride along at pos 0 with token 0 (fixed decode
            # shape == jit cache of exactly one entry); _retire cleared
            # their kv_pos rows, so each idle step re-marks only its own
            # t=0 entry, and the next admit rewrites the row wholesale.
            # Mid-prefill slots ride along the same way, parked at their
            # next-unwritten position: the garbage row the ride-along
            # write marks valid is overwritten by the slot's next chunk,
            # and the chunk kernel masks cache rows at >= pos_start, so
            # it can never be attended (per-slot attention shields every
            # other request)
            if self._spec is not None:
                # ladder rung >= 1: spec engine routed through plain
                # decode — output-identical by the lossless accept rule,
                # so suspension is the free first degradation
                self._spec.note_suspended()
            toks, active = self._decode_with_retry(active)
            tr = self.tracer
            t_c = tr.begin() if tr else 0.0
            emitted = 0
            for slot in active:
                req = self.sched.slots[slot]
                t = int(toks[slot])
                self._pos[slot] += 1
                if t == self.ecfg.eos_id:
                    self._retire(slot, "eos")
                    continue
                req.out.append(t)
                emitted += 1
                self._last_tok[slot] = t
                if len(req.out) >= req.max_new_tokens:
                    self._retire(slot, "budget")
                elif self._pos[slot] >= self.ecfg.max_len:
                    self._retire(slot, "max_len")
            self.sched.note_step(len(active))
            if self._mx:
                self._mx["tokens"].inc(emitted)
            if tr:
                tr.span_end("accept_commit", t_c, slots=len(active))
        tr = self.tracer
        if tr and self.ecfg.trace_kv_every and self.cache.mode == "int8" \
                and len(self.step_s) % self.ecfg.trace_kv_every == 0:
            # periodic KV quantization-quality sample: a host transfer of
            # live cache rows — traced-mode-only cost, span-attributed
            from .kvcache import kv_quality_counters
            t_q = tr.begin()
            tr.counter("kv_quality", kv_quality_counters(self.cache))
            tr.span_end("kv_sample", t_q)
        self.step_s.append(self.clock() - t_step0)
        self.step_prefill_tokens.append(prefill_tokens)
        self.step_decode_slots.append(n_decoding_before)
        mx = self._mx
        if mx:
            # end-of-step queueing gauges: O(n_slots) host bookkeeping,
            # no device traffic — the always-on cost the ≤1% overhead
            # bound covers
            mx["steps"].inc()
            mx["step_s"].observe(self.step_s[-1])
            if prefill_tokens:
                mx["prefill_tokens"].inc(prefill_tokens)
            occupied = in_flight = 0
            for r in self.sched.slots:
                if r is not None:
                    occupied += 1
                    in_flight += max(0, r.max_new_tokens - len(r.out))
            backlog = self._prefill_backlog()
            mx["occupancy"].set(occupied / self.ecfg.n_slots)
            mx["decoding"].set(len(self.sched.active_slots()))
            mx["backlog"].set(backlog)
            mx["in_flight"].set(in_flight)
            if self.ecfg.metrics_kv_every and self.cache.mode == "int8" \
                    and len(self.step_s) % self.ecfg.metrics_kv_every == 0:
                # periodic KV quality gauges: bounded host transfer of
                # live cache rows (kvcache.kv_quality_counters) — the
                # one metrics signal that is NOT free, which is why it
                # has its own period and defaults off
                from .kvcache import kv_quality_counters
                kc = kv_quality_counters(self.cache)
                clips = []
                for side in ("k", "v"):
                    if kc.get(f"{side}_clip_frac") is not None:
                        mx[f"kv_{side}_clip"].set(kc[f"{side}_clip_frac"])
                        mx[f"kv_{side}_occ"].set(kc[f"{side}_occupancy"])
                        clips.append(kc[f"{side}_clip_frac"])
                # stash the worse-side samples for the flight record /
                # kv_clip_spike detector (same pull, no extra transfer)
                if clips:
                    self._last_clip_frac = max(clips)
                spans = []
                for side in ("k", "v"):
                    hist = kc.get(f"{side}_span_outlier_hist")
                    if hist and sum(hist) > 0:
                        # buckets at > 4x the median chunk span — the
                        # OCS outlier tail (quality.OUTLIER_LOG2_EDGES)
                        spans.append(sum(hist[5:]) / sum(hist))
                if spans:
                    self._last_span_frac = max(spans)
        if tr:
            tr.span_end("step", t_step0,
                        prefill_tokens=prefill_tokens,
                        decode_slots=n_decoding_before)
        # --- crash safety (§13): make the boundary durable --------------
        # journal fsync FIRST, then the periodic snapshot — so a snapshot
        # never holds state the journal hasn't seen (snapshot ⊆ WAL)
        if self.journal is not None:
            self.journal.sync()
        if self.ecfg.snapshot_every and self.ecfg.snapshot_path \
                and len(self.step_s) % self.ecfg.snapshot_every == 0:
            self.snapshot()
        # --- flight record + anomaly sweep (obs/flight.py, §14) ---------
        # after the journal fsync so a bundle's journal tail includes
        # this step; the record is one small dict + ring append — the
        # always-on cost the flight_recorder overhead bound covers
        fr, det = self._flight, self._detect
        if fr is not None or det is not None:
            uids = self.sched.occupied_uids()
            rec = {
                "step": len(self.step_s) - 1,
                "step_s": round(self.step_s[-1], 6),
                "decode_s": round(
                    self.decode_step_s[-1]
                    if len(self.decode_step_s) > n_dec0 else
                    (self.spec_step_s[-1]
                     if len(self.spec_step_s) > n_spec0 else 0.0), 6),
                "draft_s": round(self._spec.last_draft_s, 6)
                if self._spec is not None and self._rung < 1 else 0.0,
                "queue": len(self.sched.queue),
                "backlog": self._prefill_backlog(),
                "occupied": len(uids),
                "decoding": n_decoding_before,
                "rung": self._rung,
                "retries": self.n_step_retries,
                "quarantined": self.n_quarantined,
                "accept": (round(self.sched.accept_ewma, 4)
                           if self._spec is not None
                           and self.sched.accept_ewma is not None
                           else None),
                "spec_off": bool(self._spec is not None
                                 and self._rung >= 1),
                "clip_frac": self._last_clip_frac,
                "span_frac": self._last_span_frac,
                "uids": uids,
            }
            if fr is not None:
                rec = fr.record(**rec)
            if det is not None:
                firings = det.sweep(rec)
                if firings:
                    self._capture_incident(firings)
        return self.sched.finished[n_done_before:]

    # -------------------------------------------- incident capture (§14) --
    def _capture_incident(self, firings, force: bool = False):
        """Write one incident bundle for a batch of detector firings —
        the first firing is the named trigger. A global cooldown
        (ecfg.incident_cooldown steps) gates bundles so a fault storm
        yields one incident, not one per step; ``force`` bypasses it
        (explicit dumps: supervisor restart, IntegrityError)."""
        if not self.ecfg.incident_dir or not firings:
            return None
        step = len(self.step_s)
        if not force and self._last_bundle_step is not None \
                and step - self._last_bundle_step \
                < self.ecfg.incident_cooldown:
            return None
        from ..obs.flight import tail_lines, write_incident_bundle
        from ..obs.provenance import provenance
        from .recovery import _engine_fingerprint, _req_doc
        trigger = firings[0]
        docs: dict = {
            "trigger.json": {
                "schema": 1, "step": step,
                "trigger": trigger.to_dict(),
                "firings": [f.to_dict() for f in firings],
                "faults_injected": (self._faults.counts()
                                    if self._faults is not None else None),
            },
            "flight.json": {
                "header": (self._flight.header()
                           if self._flight is not None else None),
                "records": (self._flight.window()
                            if self._flight is not None else []),
            },
            "metrics.json": (self.registry.snapshot()
                             if self.registry is not None else None),
            "fingerprint.json": _engine_fingerprint(self),
            "provenance.json": provenance(),
            "requests.json": {
                "active": [dict(_req_doc(r), slot=s)
                           for s, r in enumerate(self.sched.slots)
                           if r is not None],
                "queued": [_req_doc(r) for r in self.sched.queue],
                "poison_uids": (sorted(self._faults.poison_uids)
                                if self._faults is not None else []),
            },
        }
        if self.ecfg.journal_path:
            if self.journal is not None:
                self.journal.sync()
            docs["journal_tail.jsonl"] = tail_lines(
                self.ecfg.journal_path, 200)
        # sequence from what's on disk, not this object's counter: a
        # supervised restart replaces the engine but bundles persist,
        # and an overwritten bundle would silently eat an incident
        try:
            seq = len([d for d in os.listdir(self.ecfg.incident_dir)
                       if d.startswith("incident-")
                       and not d.endswith(".tmp")])
        except OSError:
            seq = 0
        name = f"incident-{seq:03d}-{trigger.detector}"
        path = write_incident_bundle(self.ecfg.incident_dir, name, docs)
        self.incidents.append(path)
        self._last_bundle_step = step
        print(f"[engine] incident bundle: {path} "
              f"(trigger {trigger.detector}: {trigger.reason})",
              file=sys.stderr)
        return path

    def dump_incident(self, detector: str, reason: str = "",
                      uid: Optional[int] = None):
        """Explicitly capture an incident bundle (bypasses the cooldown).
        Used by the serve supervisor after an ``InjectedCrash`` restart
        and by the restore path on ``IntegrityError`` — anomalies that
        happen outside the step loop, where no sweep will run."""
        from ..obs.detect import Firing
        return self._capture_incident(
            [Firing(detector, len(self.step_s), reason, uid=uid)],
            force=True)

    # ------------------------------------------------- crash safety ------
    def snapshot(self, path: Optional[str] = None) -> str:
        """Write the full serving state (quantized slot cache, draft
        twin, scheduler queue + slot table, host decode state, PRNG key)
        to ``path`` atomically (engine/recovery.py, DESIGN.md §13)."""
        from .recovery import snapshot_engine
        path = path if path is not None else self.ecfg.snapshot_path
        if not path:
            raise ValueError("snapshot needs a path (argument or "
                             "EngineConfig.snapshot_path)")
        out = snapshot_engine(self, path)
        if self._mx:
            self._mx["snapshots"].inc()
        if self.journal:
            self.journal.event("snapshot", step=len(self.step_s))
        return out

    def restore(self, path: str) -> dict:
        """Restore serving state from a snapshot into this (freshly
        constructed, idle) engine. Integrity-validated: checksums, code
        ranges, kv_pos invariants — raises ``IntegrityError`` rather
        than serve a corrupt artifact. Returns the snapshot manifest."""
        from .recovery import IntegrityError, restore_engine
        t0 = self.clock()
        try:
            manifest = restore_engine(self, path)
        except IntegrityError as e:
            # capture the rejected artifact's context before failing loud
            self.dump_incident("integrity_error", reason=str(e))
            raise
        if self._mx:
            self._mx["restores"].inc()
            self._mx["restore_s"].observe(self.clock() - t0)
        return manifest

    def recover(self, snapshot_path: Optional[str] = None,
                journal_path: Optional[str] = None) -> dict:
        """Snapshot restore + journal replay: resume what the snapshot
        holds, re-enqueue journal submissions past the snapshot horizon,
        evict anything the journal proves already retired. Either source
        may be absent (journal-only recovery re-prefills everything).
        Returns recovery.recover_engine's summary dict."""
        from .recovery import IntegrityError, recover_engine
        t0 = self.clock()
        try:
            info = recover_engine(
                self,
                snapshot_path if snapshot_path is not None
                else self.ecfg.snapshot_path,
                journal_path if journal_path is not None
                else self.ecfg.journal_path)
        except IntegrityError as e:
            self.dump_incident("integrity_error", reason=str(e))
            raise
        if self._mx:
            if info["manifest"] is not None:
                self._mx["restores"].inc()
            self._mx["replayed"].inc(info["n_restored"]
                                     + info["n_requeued"])
            self._mx["restore_s"].observe(self.clock() - t0)
        return info

    def drain(self, timeout_s: Optional[float] = None,
              stall_steps: int = 10_000) -> list[EngineRequest]:
        """Run until queue and slots are empty; returns all finished
        requests in uid order.

        Watchdog (§12): the loop is bounded by wall clock (``timeout_s``,
        None = unbounded) AND by a no-progress counter — ``stall_steps``
        consecutive steps during which nothing observable moved (no
        finish, no admission, no token committed, no prefill progress).
        A healthy engine always moves one of those per step, so tripping
        either bound means a wedge; the watchdog force-fails every
        outstanding request (reason "failed") with a loud log instead of
        hanging the caller forever. The historical drain() — plain
        ``while not idle: step()`` — is the defaults' behavior on any
        non-wedged engine."""
        t0 = self.clock()
        stalled = 0
        sig = None
        while not self.sched.idle:
            self.step()
            cur = (len(self.sched.finished), self.sched.n_admitted,
                   sum(len(r.out) for r in self.sched.slots
                       if r is not None),
                   int(self._prefill_prog.sum()))
            if cur == sig:
                stalled += 1
            else:
                stalled = 0
                sig = cur
            if stalled >= stall_steps:
                self._force_fail_outstanding(
                    f"no progress across {stalled} consecutive steps")
                break
            if timeout_s is not None and self.clock() - t0 > timeout_s:
                self._force_fail_outstanding(
                    f"drain exceeded timeout_s={timeout_s}")
                break
        self.sweep_idle_rows()
        return sorted(self.sched.finished, key=lambda r: r.uid)

    def sweep_idle_rows(self) -> None:
        """Clear the ride-along position marks idle slots accumulate.

        An idle slot in the fixed-shape decode batch re-marks its own
        t=0 row each step (by design — the next admission rewrites the
        row wholesale), so after the LAST decode step of a drain, slots
        that retired before it still carry one stray mark. Clearing
        empty slots here (target and draft caches) restores the
        "drained engine ⇒ empty slot pool" invariant the chaos harness
        leak-checks with `kvcache.occupied_slots`. O(n_slots) tiny
        dispatches, once per drain — not hot-path cost."""
        for s, r in enumerate(self.sched.slots):
            if r is None:
                self.cache = self._clear(self.cache, jnp.int32(s))
                if self._spec is not None:
                    self._spec.clear(s)

    def _force_fail_outstanding(self, why: str) -> None:
        """Watchdog action: fail every queued + slotted request so the
        drain terminates with the full exactly-once retire accounting
        intact (a wedged engine must still leave no request in limbo)."""
        n_q = len(self.sched.queue)
        n_s = sum(r is not None for r in self.sched.slots)
        print(f"[engine] drain watchdog tripped ({why}): force-failing "
              f"{n_q} queued + {n_s} slotted request(s)", file=sys.stderr)
        for slot, req in enumerate(self.sched.slots):
            if req is not None:
                self._retire(slot, "failed")
        while self.sched.queue:
            self.sched.drop_queued(self.sched.queue[0], "failed")

    # ----------------------------------------------------------- metrics --
    def metrics(self) -> dict:
        from repro.obs import mean, pct as p, phase_breakdown
        fin = self.sched.finished
        reasons: dict = {}
        for r in fin:
            reasons[r.finish_reason] = reasons.get(r.finish_reason, 0) + 1
        ttfts = [r.ttft for r in fin if r.ttft is not None]
        tps = [r.tokens_per_s for r in fin if r.tokens_per_s is not None]
        total_tokens = sum(len(r.out) for r in fin)
        wall = (self.clock() - self._t_start) if self._t_start else 0.0
        steps = np.asarray(self.decode_step_s, np.float64)
        full = np.asarray(self.step_s, np.float64)
        pmask = (np.asarray(self.step_prefill_tokens, np.int64) > 0) \
            & (np.asarray(self.step_decode_slots, np.int64) > 0)
        withp = full[pmask[:full.size]] if full.size else full
        spec = {}
        if self.ecfg.spec_k:
            hist = np.bincount(np.asarray(self.sched.accept_hist,
                                          np.int64),
                               minlength=self.ecfg.spec_k + 1) \
                if self.sched.accept_hist else np.zeros(0, np.int64)
            sstep = np.asarray(self.spec_step_s, np.float64)
            spec = {
                "spec_k": self.ecfg.spec_k,
                "spec_steps": self.n_spec_steps,
                "verify_calls": self.n_verify_calls,
                "verify_tokens": self.n_verify_tokens,
                "draft_steps": (self._spec.n_draft_steps
                                if self._spec else 0),
                "draft_proposed": self.sched.spec_proposed,
                "draft_accepted": self.sched.spec_accepted,
                "acceptance_rate": self.sched.acceptance_rate(),
                # accept_hist[a] = verify calls that accepted exactly a
                # draft tokens (a in [0, spec_k])
                "accept_hist": hist.tolist(),
                # tokens actually COMMITTED per verify (eos/budget can
                # truncate below accepted+1, so this is computed from
                # appended tokens, not from the accept histogram)
                "tokens_per_verify_mean": (
                    self.n_spec_commit_tokens / self.n_verify_calls
                    if self.n_verify_calls else None),
                "spec_step_p50_s": p(sstep, 50),
                "spec_step_p95_s": p(sstep, 95),
                "spec_by_slot": [list(x) for x in self.sched.spec_by_slot],
                # live acceptance gauge: EWMA over per-verify fractions —
                # tracks recent drift the cumulative rate smooths away
                "acceptance_ewma": self.sched.accept_ewma,
                # plain-decode steps taken while the ladder suspended
                # speculation (rung >= 1) — output-identical by the
                # accept rule, costs only acceptance on resume
                "spec_suspended_steps": (self._spec.n_suspended_steps
                                         if self._spec else 0),
            }
        out = {
            "n_finished": len(fin),
            "total_tokens": total_tokens,
            "wall_s": wall,
            "tokens_per_s": total_tokens / wall if wall > 0 else None,
            "decode_steps": self.n_decode_steps,
            "prefills": self.n_prefills,
            "prefill_chunks": self.n_prefill_chunks,
            "prefill_chunk": self.ecfg.prefill_chunk,
            "slot_utilization": self.sched.utilization(),
            "queue_depth_max": max(self.sched.queue_depth_hist, default=0),
            # always-on queueing signals (scheduler records these at
            # submit/admit time with or without a tracer — obs.summary
            # keeps the None-on-empty convention)
            "queue_depth_at_submit_p50": p(self.sched.queue_depth_submit,
                                           50),
            "queue_depth_at_submit_p95": p(self.sched.queue_depth_submit,
                                           95),
            "admit_latency_mean_s": mean(self.sched.admit_latency_s),
            "admit_latency_p50_s": p(self.sched.admit_latency_s, 50),
            "admit_latency_p95_s": p(self.sched.admit_latency_s, 95),
            "ttft_mean_s": mean(ttfts),
            "ttft_p50_s": p(ttfts, 50),
            "ttft_p95_s": p(ttfts, 95),
            "request_tokens_per_s_mean": mean(tps),
            "decode_step_p50_s": p(steps, 50),
            "decode_step_p95_s": p(steps, 95),
            "decode_step_mean_s": mean(steps),
            # full-step latency: the admission-stall telemetry — a step
            # that prefilled a whole prompt one-shot blocks every decoding
            # slot for that long; chunked prefill bounds it by the budget
            "step_p50_s": p(full, 50),
            "step_p95_s": p(full, 95),
            "step_with_prefill_p95_s": p(withp, 95),
            "steps_with_prefill": int(pmask.sum()),
            "fused_attn": self.ecfg.fused_attn,
            "kv_mode": self.cache.mode,
            "kv_static_scales": self.cache.static,
            "kv_bytes_per_token": self.cache.bytes_per_token(),
            # fault-tolerance accounting (§12): the retire-reason
            # partition (every finished request counted exactly once)
            # plus the policy counters the chaos harness asserts over
            "retire_reasons": reasons,
            "requests_shed": self.sched.n_shed,
            "requests_cancelled": self.sched.n_cancelled,
            "step_retries": self.n_step_retries,
            "quarantined": self.n_quarantined,
            "degradation_rung": self._rung,
            "degradation_transitions": (self._ladder.n_transitions
                                        if self._ladder else 0),
            # flight recorder + incident capture (§14)
            "flight_recorded": (self._flight.n_recorded
                                if self._flight is not None else 0),
            "incidents": list(self.incidents),
            "anomalies_fired": (self._detect.n_fired
                                if self._detect is not None else 0),
            **spec,
        }
        if self._faults is not None:
            out["faults_injected"] = self._faults.counts()
        if self.registry is not None:
            # the always-on registry snapshot rides along so one
            # metrics() call is the full observability surface (the
            # same dict SnapshotWriter streams and to_prometheus
            # renders)
            out["registry"] = self.registry.snapshot()
        if self.tracer:
            # traced engines embed the phase-attribution summary so every
            # metrics consumer (serve.py --metrics-json, the benchmarks)
            # gets the step-time breakdown without reparsing the trace
            out["phase_attribution"] = phase_breakdown(self.tracer.events)
            out["trace_records"] = len(self.tracer.events)
            out["trace_dropped"] = self.tracer.dropped
        return out
