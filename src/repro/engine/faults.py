"""Seeded fault injection + the graceful-degradation ladder
(DESIGN.md §12).

Production serving treats failure as the steady state: transient step
exceptions (preempted device, flaky interconnect), corrupted logits
(NaN-poisoned activations), stragglers, and malformed client input all
arrive continuously at scale. This module provides the two policy pieces
the engine consumes:

* :class:`FaultInjector` — a deterministic, seeded source of synthetic
  faults the engine enables via ``EngineConfig(fault_spec=...)``. Every
  draw comes from ONE ``numpy.random.default_rng(seed)``, so a chaos run
  is exactly reproducible: the same seed produces the same fault
  sequence, which is what lets tests/test_faults.py assert that the
  SURVIVORS of a fault storm are token-identical to an unfaulted run.
  Injection points mirror the real failure surface:

  - ``step_exception_rate``  — the decode dispatch raises (transient;
    retry-with-rollback should absorb it);
  - ``nan_logits_rate``      — one decoding slot's sampled token is
    corrupted out-of-vocab. Greedy sampling is folded into the jitted
    decode executable, so "NaN logits" is modeled at its observable
    symptom: an argmax over NaNs yields an arbitrary/invalid token id,
    and the engine's host-side in-vocab check is the detector either
    way. Unlike a raised exception this failure is per-slot
    ATTRIBUTABLE, which is what makes quarantine possible;
  - ``slow_step_rate``       — a straggler step (sleeps
    ``slow_step_s``); exercises deadline enforcement, not retry;
  - ``poison_rate``          — a submission is marked poisoned and its
    slot's token corrupts EVERY step: the deterministic-failure case
    retry can never fix, which must end in quarantine (``failed``)
    rather than wedging the batch.

* :class:`DegradationLadder` — hysteresis state machine mapping
  sustained backlog pressure onto escalating sheds of cheap-to-lose
  work: first speculation (rung 1 — output-identical by the lossless
  accept rule, so it is free), then batch-class admissions (rung 2),
  then load itself (rung 3). The engine records every rung change as a
  metrics event; thresholds default from the slot count and can be
  pinned to the measured saturation knee (scheduler.admission_set_point).
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Optional

import numpy as np

#: Sentinel written over a corrupted slot's sampled token: far outside
#: any vocab, so the engine's in-vocab check always trips on it.
POISON_TOKEN = -(1 << 30)


class StepFailure(RuntimeError):
    """A decode step produced unusable output. ``slots`` carries the
    attributable victims (empty = the whole dispatch failed with no
    per-slot signal — retry treats the two cases differently)."""

    def __init__(self, msg: str, slots=()):
        super().__init__(msg)
        self.slots = tuple(slots)


class InjectedFault(StepFailure):
    """A synthetic transient raised by the injector (never attributable
    to a slot — it models the dispatch itself failing)."""


class InjectedCrash(BaseException):
    """Injected PROCESS DEATH at a step boundary (engine/recovery.py).

    Deliberately a ``BaseException``: unlike :class:`StepFailure` this
    models the whole process dying, so the engine's retry machinery (and
    any stray ``except Exception``) must not be able to absorb it — only
    a supervisor that restarts + recovers may catch it. With
    ``crash_kill=1`` the injector SIGKILLs the process instead, the
    real thing for cross-process recovery smoke tests."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Injection configuration; all rates are per-step (or per-submit
    for ``poison_rate``) Bernoulli probabilities in [0, 1]."""

    seed: int = 0
    step_exception_rate: float = 0.0
    nan_logits_rate: float = 0.0
    slow_step_rate: float = 0.0
    slow_step_s: float = 0.005
    poison_rate: float = 0.0
    #: per-step-boundary probability of process death (raises
    #: :class:`InjectedCrash`, or SIGKILLs when ``crash_kill``) — drawn
    #: BEFORE any step work, right after the previous step's journal
    #: fsync, so the crash always lands exactly on the WAL's durability
    #: horizon
    crash_rate: float = 0.0
    #: crash via ``os.kill(getpid(), SIGKILL)`` instead of raising —
    #: real process death for cross-process recovery tests
    crash_kill: bool = False
    #: stop injecting step-level faults after this many total events
    #: (None = unbounded) — lets a storm settle so drains terminate
    #: even at extreme rates
    max_faults: Optional[int] = None

    #: CLI-string key → dataclass field (launch.serve --faults)
    _KEYS = {"seed": "seed", "exception": "step_exception_rate",
             "nan": "nan_logits_rate", "slow": "slow_step_rate",
             "slow_s": "slow_step_s", "poison": "poison_rate",
             "crash": "crash_rate", "crash_kill": "crash_kill",
             "max": "max_faults"}

    @classmethod
    def parse(cls, spec: str) -> "FaultSpec":
        """Build from a ``k=v,k=v`` CLI string, e.g.
        ``"exception=0.05,nan=0.05,poison=0.1,seed=3"``. Keys:
        exception / nan / slow / slow_s / poison / crash / crash_kill /
        seed / max."""
        kw = {}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            if "=" not in part:
                raise ValueError(f"fault spec item {part!r} is not k=v "
                                 f"(known keys: {sorted(cls._KEYS)})")
            k, v = part.split("=", 1)
            field = cls._KEYS.get(k.strip())
            if field is None:
                raise ValueError(f"unknown fault spec key {k.strip()!r} "
                                 f"(known: {sorted(cls._KEYS)})")
            if field in ("seed", "max_faults"):
                kw[field] = int(v)
            elif field == "crash_kill":
                kw[field] = bool(int(v))
            else:
                kw[field] = float(v)
        return cls(**kw)


class FaultInjector:
    """Deterministic fault source: one seeded rng drives every draw, so
    identical configs replay identical storms. The engine asks three
    questions: ``note_submit`` (is this request poisoned?), ``draw_step``
    (does this decode attempt raise / straggle?), and ``corrupt_tokens``
    (which sampled tokens come back garbage?)."""

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self.rng = np.random.default_rng(spec.seed)
        self.poison_uids: set[int] = set()
        self.last_corrupted_uids: list[int] = []
        self.n_step_exceptions = 0
        self.n_token_corruptions = 0
        self.n_slow_steps = 0
        self.n_crashes = 0

    def injected_total(self) -> int:
        """Step-level fault events so far (poisoned submissions are
        request marks, not events — quarantine bounds their damage)."""
        return (self.n_step_exceptions + self.n_token_corruptions
                + self.n_slow_steps + self.n_crashes)

    def _budget_left(self) -> bool:
        return (self.spec.max_faults is None
                or self.injected_total() < self.spec.max_faults)

    def note_submit(self, uid: int) -> bool:
        """Draw the poison mark for a new submission."""
        if self.spec.poison_rate > 0 \
                and self.rng.uniform() < self.spec.poison_rate:
            self.poison_uids.add(uid)
            return True
        return False

    def draw_step(self) -> Optional[str]:
        """At most one step-level fault per decode attempt:
        "exception" | "slow" | None."""
        s = self.spec
        if (s.step_exception_rate or s.slow_step_rate) \
                and self._budget_left():
            u = self.rng.uniform()
            if u < s.step_exception_rate:
                self.n_step_exceptions += 1
                return "exception"
            if u < s.step_exception_rate + s.slow_step_rate:
                self.n_slow_steps += 1
                return "slow"
        return None

    def draw_crash(self) -> bool:
        """Draw process death for the step boundary about to start.

        Consumes rng only when ``crash_rate`` is set, so enabling other
        fault classes alone leaves their seeded streams untouched."""
        s = self.spec
        if s.crash_rate <= 0 or not self._budget_left():
            return False
        if self.rng.uniform() < s.crash_rate:
            self.n_crashes += 1
            return True
        return False

    def crash(self) -> None:
        """Die. SIGKILL under ``crash_kill`` (no cleanup, no atexit —
        the genuine article), else raise :class:`InjectedCrash` for an
        in-process supervisor to field."""
        if self.spec.crash_kill:
            os.kill(os.getpid(), signal.SIGKILL)
        raise InjectedCrash(
            f"injected process crash at step boundary "
            f"(crash #{self.n_crashes})")

    def sleep(self) -> None:
        time.sleep(self.spec.slow_step_s)

    def corrupt_tokens(self, toks: np.ndarray, active: list,
                       uid_of: dict) -> np.ndarray:
        """Apply token-level corruption to one decode attempt's sampled
        tokens: a transient NaN-logits victim (random decoding slot) plus
        every slot currently holding a poisoned request.

        ``last_corrupted_uids`` records this attempt's victims — the
        ground truth an incident bundle's trigger attribution is checked
        against in tests (the engine independently attributes via the
        out-of-vocab slots on the StepFailure)."""
        toks = np.array(toks, copy=True)
        self.last_corrupted_uids = []
        if self.spec.nan_logits_rate > 0 and active \
                and self._budget_left() \
                and self.rng.uniform() < self.spec.nan_logits_rate:
            victim = active[int(self.rng.integers(len(active)))]
            toks[victim] = POISON_TOKEN
            self.n_token_corruptions += 1
            self.last_corrupted_uids.append(uid_of[victim])
        for s in active:
            if uid_of[s] in self.poison_uids:
                toks[s] = POISON_TOKEN
                if uid_of[s] not in self.last_corrupted_uids:
                    self.last_corrupted_uids.append(uid_of[s])
        return toks

    def counts(self) -> dict:
        return {"step_exceptions": self.n_step_exceptions,
                "token_corruptions": self.n_token_corruptions,
                "slow_steps": self.n_slow_steps,
                "crashes": self.n_crashes,
                "poisoned_submissions": len(self.poison_uids)}


class DegradationLadder:
    """Backlog-pressure → degradation-rung state machine with
    hysteresis.

    ``pressure`` (queue depth + prefill backlog chunks, the engine's
    existing queueing signals) is compared against three ascending
    ``thresholds``; the TARGET rung is the number of thresholds the
    pressure exceeds. The ladder only MOVES to the target after
    ``patience`` consecutive steps agree (and takes twice that to step
    back down), so a one-step burst never flaps speculation off/on —
    flapping costs draft-cache holes and acceptance, and admission
    churn.

    Rungs: 0 normal · 1 speculation off (output-identical, free) ·
    2 defer batch-class admissions · 3 shed queued load.
    """

    RUNGS = ("normal", "spec_off", "defer_batch", "shed")

    def __init__(self, thresholds, patience: int = 2):
        thresholds = tuple(float(t) for t in thresholds)
        if len(thresholds) != 3 or list(thresholds) != \
                sorted(set(thresholds)):
            raise ValueError(f"degrade thresholds must be 3 strictly "
                             f"ascending pressures, got {thresholds}")
        self.thresholds = thresholds
        self.patience = max(1, int(patience))
        self.rung = 0
        self.n_transitions = 0
        self._above = 0
        self._below = 0

    def target(self, pressure: float) -> int:
        return sum(pressure > t for t in self.thresholds)

    def update(self, pressure: float) -> int:
        """Feed one step's pressure; returns the (possibly new) rung."""
        tgt = self.target(pressure)
        if tgt > self.rung:
            self._above += 1
            self._below = 0
            if self._above >= self.patience:
                self.rung = tgt
                self.n_transitions += 1
                self._above = 0
        elif tgt < self.rung:
            self._below += 1
            self._above = 0
            if self._below >= 2 * self.patience:    # slower descent
                self.rung = tgt
                self.n_transitions += 1
                self._below = 0
        else:
            self._above = self._below = 0
        return self.rung
