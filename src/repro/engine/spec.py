"""Self-speculative decoding: a low-bit SplitQuant DRAFT of the served
weights proposes tokens, the full-precision TARGET verifies whole windows
in one fused pass (DESIGN.md §9).

SplitQuant's headline property — aggressively quantized models stay
*faithful* to their fp parent — is exactly what a speculative draft
needs: cheap to hold, rarely wrong. The subsystem reuses the two serving
pieces already in-tree rather than growing new ones:

  * the DRAFT is the same architecture loaded from a calibration
    :class:`~repro.calib.recipe.QuantRecipe` (mixed low-bit weights, no
    k-means at startup when the recipe ships a pre-quantized ckpt). It
    shares the target's slot-cache GEOMETRY — same (L, N, T, Hkv, D),
    same kv_mode/qchunks — but owns its own slot arrays, and decodes
    through the exact same jitted fused decode entry point as the
    target (`engine._jitted_entry_points`, greedy variant), so drafting
    is k batched decode steps over all slots at once;

  * the VERIFY pass is `kernels/prefill_attention.py` — a draft window
    *is* a prefill chunk: the window's queries attend the slot's
    committed INT8 prefix plus the window's own K/V (round-tripped
    through cache storage so every row scores exactly like a plain
    decode step, see the kernel's verify mode), the epilogue quantizes
    the window K/V, and accepted rows therefore land in the slot as
    FINAL bytes — no re-write after acceptance.

Accept rule (greedy, lossless): window = [last committed token,
d_1 .. d_{w-1}] fed at positions [pos, pos+w); verify row j's argmax
g_{j+1} is the target's greedy token after window token j. With
a = the longest prefix where d_i == g_i, the engine commits
g_1 .. g_{a+1} — a accepted drafts plus the target's own correction —
so every committed token is the target's argmax given the committed
prefix and speculative output is token-identical to plain greedy
decoding (asserted across fp / int8-dynamic / int8-static KV in
tests/test_spec.py). Rejected rows are undone by
`kvcache.rollback_slot`: kv_pos → -1 beyond the accepted point is the
whole rollback (validity-by-position), and the next write overwrites
the stale codes, so a rolled-back slot is bit-identical to one that
never speculated.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import engine as _engine
from .kvcache import init_slot_cache


def load_draft_params(recipe_dir: str, params, cfg):
    """Mint the draft weight tree from a saved QuantRecipe: restore the
    pre-quantized checkpoint if the recipe ships one (no k-means at
    engine start), else apply the recipe's per-path mixed-precision
    policies to the target's own ``params`` — the draft is the SAME
    model, just low-bit (self-speculation)."""
    from repro.calib import QuantRecipe

    rec = QuantRecipe.load(recipe_dir)
    if rec.arch and rec.arch != cfg.name:
        raise ValueError(
            f"draft recipe {recipe_dir!r} was calibrated for arch "
            f"{rec.arch!r}, serving {cfg.name!r} — a mismatched draft "
            f"would propose garbage and pay full verify cost for it")
    ck = rec.resolve_ckpt_dir(recipe_dir)
    if ck is not None:
        from repro.checkpoint import ckpt
        draft, _ = ckpt.restore(ck, params)
        return draft
    if rec.policies:
        from repro.core import QuantPolicy, quantize_tree
        draft, _ = quantize_tree(jax.random.PRNGKey(0), params,
                                 QuantPolicy(), overrides=rec.policies)
        return draft
    raise ValueError(
        f"draft recipe {recipe_dir!r} carries neither a pre-quantized "
        f"checkpoint nor quantization policies — nothing to draft with")


def accept_length(drafts, target_toks, window: int) -> int:
    """Longest accepted draft prefix: a = max n such that
    drafts[i] == target_toks[i] for all i < n. ``drafts`` are
    d_1..d_{window-1}; ``target_toks`` are the verify rows' argmax
    g_1..g_window. Returns a in [0, window-1]; the engine then commits
    target_toks[:a+1] (accepted drafts + the correction token)."""
    a = 0
    while a < window - 1 and int(drafts[a]) == int(target_toks[a]):
        a += 1
    return a


@functools.lru_cache(maxsize=None)
def jitted_verify(cfg):
    """Process-wide jitted verify entry point, one compile per (arch,
    window-bucket) — slot / pos_start / length stay traced scalars. The
    greedy argmax over every window row is folded into the executable
    (the accept rule only consumes argmax tokens), so a verify is one
    dispatch plus a (Sq,)-int host transfer. The cache is donated: the
    window's K/V codes are scattered in place."""
    from repro.models import transformer

    def vstep(p, c, toks, slot, pos_start, length):
        logits, cache = transformer.verify_step_slots(
            p, cfg, c, toks, slot, pos_start, length)
        return jnp.argmax(logits[0], axis=-1).astype(jnp.int32), cache

    return jax.jit(vstep, donate_argnums=(1,))


class SpecDecoder:
    """Draft side of the speculative engine: owns the draft weights and
    the draft slot cache (target geometry, own arrays), and mirrors every
    cache-lifecycle event — prefill, retire, rollback — so the draft's
    view of each slot tracks the committed sequence.

    The draft cache always uses DYNAMIC scales even when the target
    serves static recipe constants: the recipe was calibrated on the
    target's activations, and a mis-scaled draft cache only costs
    acceptance (never correctness — the accept rule guards that), so the
    draft keeps the scale mode that needs no extra calibration artifact.
    """

    def __init__(self, cfg, ecfg, draft_params, tracer=None,
                 registry=None):
        from repro.models.common import dtype_of
        self.cfg = cfg
        self.ecfg = ecfg
        self.k = ecfg.spec_k
        # obs.Tracer (falsy → None): the draft pass emits one aggregated
        # "draft" span per engine step with dispatch/wait attribution
        self.tracer = tracer if tracer else None
        # always-on draft-side instruments (obs.metrics): the engine
        # shares its registry so the draft's dispatch volume and wall
        # share live alongside the queueing gauges
        self._mx = None
        if registry is not None:
            self._mx = {
                "steps": registry.counter(
                    "spec_draft_steps", "batched draft decode dispatches"),
                "draft_s": registry.histogram(
                    "spec_draft_pass_seconds",
                    "whole per-engine-step draft pass (all iterations)"),
            }
        if ecfg.draft_dequantize:
            # one-time expansion of packed SplitQuantTensors into the
            # compute dtype: every draft decode step would otherwise
            # re-dequantize the whole weight tree (the low-bit recipe's
            # job here is faithfulness + storage, not per-step compute)
            from repro.core import dequantize_tree
            draft_params = dequantize_tree(draft_params)
        self.params = draft_params
        # the draft-twin cache is serving STATE, not a derived quantity:
        # its rows must stay token-aligned with the target cache or the
        # next verify window rolls back everything, so engine
        # snapshot/restore (engine/recovery.py, DESIGN.md §13) persists
        # and restores it alongside the target's under the "draft/"
        # prefix — a spec engine restored without its twin would pay a
        # silent full re-draft-prefill of every live slot
        self.cache = init_slot_cache(
            cfg, ecfg.n_slots, ecfg.max_len, mode=ecfg.kv_mode,
            dtype=dtype_of(ecfg.kv_dtype), qchunks=ecfg.kv_qchunks)
        # the draft shares the target's jitted entry points (same arch ⇒
        # same executables; only the param/cache leaves differ), so a
        # spec engine costs zero extra compiles for drafting
        self._decode, self._prefill = _engine._jitted_entry_points(
            cfg, ecfg.fused_attn, True)                    # always greedy
        self._chunk_prefill = (_engine._jitted_chunk_prefill(cfg)
                               if ecfg.prefill_chunk else None)
        self.n_draft_steps = 0
        self.n_suspended_steps = 0
        # wall of the most recent draft pass — the flight recorder's
        # per-step draft_s field (always tracked: two clock reads per
        # pass, unlike the tracer/registry views this has no off switch)
        self.last_draft_s = 0.0
        if self._mx is not None:
            self._mx["suspended"] = registry.counter(
                "spec_suspended_steps",
                "decode steps where the degradation ladder routed a "
                "spec-enabled engine through plain decode")

    def note_suspended(self) -> None:
        """Record one plain-decode step taken while speculation is
        suspended (degradation-ladder rung >= 1). Tokens committed by
        those steps are never written to the draft cache, so the slot's
        draft rows grow position HOLES; holes are masked out of draft
        attention (validity-by-position), which can only cost acceptance
        — the verify pass stays authoritative, so resuming speculation
        after a suspension remains token-identical (the `spec_k→0 is
        free` property the ladder's first rung relies on)."""
        self.n_suspended_steps += 1
        if self._mx is not None:
            self._mx["suspended"].inc()

    # ------------------------------------------------- slot lifecycle ----
    def prefill_oneshot(self, toks, slot: int, length: int) -> None:
        """Mirror a one-shot admission into the draft cache (same dense
        fp materialization + write_prefill path as the target's)."""
        _, pcache = self._prefill(self.params, toks)
        self.cache = _engine._WRITE(self.cache, jnp.int32(slot), pcache,
                                    jnp.int32(length))

    def prefill_chunk(self, toks, slot: int, pos_start: int,
                      length: int) -> None:
        """Mirror one fused prefill chunk into the draft cache."""
        _, self.cache = self._chunk_prefill(
            self.params, self.cache, toks, jnp.int32(slot),
            jnp.int32(pos_start), jnp.int32(length))

    def clear(self, slot: int) -> None:
        self.cache = _engine._CLEAR(self.cache, jnp.int32(slot))

    def rollback(self, slot: int, accept_len: int) -> None:
        """Drop draft rows for rejected tokens — identical contract to
        the target-side rollback (kv_pos → -1 beyond the accepted
        point); the next draft pass overwrites the stale codes."""
        self.cache = _engine._ROLLBACK(self.cache, jnp.int32(slot),
                                       jnp.int32(accept_len))

    # ------------------------------------------------------- drafting ----
    def draft(self, last_tok, pos, steps):
        """Propose up to k greedy tokens per slot in batched decode steps
        over the draft cache.

        last_tok / pos: (N,) host arrays of the engine's committed state;
        steps: (N,) per-slot window lengths w (0 for slots that are idle
        or mid-prefill). Iteration j feeds window token w_j at pos+j for
        every slot still inside its window, writing its draft-cache row;
        a slot past its window (and every inactive slot) PARKS — it
        re-feeds its current (token, position), so the only row it
        touches is one the next chunk / admission / draft pass overwrites
        anyway (the same ride-along invariant as the engine's decode
        batch). Running max(steps) iterations (window w needs w feeds:
        w-1 drafts plus the row-write for the window's last token) keeps
        the draft cache hole-free even on full acceptance, so acceptance
        doesn't decay over long generations.

        Returns drafts (k, N) int32 — drafts[j] is d_{j+1} per slot; rows
        at >= steps-1 are garbage the caller never reads.
        """
        N = self.ecfg.n_slots
        cur_tok = np.asarray(last_tok, np.int32).copy()
        cur_pos = np.asarray(pos, np.int32).copy()
        steps = np.asarray(steps)
        drafts = np.zeros((self.k, N), np.int32)
        tr = self.tracer
        mx = self._mx
        t_span = tr.begin() if tr else 0.0
        t_pass = time.perf_counter()
        dispatch_s = wait_s = 0.0
        n_iter = int(steps.max())
        for j in range(n_iter):
            if tr:
                t_d = tr.now()
            toks, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(cur_tok[:, None]),
                jnp.asarray(cur_pos))
            if tr:
                dispatch_s += (t_w := tr.now()) - t_d
            toks = np.asarray(toks)                # device wait per iter
            if tr:
                wait_s += tr.now() - t_w
            self.n_draft_steps += 1
            if j < self.k:
                drafts[j] = toks
            adv = (j + 1) < steps
            cur_tok = np.where(adv, toks, cur_tok).astype(np.int32)
            cur_pos = np.where(adv, cur_pos + 1, cur_pos).astype(np.int32)
        self.last_draft_s = time.perf_counter() - t_pass
        if mx:
            mx["steps"].inc(n_iter)
            mx["draft_s"].observe(self.last_draft_s)
        if tr:
            tr.span_end("draft", t_span, iters=n_iter,
                        dispatch_s=dispatch_s, wait_s=wait_s)
        return drafts
