"""Slot-indexed KV cache for the continuous-batching engine.

Layout (DESIGN.md §6): all serving state lives in preallocated arrays of
shape (L, N, T, Hkv, D) — N fixed slots, T = max sequence length. A slot
holds one request for its whole lifetime; `kv_pos[l, n, t]` records the
absolute position stored at time-index t (-1 = empty), so slots with
different prompt lengths coexist in one batched decode step and padding
never enters attention (invalid entries are masked by position, exactly
like the ring-buffer windows in `models/attention.py`).

Quantized storage (``mode="int8"``): SplitQuant §4.2 applied to
activations-at-rest. Each written K/V head-vector is split into
``qchunks`` sub-channel chunks and every chunk is quantized INT8 with its
own dynamic range (β, α) → (scale, zero) via the paper's eqs. (1)-(3).
Separate per-chunk ranges are the paper's mechanism for keeping outlier
channels from inflating everyone else's quantization step; unlike the
weight path (k-means cid per element, offline) the serving write sits on
the decode critical path, so chunk membership is fixed (contiguous
sub-channels) rather than value-clustered — no cid tensor, and dequant is
a reshape + broadcast. On read, the fused decode-attention kernel
(`repro.kernels.decode_attention`, via `fused_slot_attention`) streams
the codes + scales and dequantizes per chunk in VMEM next to the dot
product — no full-precision copy of the cache is materialized; the
legacy materialize-then-attend path (`slot_layer_update`) remains as the
cross-checked reference.

Storage cost per element: 1 byte of codes + 8·qchunks/D bytes of fp32
(scale, zero) — for D=64, qchunks=4 that is 1.5 B/elt vs 2 B (bf16) or
4 B (fp32).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantize import QuantConfig, dequantize, qparams, quantize, \
    value_range

KV_QCFG = QuantConfig(bits=8, symmetric=False)

#: Data leaves of SlotKVCache in declaration order — the serialization
#: contract used by engine snapshot/restore (engine/recovery.py): these
#: and only these arrays are persisted; mode/qchunks/static are manifest
#: metadata.
CACHE_DATA_FIELDS = ("k", "v", "kv_pos", "k_scale", "k_zero",
                     "v_scale", "v_zero")


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=CACHE_DATA_FIELDS,
                   meta_fields=("mode", "qchunks", "static"))
@dataclasses.dataclass
class SlotKVCache:
    """Slot-indexed decode cache (one layer stack, or one layer inside
    `jax.lax.scan` — every data leaf carries the same leading axes, so
    scanning the dataclass over L yields per-layer `SlotKVCache` slices).

    mode="fp":   k/v (L, N, T, Hkv, D) in a float dtype; scales are
                 zero-size placeholders (shape (L, N, T, Hkv, 0)).
    mode="int8": k/v int8 codes; {k,v}_{scale,zero} fp32 with C = qchunks
                 contiguous sub-channel chunks per head. Dynamic scales
                 (static=False) are per-entry, shape (L, N, T, Hkv, C);
                 static scales (static=True, from an offline calibration
                 recipe) are per-layer constants, shape (L, 1, 1, Hkv, C) —
                 writes skip the runtime min/max reduce entirely and the
                 scale arrays are never updated.
    """

    k: jnp.ndarray
    v: jnp.ndarray
    kv_pos: jnp.ndarray          # (L, N, T) int32, -1 = empty
    k_scale: jnp.ndarray
    k_zero: jnp.ndarray
    v_scale: jnp.ndarray
    v_zero: jnp.ndarray
    mode: str = "fp"
    qchunks: int = 4
    static: bool = False

    @property
    def n_slots(self) -> int:
        return self.k.shape[-4]

    @property
    def max_len(self) -> int:
        return self.k.shape[-3]

    def bytes_per_token(self) -> float:
        """Storage bytes per cached token per layer (both K and V).
        Static scales are per-layer constants — amortized to ~0/token."""
        Hkv, D = self.k.shape[-2], self.k.shape[-1]
        per_elt = self.k.dtype.itemsize
        per_chunk = (0 if self.static
                     else 2 * 4 * self.k_scale.shape[-1])   # scale+zero fp32
        return 2 * (Hkv * D * per_elt + Hkv * per_chunk)


def init_slot_cache(cfg, n_slots: int, max_len: int, *, mode: str = "fp",
                    dtype=jnp.float32, qchunks: int = 4,
                    kv_scales: Optional[dict] = None) -> SlotKVCache:
    """Preallocate the engine cache for a transformer-family config.

    ``kv_scales`` (int8 mode only): precomputed static quantization
    parameters from an offline calibration recipe — a dict with keys
    ``k_scale / k_zero / v_scale / v_zero``, each (L, Hkv, C) fp32. When
    given, decode writes quantize with these constants instead of running
    the per-step min/max reduce (dynamic ranges stay the default).
    """
    if mode not in ("fp", "int8"):
        raise ValueError(f"unknown KV cache mode {mode!r}")
    if kv_scales is not None and mode != "int8":
        raise ValueError("static kv_scales require mode='int8'")
    L, Hkv, D = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    if mode == "int8" and D % qchunks:
        raise ValueError(f"head_dim {D} not divisible by qchunks {qchunks}")
    shape = (L, n_slots, max_len, Hkv, D)
    C = qchunks if mode == "int8" else 0
    kv_dtype = jnp.int8 if mode == "int8" else dtype
    kv = dict(k=jnp.zeros(shape, kv_dtype), v=jnp.zeros(shape, kv_dtype),
              kv_pos=jnp.full((L, n_slots, max_len), -1, jnp.int32))
    if kv_scales is not None:
        got = check_static_scales(kv_scales, L, Hkv, qchunks)
        return SlotKVCache(**kv, **got, mode=mode, qchunks=qchunks,
                           static=True)
    sshape = (L, n_slots, max_len, Hkv, C)
    # scales init to 1 (not 0): unwritten entries must dequantize to a
    # finite 0, because masked-out attention rows still flow through the
    # p·V einsum where 0·NaN would poison the output.
    one = functools.partial(jnp.ones, dtype=jnp.float32)
    zero = functools.partial(jnp.zeros, dtype=jnp.float32)
    return SlotKVCache(
        **kv,
        k_scale=one(sshape), k_zero=zero(sshape),
        v_scale=one(sshape), v_zero=zero(sshape),
        mode=mode, qchunks=qchunks)


def check_static_scales(kv_scales: dict, L: int, Hkv: int,
                        qchunks: int) -> dict:
    """Validate recipe kv_scales ((L, Hkv, C) each) and reshape to the
    per-layer-constant cache layout (L, 1, 1, Hkv, C)."""
    expect = (L, Hkv, qchunks)
    got = {}
    for kk in ("k_scale", "k_zero", "v_scale", "v_zero"):
        arr = jnp.asarray(kv_scales[kk], jnp.float32)
        if tuple(arr.shape) != expect:
            raise ValueError(
                f"static kv_scales[{kk!r}] has shape {tuple(arr.shape)}"
                f", expected (L, Hkv, qchunks) = {expect} — was the "
                f"recipe calibrated with a different qchunks or arch?")
        got[kk] = arr.reshape(L, 1, 1, Hkv, qchunks)
    return got


# ----------------------------------------------------------- quant core ---
def quantize_kv(x: jnp.ndarray, qchunks: int):
    """x (..., Hkv, D) → (codes int8 (..., Hkv, D), scale, zero (..., Hkv, C)).

    Per-chunk dynamic ranges: split D into C contiguous chunks, each gets
    its own (β, α) → (S, Z).
    """
    *lead, H, D = x.shape
    xc = x.reshape(*lead, H, qchunks, D // qchunks)
    beta, alpha = value_range(xc, axis=-1)
    scale, zero = qparams(beta, alpha, KV_QCFG)
    q = quantize(xc, scale[..., None], zero[..., None], KV_QCFG)
    return q.reshape(x.shape), scale, zero


def quantize_kv_static(x: jnp.ndarray, scale: jnp.ndarray,
                       zero: jnp.ndarray) -> jnp.ndarray:
    """x (..., Hkv, D), scale/zero broadcastable (..., Hkv, C) → int8 codes.

    Static-scale write: no range pass at all — a single fused
    scale+round+clip over the activation (the decode-critical-path win a
    calibration recipe buys; cf. the dynamic `quantize_kv` above).

    Unlike the runtime path (paper eq. 3 rounds the zero-point to an
    integer), offline scales carry an EXACT fractional zero-point folded
    into the rounding — ``q = rint(S·x + Z)`` — which removes the
    zero-rounding error term entirely; dequantization ``(q - Z)/S`` is
    unchanged (fractional Z is just another float).
    """
    *lead, H, D = x.shape
    C = scale.shape[-1]
    xc = x.reshape(*lead, H, C, D // C).astype(jnp.float32)
    q = jnp.clip(jnp.rint(scale[..., None] * xc + zero[..., None]),
                 KV_QCFG.qmin, KV_QCFG.qmax)
    return q.astype(jnp.int8).reshape(x.shape)


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray,
                  dtype=jnp.float32) -> jnp.ndarray:
    """codes (..., Hkv, D), scale/zero (..., Hkv, C) → x̂ (..., Hkv, D)."""
    *lead, H, D = q.shape
    C = scale.shape[-1]
    qc = q.reshape(*lead, H, C, D // C)
    x = dequantize(qc, scale[..., None], zero[..., None], dtype)
    return x.reshape(q.shape)


# ----------------------------------------------- per-layer decode update ---
def slot_layer_write(cl: SlotKVCache, k_new, v_new, positions
                     ) -> SlotKVCache:
    """One decode-step cache WRITE for ONE layer: quantize-in (int8 modes)
    and scatter the new token — nothing is read back or dequantized.

    cl: per-layer slice — leaves (N, T, Hkv, D) / (N, T, Hkv, C) / (N, T).
    k_new/v_new: (N, 1, Hkv, D) post-RoPE. positions: (N, 1) int32 absolute
    per-slot positions (the time-index written is positions % T, though the
    engine never wraps — it retires at max_len).
    """
    T = cl.k.shape[-3]
    slot_t = (positions[:, 0] % T).astype(jnp.int32)       # (N,)

    def upd(buf, new, t):
        return jax.lax.dynamic_update_slice(
            buf, new.astype(buf.dtype), (t,) + (0,) * (buf.ndim - 1))

    pos_upd = dict(kv_pos=jax.vmap(upd)(cl.kv_pos,
                                        positions.astype(jnp.int32), slot_t))
    if cl.mode == "int8" and cl.static:
        # static scales: quantize with the calibrated per-layer constants —
        # no min/max reduce, and the scale arrays are never written
        qk = quantize_kv_static(k_new, cl.k_scale, cl.k_zero)
        qv = quantize_kv_static(v_new, cl.v_scale, cl.v_zero)
        return dataclasses.replace(
            cl, k=jax.vmap(upd)(cl.k, qk, slot_t),
            v=jax.vmap(upd)(cl.v, qv, slot_t), **pos_upd)
    if cl.mode == "int8":
        qk, ks, kz = quantize_kv(k_new, cl.qchunks)        # (N,1,H,D)/(N,1,H,C)
        qv, vs, vz = quantize_kv(v_new, cl.qchunks)
        return dataclasses.replace(
            cl,
            k=jax.vmap(upd)(cl.k, qk, slot_t),
            v=jax.vmap(upd)(cl.v, qv, slot_t),
            k_scale=jax.vmap(upd)(cl.k_scale, ks, slot_t),
            k_zero=jax.vmap(upd)(cl.k_zero, kz, slot_t),
            v_scale=jax.vmap(upd)(cl.v_scale, vs, slot_t),
            v_zero=jax.vmap(upd)(cl.v_zero, vz, slot_t), **pos_upd)
    return dataclasses.replace(
        cl, k=jax.vmap(upd)(cl.k, k_new, slot_t),
        v=jax.vmap(upd)(cl.v, v_new, slot_t), **pos_upd)


def materialize_layer(cl: SlotKVCache, dtype=jnp.float32):
    """Full-precision (k, v) view of one layer's slot cache — the LEGACY
    read path (and the oracle the fused kernel is tested against). Costs a
    full dequant pass + a (N, T, Hkv, D) fp copy per call."""
    if cl.mode == "int8":
        return (dequantize_kv(cl.k, cl.k_scale, cl.k_zero, dtype),
                dequantize_kv(cl.v, cl.v_scale, cl.v_zero, dtype))
    return cl.k.astype(dtype), cl.v.astype(dtype)


def slot_layer_update(cl: SlotKVCache, k_new, v_new, positions):
    """Legacy combined write + materialize: returns (k_full, v_full,
    kv_pos, new_cl) with k_full/v_full (N, T, Hkv, D) in compute precision.
    The fused decode path (`fused_slot_attention`) replaces this read —
    use `slot_layer_write` there so no full-precision copy ever exists."""
    new_cl = slot_layer_write(cl, k_new, v_new, positions)
    k_full, v_full = materialize_layer(new_cl, k_new.dtype)
    return k_full, v_full, new_cl.kv_pos, new_cl


def fused_slot_attention(cl: SlotKVCache, q, q_pos, *, use_pallas=None,
                         interpret: bool = False, kv_chunk=None):
    """Decode attention for one layer straight off the (possibly INT8)
    slot cache — dequant-in-kernel, no full-cache materialization.

    cl: per-layer slice AFTER `slot_layer_write`; q (N, Hq, D) post-RoPE;
    q_pos (N,) int32 current positions. Returns (N, Hq, D).
    """
    from repro.kernels.decode_attention import decode_attention
    if cl.mode == "int8":
        return decode_attention(
            q, cl.k, cl.v, cl.kv_pos, q_pos,
            k_scale=cl.k_scale, k_zero=cl.k_zero,
            v_scale=cl.v_scale, v_zero=cl.v_zero, mode="int8",
            per_entry_scales=not cl.static, kv_chunk=kv_chunk,
            use_pallas=use_pallas, interpret=interpret)
    return decode_attention(q, cl.k, cl.v, cl.kv_pos, q_pos, mode="fp",
                            kv_chunk=kv_chunk, use_pallas=use_pallas,
                            interpret=interpret)


def slot_chunk_prefill(cl: SlotKVCache, q, k_new, v_new, slot, pos_start,
                       length, *, kv_chunk=None, use_pallas=None,
                       interpret: bool = False, verify: bool = False):
    """One CHUNKED-PREFILL step for ONE layer and ONE slot: fused causal
    attention of the chunk's queries over [the slot's already-written
    rows] + [the chunk's own fp K/V], with the chunk quantized in-kernel
    and the codes scattered straight into rows [pos_start, pos_start+Sq)
    of the slot — the prefill-side twin of `slot_layer_write` +
    `fused_slot_attention`. No full-precision copy of the cache (and no
    dense per-request prefill cache at all) ever exists.

    cl: per-layer slice; q (Sq, Hq, D), k_new/v_new (Sq, Hkv, D) post-RoPE;
    slot/pos_start/length are traced scalars. Only the first `length` rows
    become visible (`kv_pos` = absolute position; the padded tail is
    re-marked -1, which is a no-op on rows the next chunk will overwrite
    and drops rows past max_len). Returns (o (Sq, Hq, D), new_cl).

    ``verify``: speculative-verify scoring (DESIGN.md §9) — the chunk is
    a DRAFT WINDOW and must attend its own K/V through the storage
    round-trip so every row's logits match a plain decode step of that
    token; the codes scattered into the slot are identical either way
    (accepted rows land as final slot bytes, rejected rows are undone by
    `rollback_slot`).
    """
    from repro.kernels.prefill_attention import prefill_attention

    Sq = q.shape[0]
    take = functools.partial(jax.lax.dynamic_index_in_dim, index=slot,
                             axis=0, keepdims=False)
    ck, cv, kpos = take(cl.k), take(cl.v), take(cl.kv_pos)
    kw = dict(kv_chunk=kv_chunk, use_pallas=use_pallas, interpret=interpret,
              verify=verify)
    if cl.mode == "int8" and cl.static:
        o, (qk, qv) = prefill_attention(
            q, k_new, v_new, ck, cv, kpos, pos_start, length,
            k_scale=cl.k_scale[0, 0], k_zero=cl.k_zero[0, 0],
            v_scale=cl.v_scale[0, 0], v_zero=cl.v_zero[0, 0],
            mode="int8", per_entry_scales=False, **kw)
        scale_upd = {}
    elif cl.mode == "int8":
        o, (qk, qv, ks, kz, vs, vz) = prefill_attention(
            q, k_new, v_new, ck, cv, kpos, pos_start, length,
            k_scale=take(cl.k_scale), k_zero=take(cl.k_zero),
            v_scale=take(cl.v_scale), v_zero=take(cl.v_zero),
            mode="int8", per_entry_scales=True, **kw)
        scale_upd = dict(k_scale=(cl.k_scale, ks), k_zero=(cl.k_zero, kz),
                         v_scale=(cl.v_scale, vs), v_zero=(cl.v_zero, vz))
    else:
        o, _ = prefill_attention(q, k_new, v_new, ck, cv, kpos, pos_start,
                                 length, mode="fp", **kw)
        qk, qv = k_new, v_new
        scale_upd = {}

    rows = pos_start + jnp.arange(Sq, dtype=jnp.int32)
    posv = jnp.where(jnp.arange(Sq) < length, rows, jnp.int32(-1))

    def put(buf, upd):
        # scatter with OOB drop: a bucket-padded final chunk may stick out
        # past max_len — those rows carry no valid tokens by construction
        return buf.at[slot, rows].set(upd.astype(buf.dtype), mode="drop")

    new_cl = dataclasses.replace(
        cl, k=put(cl.k, qk), v=put(cl.v, qv),
        kv_pos=cl.kv_pos.at[slot, rows].set(posv, mode="drop"),
        **{f: put(buf, upd) for f, (buf, upd) in scale_upd.items()})
    return o, new_cl


def hotswap_static_scales(cache: SlotKVCache, kv_scales: dict
                          ) -> SlotKVCache:
    """Switch a DYNAMIC int8 cache to static recipe scales mid-flight —
    no slot drain (ROADMAP item). Existing codes are requantized under the
    new constants (dequant with their per-entry scales, requantize with
    the per-layer constants — a one-time migration pass; invalid entries
    carry garbage but stay masked by kv_pos). From then on the `static`
    flag routes writes through `quantize_kv_static`: the per-step min/max
    reduce and the scale-array scatter both disappear, and the (L, N, T,
    Hkv, C) per-entry scale arrays are dropped for (L, 1, 1, Hkv, C)
    constants."""
    if cache.mode != "int8":
        raise ValueError("hot-swap requires an int8 cache")
    if cache.static:
        raise ValueError("cache already serves static scales")
    L, Hkv = cache.k.shape[0], cache.k.shape[-2]
    got = check_static_scales(kv_scales, L, Hkv, cache.qchunks)
    k = quantize_kv_static(
        dequantize_kv(cache.k, cache.k_scale, cache.k_zero),
        got["k_scale"], got["k_zero"])
    v = quantize_kv_static(
        dequantize_kv(cache.v, cache.v_scale, cache.v_zero),
        got["v_scale"], got["v_zero"])
    return dataclasses.replace(cache, k=k, v=v, static=True, **got)


# ------------------------------------------------------ slot management ---
def write_prefill(cache: SlotKVCache, slot: int, prefill_cache,
                  length: int) -> SlotKVCache:
    """Insert a single request's prefill KV (a standard `models.KVCache`
    with batch 1, k/v (L, 1, S, Hkv, D)) into slot `slot`.

    Only positions [0, length) become visible; the slot's whole kv_pos row
    is rewritten, so stale state from the slot's previous occupant (and any
    right-padding the prefill bucket added) is invalidated in one write.
    """
    k, v = prefill_cache.k[:, 0], prefill_cache.v[:, 0]    # (L, S, Hkv, D)
    L, S, H, D = k.shape
    T = cache.max_len
    if S > T:
        raise ValueError(f"prefill length {S} exceeds cache max_len {T}")
    if S < T:
        pad = [(0, 0), (0, T - S), (0, 0), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    t = jnp.arange(T, dtype=jnp.int32)
    pos_row = jnp.where(t < length, t, -1)                 # (T,)
    pos_row = jnp.broadcast_to(pos_row, (L, T))

    def put(buf, row):
        idx = (0, slot) + (0,) * (buf.ndim - 2)
        return jax.lax.dynamic_update_slice(
            buf, row[:, None].astype(buf.dtype), idx)

    if cache.mode == "int8" and cache.static:
        # per-layer static constants: index as (L, Hkv, C) for the (L, S,
        # Hkv, D) prefill block, then write codes only
        ks, kz = cache.k_scale[:, 0], cache.k_zero[:, 0]   # (L, 1, Hkv, C)
        vs, vz = cache.v_scale[:, 0], cache.v_zero[:, 0]
        qk = quantize_kv_static(k, ks, kz)
        qv = quantize_kv_static(v, vs, vz)
        return dataclasses.replace(
            cache, k=put(cache.k, qk), v=put(cache.v, qv),
            kv_pos=put(cache.kv_pos, pos_row))
    if cache.mode == "int8":
        qk, ks, kz = quantize_kv(k, cache.qchunks)
        qv, vs, vz = quantize_kv(v, cache.qchunks)
        return dataclasses.replace(
            cache, k=put(cache.k, qk), v=put(cache.v, qv),
            k_scale=put(cache.k_scale, ks), k_zero=put(cache.k_zero, kz),
            v_scale=put(cache.v_scale, vs), v_zero=put(cache.v_zero, vz),
            kv_pos=put(cache.kv_pos, pos_row))
    return dataclasses.replace(
        cache, k=put(cache.k, k), v=put(cache.v, v),
        kv_pos=put(cache.kv_pos, pos_row))


def clear_slot(cache: SlotKVCache, slot: int) -> SlotKVCache:
    """Mark a slot empty (retire). K/V bytes are left in place — kv_pos=-1
    masks them, and the next write_prefill overwrites the row."""
    row = jnp.full((cache.kv_pos.shape[0], cache.max_len), -1, jnp.int32)
    return dataclasses.replace(
        cache, kv_pos=jax.lax.dynamic_update_slice(
            cache.kv_pos, row[:, None], (0, slot, 0)))


def rollback_slot(cache: SlotKVCache, slot: int, accept_len: int
                  ) -> SlotKVCache:
    """Undo speculative writes past the accepted point: after this call
    the slot's valid content is exactly positions [0, accept_len).

    Validity-by-position makes this the WHOLE rollback (DESIGN.md §9):
    every read path masks rows by ``kv_pos``, so flipping the rejected
    rows to -1 removes them from all attention, and the codes/scales left
    behind are indistinguishable from the stale bytes any retired slot
    leaves — the next write at those positions overwrites them, which is
    why a rolled-back slot re-decoded over the accepted prefix is
    bit-identical to a slot that never speculated (hypothesis property in
    tests/test_spec.py). ``slot`` / ``accept_len`` may be traced scalars.
    """
    L, _, T = cache.kv_pos.shape
    row = jax.lax.dynamic_slice(cache.kv_pos, (0, slot, 0), (L, 1, T))
    row = jnp.where(row >= accept_len, jnp.int32(-1), row)
    return dataclasses.replace(
        cache, kv_pos=jax.lax.dynamic_update_slice(
            cache.kv_pos, row, (0, slot, 0)))


def slice_layers(cache: SlotKVCache, lo: int, hi: int) -> SlotKVCache:
    """Layer-range view, mirroring `forward`'s dense/MoE stack split."""
    return jax.tree_util.tree_map(lambda x: x[lo:hi], cache)


def occupied_slots(cache: SlotKVCache) -> list[int]:
    """Slots with ANY valid (kv_pos >= 0) row — the slot-pool leak
    check. After a full drain every request has retired and `clear_slot`
    flipped its rows to -1, so a non-empty result means a retire path
    forgot the cache half of the slot (asserted over target AND draft
    caches by the chaos harness, tests/test_faults.py). One bounded
    host transfer of the position plane; diagnostics, not hot path."""
    import numpy as np
    pos = np.asarray(cache.kv_pos)                    # (L, N, T)
    return np.unique(np.nonzero((pos >= 0).any(axis=(0, 2)))[0]).tolist()


# -------------------------------------------------- quality counters ---
def kv_quality_counters(cache: SlotKVCache, max_rows: int = 4096,
                        ref_scales: Optional[dict] = None) -> dict:
    """Sample quantization-quality counters from a live int8 slot cache
    (host-side numpy; see `repro.obs.quality` and DESIGN.md §10).

    Reads only rows kv_pos marks valid (stale retired/rolled-back bytes
    would poison the statistics), subsampling evenly to ``max_rows``
    (token, slot) rows per array so the transfer stays bounded on big
    caches. Returns a flat dict of numbers/lists — the shape the tracer's
    ``counter`` records and the Chrome exporter expect:

    * ``{k,v}_clip_frac`` / ``{k,v}_occupancy`` — code saturation and
      code-range use (`quality.code_stats`); the static-scale drift
      signals (clipping up = recipe too narrow, occupancy down = too
      wide).
    * dynamic scales only: ``{k,v}_span_median`` / ``_span_outlier_hist``
      — per-chunk range spread and the OCS outlier histogram, plus
      ``_occupancy_vs_ref`` when a recipe's ``ref_scales`` dict
      ((L, Hkv, C) arrays, same layout as `init_slot_cache`) is given to
      compare live ranges against.
    """
    import numpy as np

    from repro.obs.quality import code_stats, scale_to_span, span_stats

    if cache.mode != "int8":
        raise ValueError("KV quality counters require an int8 cache")
    valid = np.asarray(cache.kv_pos) >= 0                  # (L, N, T)
    n_valid = int(valid.sum())
    out: dict = {"valid_rows": n_valid, "static": int(cache.static),
                 "qchunks": cache.qchunks}
    if not n_valid:
        return out
    lidx, nidx, tidx = np.nonzero(valid)
    if lidx.size > max_rows:                    # even, deterministic
        keep = np.linspace(0, lidx.size - 1, max_rows).astype(np.int64)
        lidx, nidx, tidx = lidx[keep], nidx[keep], tidx[keep]
    out["sampled_rows"] = int(lidx.size)
    for name, codes in (("k", cache.k), ("v", cache.v)):
        cs = code_stats(np.asarray(codes)[lidx, nidx, tidx],
                        bits=8)
        out[f"{name}_clip_frac"] = cs["clip_frac"]
        out[f"{name}_occupancy"] = cs["occupancy"]
    if not cache.static:
        for name, scale in (("k", cache.k_scale), ("v", cache.v_scale)):
            spans = scale_to_span(np.asarray(scale)[lidx, nidx, tidx])
            ref = None
            if ref_scales is not None:
                # recipe scales are per-layer constants (L, Hkv, C):
                # broadcast to the sampled rows through lidx
                ref = scale_to_span(
                    np.asarray(ref_scales[f"{name}_scale"],
                               np.float64)[lidx])
            st = span_stats(spans, ref)
            out[f"{name}_span_median"] = st["span_median"]
            out[f"{name}_span_outlier_hist"] = st["outlier_hist"]
            if ref is not None:
                out[f"{name}_occupancy_vs_ref"] = st["occupancy_vs_ref"]
    return out
