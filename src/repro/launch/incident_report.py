"""Postmortem reporter for incident bundles (repro.obs.flight).

    PYTHONPATH=src python -m repro.launch.incident_report /tmp/incidents/incident-000-step_retry

Merges the bundle's flight-recorder window, its journal tail (plus an
optional full ``--journal``), and an optional ``--trace`` JSONL into one
uid/step-keyed timeline, names the triggering detector, and prints
root-cause hints. Options:

  --validate     structural validation for CI; exit 1 on any error
  --journal P    full request journal to merge (supersedes the tail)
  --trace P      tracer JSONL to correlate (slot spans per uid)
  --window N     how many trailing flight-record rows to print

Correlation semantics (DESIGN.md §14): the flight window is the step
axis — each record carries the uids holding slots that step, so a uid's
slot residency is the [first, last] step it appears. Journal/trace
records are uid-keyed, not step-keyed; they are joined per uid, and the
trigger's uid (when attributable) gets the merged per-uid story."""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.detect import DETECTORS
from repro.obs.flight import load_incident_bundle

_LIFECYCLE_ORDER = ("submit", "admit", "first_token", "retire")


def _journal_events(bundle: dict, journal_path: str | None) -> list[dict]:
    """Event records from --journal (preferred) or the bundle tail."""
    recs: list[dict] = []
    if journal_path:
        try:
            with open(journal_path) as f:
                recs = [json.loads(ln) for ln in f if ln.strip()]
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: --journal {journal_path} unreadable ({e}); "
                  f"falling back to bundle tail")
            recs = []
    if not recs:
        recs = bundle.get("journal_tail.jsonl", []) or []
    return [r for r in recs if r.get("kind") == "event"]


def _uid_stories(events: list[dict]) -> dict:
    """uid -> ordered lifecycle events (journal axis)."""
    out: dict = {}
    for r in events:
        uid = r.get("uid")
        if uid is None:
            continue
        out.setdefault(uid, []).append(r)
    return out


def _uid_steps(flight: list[dict]) -> dict:
    """uid -> (first step, last step) slot residency from the window."""
    out: dict = {}
    for rec in flight:
        for uid in rec.get("uids", ()) or ():
            first, _ = out.get(uid, (rec["step"], rec["step"]))
            out[uid] = (first, rec["step"])
    return out


def _fired_steps(trigger_doc: dict) -> dict:
    """step -> [detector names] for every firing in the bundle."""
    out: dict = {}
    for f in trigger_doc.get("firings", []):
        out.setdefault(f.get("step"), []).append(f.get("detector"))
    return out


def print_timeline(flight: list[dict], trigger_doc: dict,
                   window: int) -> None:
    fired = _fired_steps(trigger_doc)
    rows = flight[-window:] if window > 0 else flight
    if not rows:
        print("\ntimeline: flight window empty (recorder disabled?)")
        return
    print(f"\ntimeline — last {len(rows)} of {len(flight)} flight "
          f"records (step axis):")
    hdr = (f"  {'step':>5} {'wall ms':>8} {'q':>3} {'rung':>4} "
           f"{'retry':>5} {'quar':>4} {'acc':>5} {'clip':>5}  "
           f"uids / firings")
    print(hdr)
    print("  " + "-" * (len(hdr) - 2))
    # a window that starts at step 0 has a true zero baseline; a
    # wrapped window can only show deltas from its second row on
    prev_retries = 0 if rows[0].get("step") == 0 \
        else rows[0].get("retries", 0)
    for rec in rows:
        acc = rec.get("accept")
        clip = rec.get("clip_frac")
        d_retry = rec.get("retries", 0) - prev_retries
        prev_retries = rec.get("retries", 0)
        mark = ""
        if rec["step"] in fired:
            mark = "  << " + ",".join(fired[rec["step"]])
        print(f"  {rec['step']:>5} {rec.get('step_s', 0) * 1e3:>8.2f} "
              f"{rec.get('queue', 0):>3} {rec.get('rung', 0):>4} "
              f"{'+' + str(d_retry) if d_retry else '.':>5} "
              f"{rec.get('quarantined', 0):>4} "
              f"{'-' if acc is None else f'{acc:.2f}':>5} "
              f"{'-' if clip is None else f'{clip:.2f}':>5}  "
              f"{rec.get('uids', [])}{mark}")


def print_uid_story(uid, stories: dict, residency: dict,
                    trace_records: list[dict]) -> None:
    print(f"\nuid {uid}:")
    if uid in residency:
        a, b = residency[uid]
        print(f"  slot residency: steps {a}..{b} (flight window)")
    evs = stories.get(uid, [])
    if evs:
        for r in evs:
            extra = {k: v for k, v in r.items()
                     if k in ("reason", "slot", "n_out", "step")}
            print(f"  journal {r.get('ts', 0):9.3f}s  "
                  f"{r.get('name', '?'):<12} {extra}")
    else:
        print("  no journal events (outside tail window — pass "
              "--journal for the full WAL)")
    if trace_records:
        mine = [r for r in trace_records if r.get("uid") == uid]
        if mine:
            names = sorted({r.get("name") for r in mine})
            print(f"  trace: {len(mine)} records ({', '.join(names)})")


def root_cause_hints(bundle: dict) -> list[str]:
    """Rule-based hints from the trigger + flight window — named causal
    reads of the signals, not guesses presented as facts."""
    trig_doc = bundle["trigger.json"]
    trig = trig_doc["trigger"]
    det, uid, step = trig["detector"], trig.get("uid"), trig.get("step")
    flight = bundle["flight.json"].get("records", [])
    reqs = bundle.get("requests.json", {}) or {}
    poison = set(reqs.get("poison_uids", []) or [])
    counts = trig_doc.get("faults_injected") or {}
    hints: list[str] = []

    def rung_ascent_before(s):
        prev = 0
        for rec in flight:
            if rec["step"] >= s:
                break
            if rec.get("rung", 0) > prev:
                prev = rec["rung"]
                yield rec["step"], rec["rung"]

    if det == "step_retry":
        hints.append(
            f"step retry at step {step}"
            + (f" attributed to uid {uid}" if uid is not None else
               " (unattributable — raised exception, not corrupt output)")
            + ": all active slots rolled back and re-executed "
              "bit-identically (greedy purity).")
        if uid is not None and uid in poison:
            hints.append(f"uid {uid} is in the injector's poison set — "
                         f"corruption will recur until quarantine.")
        if any(counts.get(k) for k in ("step_exceptions",
                                       "token_corruptions")):
            hints.append(f"seeded fault injector was active "
                         f"({counts}) — injected, not organic.")
    elif det == "quarantine":
        hints.append(
            f"uid {uid} retired 'failed' after exhausting max_retries — "
            f"its output stayed corrupt across rollback re-executions.")
        if uid in poison:
            hints.append(f"uid {uid} is in the injector's poison set: "
                         f"quarantine is the designed containment.")
    elif det == "accept_collapse":
        ascents = list(rung_ascent_before(step))
        if ascents:
            s_r, rung = ascents[-1]
            hints.append(
                f"acceptance collapsed {step - s_r} steps after rung-"
                f"{rung} suspended speculation at step {s_r} — suspended "
                f"steps leave draft-cache holes that cost acceptance on "
                f"resume.")
        else:
            hints.append(
                "acceptance collapsed with no rung ascent in the window "
                "— draft/target divergence (recipe drift?), not ladder "
                "suspension.")
    elif det == "kv_clip_spike":
        later = [r for r in flight if r["step"] > step]
        base = next((r.get("retries", 0) for r in flight
                     if r["step"] == step), 0)
        if any(r.get("retries", 0) > base for r in later):
            hints.append(
                f"clip-frac spike at step {step} preceded retry "
                f"activity — saturating KV codes degrade logits before "
                f"they corrupt them.")
        hints.append(
            "clip fraction trending up means the static scales drifted "
            "narrow for live data — recalibrate the KV recipe "
            "(calib_bench) or switch the cache to dynamic scales.")
    elif det == "queue_runaway":
        if all(r.get("rung", 0) == 0 for r in flight):
            hints.append(
                "queue exceeded the admission set point with the "
                "degradation ladder flat at rung 0 — run with --degrade "
                "or lower --max-queue to shed earlier.")
        else:
            hints.append(
                "queue exceeded the set point despite ladder activity — "
                "offered load is beyond the shed thresholds.")
    elif det == "rung_ascent":
        hints.append(
            f"pressure (queue + prefill backlog) crossed a ladder "
            f"threshold at step {step}: rung 1 suspends speculation, "
            f"rung 2 defers batch admissions, rung 3 sheds queued load.")
    elif det == "step_latency_spike":
        hints.append(
            f"step wall spiked vs the rolling baseline at step {step} — "
            f"usual suspects: a jit recompile (new prefill bucket "
            f"shape), an injected slow step, or host contention.")
        if counts.get("slow_steps"):
            hints.append(f"injector reports {counts['slow_steps']} "
                         f"slow step(s) — injected straggler.")
    elif det == "integrity_error":
        hints.append(
            f"artifact failed integrity validation and was refused: "
            f"{trig.get('reason', '')} — regenerate the snapshot/recipe; "
            f"the engine never serves a corrupt artifact.")
    elif det == "injected_crash":
        hints.append(
            "process died at a step boundary (chaos crash injection); "
            "the journal tail ends at the crash horizon and the "
            "supervisor restarted + recovered from snapshot + WAL "
            "replay. Recovered outputs are bit-identical by greedy "
            "purity.")
    return hints


def validate_bundle(bundle: dict) -> list[str]:
    """Structural checks beyond load_incident_bundle's parse pass."""
    errs: list[str] = []
    trig_doc = bundle.get("trigger.json", {})
    trig = trig_doc.get("trigger") or {}
    if trig.get("detector") not in DETECTORS:
        errs.append(f"trigger detector {trig.get('detector')!r} not in "
                    f"catalog {DETECTORS}")
    if not trig_doc.get("firings"):
        errs.append("trigger.json lists no firings")
    flight = bundle.get("flight.json", {}).get("records", [])
    steps = [r.get("step") for r in flight]
    if steps != sorted(steps):
        errs.append("flight records out of step order")
    for i, rec in enumerate(flight):
        if "step_s" not in rec or "uids" not in rec:
            errs.append(f"flight record {i} missing step_s/uids")
            break
    for r in bundle.get("journal_tail.jsonl", []) or []:
        if r.get("kind") not in ("header", "event", "counter", "span"):
            errs.append(f"journal tail record kind {r.get('kind')!r} "
                        f"unknown")
            break
    reqs = bundle.get("requests.json", {})
    if not isinstance(reqs.get("active"), list) \
            or not isinstance(reqs.get("queued"), list):
        errs.append("requests.json lacks active/queued lists")
    fp = bundle.get("fingerprint.json", {})
    if not fp.get("arch"):
        errs.append("fingerprint.json lacks arch")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="merge an incident bundle + journal + trace into a "
                    "uid/step-keyed postmortem timeline")
    ap.add_argument("bundle", help="incident bundle directory")
    ap.add_argument("--validate", action="store_true",
                    help="structural validation for CI; exit 1 on error")
    ap.add_argument("--journal", default=None, metavar="PATH",
                    help="full request journal (supersedes bundle tail)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="tracer JSONL to correlate per uid")
    ap.add_argument("--window", type=int, default=30,
                    help="trailing flight rows to print (default 30)")
    args = ap.parse_args(argv)

    try:
        bundle = load_incident_bundle(args.bundle)
    except ValueError as e:
        print(f"{args.bundle}: INVALID bundle — {e}")
        return 1
    errs = validate_bundle(bundle)

    trig_doc = bundle["trigger.json"]
    trig = trig_doc["trigger"]
    fp = bundle.get("fingerprint.json", {})
    print(f"{args.bundle}: trigger {trig['detector']} at step "
          f"{trig.get('step')}"
          + (f" (uid {trig['uid']})" if trig.get("uid") is not None
             else ""))
    print(f"  reason: {trig.get('reason', '')}")
    print(f"  engine: arch {fp.get('arch')} slots {fp.get('n_slots')} "
          f"kv {fp.get('kv_mode')} spec_k {fp.get('spec_k')}")
    others = [f for f in trig_doc.get("firings", [])[1:]]
    if others:
        print(f"  co-firings: "
              + ", ".join(f"{f['detector']}@{f['step']}" for f in others))

    if errs:
        print(f"\nvalidation: {len(errs)} error(s)")
        for e in errs:
            print(f"  {e}")
        if args.validate:
            return 1
    else:
        print("validation: ok")

    flight = bundle["flight.json"].get("records", [])
    print_timeline(flight, trig_doc, args.window)

    events = _journal_events(bundle, args.journal)
    stories = _uid_stories(events)
    residency = _uid_steps(flight)
    trace_records: list[dict] = []
    if args.trace:
        from repro.obs import load_jsonl
        try:
            trace_records = load_jsonl(args.trace)
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: --trace {args.trace} unreadable ({e})")
    # the trigger's uid first, then every uid active at the trigger step
    focus: list = []
    if trig.get("uid") is not None:
        focus.append(trig["uid"])
    at_trigger = next((r.get("uids", []) for r in flight
                       if r.get("step") == trig.get("step")), [])
    focus += [u for u in at_trigger if u not in focus]
    for uid in focus[:8]:
        print_uid_story(uid, stories, residency, trace_records)

    hints = root_cause_hints(bundle)
    if hints:
        print("\nroot-cause hints:")
        for h in hints:
            print(f"  * {h}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
