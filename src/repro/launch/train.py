"""End-to-end distributed training driver.

Usage (single host / CI):
    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real fleet this same driver runs under the cluster launcher with one
process per host; the mesh adapts to jax.devices() (elastic), checkpoints
are host-sharded, and restart resumes from the last atomic step.
"""
from __future__ import annotations

import argparse
import functools

import jax

from repro.configs import get_arch
from repro.data import DataConfig, Prefetcher, synthetic_lm_batch
from repro.models import get_model
from repro.optim import adamw
from repro.runtime import train_loop
from .mesh import make_local_mesh, make_production_mesh
from .shardings import batch_shardings, opt_shardings, param_shardings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compress", default=None, choices=[None, "int8"])
    ap.add_argument("--opt-dtype", default="float32")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())
    key = jax.random.PRNGKey(0)

    opt_cfg = adamw.OptConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=min(100, args.steps // 10 + 1),
                              state_dtype=args.opt_dtype,
                              grad_compress=args.grad_compress)

    def loss_fn(p, b):
        return model.loss_fn(p, cfg, b, remat=True)

    with mesh:
        params = model.init(key, cfg)
        p_sh = param_shardings(params, mesh)
        params = jax.device_put(params, p_sh)
        opt_state = adamw.init(opt_cfg, params)
        o_sh = opt_shardings(opt_state, p_sh, mesh)
        opt_state = jax.device_put(opt_state, o_sh)

        dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                        global_batch=args.batch)
        sample = synthetic_lm_batch(dc, 0)
        b_sh = batch_shardings(sample, mesh)

        step_fn = jax.jit(train_loop.make_train_step(loss_fn, opt_cfg),
                          in_shardings=(p_sh, o_sh, b_sh),
                          donate_argnums=(0, 1))

        def make_batch(step):
            return jax.device_put(synthetic_lm_batch(dc, step), b_sh)

        pre = Prefetcher(make_batch, 0, depth=2)
        lc = train_loop.TrainLoopConfig(
            total_steps=args.steps, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every)
        params, opt_state, hist = train_loop.run(
            lc, step_fn, params, opt_state, pre.get)
        pre.stop()
        print(f"final loss {hist[-1]['loss']:.4f} "
              f"(start {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
