"""Trip-count-aware HLO analysis for the roofline terms.

``compiled.cost_analysis()`` counts each While body ONCE, so scan-over-
layers models under-report FLOPs by ~L×. This module parses the post-SPMD
per-device HLO text into its computation call graph, extracts per-
computation dot FLOPs / dot bytes / collective bytes, and walks the graph
multiplying by ``known_trip_count`` at each while op.

Reported terms (per device):
  * dot_flops        — 2·M·N·K summed over dot ops × trip counts. Vector
                       (elementwise) FLOPs are excluded: on TPU the MXU
                       term dominates the compute roofline.
  * dot_bytes        — Σ (lhs + rhs + out) bytes of every dot × trips: a
                       proxy for HBM traffic (weights/activations stream
                       HBM→VMEM per matmul; elementwise chains fuse).
  * collective_bytes — Σ output bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute ×
                       trips, per op type.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}
COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)"
    r"%?([\w\.\-]+)")


def _dims(s: str) -> list[int]:
    return [int(d) for d in s.split(",") if d]


def _nelems(s: str) -> int:
    n = 1
    for d in _dims(s):
        n *= d
    return n


def _shape_bytes(dtype: str, dims: str) -> int:
    return _nelems(dims) * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class Comp:
    name: str
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    coll_bytes: dict = field(default_factory=lambda: {o: 0.0 for o in COLL_OPS})
    coll_counts: dict = field(default_factory=lambda: {o: 0 for o in COLL_OPS})
    coll_f32_bytes: float = 0.0   # f32-wire collectives (CPU-lowering artifact)
    calls: list = field(default_factory=list)   # (callee, multiplier)


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _parse_dot(line: str, symtab: dict) -> tuple[float, float]:
    """(flops, bytes) of one dot line; operand shapes via the computation's
    symbol table (HLO prints operands as bare %names)."""
    md = _DEF_RE.match(line)
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    if md is None or mc is None:
        return 0.0, 0.0
    out_dt, out_dims = md.group(2), md.group(3)
    args_part = line.split(" dot(", 1)[1].split(")", 1)[0]
    ops = _OPERAND_RE.findall(args_part)
    lhs = symtab.get(ops[0]) if ops else None
    rhs = symtab.get(ops[1]) if len(ops) > 1 else None
    if lhs is None:
        return 0.0, 0.0
    ld = _dims(lhs[1])
    contract = 1
    for ci in _dims(mc.group(1)):
        if ci < len(ld):
            contract *= ld[ci]
    flops = 2.0 * _nelems(out_dims) * contract
    b = _shape_bytes(out_dt, out_dims) + _shape_bytes(lhs[0], lhs[1])
    if rhs is not None:
        b += _shape_bytes(rhs[0], rhs[1])
    return flops, b


def parse_hlo(text: str) -> dict[str, Comp]:
    comps: dict[str, Comp] = {}
    cur: Comp | None = None
    symtab: dict = {}
    entry = None
    for raw in text.splitlines():
        line = raw.strip()
        if line.endswith("{") and ("->" in line or line.startswith("ENTRY")):
            m = re.match(r"(ENTRY\s+)?%?([\w\.\-]+)", line)
            if m:
                cur = Comp(m.group(2))
                comps[cur.name] = cur
                symtab = {}
                # computation parameters carry shapes in the header
                for pn, pd, ps in _PARAM_RE.findall(line):
                    symtab[pn] = (pd, ps)
                if m.group(1):
                    entry = cur.name
            continue
        if line == "}":
            cur = None
            continue
        if cur is None or "=" not in line:
            continue
        md = _DEF_RE.match(line)
        if md:
            symtab[md.group(1)] = (md.group(2), md.group(3))
        if " dot(" in line:
            f, b = _parse_dot(line, symtab)
            cur.dot_flops += f
            cur.dot_bytes += b
        for op in COLL_OPS:
            if f" {op}(" in line or f" {op}-start(" in line:
                lhs = line.split(f" {op}")[0]
                shapes = _SHAPE_RE.findall(lhs)
                b = sum(_shape_bytes(d, s) for d, s in shapes)
                cur.coll_bytes[op] += b
                cur.coll_counts[op] += 1
                cur.coll_f32_bytes += sum(
                    _shape_bytes(d, s) for d, s in shapes if d == "f32")
                break
        if " while(" in line:
            trip = 1
            mt = _TRIP_RE.search(line)
            if mt:
                trip = int(mt.group(1))
            names = _CALL_ATTR_RE.findall(line)
            for n in names:
                cur.calls.append((n, trip))
        elif any(k in line for k in ("calls=", "to_apply=",
                                     "branch_computations=")):
            for n in _CALL_ATTR_RE.findall(line):
                cur.calls.append((n, 1))
    comps["__entry__"] = comps.get(entry, Comp("__missing__"))
    return comps


def analyze(text: str) -> dict:
    """Walk the call graph from ENTRY with trip-count multipliers."""
    comps = parse_hlo(text)
    entry = comps["__entry__"]
    memo: dict[str, tuple] = {}

    def total(name: str):
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None:
            return (0.0, 0.0, {o: 0.0 for o in COLL_OPS},
                    {o: 0 for o in COLL_OPS}, 0.0)
        memo[name] = (0.0, 0.0, {o: 0.0 for o in COLL_OPS},
                      {o: 0 for o in COLL_OPS}, 0.0)  # cycle guard
        f, b = c.dot_flops, c.dot_bytes
        cb = dict(c.coll_bytes)
        cc = dict(c.coll_counts)
        f32b = c.coll_f32_bytes
        for callee, mult in c.calls:
            cf, cbytes, ccoll, ccnt, cf32 = total(callee)
            f += mult * cf
            b += mult * cbytes
            f32b += mult * cf32
            for o in COLL_OPS:
                cb[o] += mult * ccoll[o]
                cc[o] += mult * ccnt[o]
        memo[name] = (f, b, cb, cc, f32b)
        return memo[name]

    f, b, cb, cc, f32b = total(entry.name)
    total_b = sum(cb.values())
    return {"dot_flops": f, "dot_bytes": b,
            "collective_bytes": cb, "collective_counts": cc,
            "collective_total_bytes": total_b,
            # XLA:CPU promotes bf16 reduces to f32 wire format; on TPU the
            # same collectives move bf16 — count those payloads at half.
            "collective_f32_bytes": f32b,
            "collective_total_bytes_tpu": total_b - 0.5 * f32b}
