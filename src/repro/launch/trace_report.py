"""Trace inspector for the engine's JSONL event logs (repro.obs).

    PYTHONPATH=src python -m repro.launch.trace_report /tmp/trace.jsonl

Prints the per-step phase breakdown (draft / verify / rollback / prefill
/ decode, with dispatch-vs-device-wait attribution), the per-request
lifecycle summary, and textual waterfalls. Options:

  --validate        validate against the event schema; exit 1 on errors
  --chrome PATH     re-export the loaded trace as Chrome/Perfetto JSON
  --waterfalls N    how many per-request waterfall rows to draw (0 = off)
  --hlo PATH        cross-check a phase's measured device wait against
                    `hlo_analysis.analyze` roofline terms for that
                    executable's HLO text dump (implied bytes/s, flop/s)
  --hlo-phase NAME  which phase the HLO dump corresponds to (default
                    "decode"; use "verify" for the spec verify
                    executable)
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs import (chrome_trace, lifecycle_summary, load_jsonl,
                       phase_breakdown, request_waterfalls,
                       validate_events)


def _fmt_ms(s) -> str:
    return "-" if s is None else f"{s * 1e3:8.2f}"


def print_phase_table(pb: dict) -> None:
    print(f"\nphase breakdown — {pb['steps']} steps, "
          f"{pb['step_total_s']:.3f} s stepped wall")
    cov = pb["coverage"]
    print(f"  coverage: {'n/a' if cov is None else f'{cov:.1%}'} of step "
          f"wall attributed to phases")
    hdr = (f"  {'phase':<16}{'count':>7}{'total s':>10}{'mean ms':>10}"
           f"{'% step':>8}{'dispatch ms':>13}{'wait ms':>10}{'host ms':>10}")
    print(hdr)
    print("  " + "-" * (len(hdr) - 2))
    order = sorted(pb["phases"].items(), key=lambda kv: -kv[1]["total_s"])
    for name, d in order:
        frac = d["frac_of_step"]
        print(f"  {name:<16}{d['count']:>7}{d['total_s']:>10.3f}"
              f"{d['mean_s'] * 1e3:>10.2f}"
              f"{'-' if frac is None else f'{frac:7.1%}':>8}"
              f"{_fmt_ms(d['dispatch_s'] / d['count']):>13}"
              f"{_fmt_ms(d['device_wait_s'] / d['count']):>10}"
              f"{_fmt_ms(d['host_s'] / d['count']):>10}")
    att = pb["attributed_s"]
    if att:
        print(f"\ndispatch-vs-device attribution over {att:.3f} s "
              f"attributed:")
        print(f"  host dispatch (inside jit calls): "
              f"{pb['dispatch_s']:.3f} s ({pb['dispatch_frac']:.1%})")
        print(f"  device wait (block_until_ready/transfer): "
              f"{pb['device_wait_s']:.3f} s ({pb['device_wait_frac']:.1%})")
        print(f"  other host (commit loops, staging, sched): "
              f"{pb['other_host_s']:.3f} s "
              f"({pb['other_host_s'] / att:.1%})")


def print_waterfalls(records: list, limit: int, width: int = 44) -> None:
    rows = [r for r in request_waterfalls(records)
            if r.get("t_submit") is not None
            and r.get("t_retire") is not None]
    if not rows or not limit:
        return
    t_lo = min(r["t_submit"] for r in rows)
    t_hi = max(r["t_retire"] for r in rows)
    span = max(t_hi - t_lo, 1e-9)

    def col(t):
        return min(width - 1, int((t - t_lo) / span * width))
    print(f"\nper-request waterfalls ({min(limit, len(rows))}/{len(rows)} "
          f"shown; . queued  = prefill  # decode):")
    for r in rows[:limit]:
        bar = [" "] * width
        t_ft = r.get("t_first_token", r["t_retire"])
        t_ad = r.get("t_admit", r["t_submit"])
        for c in range(col(r["t_submit"]), col(t_ad) + 1):
            bar[c] = "."
        for c in range(col(t_ad), col(t_ft) + 1):
            bar[c] = "="
        for c in range(col(t_ft), col(r["t_retire"]) + 1):
            bar[c] = "#"
        print(f"  uid {r['uid']:>4} |{''.join(bar)}| "
              f"{(r['total_s'] or 0) * 1e3:7.1f} ms  "
              f"slot={r.get('slot', '?')} {r.get('n_out', 0)} tok "
              f"[{r.get('reason', '?')}]")


def print_lifecycle(records: list) -> None:
    ls = lifecycle_summary(records)
    if not ls["requests"]:
        print("\nno request lifecycle events in trace")
        return
    print(f"\nlifecycle — {ls['requests']} requests, retire reasons "
          f"{ls['retire_reasons']}")
    for seg in ("queued_s", "prefill_s", "decode_s", "total_s"):
        d = ls[seg]
        print(f"  {seg[:-2]:<8} mean {_fmt_ms(d['mean'])} ms   "
              f"p50 {_fmt_ms(d['p50'])} ms   p95 {_fmt_ms(d['p95'])} ms")


#: How to regenerate an HLO text dump for --hlo (the post-SPMD
#: per-device module text `hlo_analysis.analyze` expects).
_HLO_REGEN = (
    "XLA_FLAGS=--xla_dump_to=/tmp/hlo_dump PYTHONPATH=src \\\n"
    "    python -m repro.launch.serve --arch stablelm-1.6b --reduced "
    "--requests 4\n"
    "  then pass a post-optimization module, e.g.\n"
    "  /tmp/hlo_dump/module_*jit__decode*after_optimizations.txt")


def hlo_crosscheck(pb: dict, hlo_path: str, phase: str) -> None:
    """Marry the trace's measured per-dispatch device wait for ``phase``
    to the executable's static roofline terms: implied HBM bandwidth and
    MXU throughput, the sanity check that the phase's wait is device
    compute and not something pathological.

    Missing or corrupt HLO input fails LOUDLY with the exact regen
    command (the ``--max-queue auto`` precedent): a silent zero-term
    cross-check reads as "the device is infinitely fast", which is worse
    than no cross-check."""
    from repro.launch.hlo_analysis import analyze

    try:
        with open(hlo_path) as f:
            text = f.read()
    except OSError as e:
        raise SystemExit(
            f"--hlo: cannot read {hlo_path} ({e}) — dump the "
            f"executable's HLO first:\n  {_HLO_REGEN}")
    try:
        terms = analyze(text)
    except Exception as e:
        raise SystemExit(
            f"--hlo: {hlo_path} does not parse as HLO module text "
            f"({e}) — regenerate the dump:\n  {_HLO_REGEN}")
    if not terms.get("dot_flops") and not terms.get("dot_bytes"):
        raise SystemExit(
            f"--hlo: {hlo_path} parsed but holds no dot ops — corrupt "
            f"or not a post-optimization module dump. Regenerate:\n"
            f"  {_HLO_REGEN}")
    d = pb["phases"].get(phase)
    if d is None or not d["count"]:
        print(f"\nhlo cross-check: no {phase!r} spans in trace")
        return
    wait = d["device_wait_s"] / d["count"]
    total = d["mean_s"]
    print(f"\nhlo cross-check — {phase!r} vs {hlo_path}:")
    print(f"  dot flops/dispatch: {terms['dot_flops']:.3e}   "
          f"dot bytes/dispatch: {terms['dot_bytes']:.3e}")
    if wait > 0:
        print(f"  implied over mean device wait ({wait * 1e3:.2f} ms): "
              f"{terms['dot_flops'] / wait:.3e} flop/s, "
              f"{terms['dot_bytes'] / wait:.3e} B/s")
    host = total - wait
    print(f"  mean span {total * 1e3:.2f} ms = {host * 1e3:.2f} ms host "
          f"+ {wait * 1e3:.2f} ms device wait "
          f"({'host/dispatch-bound' if host > wait else 'device-bound'})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="inspect an engine trace (JSONL from serve --trace)")
    ap.add_argument("trace", help="JSONL event log path")
    ap.add_argument("--validate", action="store_true",
                    help="schema-validate; exit 1 on any error")
    ap.add_argument("--chrome", default=None, metavar="PATH",
                    help="also export Chrome/Perfetto trace JSON")
    ap.add_argument("--waterfalls", type=int, default=8)
    ap.add_argument("--hlo", default=None, metavar="PATH",
                    help="HLO text dump to cross-check roofline terms "
                         "against the trace")
    ap.add_argument("--hlo-phase", default="decode")
    args = ap.parse_args(argv)

    records = load_jsonl(args.trace)
    head = records[0] if records else {}
    print(f"{args.trace}: {len(records) - 1} records, schema "
          f"{head.get('schema')}, dropped {head.get('dropped', 0)}"
          + (f", arch {head['arch']}" if "arch" in head else ""))
    dropped = int(head.get("dropped", 0) or 0)
    if dropped:
        # loud, not a status field: a ring-buffer overflow silently
        # truncates the OLDEST records, so every aggregate below (phase
        # fractions, coverage, lifecycle percentiles, waterfalls) is
        # computed over the tail of the run only — early prefill-heavy
        # steps are the usual casualties, which skews phase attribution
        # toward decode
        kept = max(len(records) - 1, 0)
        print(f"\n{'!' * 72}\n"
              f"!! WARNING: {dropped} trace records DROPPED (ring buffer "
              f"overflow; {kept} kept).\n"
              f"!! The oldest records are missing — phase attribution, "
              f"coverage, and\n"
              f"!! lifecycle percentiles below describe only the tail of "
              f"the run.\n"
              f"!! Re-trace with a larger EngineConfig.trace_capacity "
              f"(currently\n"
              f"!! {head.get('capacity', '?')}) or a shorter run for "
              f"trustworthy attribution.\n"
              f"{'!' * 72}")
    errs = validate_events(records)
    if errs:
        print(f"\nschema validation: {len(errs)} error(s)")
        for e in errs[:20]:
            print(f"  {e}")
        if args.validate:
            return 1
    else:
        print("schema validation: ok")

    pb = phase_breakdown(records)
    print_phase_table(pb)
    print_lifecycle(records)
    print_waterfalls(records, args.waterfalls)
    if args.hlo:
        hlo_crosscheck(pb, args.hlo, args.hlo_phase)
    if args.chrome:
        with open(args.chrome, "w") as f:
            json.dump(chrome_trace(records), f)
        print(f"\nchrome trace -> {args.chrome}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
