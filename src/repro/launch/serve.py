"""Quantized serving driver: SplitQuant-preprocess a model's weights, low-
bit quantize, and serve batched requests (the paper's deployment story).

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --reduced --bits 2 --method splitquant --requests 4
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.core import QuantConfig, QuantPolicy, quantize_tree
from repro.models import get_model
from repro.runtime.serve_loop import Request, ServeConfig, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--method", default="splitquant",
                    choices=["splitquant", "baseline", "percentile", "none"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore trained weights before quantizing")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    if args.ckpt_dir:
        from repro.checkpoint import ckpt
        (params, _), step = ckpt.restore(args.ckpt_dir, (params, None))
        print(f"restored step {step}")

    if args.method != "none":
        policy = QuantPolicy(cfg=QuantConfig(bits=args.bits),
                             method=args.method)
        params, report = quantize_tree(key, params, policy)
        print(f"quantized {len(report['quantized'])} tensors to "
              f"INT{args.bits} ({args.method}); deployed "
              f"{report['deployed_bytes']/2**20:.1f} MiB vs fp32 "
              f"{report['orig_bytes']/2**20:.1f} MiB")

    srv = Server(cfg, params, ServeConfig(
        max_batch=4, max_new_tokens=args.max_new_tokens, max_len=256))
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, size=rng.integers(4, 12)))
            for i in range(args.requests)]
    out = srv.serve(reqs)
    for r in out:
        print(f"req {r.uid}: {len(r.out)} tokens -> {r.out[:12]}")


if __name__ == "__main__":
    main()
