"""Quantized serving driver: SplitQuant-preprocess a model's weights, low-
bit quantize, and serve requests (the paper's deployment story).

Default path is the continuous-batching engine (`repro.engine`) with an
optionally INT8-quantized KV cache; `--wave` selects the legacy wave-
synchronous loop for comparison.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --reduced --bits 2 --method splitquant --requests 4 --kv-mode int8

Calibrated serving (repro.calib): `--save-recipe DIR` runs the offline
step once — quantize the weights (honoring any recipe policies), collect
KV range statistics on calibration prompts, and write a QuantRecipe +
quantized checkpoint. `--recipe DIR` then serves from that directory:
weights restore pre-quantized (no k-means at startup) and the INT8 KV
cache uses the recipe's static scales (no per-step min/max reduce).

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --reduced --bits 2 --save-recipe /tmp/rec
    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --reduced --recipe /tmp/rec --kv-mode int8
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from repro.configs import get_arch
from repro.core import QuantConfig, QuantPolicy, quantize_tree
from repro.engine import (Engine, EngineConfig, FaultSpec, InjectedCrash,
                          admission_set_point, occupied_slots)
from repro.models import get_model
from repro.runtime.serve_loop import Request, ServeConfig, Server


def load_recipe_params(recipe_dir, params, arch=None, reduced=None):
    """(params, recipe, kv_scales) from a saved QuantRecipe: restore the
    pre-quantized checkpoint if the recipe points at one (no k-means),
    else apply the recipe's per-path policies to `params`.

    ``arch``/``reduced``: when given, validated against the recipe's
    provenance — a mismatched recipe otherwise dies deep inside a
    checkpoint lookup or a shape error with no hint of the real cause.
    """
    from repro.calib import QuantRecipe
    from repro.checkpoint import ckpt

    rec = QuantRecipe.load(recipe_dir)
    if arch is not None and rec.arch and rec.arch != arch:
        raise ValueError(f"recipe {recipe_dir!r} was calibrated for arch "
                         f"{rec.arch!r}, serving {arch!r}")
    if reduced is not None and "reduced" in rec.meta \
            and bool(rec.meta["reduced"]) != bool(reduced):
        raise ValueError(f"recipe {recipe_dir!r} was calibrated with "
                         f"reduced={rec.meta['reduced']}, serving "
                         f"reduced={reduced}")
    ck = rec.resolve_ckpt_dir(recipe_dir)
    if ck is not None:
        params, step = ckpt.restore(ck, params)
        print(f"recipe: restored pre-quantized weights (step {step}) — "
              f"no k-means at startup")
    elif rec.policies:
        params, report = quantize_tree(
            jax.random.PRNGKey(0), params,
            QuantPolicy(), overrides=rec.policies)
        print(f"recipe: quantized {len(report['quantized'])} tensors from "
              f"recipe policies ({report['deployed_bytes']/2**20:.1f} MiB)")
    return params, rec, rec.kv_scales


def save_recipe(recipe_dir, cfg, model, params, args) -> None:
    """Offline calibration: quantize weights, measure KV ranges, persist
    QuantRecipe + quantized checkpoint under `recipe_dir`."""
    from repro.calib import QuantRecipe, collect_kv_stats, kv_static_scales
    from repro.checkpoint import ckpt

    policy = QuantPolicy(cfg=QuantConfig(bits=args.bits), method=args.method)
    qparams_tree, report = quantize_tree(jax.random.PRNGKey(0), params,
                                         policy)
    kv_scales = None
    if cfg.family in ("dense", "moe", "vlm"):
        rng = np.random.default_rng(0)
        # long calibration prompts: RoPE'd K ranges are position-dependent,
        # so coverage must extend past the serving prompt lengths
        calib = [rng.integers(0, cfg.vocab, size=(4, 48)) for _ in range(4)]
        kv_scales = kv_static_scales(
            collect_kv_stats(cfg, qparams_tree, calib, qchunks=4))
    os.makedirs(recipe_dir, exist_ok=True)
    ckpt.save(os.path.join(recipe_dir, "ckpt"), 0, qparams_tree)
    rec = QuantRecipe(
        name=f"{cfg.name}-int{args.bits}-{args.method}",
        arch=args.arch,
        policies={p: {"bits": d["bits"], "k": d["k"], "method": d["method"]}
                  for p, d in report["per_path"].items()},
        kv_scales=kv_scales, kv_qchunks=4, ckpt_dir="ckpt",
        meta={"deployed_bytes": report["deployed_bytes"],
              "orig_bytes": report["orig_bytes"], "reduced": args.reduced})
    rec.save(recipe_dir)
    print(f"saved recipe + quantized ckpt to {recipe_dir} "
          f"({report['deployed_bytes']/2**20:.1f} MiB deployed)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--method", default="splitquant",
                    choices=["splitquant", "baseline", "percentile", "none"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--wave", action="store_true",
                    help="use the legacy wave-batching loop")
    ap.add_argument("--slots", type=int, default=4,
                    help="engine slot count / wave max_batch")
    ap.add_argument("--kv-mode", default="fp", choices=["fp", "int8"],
                    help="engine KV cache storage (int8 = SplitQuant §4.2 "
                         "chunked-range quantization of K/V at rest)")
    ap.add_argument("--fused-attn", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="decode attention reads the slot cache through "
                         "the fused dequant-in-kernel path (Pallas on "
                         "TPU, chunked jnp elsewhere) — no full-precision "
                         "cache copy per step. Default ON; "
                         "--no-fused-attn selects the legacy materialize-"
                         "then-attend oracle")
    ap.add_argument("--prefill-chunk", type=int,
                    default=EngineConfig.prefill_chunk,
                    help="chunked fused prefill: admit at most this many "
                         "prompt tokens per engine step, quantizing K/V "
                         "in-kernel straight into the slot cache (no "
                         "dense fp prefill cache, decode keeps running "
                         "under long prompts). Default ON (engine "
                         "default); 0 = legacy one-shot prefill opt-out")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="self-speculative decoding: a low-bit draft "
                         "proposes up to k greedy tokens per slot per "
                         "step and the target verifies the window in one "
                         "fused pass (output token-identical to "
                         "spec_k=0 greedy). 0 = off")
    ap.add_argument("--draft-recipe", default=None,
                    help="calibration recipe dir the speculative DRAFT "
                         "weights are minted from (with --spec-k; "
                         "without it the target drafts for itself — "
                         "acceptance ~1 but no draft-cost win)")
    ap.add_argument("--max-queue", default="0", metavar="N|auto",
                    help="admission control: bound the submit queue at N "
                         "requests; a submit past the bound triggers "
                         "--overload-policy. 0 = unbounded (legacy). "
                         "'auto' sizes the bound from the measured "
                         "open-loop saturation knee in the repo's "
                         "BENCH_serve.json (2x the p95 queue depth at "
                         "the last SLO-attaining sweep point)")
    ap.add_argument("--overload-policy", default="reject-new",
                    choices=["reject-new", "shed-oldest", "shed-by-class"],
                    help="who loses when the bounded queue is full: the "
                         "incoming request, the oldest queued one, or "
                         "the oldest queued batch-class request "
                         "(falling back to the incoming one)")
    ap.add_argument("--degrade", action="store_true",
                    help="enable the graceful-degradation ladder: under "
                         "sustained backlog the engine first suspends "
                         "speculative decoding (output-identical), then "
                         "defers batch-class admissions, then sheds "
                         "queued work — each rung transition is a "
                         "metrics event (engine_degradation_rung)")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="seeded chaos injection, e.g. "
                         "'exception=0.05,nan=0.02,seed=3' (keys: "
                         "exception, nan, slow, slow_s, poison, crash, "
                         "crash_kill, seed, max). Failed steps retry "
                         "after KV rollback; slots that keep failing "
                         "retire as 'failed'; crash=p dies at a step "
                         "boundary (recover with --supervise or "
                         "--recover-from). Post-drain invariants (clean "
                         "retire reasons, no slot-pool leak) are "
                         "asserted. Engine only; incompatible with "
                         "--spec-k")
    ap.add_argument("--journal", default=None, metavar="PATH",
                    help="durable request journal (DESIGN.md §13): "
                         "append-only JSONL WAL of submit/admit/"
                         "first_token/retire transitions, fsync'd once "
                         "per engine step — the replay source for crash "
                         "recovery. Validates under trace_report "
                         "--validate. Engine only (not --wave)")
    ap.add_argument("--snapshot", default=None, metavar="DIR",
                    help="engine state snapshot directory (atomic "
                         "tmp+rename): quantized slot cache, draft twin, "
                         "scheduler queue + slot table, host decode "
                         "state, with per-array checksums in the "
                         "manifest. Written every --snapshot-every steps")
    ap.add_argument("--snapshot-every", type=int, default=0, metavar="N",
                    help="with --snapshot: snapshot every N engine steps "
                         "at the end-of-step boundary (after the journal "
                         "fsync). 0 = never automatically")
    ap.add_argument("--recover-from", default=None, metavar="DIR",
                    help="start by restoring this snapshot dir and "
                         "replaying --journal against it (fresh-process "
                         "recovery after a crash): snapshot-live "
                         "requests resume from their quantized KV, "
                         "journal submissions past the snapshot horizon "
                         "re-prefill, already-retired uids are reported "
                         "from the journal and never re-run. The dir "
                         "may be absent (crash before the first "
                         "snapshot) if --journal is given")
    ap.add_argument("--supervise", type=int, default=0, metavar="N",
                    help="in-process supervisor: on an injected crash "
                         "(--faults crash=p), rebuild the engine, "
                         "recover from --snapshot/--journal and keep "
                         "serving, up to N restarts. Restarted engines "
                         "run with the crash injector disarmed (the "
                         "same seed would deterministically re-crash at "
                         "the same boundary)")
    ap.add_argument("--verify-recovery", action="store_true",
                    help="after serving, re-run the same workload on an "
                         "uncrashed reference engine and assert every "
                         "normally-finished request's tokens are "
                         "identical — the zero-divergence recovery "
                         "proof (exits nonzero on mismatch)")
    ap.add_argument("--drain-timeout", type=float, default=None,
                    metavar="S",
                    help="drain watchdog: force-fail all outstanding "
                         "requests after this many wall seconds (None = "
                         "no wall limit; the no-progress watchdog still "
                         "applies)")
    ap.add_argument("--drain-stall-steps", type=int, default=10_000,
                    metavar="N",
                    help="drain watchdog: force-fail outstanding "
                         "requests after N consecutive engine steps "
                         "with no progress (tokens, admissions, prefill "
                         "chunks, or retires)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable engine tracing (repro.obs) and write the "
                         "JSONL event log here: per-request lifecycle "
                         "events + per-step phase spans with dispatch-vs-"
                         "device-wait attribution. Inspect with "
                         "launch/trace_report.py. Engine only (not "
                         "--wave); a profiling mode — adds sync points")
    ap.add_argument("--trace-chrome", default=None, metavar="PATH",
                    help="with --trace: also export a Chrome/Perfetto "
                         "trace.json (one track per slot, one per engine "
                         "phase) to this path")
    ap.add_argument("--trace-kv-every", type=int, default=0,
                    metavar="N",
                    help="with --trace and --kv-mode int8: sample KV "
                         "quantization-quality counters (clip fraction, "
                         "occupancy, outlier-chunk histogram) every N "
                         "engine steps into the trace. 0 = off")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="dump the full Engine.metrics() dict as JSON "
                         "with the shared provenance header "
                         "(machine-checkable soak runs; includes the "
                         "always-on registry snapshot, and the "
                         "phase_attribution section when --trace is on). "
                         "Engine only (not --wave)")
    ap.add_argument("--metrics-snapshot", default=None, metavar="PATH",
                    help="stream periodic JSONL snapshots of the "
                         "always-on metrics registry (queue depth, admit "
                         "latency, slot occupancy, prefill backlog, "
                         "tokens in flight, spec acceptance EWMA) to "
                         "this path while serving; line 1 is the shared "
                         "provenance header. Engine only (not --wave)")
    ap.add_argument("--metrics-interval", type=float, default=1.0,
                    metavar="S",
                    help="with --metrics-snapshot: minimum seconds "
                         "between snapshots (a final flush always "
                         "happens at drain)")
    ap.add_argument("--metrics-prom", default=None, metavar="PATH",
                    help="write the final registry state in Prometheus "
                         "text exposition format at exit (the scrape "
                         "surface, minus the HTTP listener). Engine "
                         "only (not --wave)")
    ap.add_argument("--no-metrics", action="store_true",
                    help="disable the always-on metrics registry (the "
                         "overhead-measurement configuration; metrics "
                         "are otherwise cheap enough to never turn off)")
    ap.add_argument("--incident-dir", default=None, metavar="DIR",
                    help="arm the anomaly-detector sweep and write "
                         "incident bundles (flight window + metrics + "
                         "journal tail + fingerprint + request docs) "
                         "under DIR on trigger; inspect with "
                         "repro.launch.incident_report")
    ap.add_argument("--incident-cooldown", type=int, default=50,
                    metavar="N",
                    help="steps between detector refires / bundles "
                         "(default 50) — a fault storm yields one "
                         "incident, not one per step")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore trained weights before quantizing")
    ap.add_argument("--recipe", default=None,
                    help="serve from a saved calibration recipe dir: "
                         "pre-quantized weights + static KV scales")
    ap.add_argument("--save-recipe", default=None,
                    help="run offline calibration, write recipe + "
                         "quantized ckpt to this dir, and exit")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    if args.ckpt_dir:
        from repro.checkpoint import ckpt
        (params, _), step = ckpt.restore(args.ckpt_dir, (params, None))
        print(f"restored step {step}")

    if args.save_recipe:
        save_recipe(args.save_recipe, cfg, model, params, args)
        return

    kv_scales = None
    kv_qchunks = 4
    if args.recipe:
        params, rec, kv_scales = load_recipe_params(
            args.recipe, params, arch=args.arch, reduced=args.reduced)
        kv_qchunks = rec.kv_qchunks        # scales are (L, Hkv, kv_qchunks)
        if kv_scales is not None and args.kv_mode != "int8":
            kv_scales = None               # static scales only apply to int8
    elif args.method != "none":
        policy = QuantPolicy(cfg=QuantConfig(bits=args.bits),
                             method=args.method)
        params, report = quantize_tree(key, params, policy)
        print(f"quantized {len(report['quantized'])} tensors to "
              f"INT{args.bits} ({args.method}); deployed "
              f"{report['deployed_bytes']/2**20:.1f} MiB vs fp32 "
              f"{report['orig_bytes']/2**20:.1f} MiB")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=rng.integers(4, 12))
               for _ in range(args.requests)]

    from repro.engine.engine import ENGINE_FAMILIES
    if args.draft_recipe and not args.spec_k:
        raise ValueError(
            "--draft-recipe only takes effect with --spec-k > 0 — the "
            "recipe would be silently ignored and serving would proceed "
            "plain-greedy")
    if args.spec_k and args.wave:
        # loud, mirroring the family check below: the wave loop has no
        # speculative path, and silently dropping spec_k would let an
        # operator believe they measured speculative serving
        raise NotImplementedError(
            "--wave has no speculative path (spec_k > 0 is an engine "
            "feature) — drop --wave or --spec-k")
    if args.spec_k and cfg.family not in ENGINE_FAMILIES:
        # loud, not a silent wave fallback: the caller asked for
        # speculative decoding and these families cannot provide the
        # positional rollback it needs — surface the family's own reason
        from repro.models import get_model as _gm
        vf = getattr(_gm(cfg), "verify_step_slots", None)
        if vf is None:
            raise NotImplementedError(
                f"--spec-k: the {cfg.family!r} family has no speculative "
                f"verify path")
        vf()
    if (args.trace_chrome or args.trace_kv_every) and not args.trace:
        raise ValueError(
            "--trace-chrome / --trace-kv-every require --trace — without "
            "it no trace is recorded and the flags would be silently "
            "ignored")
    if not args.wave and cfg.family not in ENGINE_FAMILIES:
        print(f"note: {cfg.family!r} family has no slot-cache layout yet; "
              f"serving with the wave loop")
        args.wave = True
    if args.no_metrics and (args.metrics_snapshot or args.metrics_prom):
        raise ValueError(
            "--no-metrics disables the registry the "
            "--metrics-snapshot/--metrics-prom exporters read — drop "
            "one side")
    if args.wave and (args.trace or args.metrics_json
                      or args.metrics_snapshot or args.metrics_prom):
        # loud, mirroring the spec_k check above: the wave loop has no
        # tracer, registry, or metrics dict, and silently dropping the
        # flags would let an operator believe they captured a trace
        raise NotImplementedError(
            "--trace/--metrics-json/--metrics-snapshot/--metrics-prom "
            "are engine features — the wave loop has no tracer, "
            "registry, or metrics() snapshot; drop --wave")
    if args.wave and (args.faults or args.degrade
                      or args.max_queue not in ("0", 0)):
        raise NotImplementedError(
            "--faults/--degrade/--max-queue are engine features — the "
            "wave loop has no retry, ladder, or admission control; "
            "drop --wave")
    if args.wave and (args.journal or args.snapshot or args.recover_from
                      or args.supervise or args.verify_recovery):
        raise NotImplementedError(
            "--journal/--snapshot/--recover-from/--supervise/"
            "--verify-recovery are engine features — the wave loop has "
            "no journal, snapshot, or recovery path; drop --wave")
    if args.wave and args.incident_dir:
        raise NotImplementedError(
            "--incident-dir is an engine feature — the wave loop has no "
            "flight recorder or anomaly detectors; drop --wave")
    if args.snapshot_every and not args.snapshot:
        raise ValueError(
            "--snapshot-every without --snapshot DIR has nowhere to "
            "write — give a snapshot directory or drop the interval")
    if args.supervise and not (args.journal or args.snapshot):
        raise ValueError(
            "--supervise has nothing to recover from — give --journal "
            "and/or --snapshot (journal-only recovery re-prefills "
            "everything; snapshots make restarts cheap)")
    if args.recover_from and not os.path.isdir(args.recover_from) \
            and not args.journal:
        raise ValueError(
            f"--recover-from: {args.recover_from!r} does not exist and "
            f"no --journal was given — there is no state to recover")
    if args.max_queue == "auto":
        # size the bound from the committed open-loop knee: the p95
        # queue depth at the last sweep point that still attained its
        # SLO is the deepest backlog this box has been MEASURED to
        # absorb — 2x that is the admission set point (DESIGN.md §12).
        # Every failure here is loud: 'auto' with no measurement would
        # silently serve unbounded, which is the opposite of what the
        # operator asked for
        root = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "..", "..", "..")
        bench = os.path.abspath(os.path.join(root, "BENCH_serve.json"))
        regen = ("PYTHONPATH=src python benchmarks/serve_bench.py "
                 "--requests 12")
        import json as _json
        try:
            with open(bench) as f:
                data = _json.load(f)
        except FileNotFoundError:
            raise SystemExit(
                f"--max-queue auto: {bench} not found — the admission "
                f"bound is sized from the measured open-loop saturation "
                f"knee; run the serving benchmark once to produce it:\n"
                f"  {regen}")
        except ValueError as e:
            raise SystemExit(
                f"--max-queue auto: {bench} is not valid JSON ({e}) — "
                f"regenerate it:\n  {regen}")
        if "open_loop" not in data:
            raise SystemExit(
                f"--max-queue auto: {bench} has no 'open_loop' section "
                f"(it predates the open-loop SLO sweep) — regenerate "
                f"it:\n  {regen}")
        max_queue = admission_set_point(data["open_loop"]) or 0
        print(f"admission: --max-queue auto -> "
              f"{max_queue or 'unbounded (no measured knee)'} "
              f"(from {bench})")
    else:
        max_queue = int(args.max_queue)
    if args.wave:
        srv = Server(cfg, params, ServeConfig(
            max_batch=args.slots, max_new_tokens=args.max_new_tokens,
            max_len=256))
        out = srv.serve([Request(i, p) for i, p in enumerate(prompts)])
        for r in out:
            print(f"req {r.uid}: {len(r.out)} tokens -> {r.out[:12]}")
        return

    base_faults = FaultSpec.parse(args.faults) if args.faults else None

    def mk_engine(registry=None, resume=False, faults=base_faults):
        # rebuildable so the supervisor can replace a crashed engine
        # in-process; `registry` carries metric counters across restarts
        # (restore/replay counts must survive into --metrics-prom)
        return Engine(cfg, params, EngineConfig(
            n_slots=args.slots, max_len=256,
            max_new_tokens=args.max_new_tokens, kv_mode=args.kv_mode,
            kv_qchunks=kv_qchunks, fused_attn=args.fused_attn,
            prefill_chunk=args.prefill_chunk, spec_k=args.spec_k,
            draft_recipe=args.draft_recipe, metrics=not args.no_metrics,
            trace=bool(args.trace), trace_kv_every=args.trace_kv_every,
            max_queue=max_queue, overload_policy=args.overload_policy,
            degrade=args.degrade, fault_spec=faults,
            journal_path=args.journal, journal_resume=resume,
            snapshot_path=args.snapshot,
            snapshot_every=args.snapshot_every,
            incident_dir=args.incident_dir,
            incident_cooldown=args.incident_cooldown),
            kv_scales=kv_scales, registry=registry)

    # --recover-from is a fresh-process restart: the journal already
    # holds this workload's submit records, so the WAL is appended to
    # (resume) rather than truncated
    eng = mk_engine(resume=args.recover_from is not None)
    writer = None
    if args.metrics_snapshot:
        from repro.kernels import act_quant
        from repro.obs import RegistryQuantProbe, SnapshotWriter
        writer = SnapshotWriter(args.metrics_snapshot, eng.registry,
                                interval_s=args.metrics_interval)
        # live act-quant clip-fraction gauges: the observed kernel
        # wrappers feed the registry through the existing probe hook
        act_quant.set_quality_probe(RegistryQuantProbe(eng.registry))
    recovered = {}              # uid -> journal retire record (pre-crash)
    if args.recover_from is not None:
        info = eng.recover(args.recover_from, args.journal)
        recovered.update(info["retired"])
        print(f"recover: {info['n_restored']} live requests restored"
              f"{' from snapshot' if info['manifest'] else ' (no snapshot)'}"
              f", {info['n_requeued']} re-enqueued from the journal, "
              f"{len(info['retired'])} already retired pre-crash")
    else:
        for p in prompts:
            eng.submit(p)

    def run_to_drain(eng):
        if writer is None:
            return eng.drain(timeout_s=args.drain_timeout,
                             stall_steps=args.drain_stall_steps)
        # step manually so snapshots land DURING the run (the point of
        # an open-ended soak), not just at drain
        while not eng.sched.idle:
            eng.step()
            writer.maybe_write()
        writer.write()                            # final flush
        return sorted(eng.sched.finished, key=lambda r: r.uid)

    restarts = 0
    while True:
        try:
            fin = run_to_drain(eng)
            break
        except InjectedCrash as exc:
            if restarts >= args.supervise:
                raise
            restarts += 1
            print(f"supervisor: engine crashed ({exc}) — restart "
                  f"{restarts}/{args.supervise}, recovering from "
                  f"{'snapshot+journal' if args.snapshot else 'journal'}",
                  flush=True)
            if args.incident_dir:
                # capture from the CRASHED engine, whose flight window
                # and scheduler state describe the death — the rebuilt
                # engine starts with an empty ring
                eng.dump_incident("injected_crash", reason=str(exc))
            # crash injector disarmed on restart: a fresh injector with
            # the same seed would re-crash at the same step boundary,
            # turning every supervised run into a restart-budget exhaust
            import dataclasses as _dc
            calm = _dc.replace(base_faults, crash_rate=0.0) \
                if base_faults else None
            eng = mk_engine(registry=eng.registry, resume=True,
                            faults=calm)
            info = eng.recover(args.snapshot, args.journal)
            recovered.update(info["retired"])
    for uid in sorted(recovered):
        rec = recovered[uid]
        print(f"req {uid}: {rec['n_out']} tokens ({rec['reason']}) "
              f"-> {rec['out'][:12]}  (retired pre-crash, from journal)")
    for r in fin:
        # shed/failed/expired requests never produced a first token, so
        # ttft/tokens_per_s are None — a chaos run must not crash the
        # report loop that summarizes it
        ttft = "n/a" if r.ttft is None else f"{r.ttft*1e3:.0f} ms"
        tps = "n/a" if r.tokens_per_s is None \
            else f"{r.tokens_per_s:.1f} tok/s"
        print(f"req {r.uid}: {len(r.out)} tokens ({r.finish_reason}) "
              f"-> {r.out[:12]}  (ttft {ttft}, {tps})")
    m = eng.metrics()
    if args.faults:
        # chaos invariants (DESIGN.md §12): every submitted request
        # retired exactly once with a schema reason, and the drained
        # engine holds no residual state — a fault injector that leaks
        # slots or finish states would silently poison later admissions
        from repro.obs.schema import RETIRE_REASONS
        reasons = sorted([r.finish_reason for r in eng.sched.finished]
                         + [rec["reason"] for rec in recovered.values()])
        bad = [x for x in reasons if x not in RETIRE_REASONS]
        eng.sweep_idle_rows()       # idempotent; the manual-step path
        leak = occupied_slots(eng.cache)  # (snapshot writer) skips drain
        problems = []
        # exactly-once across incarnations: live finishes and journal-
        # replayed retires must partition the workload, never overlap
        live_uids = {r.uid for r in eng.sched.finished}
        twice = sorted(live_uids & set(recovered))
        if twice:
            problems.append(f"uids retired twice (live + journal): "
                            f"{twice}")
        if len(live_uids | set(recovered)) != len(prompts):
            problems.append(f"{len(live_uids | set(recovered))} retired "
                            f"!= {len(prompts)} submitted")
        if bad:
            problems.append(f"non-schema retire reasons {bad}")
        if any(eng.sched.slots) or eng.sched.queue:
            problems.append("scheduler not empty after drain")
        if leak:
            problems.append(f"slot-pool leak: cache rows {leak} still "
                            f"occupied")
        print(f"chaos  : injected {m.get('faults_injected')}, "
              f"{m['step_retries']} step retries, retire reasons "
              f"{m['retire_reasons']}")
        if problems:
            raise SystemExit("chaos invariants VIOLATED: "
                             + "; ".join(problems))
    if args.verify_recovery:
        # zero-divergence proof (DESIGN.md §13): greedy decode is a
        # pure function of (weights, prompt), so every request that
        # finished normally — pre-crash from the journal, resumed from
        # a snapshot, or re-prefilled after replay — must be token-
        # identical to a run that never crashed
        normal = ("eos", "budget", "max_len", "zero_budget")
        ref = Engine(cfg, params, EngineConfig(
            n_slots=args.slots, max_len=256,
            max_new_tokens=args.max_new_tokens, kv_mode=args.kv_mode,
            kv_qchunks=kv_qchunks, fused_attn=args.fused_attn,
            prefill_chunk=args.prefill_chunk, spec_k=args.spec_k,
            draft_recipe=args.draft_recipe, metrics=False),
            kv_scales=kv_scales)
        for p in prompts:
            ref.submit(p)
        ref_out = {r.uid: list(r.out) for r in ref.drain()}
        got = {uid: (list(rec["out"]), rec["reason"])
               for uid, rec in recovered.items()}
        got.update({r.uid: (list(r.out), r.finish_reason) for r in fin})
        survivors = sorted(u for u, (_, why) in got.items()
                           if why in normal)
        diverged = [u for u in survivors if got[u][0] != ref_out.get(u)]
        if diverged:
            raise SystemExit(
                f"recovery verification FAILED: requests {diverged} "
                f"diverged from the uncrashed reference run")
        excl = len(got) - len(survivors)
        print(f"recover: {len(survivors)} surviving requests verified "
              f"token-identical to an uncrashed reference run"
              + (f" ({excl} shed/failed/expired excluded)" if excl
                 else ""))
    print(f"engine: {m['tokens_per_s']:.1f} tok/s, "
          f"util {m['slot_utilization']:.0%}, kv={m['kv_mode']}"
          f"{'/static' if m['kv_static_scales'] else ''} "
          f"({m['kv_bytes_per_token']:.0f} B/token/layer)")
    if args.spec_k:
        rate = m["acceptance_rate"]
        print(f"spec   : k={m['spec_k']}, acceptance "
              f"{'n/a' if rate is None else f'{rate:.1%}'}, "
              f"{m['draft_accepted']}/{m['draft_proposed']} drafts "
              f"accepted over {m['verify_calls']} verifies "
              f"({m['tokens_per_verify_mean'] or 0:.2f} tokens/verify)")
    if args.trace:
        n = eng.tracer.to_jsonl(args.trace)
        print(f"trace  : {n} records -> {args.trace} "
              f"({eng.tracer.dropped} dropped); inspect with "
              f"python -m repro.launch.trace_report {args.trace}")
        if args.trace_chrome:
            eng.tracer.to_chrome(args.trace_chrome)
            print(f"trace  : chrome/perfetto -> {args.trace_chrome}")
        pa = m["phase_attribution"]
        if pa["coverage"] is not None:
            print(f"trace  : phase coverage {pa['coverage']:.0%} of "
                  f"step wall; dispatch {pa['dispatch_frac']:.0%} / "
                  f"device wait {pa['device_wait_frac']:.0%} of "
                  f"attributed time")
    if args.incident_dir:
        # count on disk, not eng.incidents: supervised restarts replace
        # the engine object but the bundles persist
        bundles = sorted(
            d for d in (os.listdir(args.incident_dir)
                        if os.path.isdir(args.incident_dir) else [])
            if d.startswith("incident-"))
        print(f"incidents: {len(bundles)} bundle(s) -> "
              f"{args.incident_dir}"
              + (f"; inspect with python -m repro.launch.incident_report "
                 f"{os.path.join(args.incident_dir, bundles[0])}"
                 if bundles else " (no anomalies)"))
    if args.metrics_snapshot:
        print(f"metrics: {writer.seq} snapshots -> "
              f"{args.metrics_snapshot}")
    if args.metrics_prom:
        with open(args.metrics_prom, "w") as f:
            f.write(eng.registry.to_prometheus())
        print(f"metrics: prometheus text -> {args.metrics_prom}")
    if args.metrics_json:
        import json

        from repro.obs import provenance

        # the same provenance header every BENCH_*.json carries — a
        # metrics dump without it is uninterpretable once copied off-box
        with open(args.metrics_json, "w") as f:
            json.dump({"provenance": provenance(), **m}, f, indent=2,
                      default=float)
        print(f"metrics: -> {args.metrics_json}")


if __name__ == "__main__":
    main()
