"""Quantized serving driver: SplitQuant-preprocess a model's weights, low-
bit quantize, and serve requests (the paper's deployment story).

Default path is the continuous-batching engine (`repro.engine`) with an
optionally INT8-quantized KV cache; `--wave` selects the legacy wave-
synchronous loop for comparison.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --reduced --bits 2 --method splitquant --requests 4 --kv-mode int8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.core import QuantConfig, QuantPolicy, quantize_tree
from repro.engine import Engine, EngineConfig
from repro.models import get_model
from repro.runtime.serve_loop import Request, ServeConfig, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--method", default="splitquant",
                    choices=["splitquant", "baseline", "percentile", "none"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--wave", action="store_true",
                    help="use the legacy wave-batching loop")
    ap.add_argument("--slots", type=int, default=4,
                    help="engine slot count / wave max_batch")
    ap.add_argument("--kv-mode", default="fp", choices=["fp", "int8"],
                    help="engine KV cache storage (int8 = SplitQuant §4.2 "
                         "chunked-range quantization of K/V at rest)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore trained weights before quantizing")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    if args.ckpt_dir:
        from repro.checkpoint import ckpt
        (params, _), step = ckpt.restore(args.ckpt_dir, (params, None))
        print(f"restored step {step}")

    if args.method != "none":
        policy = QuantPolicy(cfg=QuantConfig(bits=args.bits),
                             method=args.method)
        params, report = quantize_tree(key, params, policy)
        print(f"quantized {len(report['quantized'])} tensors to "
              f"INT{args.bits} ({args.method}); deployed "
              f"{report['deployed_bytes']/2**20:.1f} MiB vs fp32 "
              f"{report['orig_bytes']/2**20:.1f} MiB")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=rng.integers(4, 12))
               for _ in range(args.requests)]

    from repro.engine.engine import ENGINE_FAMILIES
    if not args.wave and cfg.family not in ENGINE_FAMILIES:
        print(f"note: {cfg.family!r} family has no slot-cache layout yet; "
              f"serving with the wave loop")
        args.wave = True
    if args.wave:
        srv = Server(cfg, params, ServeConfig(
            max_batch=args.slots, max_new_tokens=args.max_new_tokens,
            max_len=256))
        out = srv.serve([Request(i, p) for i, p in enumerate(prompts)])
        for r in out:
            print(f"req {r.uid}: {len(r.out)} tokens -> {r.out[:12]}")
        return

    eng = Engine(cfg, params, EngineConfig(
        n_slots=args.slots, max_len=256,
        max_new_tokens=args.max_new_tokens, kv_mode=args.kv_mode))
    for p in prompts:
        eng.submit(p)
    for r in eng.drain():
        print(f"req {r.uid}: {len(r.out)} tokens -> {r.out[:12]}  "
              f"(ttft {r.ttft*1e3:.0f} ms, {r.tokens_per_s:.1f} tok/s)")
    m = eng.metrics()
    print(f"engine: {m['tokens_per_s']:.1f} tok/s, "
          f"util {m['slot_utilization']:.0%}, kv={m['kv_mode']} "
          f"({m['kv_bytes_per_token']:.0f} B/token/layer)")


if __name__ == "__main__":
    main()
