"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell against the production mesh, with no real device allocation
(ShapeDtypeStruct stand-ins), and extract the roofline terms.

MUST set the forced device count before ANY jax import side effects.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse      # noqa: E402
import functools     # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp                          # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_arch, cell_is_runnable  # noqa: E402
from repro.core import QuantConfig, QuantPolicy, quantize_tree  # noqa: E402
from repro.models import get_model, init_cache_for  # noqa: E402
from repro.models.transformer import VLM_PATCH_DIM  # noqa: E402
from repro.optim import adamw                    # noqa: E402
from .mesh import data_axes, make_production_mesh  # noqa: E402
from .shardings import (batch_shardings, cache_shardings, opt_shardings,
                        param_shardings)         # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

# ----------------------------------------------------------- input specs --
def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = get_arch(arch)
    shp = SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    sds = jax.ShapeDtypeStruct
    if shp.kind in ("train", "prefill"):
        batch = {"tokens": sds((B, S), i32)}
        if shp.kind == "train":
            batch["labels"] = sds((B, S), i32)
        if cfg.family == "vlm":
            P_img = cfg.n_prefix_embeds
            batch["tokens"] = sds((B, S - P_img), i32)
            if shp.kind == "train":
                batch["labels"] = sds((B, S - P_img), i32)
            batch["patch_embeds"] = sds((B, P_img, VLM_PATCH_DIM), bf16)
        if cfg.family == "audio":
            batch["frames"] = sds((B, cfg.enc_seq, cfg.d_model), bf16)
        return batch
    # decode: one new token against a cache of S positions
    return {"tokens": sds((B, 1), i32), "pos": sds((), i32)}


def abstract_params(cfg, quantized: bool, bits: int = 4):
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(functools.partial(model.init, cfg=cfg), key)
    if quantized:
        policy = QuantPolicy(cfg=QuantConfig(bits=bits), method="splitquant")
        params = jax.eval_shape(
            lambda p: quantize_tree(key, p, policy)[0], params)
    return params


def abstract_cache(cfg, B, S):
    return jax.eval_shape(
        functools.partial(init_cache_for, cfg, B, S))


# ------------------------------------------------------------- HLO stats --
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _tensor_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-op output bytes, summed from the post-SPMD per-device
    module. `-start` variants counted once (their `-done` pair is skipped)."""
    totals = {op: 0 for op in _COLL_OPS}
    counts = {op: 0 for op in _COLL_OPS}
    for line in hlo_text.splitlines():
        for op in _COLL_OPS:
            if f" {op}(" in line or f" {op}-start(" in line:
                lhs = line.split(f" {op}")[0]
                b = sum(_tensor_bytes(d, s)
                        for d, s in _SHAPE_RE.findall(lhs))
                totals[op] += b
                counts[op] += 1
                break
    return {"bytes": totals, "counts": counts,
            "total_bytes": sum(totals.values())}


# -------------------------------------------------------------- lowering --
def build_step(cfg, shape_name: str, mesh, quantized: bool,
               opt_dtype: str = "bfloat16", bits: int = 4,
               kv_chunk_train: int = 1024, kv_chunk_prefill: int = 2048,
               serve_fsdp: bool | None = None):
    """Returns (jitted_fn, abstract_args).

    serve_fsdp: None ⇒ FSDP weights for bf16 serving, TP-only for
    quantized serving (the low-bit residency the paper enables).
    """
    model = get_model(cfg)
    shp = SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    batch = input_specs(cfg.name, shape_name)
    params = abstract_params(cfg, quantized and shp.kind != "train",
                             bits=bits)
    dp_size = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    if shp.kind == "train":
        p_sh = param_shardings(params, mesh)
    else:
        fsdp = (not quantized) if serve_fsdp is None else serve_fsdp
        p_sh = param_shardings(params, mesh, fsdp=fsdp)
    b_sh = batch_shardings(batch, mesh)

    if shp.kind == "train":
        opt_cfg = adamw.OptConfig(state_dtype=opt_dtype)
        opt_state = jax.eval_shape(
            functools.partial(adamw.init, opt_cfg), params)
        o_sh = opt_shardings(opt_state, p_sh, mesh)

        def loss_fn(p, b):
            return model.loss_fn(p, cfg, b, kv_chunk=kv_chunk_train,
                                 remat=True, moe_blocks=dp_size)

        step = train_loop_step(loss_fn, opt_cfg)
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     donate_argnums=(0, 1))
        return fn, (params, opt_state, batch)

    if shp.kind == "prefill":
        def fn(p, b):
            kw = {"moe_blocks": dp_size} if cfg.family in ("moe", "dense",
                                                           "vlm") else {}
            return model.prefill(p, cfg, b, max_len=S,
                                 kv_chunk=kv_chunk_prefill, **kw)
        jfn = jax.jit(fn, in_shardings=(p_sh, b_sh))
        return jfn, (params, batch)

    # decode
    cache = abstract_cache(cfg, B, S)
    c_sh = cache_shardings(cache, mesh)
    tok_sh = batch_shardings({"tokens": batch["tokens"]}, mesh)["tokens"]
    rep = NamedSharding(mesh, P())
    tp = mesh.shape.get("model", 1)
    # time-sharded ring decode: cache T over "model" when kv heads can't be
    use_tshard = (cfg.family in ("dense", "moe", "vlm") and S >= 16384 and
                  S % tp == 0 and cfg.n_kv_heads < tp)

    def fn(p, c, t, pos):
        if cfg.family in ("dense", "moe", "vlm"):
            return model.decode_step(p, cfg, c, t, pos, tshard=use_tshard)
        return model.decode_step(p, cfg, c, t, pos)

    jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, tok_sh, rep),
                  donate_argnums=(1,))
    return jfn, (params, cache, batch["tokens"], batch["pos"])


def train_loop_step(loss_fn, opt_cfg):
    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = adamw.update(opt_cfg, opt_state, params,
                                             grads)
        return params, opt_state, {**metrics, **om}
    return step


def run_cell(arch: str, shape_name: str, multi_pod: bool, quantized: bool,
             opt_dtype: str = "bfloat16", bits: int = 4,
             save: bool = True, verbose: bool = True,
             kv_chunk_train: int = 1024,
             kv_chunk_prefill: int = 2048,
             tag: str = "") -> dict:
    cfg = get_arch(arch)
    shp = SHAPES[shape_name]
    ok, reason = cell_is_runnable(cfg, shp)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "quantized": quantized, "status": "skip", "reason": reason}
    if not ok:
        if verbose:
            print(f"[skip] {arch} × {shape_name}: {reason}")
        if save:
            _save(result, tag)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    try:
        with mesh:
            fn, args = build_step(cfg, shape_name, mesh, quantized,
                                  opt_dtype=opt_dtype, bits=bits,
                                  kv_chunk_train=kv_chunk_train,
                                  kv_chunk_prefill=kv_chunk_prefill)
            lowered = fn.lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo_text = compiled.as_text()
            coll = collective_bytes(hlo_text)
            from .hlo_analysis import analyze as hlo_analyze
            weighted = hlo_analyze(hlo_text)
        result.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "n_devices": mesh.size,
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes":
                    getattr(mem, "generated_code_size_in_bytes", None),
            },
            "flops_xla_raw": cost.get("flops"),
            "bytes_accessed_xla_raw": cost.get("bytes accessed"),
            "collectives_raw": coll,
            "dot_flops": weighted["dot_flops"],
            "dot_bytes": weighted["dot_bytes"],
            "collectives": {
                "bytes": weighted["collective_bytes"],
                "counts": weighted["collective_counts"],
                "total_bytes": weighted["collective_total_bytes"],
                "f32_bytes": weighted["collective_f32_bytes"],
                "total_bytes_tpu": weighted["collective_total_bytes_tpu"]},
        })
        if quantized:
            result["bits"] = bits
        if verbose:
            print(f"[ok] {arch} × {shape_name} × {mesh_name}"
                  f"{' ×int'+str(bits) if quantized else ''}: "
                  f"lower {t_lower:.0f}s compile {t_compile:.0f}s  "
                  f"dotflops/dev {weighted['dot_flops']:.3e}  "
                  f"coll {weighted['collective_total_bytes']/2**20:.1f} "
                  f"MiB/dev")
    except Exception as e:  # noqa: BLE001 — dry-run failures are data
        result.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]})
        if verbose:
            print(f"[ERROR] {arch} × {shape_name} × {mesh_name}: "
                  f"{type(e).__name__}: {str(e)[:300]}")
    if save:
        _save(result, tag)
    return result


def _save(result: dict, tag: str = ""):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    q = "_q" + str(result.get("bits", "")) if result.get("quantized") else ""
    t = f"_{tag}" if tag else ""
    name = (f"{result['arch']}_{result['shape']}_{result['mesh']}{q}{t}"
            ".json")
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(result, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--quantized", action="store_true")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--opt-dtype", default="bfloat16")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    from repro.configs import ASSIGNED
    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                r = run_cell(arch, shape, mp, args.quantized,
                             opt_dtype=args.opt_dtype, bits=args.bits,
                             tag=args.tag)
                n_err += r["status"] == "error"
    print(f"done; {n_err} errors")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
