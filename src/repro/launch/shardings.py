"""Sharding rules: parameter/activation PartitionSpecs by path + shape.

Layout (DESIGN.md §4):
  * batch over ("pod","data") — pure DP across pods;
  * tensor parallelism over "model": attention heads, FFN hidden, vocab,
    MoE experts (EP);
  * FSDP (ZeRO-3) over "data" for the *other* matrix dim of every weight —
    GSPMD all-gathers on use;
  * every rule checks divisibility and falls back to replication for that
    dim, so odd head counts (whisper H=6, rwkv H=40) stay correct.

Quantized leaves: SplitQuantTensor.q/.cid shard like the weight; scales are
replicated (k×N fp32 — negligible).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.apply import infer_stack_dims
from .mesh import data_axes

#: projections whose FIRST matrix dim is the TP dim (output/down projs)
ROW_TP_FRAGMENTS = ("w_down", "wo", "w_out", "bo", "ffn/wv")
#: leaves that are semantically embedding tables (vocab-dim TP)
TABLE_FRAGMENTS = ("embed", "pos_table", "enc_pos", "dec_pos")


def _axis_size(mesh, name) -> int:
    return mesh.shape[name]


def _fits(dim: int, mesh, axis) -> bool:
    if axis is None:
        return True
    sizes = ([_axis_size(mesh, a) for a in axis]
             if isinstance(axis, tuple) else [_axis_size(mesh, axis)])
    n = 1
    for s in sizes:
        n *= s
    return dim % n == 0 and dim >= n


def _guard(shape, spec, mesh):
    """Replace non-divisible entries with None."""
    out = []
    for dim, ax in zip(shape, spec):
        out.append(ax if _fits(dim, mesh, ax) else None)
    return P(*out)


def spec_for_param(path_s: str, leaf, mesh, fsdp_enabled: bool = True) -> P:
    """PartitionSpec for one parameter leaf (dense array).

    ``fsdp_enabled=False`` is the SERVING layout: weights replicated over
    the data axes, TP-only — no per-step FSDP all-gathers. This is what
    low-bit quantization buys at scale: e.g. mistral-large-123b INT4 is
    5.8 GB/chip TP-16-resident, where bf16 (15.4 GB) does not fit beside
    its KV cache (DESIGN.md §2, EXPERIMENTS.md §Perf cell C).
    """
    fsdp, tp = ("data" if fsdp_enabled else None), "model"
    shape = tuple(leaf.shape)
    nd = len(shape)
    if nd == 0:
        return P()
    if any(f in path_s for f in TABLE_FRAGMENTS):
        # (V, d) tables: vocab over TP, features over FSDP
        spec = [None] * nd
        if nd >= 2:
            spec[-2], spec[-1] = tp, fsdp
        return _guard(shape, spec, mesh)

    sd = infer_stack_dims(path_s, leaf)
    mat = nd - sd
    if mat <= 1:
        # biases / gates / norms: replicate (small)
        return P(*([None] * nd))

    lead = [None] * sd
    is_expert = sd >= 2                        # (L, E, d, f) MoE experts
    if is_expert:
        lead = [None, tp]                      # EP over "model"
        row_ax, col_ax = fsdp, None
    elif any(f in path_s for f in ROW_TP_FRAGMENTS):
        row_ax, col_ax = tp, fsdp              # (f|HD, d) down/out proj
    else:
        row_ax, col_ax = fsdp, tp              # (d, f|HD) up/in proj
    spec = lead + [None] * (mat - 2) + [row_ax, col_ax]
    return _guard(shape, spec, mesh)


def param_shardings(params, mesh, fsdp: bool = True) -> Any:
    """Pytree of NamedShardings matching `params` (dense or quantized).
    SplitQuantTensor subleaves get derived specs. ``fsdp=False`` = serving
    layout (TP-only, weights replicated over data)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        path_s = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                          for p in path).lower()
        if path_s.endswith("/q") or path_s.endswith("/cid"):
            base = path_s.rsplit("/", 1)[0]
            spec = spec_for_param(base, leaf, mesh, fsdp_enabled=fsdp)
        elif path_s.endswith("/scale") or path_s.endswith("/zero"):
            spec = P(*([None] * leaf.ndim))
        else:
            spec = spec_for_param(path_s, leaf, mesh, fsdp_enabled=fsdp)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(batch, mesh) -> Any:
    """Batch-dim-0 sharding over the data axes for every batch leaf."""
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]

    def spec(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return NamedSharding(mesh, P())
        s = [dp] + [None] * (len(shape) - 1)
        return NamedSharding(mesh, _guard(shape, s, mesh))

    return jax.tree_util.tree_map(spec, batch)


def cache_shardings(cache, mesh) -> Any:
    """KV/recurrent caches: (L, B, T, H, D)-style → batch over data axes,
    head/feature dim over "model" when divisible."""
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    tp = "model"

    def spec(leaf):
        shape = tuple(leaf.shape)
        nd = len(shape)
        if nd <= 2:                      # slot_pos (L, T)
            return NamedSharding(mesh, P(*([None] * nd)))
        s = [None, dp] + [None] * (nd - 2)
        if nd >= 4:
            s[-2] = tp                   # heads (KV) / width dim
            guarded = _guard(shape, s, mesh)
            if nd == 5 and guarded[-2] is None:
                # KV heads < TP degree (GQA kv=8 on TP=16): shard the
                # TIME dim over "model" instead — keeps the 1.5 TB-scale
                # 32k cache within HBM (§Perf cell C iter 2).
                s = [None, dp, tp, None, None]
            return NamedSharding(mesh, _guard(shape, s, mesh))
        elif nd == 3:
            if jnp.issubdtype(leaf.dtype, jnp.integer):
                # (L, B, T) per-request slot_pos / engine kv_pos: batch only
                return NamedSharding(mesh, _guard(shape, s, mesh))
            s[-1] = tp                   # (L, B, r) recurrent state width
        return NamedSharding(mesh, _guard(shape, s, mesh))

    return jax.tree_util.tree_map(spec, cache)


def opt_shardings(opt_state, param_sh, mesh) -> Any:
    """Optimizer m/v/err mirror the param shardings; step is replicated."""
    from repro.optim.adamw import OptState
    rep = NamedSharding(mesh, P())
    return OptState(step=rep, m=param_sh, v=param_sh,
                    err=param_sh if opt_state.err is not None else None)
