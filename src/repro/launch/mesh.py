"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is pure data parallelism (no FSDP across pods: cross-pod DCI links are
an order of magnitude slower than intra-pod ICI, so only gradient
all-reduce crosses them).

Functions, not module constants: importing this module never touches jax
device state (required so smoke tests see 1 CPU device while the dry-run
sees 512 forced host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist, as a 1×N (data, model) mesh — used by tests
    and the single-host train driver (elastic: adapts to the fleet)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Mesh axes carrying the batch dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
