"""Greedy mixed-precision bit allocation under a deployed-bytes budget.

Given the sensitivity table (per group: calibration error and deployed
bytes at each candidate bit-width), start every group at the lowest
bit-width and repeatedly buy the upgrade with the best error-reduction per
extra byte that still fits the budget — the classic greedy knapsack
heuristic for per-layer bit assignment (Nayak et al., 1910.04877), applied
on top of SplitQuant's outlier-aware splitting (splitting composes with
per-layer decisions, cf. outlier channel splitting, 1901.09504).

The result is an overrides map for ``quantize_tree(overrides=...)`` /
``QuantRecipe.policies`` — i.e. a *deployable* allocation, not a report.
"""
from __future__ import annotations

from typing import Optional


def uniform_bytes(table: dict, bits: int) -> int:
    """Deployed bytes if every group uniformly gets ``bits``."""
    return sum(r["per_bits"][bits]["bytes"] for r in table.values())


def greedy_allocate(table: dict, budget_bytes: float, *,
                    metric: str = "kl",
                    method: str = "splitquant", k: int = 3) -> dict:
    """Allocate per-group bit-widths under ``budget_bytes``.

    ``table``: :func:`repro.calib.sensitivity.layer_sensitivity` output.
    ``metric``: "kl" or "mse" — the calibration error being minimized.

    Returns ``{"overrides": {path: {bits, method, k}}, "assignment":
    {path: bits}, "total_bytes": int, "avg_bits": float, "feasible":
    bool}`` — ``feasible`` is False when even the all-minimum assignment
    exceeds the budget (the minimum assignment is still returned).
    """
    paths = sorted(table.keys())
    if not paths:
        raise ValueError("empty sensitivity table")
    bits_lists = {p: sorted(table[p]["per_bits"].keys()) for p in paths}
    assign = {p: bits_lists[p][0] for p in paths}

    def group_bytes(p):
        return table[p]["per_bits"][assign[p]]["bytes"]

    def group_err(p, bits):
        return table[p]["per_bits"][bits][metric]

    total = sum(group_bytes(p) for p in paths)
    feasible = total <= budget_bytes
    while True:
        best = None                      # (gain_per_byte, path, next_bits)
        for p in paths:
            blist = bits_lists[p]
            i = blist.index(assign[p])
            if i + 1 >= len(blist):
                continue
            nxt = blist[i + 1]
            extra = table[p]["per_bits"][nxt]["bytes"] - group_bytes(p)
            if total + extra > budget_bytes:
                continue
            gain = group_err(p, assign[p]) - group_err(p, nxt)
            # upgrades that cost nothing extra are always taken first
            rate = gain / max(extra, 1)
            if gain > 0 and (best is None or rate > best[0]):
                best = (rate, p, nxt, extra)
        if best is None:
            break
        _, p, nxt, extra = best
        assign[p] = nxt
        total += extra

    n_weights = sum(table[p]["size"] for p in paths)
    avg_bits = sum(assign[p] * table[p]["size"] for p in paths) / n_weights
    overrides = {p: {"bits": int(assign[p]), "method": method, "k": k}
                 for p in paths}
    return {"overrides": overrides,
            "assignment": {p: int(assign[p]) for p in paths},
            "total_bytes": int(total),
            "avg_bits": float(avg_bits),
            "feasible": bool(feasible)}


def best_uniform_within(table: dict, budget_bytes: float) -> Optional[int]:
    """Largest uniform bit-width whose deployment fits the budget (None if
    not even the smallest fits) — the fair uniform baseline at a budget."""
    fits = [b for b in sorted(next(iter(table.values()))["per_bits"])
            if uniform_bytes(table, b) <= budget_bytes]
    return max(fits) if fits else None
