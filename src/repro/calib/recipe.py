"""QuantRecipe: the serializable product of offline calibration.

A recipe is everything the serving stack needs to deploy a quantized model
*without* redoing any calibration work at startup:

  * ``policies``   — per-path ``{bits, k, method[, percentile]}`` overrides
                     for :func:`repro.core.apply.quantize_tree` (the output
                     of :mod:`repro.calib.allocate`);
  * ``kv_scales``  — static per-layer INT8 KV-cache quantization params
                     (``k_scale/k_zero/v_scale/v_zero``, each (L, Hkv, C))
                     that let the engine skip the per-step min/max reduce;
  * ``act_scales`` — static per-site activation scale/zero arrays for the
                     fused act-quant kernel path;
  * ``ckpt_dir``   — optional pointer to a checkpoint of the already-
                     quantized weight tree (see ``checkpoint/ckpt.py``
                     quant-meta support), so serving never re-runs k-means.

On disk a recipe is a directory: ``recipe.json`` holds everything scalar
and the policy map; ``scales.npz`` holds the arrays. Loading is a plain
read — no model, no data, no clustering.

Integrity (DESIGN.md §13): ``save`` records per-array CRC32 checksums in
``recipe.json``; ``load`` verifies them (when present — older recipes
predate the field) and validates the scale invariants (finite, and
strictly positive for ``*_scale`` — a zero or negative quantization
step can only come from corruption), raising
``engine.recovery.IntegrityError`` rather than letting a corrupt recipe
quantize the serving cache.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

import numpy as np

RECIPE_JSON = "recipe.json"
SCALES_NPZ = "scales.npz"

KV_KEYS = ("k_scale", "k_zero", "v_scale", "v_zero")


@dataclasses.dataclass
class QuantRecipe:
    """Offline calibration output (see module docstring)."""

    name: str = "recipe"
    arch: str = ""
    #: per-path quantize_tree overrides: {path: {bits|k|method|percentile}}
    policies: dict = dataclasses.field(default_factory=dict)
    #: static KV quant params {k_scale,k_zero,v_scale,v_zero: (L, Hkv, C)}
    kv_scales: Optional[dict] = None
    kv_qchunks: int = 4
    #: static activation params {site: {"scale": arr, "zero": arr}}
    act_scales: Optional[dict] = None
    #: checkpoint dir holding the pre-quantized weight tree (no k-means
    #: at serve startup); relative paths resolve against the recipe dir
    ckpt_dir: Optional[str] = None
    #: free-form provenance (budget, calibration set, sensitivity summary)
    meta: dict = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------- save ---
    def save(self, recipe_dir: str) -> str:
        os.makedirs(recipe_dir, exist_ok=True)
        arrays = {}
        if self.kv_scales is not None:
            missing = [kk for kk in KV_KEYS if kk not in self.kv_scales]
            if missing:
                raise ValueError(f"kv_scales missing {missing}")
            for kk in KV_KEYS:
                arrays[f"kv/{kk}"] = np.asarray(self.kv_scales[kk],
                                                np.float32)
        for site, sz in (self.act_scales or {}).items():
            arrays[f"act/{site}/scale"] = np.asarray(sz["scale"], np.float32)
            arrays[f"act/{site}/zero"] = np.asarray(sz["zero"], np.float32)
        from repro.engine.recovery import checksum_arrays
        doc = {
            "name": self.name,
            "arch": self.arch,
            "policies": self.policies,
            "kv_qchunks": self.kv_qchunks,
            "has_kv_scales": self.kv_scales is not None,
            "act_sites": sorted((self.act_scales or {}).keys()),
            "ckpt_dir": self.ckpt_dir,
            "meta": self.meta,
            "checksums": checksum_arrays(arrays),
        }
        tmp = os.path.join(recipe_dir, RECIPE_JSON + ".tmp")
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True, default=str)
        if arrays:
            np.savez(os.path.join(recipe_dir, SCALES_NPZ), **arrays)
        os.replace(tmp, os.path.join(recipe_dir, RECIPE_JSON))
        return recipe_dir

    # ------------------------------------------------------------- load ---
    @classmethod
    def load(cls, recipe_dir: str) -> "QuantRecipe":
        with open(os.path.join(recipe_dir, RECIPE_JSON)) as f:
            doc = json.load(f)
        npz_path = os.path.join(recipe_dir, SCALES_NPZ)
        arrays = dict(np.load(npz_path)) if os.path.exists(npz_path) else {}
        # integrity gate (engine/recovery.py, DESIGN.md §13)
        from repro.engine.recovery import (check_finite, check_positive,
                                           verify_checksums)
        if "checksums" in doc:
            verify_checksums(arrays, doc["checksums"], context=recipe_dir)
        for key, a in arrays.items():
            # KV scales are divisors in dequant: zero/negative can only
            # be corruption. Act sites keep the weaker finite-only check
            # (a dead site legitimately calibrates to a degenerate range)
            if key.startswith("kv/") and key.endswith("_scale"):
                check_positive(key, a, context=recipe_dir)
            else:
                check_finite(key, a, context=recipe_dir)
        kv_scales = None
        if doc.get("has_kv_scales"):
            kv_scales = {kk: arrays[f"kv/{kk}"] for kk in KV_KEYS}
        act_scales = {site: {"scale": arrays[f"act/{site}/scale"],
                             "zero": arrays[f"act/{site}/zero"]}
                      for site in doc.get("act_sites", [])}
        return cls(name=doc["name"], arch=doc["arch"],
                   policies=doc.get("policies", {}),
                   kv_scales=kv_scales,
                   kv_qchunks=int(doc.get("kv_qchunks", 4)),
                   act_scales=act_scales or None,
                   ckpt_dir=doc.get("ckpt_dir"),
                   meta=doc.get("meta", {}))

    def resolve_ckpt_dir(self, recipe_dir: str) -> Optional[str]:
        """ckpt_dir as an absolute path (relative = inside the recipe)."""
        if self.ckpt_dir is None:
            return None
        if os.path.isabs(self.ckpt_dir):
            return self.ckpt_dir
        return os.path.join(recipe_dir, self.ckpt_dir)
