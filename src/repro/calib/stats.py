"""Calibration pass: run batches through a registry model and collect the
range statistics the rest of the calibration stack consumes.

Two collectors, matching the two places SplitQuant quantizes activations:

* :func:`collect_act_stats` — per-layer, per-site activation ranges of the
  encoder family's §4.2 tap points (min/max, symmetric percentile clip
  points, per-chunk min/max) via the instrumented forward pass
  (``bert_tiny.forward(collect_stats=...)`` emits stats through the layer
  scan, so a 2-layer model costs one forward per batch, not 2·sites).
* :func:`collect_kv_stats` — per-layer, per-head, per-chunk K/V ranges of
  a transformer-family model, measured on the actual prefill path (the
  same tensors the engine's INT8 slot cache stores at rest).

Batch-to-batch merging is exact for min/max (running min/max) and the
standard observer approximation for percentiles (running mean of
per-batch percentiles — a single batch cannot see the global quantiles).

From the merged stats, :func:`kv_static_scales` / :func:`act_static_scales`
derive the (S, Z) constants a :class:`~repro.calib.recipe.QuantRecipe`
ships to serving, where they replace the runtime min/max reduce.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_model


@dataclasses.dataclass
class ActStats:
    """Merged activation statistics. ``sites[name]`` maps each stat
    (min/max/p_lo/p_hi scalars-per-layer (L,), chunk_min/chunk_max
    (L, C)) to a numpy array."""

    sites: dict
    n_chunks: int
    percentile: float
    n_batches: int = 0


def _merge(acc: Optional[dict], new: dict, n_seen: int) -> dict:
    """Merge one batch's stats tree into the accumulator (numpy)."""
    new = {k: {s: np.asarray(v) for s, v in d.items()}
           for k, d in new.items()}
    if acc is None:
        return new
    out = {}
    for site, d in new.items():
        a = acc[site]
        out[site] = {
            "min": np.minimum(a["min"], d["min"]),
            "max": np.maximum(a["max"], d["max"]),
            "chunk_min": np.minimum(a["chunk_min"], d["chunk_min"]),
            "chunk_max": np.maximum(a["chunk_max"], d["chunk_max"]),
            # running mean over batches for the quantile estimates
            "p_lo": a["p_lo"] + (d["p_lo"] - a["p_lo"]) / (n_seen + 1),
            "p_hi": a["p_hi"] + (d["p_hi"] - a["p_hi"]) / (n_seen + 1),
        }
    return out


def collect_act_stats(cfg, params, batches: Iterable[dict], *,
                      n_chunks: int = 3, percentile: float = 0.99
                      ) -> ActStats:
    """Per-layer activation ranges at the §4.2 tap sites of an encoder
    (BERT-Tiny) model over an iterable of calibration batches."""
    model = get_model(cfg)
    opts = {"n_chunks": n_chunks, "percentile": percentile}

    @jax.jit
    def stats_pass(p, b):
        _, stats = model.forward(p, cfg, b, collect_stats=opts)
        return stats

    acc, n = None, 0
    for b in batches:
        jb = {k: jnp.asarray(v) for k, v in b.items()
              if k in ("tokens", "mask")}
        acc = _merge(acc, jax.device_get(stats_pass(params, jb)), n)
        n += 1
    if acc is None:
        raise ValueError("no calibration batches")
    return ActStats(sites=acc, n_chunks=n_chunks, percentile=percentile,
                    n_batches=n)


def collect_kv_stats(cfg, params, batches: Iterable[np.ndarray], *,
                     qchunks: int = 4) -> dict:
    """Per-(layer, head, chunk) K/V ranges of a transformer-family model.

    ``batches``: iterable of (B, S) int32 token arrays (equal S per batch;
    serving calibration needs no labels). Runs the real ``prefill`` and
    reduces the assembled cache K/V (L, B, S, Hkv, D) over batch, position
    and within-chunk channels → min/max (L, Hkv, C), merged across
    batches. Returns {"k_min","k_max","v_min","v_max"}.
    """
    model = get_model(cfg)
    D = cfg.head_dim
    if D % qchunks:
        raise ValueError(f"head_dim {D} not divisible by qchunks {qchunks}")

    @jax.jit
    def ranges(p, toks):
        _, cache = model.prefill(p, cfg, {"tokens": toks})
        out = {}
        for name, buf in (("k", cache.k), ("v", cache.v)):
            L, B, S, H, _ = buf.shape
            xc = buf.astype(jnp.float32).reshape(L, B, S, H, qchunks,
                                                 D // qchunks)
            out[f"{name}_min"] = jnp.min(xc, axis=(1, 2, 5))   # (L, H, C)
            out[f"{name}_max"] = jnp.max(xc, axis=(1, 2, 5))
        return out

    acc = None
    for toks in batches:
        r = jax.device_get(ranges(params, jnp.asarray(toks, jnp.int32)))
        if acc is None:
            acc = r
        else:
            for kk in ("k_min", "v_min"):
                acc[kk] = np.minimum(acc[kk], r[kk])
            for kk in ("k_max", "v_max"):
                acc[kk] = np.maximum(acc[kk], r[kk])
    if acc is None:
        raise ValueError("no calibration batches")
    return acc


def static_qparams(beta: np.ndarray, alpha: np.ndarray, *, bits: int = 8
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Offline (β, α) → (S, Z) with an EXACT fractional zero-point.

    The runtime `qparams` follows paper eq. 3 and ROUNDS the zero-point
    to an integer; static quantizers (`quantize_kv_static`,
    `act_split_quantize_static`) fold Z into the rounding instead —
    ``q = rint(S·x + Z)`` — so the zero-rounding error term does not
    apply to calibrated scales. Single derivation shared by the KV and
    activation recipe payloads.
    """
    beta = np.asarray(beta, np.float32)
    alpha = np.asarray(alpha, np.float32)
    qmin = -(2 ** (bits - 1))
    levels = 2 ** bits - 1
    span = alpha - beta
    amax = np.maximum(np.abs(beta), np.abs(alpha))
    # degenerate (constant) chunks: S = 1/|v| maps v to code ±1 exactly
    degenerate = np.where(amax > 0, 1.0 / np.where(amax > 0, amax, 1.0), 1.0)
    scale = np.where(span > 0, levels / np.where(span > 0, span, 1.0),
                     degenerate).astype(np.float32)
    zero = np.where(span > 0, qmin - scale * beta, 0.0).astype(np.float32)
    return scale, zero


def kv_static_scales(kv_stats: dict, *, bits: int = 8,
                     margin: float = 1.0) -> dict:
    """(β, α) per (L, Hkv, C) → static (S, Z) for the engine slot cache.

    ``margin`` > 1 widens the calibrated range symmetrically around its
    midpoint — headroom against decode-time values the calibration set
    never produced (clipping is the failure mode of static scales;
    min/max beats percentile clipping here for the same reason it does in
    the paper's weight study).
    """
    out = {}
    for name in ("k", "v"):
        beta = np.asarray(kv_stats[f"{name}_min"], np.float32)
        alpha = np.asarray(kv_stats[f"{name}_max"], np.float32)
        if margin != 1.0:
            mid = (alpha + beta) / 2
            half = (alpha - beta) / 2 * margin
            beta, alpha = mid - half, mid + half
        scale, zero = static_qparams(beta, alpha, bits=bits)
        out[f"{name}_scale"] = scale
        out[f"{name}_zero"] = zero
    return out


def act_static_scales(stats: ActStats, *, bits: int = 8,
                      use_percentile: bool = False) -> dict:
    """Per-site static activation (S, Z) from merged stats, per layer and
    chunk: {site: {"scale": (L, C), "zero": (L, C)}} — the recipe payload
    the fused act-quant kernel (`act_split_quantize_static`) consumes
    instead of a runtime range pass. Zero-points are exact/fractional,
    via the same `static_qparams` the KV payload uses.

    ``use_percentile`` clips to the calibrated percentile range instead of
    absolute min/max (the whole-tensor percentile applied per chunk).
    """
    out = {}
    for site, d in stats.sites.items():
        beta = np.asarray(d["chunk_min"], np.float32)
        alpha = np.asarray(d["chunk_max"], np.float32)
        if use_percentile:
            beta = np.maximum(beta, d["p_lo"][..., None])
            alpha = np.minimum(alpha, d["p_hi"][..., None])
        scale, zero = static_qparams(beta, alpha, bits=bits)
        out[site] = {"scale": scale, "zero": zero}
    return out
