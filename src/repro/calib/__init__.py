"""Offline calibration subsystem: measure → decide → serialize → serve.

Dataflow (DESIGN.md §7):

    stats.collect_*           activation / KV range statistics
        │
    sensitivity.layer_sensitivity
        │                     per-group logit damage × deployed bytes
    allocate.greedy_allocate
        │                     mixed-precision (bits, k, method) per path
    recipe.QuantRecipe        JSON + npz on disk
        │
    quantize_tree(overrides=…) + Engine(kv_scales=…) + ckpt

Everything here runs offline, once; serving (`launch/serve.py --recipe`)
only reads the recipe (and optionally a pre-quantized checkpoint), so no
k-means, no calibration batches, and no runtime min/max on the decode hot
path.
"""
from .allocate import best_uniform_within, greedy_allocate, uniform_bytes
from .recipe import QuantRecipe
from .sensitivity import (layer_sensitivity, quantizable_groups,
                          sensitivity_summary)
from .stats import (ActStats, act_static_scales, collect_act_stats,
                    collect_kv_stats, kv_static_scales, static_qparams)

__all__ = [
    "ActStats", "QuantRecipe", "act_static_scales", "best_uniform_within",
    "collect_act_stats", "collect_kv_stats", "greedy_allocate",
    "kv_static_scales", "layer_sensitivity", "quantizable_groups",
    "sensitivity_summary", "static_qparams", "uniform_bytes",
]
