"""Per-layer quantization sensitivity on a calibration set.

For each quantizable parameter group (one pytree path — stacked layer
groups count once and are quantized with the usual leading-axis vmap) and
each candidate bit-width, quantize ONLY that group, run the model on the
calibration batch, and score the damage against the FP32 logits:

    mse = E[(z_q - z_fp)²]          kl = E[KL(softmax z_fp ‖ softmax z_q)]

The evaluation reuses a single jitted forward for every (group, bits)
candidate — the perturbed tree is always dense fp32 (quantize →
dequantize), so the jit cache has exactly one entry and BERT-Tiny's full
sweep (≈16 groups × 3 bit-widths) runs in seconds on CPU.

The output table also records each group's deployed bytes per bit-width,
which is exactly what :mod:`repro.calib.allocate` needs to trade accuracy
against a byte budget.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QuantPolicy, resolve_policy
from repro.core.apply import _path_str, _quantizable, infer_stack_dims
from repro.core.splitquant import baseline_quant_tensor, splitquant_tensor


def _kl(logp_ref, logp_q):
    """Mean KL(ref ‖ q) over examples from log-probs (..., n_classes)."""
    p = jnp.exp(logp_ref)
    return jnp.mean(jnp.sum(p * (logp_ref - logp_q), axis=-1))


def quantizable_groups(params, policy: QuantPolicy,
                       is_quantizable: Optional[Callable] = None) -> list:
    """[(path_s, leaf_index, leaf, stack_dims)] for quantizable leaves, in
    tree-flatten order — the same paths quantize_tree reports/overrides."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    groups = []
    for i, (path, leaf) in enumerate(flat):
        path_s = _path_str(path)
        if (is_quantizable or _quantizable)(path_s, leaf, policy):
            groups.append((path_s, i, leaf, infer_stack_dims(path_s, leaf)))
    return groups


def layer_sensitivity(key: jax.Array, cfg, params,
                      forward_fn: Callable, calib_batch: dict, *,
                      policy: Optional[QuantPolicy] = None,
                      bits_list=(2, 4, 8),
                      is_quantizable: Optional[Callable] = None) -> dict:
    """Sensitivity table {path: {"orig_bytes", "size", "per_bits":
    {bits: {"mse", "kl", "bytes"}}}}.

    ``forward_fn(params, batch) -> logits`` — jitted once here and shared
    by every candidate. ``policy`` fixes method/k (default: the paper's
    splitquant, k=3).
    """
    policy = policy or QuantPolicy()
    flat, treedef = jax.tree_util.tree_flatten(params)
    groups = quantizable_groups(params, policy, is_quantizable)

    eval_logits = jax.jit(forward_fn)
    batch = {k: jnp.asarray(v) for k, v in calib_batch.items()}
    logits_fp = eval_logits(params, batch)
    logp_fp = jax.nn.log_softmax(logits_fp, axis=-1)

    @jax.jit
    def score(logits_q):
        logp_q = jax.nn.log_softmax(logits_q, axis=-1)
        return (jnp.mean((logits_q - logits_fp) ** 2),
                _kl(logp_fp, logp_q))

    table = {}
    for path_s, idx, leaf, sd in groups:
        key, sub = jax.random.split(key)
        row = {"orig_bytes": int(leaf.size * 4), "size": int(leaf.size),
               "per_bits": {}}
        for bits in bits_list:
            eff = resolve_policy(policy.replace(
                cfg=dataclasses.replace(policy.cfg, bits=bits)))
            if eff.method == "splitquant":
                sq = splitquant_tensor(sub, leaf, eff.cfg, k=eff.k,
                                       sample_size=eff.sample_size,
                                       stack_dims=sd)
            else:
                sq = baseline_quant_tensor(leaf, eff.cfg, stack_dims=sd)
            perturbed = list(flat)
            perturbed[idx] = sq.dequantize().astype(leaf.dtype)
            logits_q = eval_logits(
                jax.tree_util.tree_unflatten(treedef, perturbed), batch)
            mse, kl = score(logits_q)
            row["per_bits"][int(bits)] = {
                "mse": float(mse), "kl": float(kl),
                "bytes": int(sq.nbytes_deployed()),
            }
        table[path_s] = row
    return table


def sensitivity_summary(table: dict, bits: int = 2) -> list:
    """[(path, kl)] sorted most-sensitive-first at the probe bit-width —
    the human-readable ranking for logs and the recipe's provenance."""
    rows = [(p, r["per_bits"][bits]["kl"]) for p, r in table.items()
            if bits in r["per_bits"]]
    return sorted(rows, key=lambda t: -t[1])
