"""Public jit'd wrappers around the SplitQuant kernels.

`linear()` is the single entry point models use: it dispatches on the weight
leaf type (dense array vs SplitQuantTensor) and on the backend (Pallas TPU
kernel vs XLA-fused jnp reference — the latter also serves CPU/dry-run).
"""
from __future__ import annotations

import functools
from typing import Union

import jax
import jax.numpy as jnp

from repro.core.splitquant import SplitQuantTensor
from . import ref
from .packing import pack_cids, pack_codes
from .splitquant_matmul import splitquant_matmul


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def dequant_constants(sqt: SplitQuantTensor) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Affine dequant constants broadcast to (k, N):
    recip = 1/scale, shift = -zero/scale, so  ŵ = q·recip + shift."""
    N = sqt.q.shape[-1]
    scale = sqt.scale
    zero = sqt.zero
    if scale.ndim == 1:
        scale = jnp.broadcast_to(scale[:, None], (sqt.k, N))
        zero = jnp.broadcast_to(zero[:, None], (sqt.k, N))
    recip = 1.0 / scale
    shift = -zero / scale
    return recip.astype(jnp.float32), shift.astype(jnp.float32)


def pack_for_kernel(sqt: SplitQuantTensor):
    """(q_packed, cid_packed, recip, shift) in the kernel's layout.
    Weight must be 2-D (K, N) at runtime (in-scan slices of stacked
    tensors qualify)."""
    assert sqt.q.ndim == 2, sqt.q.shape
    qp = pack_codes(sqt.q, sqt.bits)
    cp = pack_cids(sqt.cid)
    recip, shift = dequant_constants(sqt)
    return qp, cp, recip, shift


@functools.partial(jax.jit, static_argnames=("bits", "k", "use_pallas",
                                             "block_m", "block_n", "block_k",
                                             "interpret"))
def quantized_matmul(x, q_packed, cid_packed, recip, shift, *, bits: int,
                     k: int = 3, use_pallas: bool = False,
                     block_m: int = 256, block_n: int = 256,
                     block_k: int = 512, interpret: bool = False):
    """y = x · Ŵ for a packed SplitQuant weight. x: (..., K) → (..., N)."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = q_packed.shape[1]
    x2 = x.reshape(-1, K)
    if not use_pallas:
        y = ref.splitquant_matmul_ref(x2, q_packed, cid_packed, recip, shift, bits)
        return y.reshape(*lead, N)

    M = x2.shape[0]
    bm = min(block_m, _round_up(M, 128))
    Mp = _round_up(M, bm)
    Np = _round_up(N, block_n)
    Kp = _round_up(K, block_k)
    per_q, per_c = 8 // bits, 4
    x2 = jnp.pad(x2, ((0, Mp - M), (0, Kp - K)))
    q_packed = jnp.pad(q_packed, ((0, (Kp - K) // per_q), (0, Np - N)))
    cid_packed = jnp.pad(cid_packed, ((0, (Kp - K) // per_c), (0, Np - N)))
    # padded columns get recip=1/shift=0; padded rows contribute q=qmin codes
    # times x=0 rows — but K-padding adds x zeros, so products vanish anyway.
    recip = jnp.pad(recip, ((0, 0), (0, Np - N)), constant_values=1.0)
    shift = jnp.pad(shift, ((0, 0), (0, Np - N)))
    y = splitquant_matmul(x2, q_packed, cid_packed, recip, shift, bits=bits,
                          k=k, block_m=bm, block_n=block_n, block_k=block_k,
                          interpret=interpret)
    return y[:M, :N].reshape(*lead, N)


def linear(x: jnp.ndarray, w: Union[jnp.ndarray, SplitQuantTensor],
           b=None, *, use_pallas: bool = False, interpret: bool = False):
    """Dense layer with transparent SplitQuant dispatch.

    NOTE (K-padding correctness): with use_pallas, padded K rows of the
    packed weight dequantize to  qmin·recip + shift ≠ 0, but the matching x
    columns are zero-padded so the extra products are exactly 0.
    """
    if isinstance(w, SplitQuantTensor):
        if w.q.ndim != 2:
            wx = w.dequantize()
            y = jnp.dot(x, wx.astype(x.dtype))
        else:
            qp, cp, recip, shift = pack_for_kernel(w)
            y = quantized_matmul(x, qp, cp, recip, shift, bits=w.bits, k=w.k,
                                 use_pallas=use_pallas, interpret=interpret)
    else:
        y = jnp.dot(x, w)
    if b is not None:
        bb = b.dequantize() if isinstance(b, SplitQuantTensor) else b
        y = y + bb
    return y
