"""Pallas TPU kernel: chunked RWKV6 WKV (data-dependent-decay linear
attention — the TPU-native adaptation of RWKV-LM's CUDA kernel).

Recurrence (per head; key dim i, value dim j):
    S_t[i,j] = w_t[i]·S_{t-1}[i,j] + k_t[i]·v_t[j]
    y_t[j]   = Σ_i r_t[i]·(S_{t-1}[i,j] + u[i]·k_t[i]·v_t[j])

A step-by-step scan is latency-bound on TPU (4096 sequential VPU steps).
The chunked form (GLA-style) turns it into MXU work: with chunk length L
and in-chunk log-decays c[t] = Σ_{s≤t} log w_s (so c ≤ 0, monotone ↓):

    intra:  att[t,s] = Σ_i r_t[i]·k_s[i]·exp(c[t-1,i] − c[s,i])   (s < t)
            att[t,t] = Σ_i r_t[i]·u[i]·k_t[i]
    inter:  y += (r ⊙ exp(c_prev)) @ S_in
    carry:  S_out = diag(exp(c[L−1]))·S_in + (k ⊙ exp(c[L−1] − c))ᵀ @ v

Every exponent is ≤ 0 (differences of a decreasing cumsum within the
chunk), so the chunked form needs NO clamping — the key numerical property
that makes this port exact. The (L, L, K) pairwise-decay tensor stays tiny
(L=16, K=64 → 64 KiB) and lives entirely in VMEM; the state S (K, V) is a
VMEM scratch carried across the sequential chunk grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_ref, *,
            n_chunks: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)          # (L, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # (L, V)
    w = w_ref[0].astype(jnp.float32)          # (L, K) decay ∈ (0, 1)
    u = u_ref[0].astype(jnp.float32)          # (1, K)
    L = r.shape[0]

    lw = jnp.log(jnp.maximum(w, 1e-30))
    c = jnp.cumsum(lw, axis=0)                # inclusive, ≤ 0, decreasing
    cp = c - lw                               # exclusive (c[t-1], c[-1]=0)

    # intra-chunk attention, all exponents ≤ 0
    D = cp[:, None, :] - c[None, :, :]        # (L, L, K)
    mask = (jnp.arange(L)[:, None] > jnp.arange(L)[None, :])
    E = jnp.where(mask[:, :, None], jnp.exp(D), 0.0)
    att = jnp.einsum("tk,sk,tsk->ts", r, k, E)
    att = att + jnp.eye(L) * jnp.sum(r * u * k, axis=-1)[:, None]

    s_in = s_ref[...]                          # (K, V)
    y = att @ v + (r * jnp.exp(cp)) @ s_in
    decay_out = jnp.exp(c[-1])                 # (K,)
    s_ref[...] = decay_out[:, None] * s_in + \
        (k * jnp.exp(c[-1][None, :] - c)).T @ v
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_chunked(r, k, v, w, u, *, chunk: int = 16, interpret: bool = False):
    """r,k,w: (BH, T, K); v: (BH, T, V); u: (BH, K) → y (BH, T, V).
    T must divide by `chunk` (ops-level callers pad)."""
    BH, T, K = r.shape
    V = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    n_chunks = T // chunk
    grid = (BH, n_chunks)
    kernel = functools.partial(_kernel, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, V), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, K), lambda b, t: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, V), lambda b, t: (b, t, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, V), r.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)


def wkv_ref(r, k, v, w, u):
    """Sequential oracle — the recurrence exactly as rwkv6._time_mix."""
    rf, kf, vf, wf = (a.astype(jnp.float32).transpose(1, 0, 2)
                      for a in (r, k, v, w))            # (T, BH, ·)
    uf = u.astype(jnp.float32)

    def step(S, xs):
        r_t, k_t, v_t, w_t = xs
        kv = k_t[..., :, None] * v_t[..., None, :]      # (BH, K, V)
        y = jnp.einsum("bi,bij->bj", r_t, S + uf[..., None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    S0 = jnp.zeros((r.shape[0], r.shape[2], v.shape[2]), jnp.float32)
    _, ys = jax.lax.scan(step, S0, (rf, kf, vf, wf))
    return ys.transpose(1, 0, 2).astype(r.dtype)


def wkv_chunked_jnp(r, k, v, w, u, chunk: int = 16, s0=None):
    """Pure-jnp chunked form (same math as the kernel) — the model-level
    fast path for training/prefill on any backend. ``s0``: optional
    (BH, K, V) carry-in state. Returns (y, s_final)."""
    BH, T, K = r.shape
    V = v.shape[-1]
    n = T // chunk
    rc, kc, wc = (a.astype(jnp.float32).reshape(BH, n, chunk, K)
                  for a in (r, k, w))
    vc = v.astype(jnp.float32).reshape(BH, n, chunk, V)
    lw = jnp.log(jnp.maximum(wc, 1e-30))
    c = jnp.cumsum(lw, axis=2)
    cp = c - lw
    D = cp[:, :, :, None, :] - c[:, :, None, :, :]      # (BH,n,L,L,K)
    mask = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])
    E = jnp.where(mask[None, None, :, :, None], jnp.exp(D), 0.0)
    att = jnp.einsum("bntk,bnsk,bntsk->bnts", rc, kc, E)
    diag = jnp.einsum("bntk,bntk->bnt", rc * u[:, None, None, :], kc)
    att = att + jnp.eye(chunk)[None, None] * diag[..., None]
    y_intra = jnp.einsum("bnts,bnsv->bntv", att, vc)

    # inter-chunk: scan the state over chunks
    k_dec = kc * jnp.exp(c[:, :, -1:, :] - c)            # (BH,n,L,K)
    s_updates = jnp.einsum("bntk,bntv->bnkv", k_dec, vc)
    chunk_decay = jnp.exp(c[:, :, -1, :])                # (BH,n,K)

    def scan_chunks(S, xs):
        upd, dec, r_exp = xs
        y = jnp.einsum("btk,bkv->btv", r_exp, S)
        S = dec[:, :, None] * S + upd
        return S, y

    r_exp = rc * jnp.exp(cp)                             # (BH,n,L,K)
    S0 = (jnp.zeros((BH, K, V), jnp.float32) if s0 is None
          else s0.astype(jnp.float32))
    S_final, y_inter = jax.lax.scan(
        scan_chunks, S0,
        (s_updates.transpose(1, 0, 2, 3), chunk_decay.transpose(1, 0, 2),
         r_exp.transpose(1, 0, 2, 3)))
    y = y_intra + y_inter.transpose(1, 0, 2, 3)
    return y.reshape(BH, T, V).astype(r.dtype), S_final
