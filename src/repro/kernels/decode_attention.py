"""Pallas TPU kernel: fused decode attention over the quantized slot cache.

One decode step reads the whole per-layer slot cache — this is THE
bandwidth-bound op of serving (DESIGN.md §6). Before this kernel the int8
cache was dequantized into a full-precision (N, T, Hkv, D) copy every step
and handed to dense `attend`, so HBM traffic was fp32-serving traffic PLUS
the dequant pass. Here the INT8 codes and per-chunk (scale, zero) stream
HBM→VMEM once, dequantize per sub-channel chunk in VMEM right next to the
dot product (SplitQuant §4.2 ranges finally pay for themselves at ~1.5
B/elt moved), and a flash-style online softmax accumulates across KV
chunks — no full-precision copy of the cache ever exists.

Shapes (one layer, decode S=1 per slot):
  q       (N, Hq, D)    post-RoPE queries, one token per slot
  k, v    (N, T, Hkv, D) int8 codes (mode="int8") or float (mode="fp")
  kv_pos  (N, T) int32  absolute position per time index, -1 = empty
  q_pos   (N,)   int32  per-slot current absolute position
  scales  per-entry (N, T, Hkv, C) fp32, or per-layer static (1, 1, Hkv, C)

Grid: (N slots, T / Tc chunks) — chunk index fastest, so the (m, l, acc)
online-softmax state for one slot lives in VMEM scratch across its chunk
sweep and the output block is written once at the final chunk. Blocks per
program: q (1, Hq, D), K/V (1, Tc, Hkv, D), scales (1, Tc, Hkv, C) dynamic
/ (1, 1, Hkv, C) static, kv_pos (1, Tc); q_pos rides in SMEM. GQA (Hq =
G·Hkv) is accumulated in the grouped (Hkv, G, ·) layout — K/V are never
broadcast to Hq. Chunks whose kv_pos entries are all -1 (dead slots,
unwritten tail) are skipped under `pl.when`: past the validity mask they
cost no flops, so a 512-deep cache with 100-deep occupants does ~1/4 of
the work. Fully-empty slots return exact 0 (the materialized reference
returns a meaningless mean-V row there; the engine discards both).

VMEM per program (Tc=128, Hkv=8, D=128, C=4): K+V codes 2·128·8·128 =
256 KiB int8, scales 2·2·128·8·4·4 = 64 KiB, q/acc 2·Hq·D·4 ≪ 1 MiB —
well under budget; Tc is the knob if D grows.

The same math ships as a pure-jnp chunked path (`use_pallas=False`, the
CPU lowering — `jax.lax.cond` gives it the same dead-chunk skip) and the
kernel itself runs under `interpret=True` as the reference fallback in
tests. Numerics match the materialize-then-`attend` path to reduction
order (same masked softmax: invalid entries get exactly-zero weight).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dequant_chunk(codes, scale, zero):
    """codes (..., H, D) int, scale/zero (..., H, C) → fp32 (..., H, D).
    Per-sub-channel-chunk affine dequant, entirely in registers/VMEM."""
    *lead, H, D = codes.shape
    C = scale.shape[-1]
    qc = codes.astype(jnp.float32).reshape(*lead, H, C, D // C)
    x = (qc - zero[..., None]) / scale[..., None]
    return x.reshape(*lead, H, D)


def _pick_kv_chunk(T: int, kv_chunk) -> int:
    """Largest divisor of T that is ≤ the requested chunk (default 128).

    T with no usable divisor (prime / awkward max_len) falls back to ONE
    chunk of T rather than a degenerate Tc=1 sweep — a T-iteration grid
    would be orders of magnitude slower than the materialized path."""
    want = min(T, 128 if kv_chunk is None else kv_chunk)
    for c in range(want, 0, -1):
        if T % c == 0:
            return c if c >= max(2, want // 8) else T
    return T


# ------------------------------------------------------------- kernel ---
def _fused_kernel(qpos_ref, q_ref, kpos_ref, k_ref, v_ref, *rest,
                  mode: str, n_chunks: int, groups: int, per_entry: bool):
    if mode == "int8":
        ks_ref, kz_ref, vs_ref, vz_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    j = pl.program_id(1)
    Hq, D = q_ref.shape[1], q_ref.shape[2]
    Hkv = k_ref.shape[2]
    G = groups

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kpos = kpos_ref[...]                                   # (1, Tc)
    qpos = qpos_ref[0, 0]
    valid = (kpos >= 0) & (kpos <= qpos)                   # (1, Tc), causal

    @pl.when(jnp.any(valid))
    def _compute():
        q = q_ref[0].astype(jnp.float32) * (D ** -0.5)     # (Hq, D)
        if mode == "int8":
            # dynamic blocks are (1, Tc, Hkv, C); static (1, 1, Hkv, C)
            # constants broadcast over the chunk's time axis
            sel = (lambda r: r[0]) if per_entry else (lambda r: r[0, 0])
            kc = _dequant_chunk(k_ref[0], sel(ks_ref), sel(kz_ref))
            vc = _dequant_chunk(v_ref[0], sel(vs_ref), sel(vz_ref))
        else:
            kc = k_ref[0].astype(jnp.float32)              # (Tc, Hkv, D)
            vc = v_ref[0].astype(jnp.float32)
        qg = q.reshape(Hkv, G, D)
        # scores (Hkv, G, Tc): batch Hkv, contract D — K never expands to Hq
        s = jax.lax.dot_general(qg, kc, (((2,), (2,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32)
        s = s.reshape(Hq, kc.shape[0])
        s = jnp.where(valid, s, NEG_INF)                   # (Hq, Tc)
        m_prev = m_ref[:, 0]                               # (Hq,)
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # exactly-zero weight on invalid entries (matches the reference:
        # exp(NEG_INF - m) underflows to 0 whenever any valid entry exists)
        p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pg = p.reshape(Hkv, G, kc.shape[0])
        pv = jax.lax.dot_general(pg, vc, (((2,), (0,)), ((0,), (1,))),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv.reshape(Hq, D)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(j == n_chunks - 1)
    def _flush():
        l = l_ref[:, :1]                                   # (Hq, 1)
        o = jnp.where(l > 0, acc_ref[...] / jnp.maximum(l, 1e-30), 0.0)
        o_ref[0] = o.astype(o_ref.dtype)


def _decode_attention_pallas(q, k, v, kv_pos, q_pos, scales, *, mode,
                             per_entry, kv_chunk, interpret):
    N, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    Tc = _pick_kv_chunk(T, kv_chunk)
    nc = T // Tc
    kernel = functools.partial(_fused_kernel, mode=mode, n_chunks=nc,
                               groups=Hq // Hkv, per_entry=per_entry)
    in_specs = [
        pl.BlockSpec((1, 1), lambda n, j: (n, 0),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((1, Hq, D), lambda n, j: (n, 0, 0)),
        pl.BlockSpec((1, Tc), lambda n, j: (n, j)),
        pl.BlockSpec((1, Tc, Hkv, D), lambda n, j: (n, j, 0, 0)),
        pl.BlockSpec((1, Tc, Hkv, D), lambda n, j: (n, j, 0, 0)),
    ]
    args = [q_pos.reshape(N, 1).astype(jnp.int32), q, kv_pos, k, v]
    if mode == "int8":
        C = scales[0].shape[-1]
        if per_entry:
            sspec = pl.BlockSpec((1, Tc, Hkv, C), lambda n, j: (n, j, 0, 0))
        else:
            sspec = pl.BlockSpec((1, 1, Hkv, C), lambda n, j: (0, 0, 0, 0))
        in_specs += [sspec] * 4
        args += list(scales)
    return pl.pallas_call(
        kernel,
        grid=(N, nc),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Hq, D), lambda n, j: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Hq, 128), jnp.float32),            # running max
            pltpu.VMEM((Hq, 128), jnp.float32),            # running sum
            pltpu.VMEM((Hq, D), jnp.float32),              # output acc
        ],
        interpret=interpret,
    )(*args)


# ------------------------------------------------- jnp chunked lowering ---
def _decode_attention_jnp(q, k, v, kv_pos, q_pos, scales, *, mode,
                          per_entry, kv_chunk):
    """Same online-softmax chunk sweep in pure jnp — the CPU path. Only a
    (N, Tc, Hkv, D) chunk is ever dequantized (transient, register-sized);
    `lax.cond` skips chunks with no valid entry, mirroring the kernel's
    `pl.when` dead-chunk skip. Chunks are carved out lazily with
    `dynamic_slice` INSIDE the cond branch — only the per-chunk kv_pos row
    (N·Tc int32) is read unconditionally, so a skipped chunk's codes and
    scales never move at all (a pre-chunked scan input would copy the
    whole cache into transposed scan leaves every step)."""
    N, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    Tc = _pick_kv_chunk(T, kv_chunk)
    nc = T // Tc
    qs = (q.astype(jnp.float32) * (D ** -0.5)).reshape(N, Hkv, G, D)
    qp = q_pos.astype(jnp.int32)[:, None]                  # (N, 1)

    def step(carry, j):
        m, l, acc = carry
        t0 = j * Tc
        pos_c = jax.lax.dynamic_slice_in_dim(kv_pos, t0, Tc, 1)  # (N, Tc)
        valid = (pos_c >= 0) & (pos_c <= qp)               # (N, Tc)

        def compute(carry):
            m, l, acc = carry

            def chunk(x):                                  # (N, T, ...) →
                return jax.lax.dynamic_slice_in_dim(x, t0, Tc, 1)

            if mode == "int8":
                ks, kz = ((chunk(scales[0]), chunk(scales[1])) if per_entry
                          else (scales[0], scales[1]))
                vs, vz = ((chunk(scales[2]), chunk(scales[3])) if per_entry
                          else (scales[2], scales[3]))
                kc = _dequant_chunk(chunk(k), ks, kz)      # (N, Tc, Hkv, D)
                vc = _dequant_chunk(chunk(v), vs, vz)
            else:
                kc = chunk(k).astype(jnp.float32)
                vc = chunk(v).astype(jnp.float32)
            s = jnp.einsum("nkgd,ntkd->nkgt", qs, kc,
                           preferred_element_type=jnp.float32)
            msk = valid[:, None, None, :]
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.where(msk, jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "nkgt,ntkd->nkgd", p, vc,
                preferred_element_type=jnp.float32)
            return m_new, l_new, acc_new

        carry = jax.lax.cond(jnp.any(valid), compute, lambda c: c, carry)
        return carry, None

    m0 = jnp.full((N, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((N, Hkv, G), jnp.float32)
    a0 = jnp.zeros((N, Hkv, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  jnp.arange(nc, dtype=jnp.int32))
    o = jnp.where(l[..., None] > 0, acc / jnp.maximum(l, 1e-30)[..., None],
                  0.0)
    return o.reshape(N, Hq, D).astype(q.dtype)


# ---------------------------------------------------------- entry point ---
def decode_attention(q, k, v, kv_pos, q_pos, *, k_scale=None, k_zero=None,
                     v_scale=None, v_zero=None, mode: str = "fp",
                     per_entry_scales: bool = True, kv_chunk=None,
                     use_pallas=None, interpret: bool = False):
    """Fused decode attention over one layer's slot cache (see module doc).

    mode="fp":   k/v are float; scale/zero args are ignored.
    mode="int8": k/v are int8 codes; scales are per-entry
                 (per_entry_scales=True, (N, T, Hkv, C)) or per-layer
                 static constants ((1, 1, Hkv, C)).
    use_pallas:  None = auto (Pallas on TPU, jnp chunk sweep elsewhere);
                 True with interpret=True is the reference fallback.
    Returns (N, Hq, D) in q.dtype.
    """
    if mode not in ("fp", "int8"):
        raise ValueError(f"unknown mode {mode!r}")
    N, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    scales = None
    if mode == "int8":
        scales = (k_scale, k_zero, v_scale, v_zero)
        if any(s is None for s in scales):
            raise ValueError("mode='int8' requires all four scale arrays")
        if D % k_scale.shape[-1]:
            raise ValueError(f"head_dim {D} not divisible by "
                             f"qchunks {k_scale.shape[-1]}")
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return _decode_attention_pallas(
            q, k, v, kv_pos, q_pos, scales, mode=mode,
            per_entry=per_entry_scales, kv_chunk=kv_chunk,
            interpret=interpret)
    return _decode_attention_jnp(
        q, k, v, kv_pos, q_pos, scales, mode=mode,
        per_entry=per_entry_scales, kv_chunk=kv_chunk)
