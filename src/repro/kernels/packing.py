"""Bit-packing for low-bit codes and cluster ids.

Codes are packed along axis 0 (the contraction axis K of a (K, N) weight),
``8 // bits`` codes per uint8 byte:

    byte[i, n] = Σ_p  u[i*per + p, n] << (bits * p)

where ``u = q - qmin`` is the unsigned code. Packing along K keeps a
(block_k, block_n) VMEM tile contiguous in the packed layout.
"""
from __future__ import annotations

import jax.numpy as jnp


def pack_codes(q: jnp.ndarray, bits: int) -> jnp.ndarray:
    """(K, N) int8 signed codes → (K*bits/8, N) uint8 packed."""
    if bits == 8:
        return (q.astype(jnp.int16) + 128).astype(jnp.uint8)
    per = 8 // bits
    K = q.shape[0]
    assert K % per == 0, f"K={K} not divisible by {per} (bits={bits})"
    qmin = -(2 ** (bits - 1))
    u = (q.astype(jnp.int32) - qmin).astype(jnp.uint32)          # [0, 2^bits)
    u = u.reshape(K // per, per, *q.shape[1:])
    byte = jnp.zeros(u.shape[0:1] + u.shape[2:], jnp.uint32)
    for p in range(per):
        byte = byte | (u[:, p] << (bits * p))
    return byte.astype(jnp.uint8)


def unpack_codes(packed: jnp.ndarray, bits: int) -> jnp.ndarray:
    """(K*bits/8, N) uint8 → (K, N) int8 signed codes. jnp-only; safe to call
    inside a Pallas kernel body."""
    if bits == 8:
        return (packed.astype(jnp.int16) - 128).astype(jnp.int8)
    per = 8 // bits
    mask = (1 << bits) - 1
    qmin = -(2 ** (bits - 1))
    b = packed.astype(jnp.int32)
    parts = [((b >> (bits * p)) & mask) for p in range(per)]     # each (Kp, N)
    u = jnp.stack(parts, axis=1)                                 # (Kp, per, N)
    u = u.reshape(packed.shape[0] * per, *packed.shape[1:])
    return (u + qmin).astype(jnp.int8)


def pack_cids(cid: jnp.ndarray) -> jnp.ndarray:
    """(K, N) uint8 cluster ids (< 4) → (K/4, N) uint8, 2 bits each."""
    per, bits = 4, 2
    K = cid.shape[0]
    assert K % per == 0
    u = cid.astype(jnp.uint32).reshape(K // per, per, *cid.shape[1:])
    byte = jnp.zeros(u.shape[0:1] + u.shape[2:], jnp.uint32)
    for p in range(per):
        byte = byte | (u[:, p] << (bits * p))
    return byte.astype(jnp.uint8)


def unpack_cids(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_cids`. jnp-only."""
    per, bits, mask = 4, 2, 3
    b = packed.astype(jnp.int32)
    parts = [((b >> (bits * p)) & mask) for p in range(per)]
    u = jnp.stack(parts, axis=1)
    return u.reshape(packed.shape[0] * per, *packed.shape[1:]).astype(jnp.uint8)
