"""Pallas TPU kernels for the SplitQuant hot path (fused dequant-matmul),
plus packing utilities and the pure-jnp oracle used for validation."""
from . import act_quant, ops, packing, ref
from .act_quant import (act_split_quantize, act_split_quantize_ref,
                        act_split_quantize_static,
                        act_split_quantize_static_ref)
from .decode_attention import decode_attention
from .ops import linear, quantized_matmul, pack_for_kernel, dequant_constants
from .splitquant_matmul import splitquant_matmul

__all__ = ["ops", "ref", "packing", "act_quant", "linear",
           "quantized_matmul", "pack_for_kernel", "dequant_constants",
           "splitquant_matmul", "act_split_quantize",
           "act_split_quantize_ref", "act_split_quantize_static",
           "act_split_quantize_static_ref", "decode_attention"]
