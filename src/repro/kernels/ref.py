"""Pure-jnp oracle for the fused SplitQuant dequant-matmul.

Two mathematically identical formulations:

  * :func:`splitquant_matmul_ref` — the fused form the TPU kernel computes
    (per-element cluster-indexed dequant, one dense matmul);
  * :func:`splitquant_matmul_paper` — the paper's literal form (k split
    layers, partial outputs summed). Used by tests to prove the kernel
    computes exactly the paper's function.
"""
from __future__ import annotations

import jax.numpy as jnp

from .packing import unpack_cids, unpack_codes


def dequant_weight_ref(q_packed: jnp.ndarray, cid_packed: jnp.ndarray,
                       recip: jnp.ndarray, shift: jnp.ndarray,
                       bits: int, dtype=jnp.float32) -> jnp.ndarray:
    """Ŵ[k, n] = q[k, n] * recip[cid[k, n], n] + shift[cid[k, n], n].

    ``recip = 1/scale`` and ``shift = -zero/scale`` are the host-precomputed
    affine dequant constants, shape (k, N).
    """
    q = unpack_codes(q_packed, bits).astype(jnp.float32)          # (K, N)
    cid = unpack_cids(cid_packed)                                 # (K, N)
    n_idx = jnp.arange(q.shape[1])
    w = q * recip[cid, n_idx] + shift[cid, n_idx]
    return w.astype(dtype)


def splitquant_matmul_ref(x: jnp.ndarray, q_packed: jnp.ndarray,
                          cid_packed: jnp.ndarray, recip: jnp.ndarray,
                          shift: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Fused form: y = x · Ŵ, accumulated in fp32."""
    w = dequant_weight_ref(q_packed, cid_packed, recip, shift, bits,
                           dtype=x.dtype)
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def splitquant_matmul_paper(x: jnp.ndarray, q_packed: jnp.ndarray,
                            cid_packed: jnp.ndarray, recip: jnp.ndarray,
                            shift: jnp.ndarray, bits: int,
                            k: int = 3) -> jnp.ndarray:
    """Paper's 3-layer form: y = Σ_c x · (Ŵ ⊙ [cid == c])."""
    w = dequant_weight_ref(q_packed, cid_packed, recip, shift, bits,
                           dtype=x.dtype)
    cid = unpack_cids(cid_packed)
    y = jnp.zeros((*x.shape[:-1], w.shape[1]), jnp.float32)
    for c in range(k):
        w_c = jnp.where(cid == c, w, 0).astype(x.dtype)
        y = y + jnp.dot(x, w_c, preferred_element_type=jnp.float32)
    return y.astype(x.dtype)
