"""Pallas TPU kernel: fused chunked-prefill attention over the quantized
slot cache, with quantize-in-kernel K/V writes.

This is the prefill-side twin of `decode_attention`. Before it, every
admitted request ran the pre-engine prefill: a dense full-precision
(L, S, Hkv, D) KV cache was materialized (`models/transformer.py:prefill`),
padded to a bucket, re-quantized, and copied into the slot cache
(`engine/kvcache.py:write_prefill`) — the last full-precision KV
materialization in serving, and the engine blocked all decoding for the
whole prompt length while it happened. Here a prompt is prefilled in
chunks: one call computes causal self-attention for a chunk of Sq prompt
tokens of ONE slot against (a) the slot's already-written cache rows
(INT8 codes dequantized per sub-channel chunk in VMEM, exactly like the
decode kernel) and (b) the chunk's own full-precision K/V, and in the
kernel epilogue quantizes the chunk's K/V (SplitQuant §4.2 per-chunk
ranges — dynamic per-entry, or static per-layer scales from a calibration
recipe) so the caller scatters the CODES straight into the slot cache's
storage layout. No (L, S, Hkv, D) fp cache ever exists, and
`write_prefill`'s pad + requantize + copy disappears.

Shapes (one layer, one slot, one chunk):
  q             (Sq, Hq, D)   post-RoPE chunk queries (Sq = padded chunk)
  k_new, v_new  (Sq, Hkv, D)  post-RoPE chunk K/V, full precision
  cache_k/v     (T, Hkv, D)   the slot's rows: int8 codes or float
  kv_pos        (T,) int32    absolute position per row, -1 = empty
  pos_start     scalar        absolute position of chunk token 0
  length        scalar        valid tokens in the chunk (rest is padding)
  scales        per-entry (T, Hkv, C) fp32, or static per-layer (Hkv, C)

Grid: (Sq/Bq query blocks, T/Tc cache chunks + 1). The KV sweep (j) is
fastest: each query block's (m, l, acc) online-softmax state lives in VMEM
scratch across the sweep. Iterations j < nc stream the slot's CACHE rows —
valid entries are exactly those with 0 <= kv_pos < pos_start (everything
earlier than the chunk; rows at >= pos_start are stale or decode-parking
garbage by the engine's invariants), so no per-query causal test is needed
and chunks with no valid entry are skipped under `pl.when` (a chunk at
pos_start=0 skips the whole sweep). The final iteration j == nc attends
the chunk's own fp K/V under the intra-chunk causal mask
(key_idx <= query_idx, key_idx < length), flushes the output block, and —
once, at query block 0 — quantizes the chunk K/V: dynamic mode computes
per-(token, head, sub-channel-chunk) (β, α) → (S, Z) with the exact
`core.quantize` eq. (1)-(3) arithmetic (codes are bit-identical to
`engine.kvcache.quantize_kv`, so chunked and one-shot prefill fill the
cache with the same bytes); static mode applies recipe constants expanded
to per-column rows through `act_quant.chunk_id_map` with the exact
fractional zero-point fold of `quantize_kv_static`.

Bytes moved per prefill token per layer (C=4, D=64, fp32 compute; see
DESIGN.md §6 for the table): the legacy path materializes 2·Hkv·D·4 B of
fp cache, re-reads it for write_prefill's quantize and writes codes
(~8 B/elt of K/V traffic plus the bucket-pad copy); the fused path moves
the chunk once into VMEM and writes 1 B/elt codes + amortized scales
(~1.5 B/elt), with prior-chunk reads scaling with the written prefix, not
with max_len.

The same math ships as a pure-jnp chunked sweep (`use_pallas=False`, the
CPU lowering, `lax.cond` dead-chunk skip) and the kernel runs under
`interpret=True` as the reference fallback in tests
(`tests/test_prefill_attention.py`).

Speculative VERIFY mode (``verify=True``, DESIGN.md §9): the same kernel
doubles as the multi-token scorer of the self-speculative decoder — a
draft window *is* a prefill chunk. The one difference is what the final
iteration attends: plain prefill attends the chunk's own K/V at full
precision (matching the legacy one-shot prefill, where the whole prompt
is scored in fp), but a verify window must reproduce PLAIN DECODE, and a
decode step writes its quantized K/V first and then attends the cache —
i.e. every token sees itself and its in-window predecessors through the
quantization round-trip. Verify mode therefore quantizes the window K/V
*first* (the identical arithmetic the epilogue stores) and attends the
dequantized codes under the intra-chunk causal mask; for a float cache
it round-trips through the cache dtype. Without this, int8 verify logits
would see fp intra-window K/V that plain decode never sees, and the
accept rule's token-identity guarantee would quietly break.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantize import QuantConfig, qparams, quantize, value_range

from .decode_attention import NEG_INF, _dequant_chunk, _pick_kv_chunk

KV_QCFG = QuantConfig(bits=8, symmetric=False)


# ----------------------------------------------------------- quant math ---
def _dyn_quantize(x, C):
    """x (S, H, D) fp → (codes int8 (S, H, D), scale/zero fp32 (S, H, C)).

    The `engine.kvcache.quantize_kv` composition (value_range → qparams →
    quantize) — ONE implementation shared by the Pallas epilogue and the
    jnp lowering (the core ops are pure jnp, so they trace inside the
    kernel too), keeping chunk codes bit-identical to what the one-shot
    `write_prefill` path stores by construction."""
    S, H, D = x.shape
    xc = x.astype(jnp.float32).reshape(S, H, C, D // C)
    beta, alpha = value_range(xc, axis=-1)
    scale, zero = qparams(beta, alpha, KV_QCFG)
    q = quantize(xc, scale[..., None], zero[..., None], KV_QCFG)
    return q.reshape(S, H, D), scale, zero


def _static_quantize_cols(x, scale_col, zero_col):
    """x (S, H, D) fp, scale/zero per-column (H, D) → int8 codes. The
    fractional zero-point is folded into the rounding, matching
    `quantize_kv_static` exactly (per-column expansion of even chunks is
    the identical scalar per element)."""
    q = jnp.clip(jnp.rint(scale_col * x.astype(jnp.float32) + zero_col),
                 -128, 127)
    return q.astype(jnp.int8)


def _dequant_cols(codes, scale_col, zero_col):
    """Static per-column affine dequant: (codes - Z) / S elementwise."""
    return (codes.astype(jnp.float32) - zero_col) / scale_col


# ------------------------------------------------------------- kernel ---
def _prefill_kernel(info_ref, q_ref, kpos_ref, ck_ref, cv_ref, kn_ref,
                    vn_ref, *rest, mode: str, per_entry: bool,
                    n_cache_chunks: int, groups: int, qchunks: int,
                    verify: bool):
    if mode == "int8" and per_entry:
        (ks_ref, kz_ref, vs_ref, vz_ref, o_ref, qk_ref, qv_ref, oks_ref,
         okz_ref, ovs_ref, ovz_ref, m_ref, l_ref, acc_ref) = rest
    elif mode == "int8":
        (ksc_ref, kzc_ref, vsc_ref, vzc_ref, o_ref, qk_ref, qv_ref,
         m_ref, l_ref, acc_ref) = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    i, j = pl.program_id(0), pl.program_id(1)
    nc = n_cache_chunks
    Bq, Hq, D = q_ref.shape
    Hkv = ck_ref.shape[1]
    G = groups
    pos_start = info_ref[0, 0]
    length = info_ref[0, 1]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # grouped (Hkv, Bq, G, ·) layout throughout — K/V never expand to Hq
    qg = (q_ref[...].astype(jnp.float32) * (D ** -0.5)).reshape(
        Bq, Hkv, G, D)

    def online_update(kc, vc, valid):
        """kc/vc (Tk, Hkv, D) fp32, valid (Bq|1, Tk) → scratch update."""
        s = jax.lax.dot_general(qg, kc, (((3,), (2,)), ((1,), (1,))),
                                preferred_element_type=jnp.float32)
        # s: (Hkv, Bq, G, Tk)
        msk = valid[None, :, None, :]
        s = jnp.where(msk, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(msk, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(p, vc, (((3,), (0,)), ((0,), (1,))),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[..., None] + pv
        m_ref[...] = m_new

    kpos = kpos_ref[...]                                   # (1, Tc)
    # cache rows are valid iff written AND strictly before the chunk: rows
    # at >= pos_start are stale previous-occupant data or the engine's
    # decode-parking garbage, and the chunk's own K/V arrive via kn/vn
    cache_valid = (kpos >= 0) & (kpos < pos_start)

    @pl.when((j < nc) & jnp.any(cache_valid))
    def _cache_chunk():
        if mode == "int8":
            if per_entry:
                kc = _dequant_chunk(ck_ref[...], ks_ref[...], kz_ref[...])
                vc = _dequant_chunk(cv_ref[...], vs_ref[...], vz_ref[...])
            else:
                kc = _dequant_cols(ck_ref[...], ksc_ref[...], kzc_ref[...])
                vc = _dequant_cols(cv_ref[...], vsc_ref[...], vzc_ref[...])
        else:
            kc = ck_ref[...].astype(jnp.float32)
            vc = cv_ref[...].astype(jnp.float32)
        online_update(kc, vc, cache_valid)

    @pl.when(j == nc)
    def _chunk_and_flush():
        Sq = kn_ref.shape[0]
        qidx = i * Bq + jax.lax.broadcasted_iota(jnp.int32, (Bq, Sq), 0)
        cidx = jax.lax.broadcasted_iota(jnp.int32, (Bq, Sq), 1)
        valid = (cidx <= qidx) & (cidx < length)           # (Bq, Sq) causal
        kn = kn_ref[...].astype(jnp.float32)
        vn = vn_ref[...].astype(jnp.float32)
        if verify:
            # speculative verify: attend the window's own K/V through the
            # SAME storage round-trip a decode-step write applies (codes
            # are what a plain-decode successor would have attended), so
            # the accept rule compares against plain-decode logits
            if mode == "int8" and per_entry:
                q8, s, z = _dyn_quantize(kn_ref[...], qchunks)
                kn = _dequant_chunk(q8, s, z)
                q8, s, z = _dyn_quantize(vn_ref[...], qchunks)
                vn = _dequant_chunk(q8, s, z)
            elif mode == "int8":
                kn = _dequant_cols(_static_quantize_cols(
                    kn_ref[...], ksc_ref[...], kzc_ref[...]),
                    ksc_ref[...], kzc_ref[...])
                vn = _dequant_cols(_static_quantize_cols(
                    vn_ref[...], vsc_ref[...], vzc_ref[...]),
                    vsc_ref[...], vzc_ref[...])
            else:
                kn = kn_ref[...].astype(ck_ref.dtype).astype(jnp.float32)
                vn = vn_ref[...].astype(cv_ref.dtype).astype(jnp.float32)
        online_update(kn, vn, valid)
        l = l_ref[...]
        o = jnp.where(l[..., None] > 0,
                      acc_ref[...] / jnp.maximum(l, 1e-30)[..., None], 0.0)
        # (Hkv, Bq, G, D) → (Bq, Hq, D)
        o_ref[...] = o.transpose(1, 0, 2, 3).reshape(Bq, Hq, D).astype(
            o_ref.dtype)

    if mode == "int8":
        # epilogue: quantize the chunk's K/V once (query block 0) so the
        # caller scatters codes straight into the slot cache layout
        @pl.when((j == nc) & (i == 0))
        def _quantize_chunk():
            if per_entry:
                for x_ref, cq_ref, cs_ref, cz_ref in (
                        (kn_ref, qk_ref, oks_ref, okz_ref),
                        (vn_ref, qv_ref, ovs_ref, ovz_ref)):
                    q8, s, z = _dyn_quantize(x_ref[...], qchunks)
                    cq_ref[...] = q8
                    cs_ref[...] = s
                    cz_ref[...] = z
            else:
                qk_ref[...] = _static_quantize_cols(
                    kn_ref[...], ksc_ref[...], kzc_ref[...])
                qv_ref[...] = _static_quantize_cols(
                    vn_ref[...], vsc_ref[...], vzc_ref[...])


def _prefill_attention_pallas(q, k_new, v_new, cache_k, cache_v, kv_pos,
                              pos_start, length, scales, *, mode, per_entry,
                              kv_chunk, q_block, interpret, verify=False):
    Sq, Hq, D = q.shape
    T, Hkv = cache_k.shape[0], cache_k.shape[1]
    Tc = _pick_kv_chunk(T, kv_chunk)
    nc = T // Tc
    Bq = _pick_kv_chunk(Sq, 128 if q_block is None else q_block)
    nq = Sq // Bq
    G = Hq // Hkv
    C = scales[0].shape[-1] if (mode == "int8" and per_entry) else 0
    qchunks = C if per_entry else (scales[0].shape[-1] if mode == "int8"
                                   else 0)
    jc = lambda j: jnp.minimum(j, nc - 1)      # clamp: block unused at j=nc
    info = jnp.asarray([[pos_start, length]], jnp.int32)
    in_specs = [
        pl.BlockSpec((1, 2), lambda i, j: (0, 0), memory_space=pltpu.SMEM),
        pl.BlockSpec((Bq, Hq, D), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((1, Tc), lambda i, j: (0, jc(j))),
        pl.BlockSpec((Tc, Hkv, D), lambda i, j: (jc(j), 0, 0)),
        pl.BlockSpec((Tc, Hkv, D), lambda i, j: (jc(j), 0, 0)),
        pl.BlockSpec((Sq, Hkv, D), lambda i, j: (0, 0, 0)),
        pl.BlockSpec((Sq, Hkv, D), lambda i, j: (0, 0, 0)),
    ]
    args = [info, q, kv_pos.reshape(1, T).astype(jnp.int32),
            cache_k, cache_v, k_new, v_new]
    out_specs = [pl.BlockSpec((Bq, Hq, D), lambda i, j: (i, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct((Sq, Hq, D), q.dtype)]
    if mode == "int8":
        if per_entry:
            sspec = pl.BlockSpec((Tc, Hkv, C), lambda i, j: (jc(j), 0, 0))
            in_specs += [sspec] * 4
            args += list(scales)
            code_spec = pl.BlockSpec((Sq, Hkv, D), lambda i, j: (0, 0, 0))
            cs_spec = pl.BlockSpec((Sq, Hkv, C), lambda i, j: (0, 0, 0))
            out_specs += [code_spec] * 2 + [cs_spec] * 4
            out_shape += [jax.ShapeDtypeStruct((Sq, Hkv, D), jnp.int8)] * 2
            out_shape += [jax.ShapeDtypeStruct((Sq, Hkv, C),
                                               jnp.float32)] * 4
        else:
            # static: per-column (Hkv, D) rows expanded via chunk_id_map —
            # one broadcast multiply serves cache dequant AND the epilogue
            sspec = pl.BlockSpec((Hkv, D), lambda i, j: (0, 0))
            in_specs += [sspec] * 4
            args += list(scales)
            code_spec = pl.BlockSpec((Sq, Hkv, D), lambda i, j: (0, 0, 0))
            out_specs += [code_spec] * 2
            out_shape += [jax.ShapeDtypeStruct((Sq, Hkv, D), jnp.int8)] * 2
    kernel = functools.partial(
        _prefill_kernel, mode=mode, per_entry=per_entry, n_cache_chunks=nc,
        groups=G, qchunks=qchunks, verify=verify)
    outs = pl.pallas_call(
        kernel,
        grid=(nq, nc + 1),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((Hkv, Bq, G), jnp.float32),         # running max
            pltpu.VMEM((Hkv, Bq, G), jnp.float32),         # running sum
            pltpu.VMEM((Hkv, Bq, G, D), jnp.float32),      # output acc
        ],
        interpret=interpret,
    )(*args)
    return outs[0], tuple(outs[1:])


# ------------------------------------------------- jnp chunked lowering ---
def _prefill_attention_jnp(q, k_new, v_new, cache_k, cache_v, kv_pos,
                           pos_start, length, scales, *, mode, per_entry,
                           kv_chunk, verify=False):
    """Same online-softmax sweep in pure jnp — the CPU path. `lax.cond`
    skips cache chunks with no valid entry (lazy `dynamic_slice` inside
    the branch, so skipped codes never move), then a final step attends
    the chunk's own fp K/V under the intra-chunk causal mask."""
    Sq, Hq, D = q.shape
    T, Hkv = cache_k.shape[0], cache_k.shape[1]
    G = Hq // Hkv
    Tc = _pick_kv_chunk(T, kv_chunk)
    nc = T // Tc
    qs = (q.astype(jnp.float32) * (D ** -0.5)).reshape(Sq, Hkv, G, D)
    pos_start = jnp.asarray(pos_start, jnp.int32)
    length = jnp.asarray(length, jnp.int32)

    def update(carry, kc, vc, valid):
        m, l, acc = carry
        s = jnp.einsum("skgd,tkd->skgt", qs, kc,
                       preferred_element_type=jnp.float32)
        msk = valid[:, None, None, :] if valid.ndim == 2 \
            else valid[None, None, None, :]
        s = jnp.where(msk, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(msk, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "skgt,tkd->skgd", p, vc, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    def cache_step(carry, j):
        t0 = j * Tc
        pos_c = jax.lax.dynamic_slice_in_dim(kv_pos, t0, Tc, 0)    # (Tc,)
        valid = (pos_c >= 0) & (pos_c < pos_start)

        def compute(carry):
            def chunk(x):
                return jax.lax.dynamic_slice_in_dim(x, t0, Tc, 0)

            if mode == "int8":
                if per_entry:
                    kc = _dequant_chunk(chunk(cache_k), chunk(scales[0]),
                                        chunk(scales[1]))
                    vc = _dequant_chunk(chunk(cache_v), chunk(scales[2]),
                                        chunk(scales[3]))
                else:
                    kc = _dequant_cols(chunk(cache_k), scales[0], scales[1])
                    vc = _dequant_cols(chunk(cache_v), scales[2], scales[3])
            else:
                kc = chunk(cache_k).astype(jnp.float32)
                vc = chunk(cache_v).astype(jnp.float32)
            return update(carry, kc, vc, valid)

        return jax.lax.cond(jnp.any(valid), compute, lambda c: c, carry), \
            None

    m0 = jnp.full((Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((Sq, Hkv, G), jnp.float32)
    a0 = jnp.zeros((Sq, Hkv, G, D), jnp.float32)
    carry, _ = jax.lax.scan(cache_step, (m0, l0, a0),
                            jnp.arange(nc, dtype=jnp.int32))
    qidx = jnp.arange(Sq, dtype=jnp.int32)
    cidx = jnp.arange(Sq, dtype=jnp.int32)
    valid = (cidx[None, :] <= qidx[:, None]) & (cidx[None, :] < length)
    kn = k_new.astype(jnp.float32)
    vn = v_new.astype(jnp.float32)
    if verify:
        # same storage round-trip as the Pallas verify branch: the window
        # attends itself exactly as a plain decode step would (quantized
        # codes for int8 caches, cache-dtype cast for float caches)
        if mode == "int8" and per_entry:
            q8, s, z = _dyn_quantize(k_new, scales[0].shape[-1])
            kn = _dequant_chunk(q8, s, z)
            q8, s, z = _dyn_quantize(v_new, scales[0].shape[-1])
            vn = _dequant_chunk(q8, s, z)
        elif mode == "int8":
            kn = _dequant_cols(_static_quantize_cols(
                k_new, scales[0], scales[1]), scales[0], scales[1])
            vn = _dequant_cols(_static_quantize_cols(
                v_new, scales[2], scales[3]), scales[2], scales[3])
        else:
            kn = k_new.astype(cache_k.dtype).astype(jnp.float32)
            vn = v_new.astype(cache_v.dtype).astype(jnp.float32)
    m, l, acc = update(carry, kn, vn, valid)
    o = jnp.where(l[..., None] > 0, acc / jnp.maximum(l, 1e-30)[..., None],
                  0.0)
    return o.reshape(Sq, Hq, D).astype(q.dtype)


# ---------------------------------------------------------- entry point ---
def prefill_attention(q, k_new, v_new, cache_k, cache_v, kv_pos, pos_start,
                      length, *, k_scale=None, k_zero=None, v_scale=None,
                      v_zero=None, mode: str = "fp",
                      per_entry_scales: bool = True, kv_chunk=None,
                      q_block=None, use_pallas=None,
                      interpret: bool = False, verify: bool = False):
    """Fused chunked-prefill attention for one layer / one slot / one
    prompt chunk (see module doc).

    mode="fp":   cache is float; scale args ignored; returns (o, ()).
    mode="int8": cache is int8 codes. per_entry_scales=True: scales are
                 per-entry (T, Hkv, C); returns (o, (qk, qv, ks, kz, vs,
                 vz)) with the chunk's codes + fresh dynamic scales.
                 per_entry_scales=False: scales are static per-layer
                 (Hkv, C) recipe constants; returns (o, (qk, qv)).
    use_pallas:  None = auto (Pallas on TPU, jnp sweep elsewhere);
                 True with interpret=True is the reference fallback.
    verify:      speculative-verify scoring (module doc): the chunk
                 attends its OWN K/V through the storage round-trip
                 (quantize→dequantize, or the cache-dtype cast) instead
                 of at full precision, so each window row's logits match
                 a plain decode step of that token. Written codes are
                 unchanged.
    """
    if mode not in ("fp", "int8"):
        raise ValueError(f"unknown mode {mode!r}")
    Sq, Hq, D = q.shape
    Hkv = cache_k.shape[1]
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    scales = None
    if mode == "int8":
        scales = (k_scale, k_zero, v_scale, v_zero)
        if any(s is None for s in scales):
            raise ValueError("mode='int8' requires all four scale arrays")
        C = k_scale.shape[-1]
        if D % C:
            raise ValueError(f"head_dim {D} not divisible by qchunks {C}")
        if not per_entry_scales:
            # expand static (Hkv, C) recipe constants to per-column rows —
            # act_quant's chunk-id map, reused at the head-dim granularity
            from .act_quant import chunk_id_map
            cid = jnp.asarray(chunk_id_map(D, C))
            scales = tuple(jnp.take(s.astype(jnp.float32), cid, axis=-1)
                           for s in scales)              # 4 × (Hkv, D)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return _prefill_attention_pallas(
            q, k_new, v_new, cache_k, cache_v, kv_pos, pos_start, length,
            scales, mode=mode, per_entry=per_entry_scales,
            kv_chunk=kv_chunk, q_block=q_block, interpret=interpret,
            verify=verify)
    o = _prefill_attention_jnp(
        q, k_new, v_new, cache_k, cache_v, kv_pos, pos_start, length,
        scales, mode=mode, per_entry=per_entry_scales, kv_chunk=kv_chunk,
        verify=verify)
    if mode != "int8":
        return o, ()
    if per_entry_scales:
        qk, ks, kz = _dyn_quantize(k_new, C)
        qv, vs, vz = _dyn_quantize(v_new, C)
        return o, (qk, qv, ks, kz, vs, vz)
    qk = _static_quantize_cols(k_new, scales[0], scales[1])
    qv = _static_quantize_cols(v_new, scales[2], scales[3])
    return o, (qk, qv)
