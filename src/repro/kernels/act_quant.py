"""Pallas TPU kernel: fused activation SPLIT-quantization (paper §4.2).

At serving time activations are quantized dynamically: the vector of
length n is split into ``n_chunks`` chunks, each quantized with its own
runtime (β, α). Unfused, this is 2 passes over the activation in HBM
(min/max reduce, then scale). The kernel fuses both into one VMEM-resident
pass per (row-block × chunk): ranges never leave VMEM, and the int8 codes
+ per-(row, chunk) scale/zero stream out at ¼ the bf16 bytes.

Grid: (rows / block_r, n_chunks). Each program owns a (block_r, chunk)
tile: reduce β/α over the chunk width, derive (S, Z) per row, emit codes.
Per-ROW ranges (finer than the paper's per-tensor-per-chunk — rows are
independent tokens, so this is strictly better and free on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _kernel(x_ref, q_ref, scale_ref, zero_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)                 # (br, cw)
    beta = jnp.min(x, axis=-1, keepdims=True)
    alpha = jnp.max(x, axis=-1, keepdims=True)
    span = alpha - beta
    levels = float(2 ** bits - 1)
    qmin = -(2 ** (bits - 1))
    qmax = 2 ** (bits - 1) - 1
    amax = jnp.maximum(jnp.abs(beta), jnp.abs(alpha))
    degenerate = jnp.where(amax > 0, 1.0 / jnp.where(amax > 0, amax, 1.0),
                           1.0)
    scale = jnp.where(span > 0, levels / jnp.where(span > 0, span, 1.0),
                      degenerate)
    zero = jnp.where(span > 0, -(2.0 ** (bits - 1)) - jnp.rint(scale * beta),
                     0.0)
    q = jnp.clip(jnp.rint(scale * x) + zero, qmin, qmax)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale
    zero_ref[...] = zero


@functools.partial(jax.jit, static_argnames=("bits", "n_chunks", "block_r",
                                             "interpret"))
def act_split_quantize(x: jnp.ndarray, *, bits: int = 8, n_chunks: int = 3,
                       block_r: int = 256, interpret: bool = False):
    """x: (R, N) → (q int8 (R, N), scale (R, n_chunks), zero (R, n_chunks)).

    N must divide by n_chunks; R by block_r (callers pad — see ops).
    """
    R, N = x.shape
    assert N % n_chunks == 0 and R % block_r == 0, (x.shape, n_chunks,
                                                    block_r)
    cw = N // n_chunks
    grid = (R // block_r, n_chunks)
    kernel = functools.partial(_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_r, cw), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block_r, cw), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, 1), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, N), jnp.int8),
            jax.ShapeDtypeStruct((R, n_chunks), jnp.float32),
            jax.ShapeDtypeStruct((R, n_chunks), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def chunk_id_map(n: int, n_chunks: int) -> np.ndarray:
    """(n,) int32 chunk id per column for splitting a width-``n`` axis into
    ``n_chunks`` contiguous ``array_split`` chunks (uneven widths put the
    extra columns in the leading chunks; even widths reproduce the plain
    reshape grouping exactly). Shared by the static act-quant kernel below
    and the prefill-attention epilogue (`kernels/prefill_attention.py`) —
    gathering per-chunk (scale, zero) through this map turns chunked
    quantization into a single per-column broadcast multiply, one kernel
    launch for any chunking."""
    from repro.core.splitquant import activation_chunk_bounds

    bounds = activation_chunk_bounds(n, n_chunks)
    return np.repeat(np.arange(n_chunks), np.diff(bounds)).astype(np.int32)


def _static_kernel(x_ref, scale_ref, zero_ref, q_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)                 # (br, N)
    scale = scale_ref[...]                             # (1, N) per-column
    zero = zero_ref[...]
    qmin = -(2 ** (bits - 1))
    qmax = 2 ** (bits - 1) - 1
    # offline zero-points are exact (fractional) and folded into the
    # rounding — no eq.-3 zero-rounding error term on the static path
    q_ref[...] = jnp.clip(jnp.rint(scale * x + zero), qmin,
                          qmax).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bits", "block_r", "interpret"))
def act_split_quantize_static(x: jnp.ndarray, scale: jnp.ndarray,
                              zero: jnp.ndarray, *, bits: int = 8,
                              block_r: int = 256, interpret: bool = False):
    """Static-scale variant: quantize with precomputed per-chunk (S, Z)
    from an offline calibration recipe. x: (R, N), scale/zero:
    (n_chunks,) → q int8 (R, N).

    No in-kernel range reduce — one scale+round+clip pass, which removes
    the runtime min/max from the serving hot path. Use the dynamic
    `act_split_quantize` as the fallback when no recipe is loaded.

    ONE pallas_call for every chunking, even or uneven: the static
    `array_split` chunk bounds become a per-column chunk-id map, the
    (n_chunks,) scales gather through it into per-column (1, N) rows (an
    N-element host-free gather, fused into the jit), and the kernel is a
    pure row-block broadcast multiply. Previously indivisible widths
    launched one pallas_call per chunk — n_chunks kernel launches per
    layer call, now 1. Each program owns a full-width (block_r, N) tile;
    at serving widths (N ≤ 8k) that is ≪ VMEM, shrink block_r if N grows.
    """
    R, N = x.shape
    n_chunks = scale.shape[-1]
    assert R % block_r == 0, (x.shape, block_r)
    cid = jnp.asarray(chunk_id_map(N, n_chunks))               # (N,)
    scale_row = jnp.take(scale.astype(jnp.float32).reshape(-1), cid)[None]
    zero_row = jnp.take(zero.astype(jnp.float32).reshape(-1), cid)[None]
    return pl.pallas_call(
        functools.partial(_static_kernel, bits=bits),
        grid=(R // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, N), lambda i: (i, 0)),
            pl.BlockSpec((1, N), lambda i: (0, 0)),
            pl.BlockSpec((1, N), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, N), jnp.int8),
        interpret=interpret,
    )(x, scale_row, zero_row)


def act_split_quantize_static_ref(x: jnp.ndarray, scale: jnp.ndarray,
                                  zero: jnp.ndarray, *, bits: int = 8):
    """Pure-jnp oracle for the static-scale kernel (fractional zero folded
    into the rounding, matching `quantize_kv_static`; uneven array_split
    chunks for indivisible widths)."""
    from repro.core.splitquant import activation_chunk_bounds
    R, N = x.shape
    n_chunks = scale.shape[-1]
    bounds = activation_chunk_bounds(N, n_chunks)
    qmin, qmax = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    outs = []
    for c, (lo, hi) in enumerate(zip(bounds, bounds[1:])):
        xc = x[:, lo:hi].astype(jnp.float32)
        outs.append(jnp.clip(jnp.rint(scale[c] * xc + zero[c]), qmin, qmax))
    return jnp.concatenate(outs, axis=1).astype(jnp.int8)


def act_split_quantize_ref(x: jnp.ndarray, *, bits: int = 8,
                           n_chunks: int = 3):
    """Pure-jnp oracle (per-row per-chunk ranges, eqs. 1-3)."""
    from repro.core.quantize import QuantConfig, qparams, quantize
    R, N = x.shape
    cfg = QuantConfig(bits=bits)
    xc = x.reshape(R, n_chunks, N // n_chunks).astype(jnp.float32)
    beta = jnp.min(xc, axis=-1)
    alpha = jnp.max(xc, axis=-1)
    scale, zero = qparams(beta, alpha, cfg)            # (R, n_chunks)
    q = quantize(xc, scale[..., None], zero[..., None], cfg)
    return q.reshape(R, N), scale, zero


# ------------------------------------------------ quality observation ---
#: module-level quality probe (`repro.obs.quality.ActQuantProbe`) fed by
#: the *_observed host wrappers below. The jitted kernels stay untouched
#: — observation happens on their OUTPUTS, and pulling codes to host is
#: the (deliberate, observed-mode-only) cost. None = observation off.
_QUALITY_PROBE = None


def set_quality_probe(probe) -> None:
    """Install the module-level `ActQuantProbe` (None clears it). The
    probe sees every `act_split_quantize_observed` /
    `act_split_quantize_static_observed` call's codes + dynamic scales."""
    global _QUALITY_PROBE
    _QUALITY_PROBE = probe if probe else None


def act_split_quantize_observed(x, *, layer=None, **kw):
    """`act_split_quantize` + quality observation: same returns, and when
    a probe is installed its saturation/occupancy counters (plus the
    per-row-chunk range spread, via the dynamic scales) accumulate."""
    q, scale, zero = act_split_quantize(x, **kw)
    probe = _QUALITY_PROBE
    if probe is not None:
        probe.observe(np.asarray(q), np.asarray(scale), layer=layer)
    return q, scale, zero


def act_split_quantize_static_observed(x, scale, zero, *, layer=None,
                                       **kw):
    """`act_split_quantize_static` + quality observation. Static scales
    carry no per-call range information, so the probe sees codes only —
    clip fraction and code occupancy, exactly the drift signals a frozen
    recipe needs watched (DESIGN.md §10)."""
    q = act_split_quantize_static(x, scale, zero, **kw)
    probe = _QUALITY_PROBE
    if probe is not None:
        probe.observe(np.asarray(q), layer=layer)
    return q


def dequantize_act(q, scale, zero, dtype=jnp.float32):
    """Works for both layouts: dynamic per-row scale/zero (R, n_chunks)
    and static per-tensor scale/zero (n_chunks,), including static scales
    over uneven (array_split) chunk widths."""
    R, N = q.shape
    n_chunks = scale.shape[-1]
    if N % n_chunks:
        from repro.core.splitquant import activation_chunk_bounds
        assert scale.ndim == 1, "uneven chunks require static (1-D) scales"
        bounds = activation_chunk_bounds(N, n_chunks)
        outs = [(q[:, lo:hi].astype(jnp.float32) - zero[c]) / scale[c]
                for c, (lo, hi) in enumerate(zip(bounds, bounds[1:]))]
        return jnp.concatenate(outs, axis=1).astype(dtype)
    if scale.ndim == 1:
        scale = scale[None]
        zero = zero[None]
    qc = q.reshape(R, n_chunks, N // n_chunks).astype(jnp.float32)
    x = (qc - zero[..., None]) / scale[..., None]
    return x.reshape(R, N).astype(dtype)
