"""Pallas TPU kernel: fused activation SPLIT-quantization (paper §4.2).

At serving time activations are quantized dynamically: the vector of
length n is split into ``n_chunks`` chunks, each quantized with its own
runtime (β, α). Unfused, this is 2 passes over the activation in HBM
(min/max reduce, then scale). The kernel fuses both into one VMEM-resident
pass per (row-block × chunk): ranges never leave VMEM, and the int8 codes
+ per-(row, chunk) scale/zero stream out at ¼ the bf16 bytes.

Grid: (rows / block_r, n_chunks). Each program owns a (block_r, chunk)
tile: reduce β/α over the chunk width, derive (S, Z) per row, emit codes.
Per-ROW ranges (finer than the paper's per-tensor-per-chunk — rows are
independent tokens, so this is strictly better and free on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, q_ref, scale_ref, zero_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)                 # (br, cw)
    beta = jnp.min(x, axis=-1, keepdims=True)
    alpha = jnp.max(x, axis=-1, keepdims=True)
    span = alpha - beta
    levels = float(2 ** bits - 1)
    qmin = -(2 ** (bits - 1))
    qmax = 2 ** (bits - 1) - 1
    amax = jnp.maximum(jnp.abs(beta), jnp.abs(alpha))
    degenerate = jnp.where(amax > 0, 1.0 / jnp.where(amax > 0, amax, 1.0),
                           1.0)
    scale = jnp.where(span > 0, levels / jnp.where(span > 0, span, 1.0),
                      degenerate)
    zero = jnp.where(span > 0, -(2.0 ** (bits - 1)) - jnp.rint(scale * beta),
                     0.0)
    q = jnp.clip(jnp.rint(scale * x) + zero, qmin, qmax)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale
    zero_ref[...] = zero


@functools.partial(jax.jit, static_argnames=("bits", "n_chunks", "block_r",
                                             "interpret"))
def act_split_quantize(x: jnp.ndarray, *, bits: int = 8, n_chunks: int = 3,
                       block_r: int = 256, interpret: bool = False):
    """x: (R, N) → (q int8 (R, N), scale (R, n_chunks), zero (R, n_chunks)).

    N must divide by n_chunks; R by block_r (callers pad — see ops).
    """
    R, N = x.shape
    assert N % n_chunks == 0 and R % block_r == 0, (x.shape, n_chunks,
                                                    block_r)
    cw = N // n_chunks
    grid = (R // block_r, n_chunks)
    kernel = functools.partial(_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_r, cw), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block_r, cw), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, 1), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, N), jnp.int8),
            jax.ShapeDtypeStruct((R, n_chunks), jnp.float32),
            jax.ShapeDtypeStruct((R, n_chunks), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def act_split_quantize_ref(x: jnp.ndarray, *, bits: int = 8,
                           n_chunks: int = 3):
    """Pure-jnp oracle (per-row per-chunk ranges, eqs. 1-3)."""
    from repro.core.quantize import QuantConfig, qparams, quantize
    R, N = x.shape
    cfg = QuantConfig(bits=bits)
    xc = x.reshape(R, n_chunks, N // n_chunks).astype(jnp.float32)
    beta = jnp.min(xc, axis=-1)
    alpha = jnp.max(xc, axis=-1)
    scale, zero = qparams(beta, alpha, cfg)            # (R, n_chunks)
    q = quantize(xc, scale[..., None], zero[..., None], cfg)
    return q.reshape(R, N), scale, zero


def dequantize_act(q, scale, zero, dtype=jnp.float32):
    R, N = q.shape
    n_chunks = scale.shape[-1]
    qc = q.reshape(R, n_chunks, N // n_chunks).astype(jnp.float32)
    x = (qc - zero[..., None]) / scale[..., None]
    return x.reshape(R, N).astype(dtype)
