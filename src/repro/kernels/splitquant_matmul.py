"""Pallas TPU kernel: fused SplitQuant dequant-matmul.

y[m, n] = Σ_k x[m, k] · ( q[k, n] · recip[cid[k,n], n] + shift[cid[k,n], n] )

Design (DESIGN.md §2): the paper's three split layers are realized as one
dense matmul whose weight tile is dequantized on the fly in VMEM with
cluster-indexed scales. Packed low-bit codes (2/4/8-bit) and 2-bit cluster
ids are staged HBM→VMEM as uint8, unpacked to int, scaled per cluster on the
VPU, then fed to the MXU in the input dtype with fp32 accumulation.

VMEM budget per grid step (defaults bm=bn=256, bk=512, bf16 x):
  x tile 256·512·2 = 256 KiB, packed q 512/4·256 = 32 KiB (int2),
  cid 512/4·256 = 32 KiB, w tile 512·256·2 = 256 KiB, acc 256·256·4 = 256 KiB
  → ~0.9 MiB ≪ 16 MiB VMEM; MXU dims all multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .packing import unpack_cids, unpack_codes


def _select_per_cluster(vals: jnp.ndarray, cid: jnp.ndarray, k: int) -> jnp.ndarray:
    """vals: (k, bn) per-cluster constants; cid: (bk, bn) → (bk, bn).
    k is static and tiny (≤4), so an unrolled masked sum beats a gather on
    the VPU (no dynamic addressing)."""
    out = jnp.zeros(cid.shape, jnp.float32)
    for c in range(k):
        out = out + jnp.where(cid == c, vals[c][None, :], 0.0)
    return out


def _kernel(x_ref, qp_ref, cp_ref, recip_ref, shift_ref, o_ref, acc_ref,
            *, bits: int, k: int, n_ksteps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = unpack_codes(qp_ref[...], bits).astype(jnp.float32)      # (bk, bn)
    cid = unpack_cids(cp_ref[...])                                # (bk, bn)
    recip = _select_per_cluster(recip_ref[...], cid, k)
    shift = _select_per_cluster(shift_ref[...], cid, k)
    w = (q * recip + shift).astype(x_ref.dtype)                  # dequant in VMEM
    acc_ref[...] += jnp.dot(x_ref[...], w,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_ksteps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "k", "block_m", "block_n", "block_k", "interpret"))
def splitquant_matmul(x: jnp.ndarray, q_packed: jnp.ndarray,
                      cid_packed: jnp.ndarray, recip: jnp.ndarray,
                      shift: jnp.ndarray, *, bits: int, k: int = 3,
                      block_m: int = 256, block_n: int = 256,
                      block_k: int = 512, interpret: bool = False
                      ) -> jnp.ndarray:
    """x: (M, K); q_packed: (K·bits/8, N) uint8; cid_packed: (K/4, N) uint8;
    recip/shift: (k, N) fp32. Returns (M, N) in x.dtype.

    M, N, K must be multiples of the block sizes (ops.py pads).
    """
    M, K = x.shape
    N = q_packed.shape[1]
    per_q = 8 // bits
    per_c = 4
    assert q_packed.shape[0] * per_q == K, (q_packed.shape, K, bits)
    assert cid_packed.shape[0] * per_c == K
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        (M, N, K), (block_m, block_n, block_k))
    n_ksteps = K // block_k
    grid = (M // block_m, N // block_n, n_ksteps)

    kernel = functools.partial(_kernel, bits=bits, k=k, n_ksteps=n_ksteps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k // per_q, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_k // per_c, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((recip.shape[0], block_n), lambda i, j, kk: (0, j)),
            pl.BlockSpec((shift.shape[0], block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        # fp32 accumulator tile, persistent across the K loop
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, q_packed, cid_packed, recip, shift)
