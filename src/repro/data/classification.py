"""Synthetic text-classification datasets for the paper's Table 1 repro.

The paper uses DAIR.AI emotion (6-way) and UCI SMS spam (2-way). Both are
unavailable offline, so we generate token-sequence classification tasks of
matched structure: class-conditional keyword distributions over a WordPiece-
sized vocab with a common background distribution — the same shape of
problem BERT-Tiny solves (a few discriminative tokens amid filler).

Difficulty is controlled by keyword rate/overlap so that a fine-tuned
BERT-Tiny lands in the paper's accuracy regime (~90% for the 6-way task,
~98% for the binary task).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ClsDataset:
    name: str
    n_classes: int
    seq_len: int
    tokens: np.ndarray     # (N, S) int32
    labels: np.ndarray     # (N,)  int32
    mask: np.ndarray       # (N, S) int32


def _make(name: str, n_classes: int, n_samples: int, seq_len: int,
          vocab: int, keyword_rate: float, n_keywords: int,
          noise: float, seed: int) -> ClsDataset:
    rng = np.random.default_rng(seed)
    # per-class keyword vocab (disjoint), shared background band
    kw = rng.choice(np.arange(1000, vocab), size=(n_classes, n_keywords),
                    replace=False)
    N, S = n_samples, seq_len
    labels = rng.integers(0, n_classes, size=N)
    lengths = rng.integers(S // 4, S, size=N)
    toks = rng.integers(100, 1000, size=(N, S))            # background band
    for i in range(N):
        L = lengths[i]
        n_kw = max(1, int(keyword_rate * L))
        pos = rng.choice(np.arange(1, L), size=min(n_kw, L - 1),
                         replace=False)
        cls = labels[i]
        # label noise: sometimes plant another class's keywords
        eff = cls if rng.random() > noise else rng.integers(0, n_classes)
        toks[i, pos] = rng.choice(kw[eff], size=len(pos))
        toks[i, L:] = 0                                     # pad
    toks[:, 0] = 101                                        # [CLS]
    mask = (toks != 0).astype(np.int32)
    return ClsDataset(name, n_classes, S, toks.astype(np.int32),
                      labels.astype(np.int32), mask)


def emotion_like(n_samples=4000, seq_len=64, vocab=30522, seed=0):
    """6-way, harder task → FP32 accuracy ≈ 0.90 (paper: 90.2%)."""
    return _make("emotion", 6, n_samples, seq_len, vocab,
                 keyword_rate=0.12, n_keywords=24, noise=0.08, seed=seed)


def spam_like(n_samples=4000, seq_len=64, vocab=30522, seed=1):
    """binary, easier task → FP32 accuracy ≈ 0.98 (paper: 98.4%)."""
    return _make("spam", 2, n_samples, seq_len, vocab,
                 keyword_rate=0.12, n_keywords=60, noise=0.035, seed=seed)


def batches(ds: ClsDataset, batch_size: int, *, seed=0, train=True,
            epochs=1):
    rng = np.random.default_rng(seed)
    N = ds.tokens.shape[0]
    for _ in range(epochs):
        idx = rng.permutation(N) if train else np.arange(N)
        for i in range(0, N - batch_size + 1, batch_size):
            j = idx[i:i + batch_size]
            yield {"tokens": ds.tokens[j], "labels": ds.labels[j],
                   "mask": ds.mask[j]}
