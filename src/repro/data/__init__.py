from .pipeline import DataConfig, synthetic_lm_batch, Prefetcher
from . import classification
