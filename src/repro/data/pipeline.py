"""Deterministic, restart-safe data pipeline.

Design for 1000+ nodes: the pipeline is STATELESS — batch contents are a
pure function of (seed, step, shard), so checkpoint/restart needs only the
step counter (no data-iterator state), and elastic re-sharding is just a
different (shard, n_shards) mapping over the same index space. A background
thread prefetches ahead of the training loop.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "lm"          # lm | classification


def synthetic_lm_batch(cfg: DataConfig, step: int, shard: int = 0,
                       n_shards: int = 1) -> dict:
    """Markov-chain-ish synthetic token stream: learnable structure (next
    token depends on current) so loss decreases measurably during tests.

    Pure function of (seed, step, shard) — restart-safe by construction.
    """
    per_shard = cfg.global_batch // n_shards
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard]))
    B, S, V = per_shard, cfg.seq_len, cfg.vocab
    # structured stream: x_{t+1} = (a*x_t + drift) mod V with noise
    a = 31
    x0 = rng.integers(0, V, size=(B, 1))
    drift = rng.integers(0, 7, size=(B, 1))
    toks = np.empty((B, S + 1), np.int64)
    toks[:, :1] = x0
    for t in range(S):
        nxt = (a * toks[:, t:t + 1] + drift) % V
        noise = rng.random((B, 1)) < 0.1
        rand = rng.integers(0, V, size=(B, 1))
        toks[:, t + 1:t + 2] = np.where(noise, rand, nxt)
    return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32)}


class Prefetcher:
    """Runs `make_batch(step)` in a background thread, `depth` batches
    ahead. `get(step)` returns batches strictly in order."""

    def __init__(self, make_batch, start_step: int, depth: int = 2):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._next_to_produce = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            step = self._next_to_produce
            batch = self._make(step)
            self._q.put((step, batch))
            self._next_to_produce = step + 1

    def get(self, step: int):
        while True:
            s, b = self._q.get()
            if s == step:
                return b
            # stale batch from before a restart — drop it

    def stop(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
