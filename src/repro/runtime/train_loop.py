"""Fault-tolerant training loop.

Scale story (1000+ nodes):
  * checkpoint/restart — atomic checkpoints every `ckpt_every` steps; on any
    device/runtime failure the loop restores the last good step and resumes
    (data pipeline is stateless, so resume = set the step counter);
  * straggler mitigation — per-step wall-time EWMA; steps slower than
    `straggler_factor`× the EWMA are logged and counted (on a real fleet
    this signal feeds the reshard/elastic controller);
  * retry budget — transient failures retry up to `max_failures` times
    before surfacing.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt as ckpt_lib
from repro.optim import adamw


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_async: bool = True
    max_failures: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


class StragglerMonitor:
    def __init__(self, factor: float):
        self.factor = factor
        self.ewma: Optional[float] = None
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.factor * self.ewma
        self.ewma = dt if self.ewma is None else 0.9 * self.ewma + 0.1 * dt
        if slow:
            self.flagged += 1
        return slow


def make_train_step(loss_fn: Callable, opt_cfg: adamw.OptConfig):
    """loss_fn(params, batch) → (loss, metrics). Returns jit-able
    step(params, opt_state, batch) → (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = adamw.update(
            opt_cfg, opt_state, params, grads)
        return params, opt_state, {**metrics, **opt_metrics, "loss": loss}

    return train_step


def run(loop_cfg: TrainLoopConfig, train_step, params, opt_state,
        make_batch: Callable[[int], dict], *, inject_failure=None,
        log: Callable = print):
    """Run to total_steps with checkpoint/restart. `inject_failure(step)`
    (tests) may raise to exercise the recovery path.

    Returns (params, opt_state, history).
    """
    step = 0
    if loop_cfg.ckpt_dir:
        last = ckpt_lib.latest_step(loop_cfg.ckpt_dir)
        if last is not None:
            (params, opt_state), step = ckpt_lib.restore(
                loop_cfg.ckpt_dir, (params, opt_state))
            log(f"[restore] resumed from step {step}")

    monitor = StragglerMonitor(loop_cfg.straggler_factor)
    failures = 0
    history = []
    while step < loop_cfg.total_steps:
        t0 = time.perf_counter()
        try:
            if inject_failure is not None:
                inject_failure(step)
            batch = make_batch(step)
            params, opt_state, metrics = train_step(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
        except (jax.errors.JaxRuntimeError, RuntimeError, ValueError) as e:
            failures += 1
            log(f"[failure] step {step}: {type(e).__name__}: {e}")
            if failures > loop_cfg.max_failures:
                raise
            if loop_cfg.ckpt_dir and ckpt_lib.latest_step(loop_cfg.ckpt_dir) is not None:
                (params, opt_state), step = ckpt_lib.restore(
                    loop_cfg.ckpt_dir, (params, opt_state))
                log(f"[recover] restored step {step}, retrying")
            continue

        dt = time.perf_counter() - t0
        if monitor.observe(dt):
            log(f"[straggler] step {step} took {dt*1e3:.1f} ms "
                f"(ewma {monitor.ewma*1e3:.1f} ms)")
        step += 1
        history.append({k: float(v) for k, v in metrics.items()})
        if step % loop_cfg.log_every == 0:
            log(f"step {step:5d} loss {history[-1]['loss']:.4f} "
                f"({dt*1e3:.0f} ms)")
        if loop_cfg.ckpt_dir and step % loop_cfg.ckpt_every == 0:
            ckpt_lib.save(loop_cfg.ckpt_dir, step, (params, opt_state),
                          blocking=not loop_cfg.ckpt_async)
    if loop_cfg.ckpt_dir:
        ckpt_lib.wait_for_async()
        ckpt_lib.save(loop_cfg.ckpt_dir, step, (params, opt_state),
                      blocking=True)
    return params, opt_state, history
