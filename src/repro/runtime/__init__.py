from . import train_loop, serve_loop
