"""Batched serving loop for quantized models.

The deployment path of the paper: weights are SplitQuant-preprocessed and
low-bit quantized once offline (`quantize_tree`), then served with the
fused cluster-dequant matmul. The loop does continuous batching over a
request queue: prefill new requests, decode the active batch one token per
step, retire finished sequences.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_model


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_new_tokens: int = 32
    max_len: int = 256
    temperature: float = 0.0        # 0 ⇒ greedy
    eos_id: int = -1                # -1 ⇒ never stop early


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Minimal continuous-batching server (single-wave variant: requests
    are grouped into prefill waves of up to max_batch; each wave decodes
    together — the structure a production scheduler slots into)."""

    def __init__(self, cfg, params, serve_cfg: ServeConfig,
                 rng: Optional[jax.Array] = None):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.scfg = serve_cfg
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self._decode = jax.jit(
            lambda p, c, t, pos: self.model.decode_step(p, cfg, c, t, pos))

    def _sample(self, logits):
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits[:, -1], axis=-1)
        self.rng, k = jax.random.split(self.rng)
        return jax.random.categorical(k, logits[:, -1] / self.scfg.temperature)

    def serve(self, requests: list[Request]) -> list[Request]:
        scfg = self.scfg
        for i in range(0, len(requests), scfg.max_batch):
            wave = requests[i:i + scfg.max_batch]
            S = max(len(r.prompt) for r in wave)
            toks = np.zeros((len(wave), S), np.int32)
            for j, r in enumerate(wave):
                toks[j, S - len(r.prompt):] = r.prompt      # left-pad
            batch = {"tokens": jnp.asarray(toks)}
            logits, cache = self.model.prefill(
                self.params, self.cfg, batch, max_len=scfg.max_len)
            tok = self._sample(logits)
            for j, r in enumerate(wave):
                r.out.append(int(tok[j]))
            pos = S
            for _ in range(scfg.max_new_tokens - 1):
                logits, cache = self._decode(
                    self.params, cache, tok[:, None].astype(jnp.int32),
                    jnp.int32(pos))
                tok = self._sample(logits)
                pos += 1
                alive = False
                for j, r in enumerate(wave):
                    if r.done:
                        continue
                    t = int(tok[j])
                    if t == scfg.eos_id:
                        r.done = True
                    else:
                        r.out.append(t)
                        alive = True
                if not alive:
                    break
            for r in wave:
                r.done = True
        return requests
