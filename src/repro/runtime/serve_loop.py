"""Batched wave serving loop for quantized models.

The original deployment path of the paper: weights are SplitQuant-
preprocessed and low-bit quantized once offline (`quantize_tree`), then
served with the fused cluster-dequant matmul. Requests are grouped into
prefill waves of up to max_batch; each wave decodes together until every
member finishes — a finished (or short) request's slot stays occupied
until the wave's longest generation completes.

This wave-synchronous loop is kept as the baseline the continuous-
batching engine (`repro.engine`) is benchmarked against; new serving code
should use the engine. `benchmarks/serve_bench.py` measures the gap.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_model

#: families whose prefill accepts pad_mask (per-request KV validity)
PAD_MASK_FAMILIES = ("dense", "moe", "vlm")


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_new_tokens: int = 32
    max_len: int = 256
    temperature: float = 0.0        # 0 ⇒ greedy
    eos_id: int = -1                # -1 ⇒ never stop early


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: Optional[int] = None   # None ⇒ ServeConfig budget
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Minimal wave-batching server (baseline for `repro.engine.Engine`).

    Prompts in a wave are left-padded to a common length; the pad tokens
    are excluded from attention via a pad mask threaded through
    `model.prefill` (their K/V entries are marked position -1, the same
    invalid marker empty ring slots use)."""

    def __init__(self, cfg, params, serve_cfg: ServeConfig,
                 rng: Optional[jax.Array] = None):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.scfg = serve_cfg
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self._decode = jax.jit(
            lambda p, c, t, pos: self.model.decode_step(p, cfg, c, t, pos))

    def _sample(self, logits):
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits[:, -1], axis=-1)
        self.rng, k = jax.random.split(self.rng)
        return jax.random.categorical(k, logits[:, -1] / self.scfg.temperature)

    def serve(self, requests: list[Request]) -> list[Request]:
        scfg = self.scfg
        for i in range(0, len(requests), scfg.max_batch):
            wave = requests[i:i + scfg.max_batch]
            S = max(len(r.prompt) for r in wave)
            toks = np.zeros((len(wave), S), np.int32)
            pad = np.ones((len(wave), S), bool)
            for j, r in enumerate(wave):
                toks[j, S - len(r.prompt):] = r.prompt      # left-pad
                pad[j, S - len(r.prompt):] = False
            batch = {"tokens": jnp.asarray(toks)}
            kw = {}
            if self.cfg.family in PAD_MASK_FAMILIES:
                kw["pad_mask"] = jnp.asarray(pad)
            logits, cache = self.model.prefill(
                self.params, self.cfg, batch, max_len=scfg.max_len, **kw)
            tok = self._sample(logits)
            limits = [scfg.max_new_tokens if r.max_new_tokens is None
                      else r.max_new_tokens for r in wave]
            for j, r in enumerate(wave):
                t = int(tok[j])
                # eos is never emitted — also on the prefill-sampled first
                # token (same semantics as the engine)
                if limits[j] <= 0 or t == scfg.eos_id:
                    r.done = True
                    continue
                r.out.append(t)
                if len(r.out) >= limits[j]:
                    r.done = True
            pos = S
            for _ in range(max(limits + [1]) - 1):
                logits, cache = self._decode(
                    self.params, cache, tok[:, None].astype(jnp.int32),
                    jnp.int32(pos))
                tok = self._sample(logits)
                pos += 1
                alive = False
                for j, r in enumerate(wave):
                    if r.done:
                        continue
                    t = int(tok[j])
                    if t == scfg.eos_id:
                        r.done = True
                        continue
                    r.out.append(t)
                    if len(r.out) >= limits[j]:
                        r.done = True
                    else:
                        alive = True
                if not alive:
                    break
            for r in wave:
                r.done = True
        return requests
