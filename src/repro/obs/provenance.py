"""Shared provenance header for every serialized metrics artifact.

One definition feeds BENCH_serve/calib/spec.json (via
`benchmarks/run.py:provenance`, which re-exports this), `serve.py
--metrics-json`, and `obs.metrics.SnapshotWriter` headers — a tokens/s
delta or a clip-fraction trend means nothing without the jax version,
device kind and git revision that produced each side. Lived in
benchmarks/ through PR 6; moved under `repro.obs` so in-tree serving
code can embed it without reaching outside the package.
"""
from __future__ import annotations

import os
import time


def git_revision(root: str | None = None) -> dict:
    """Best-effort (commit, dirty) of the repo this package sits in —
    None values rather than a crash when git or the .git dir is
    unavailable (artifacts get copied around; provenance should survive
    that)."""
    import subprocess
    if root is None:
        root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root, capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
        dirty = bool(subprocess.run(
            ["git", "status", "--porcelain"], cwd=root,
            capture_output=True, text=True, timeout=10,
            check=True).stdout.strip())
        return {"git_commit": commit, "git_dirty": dirty}
    except Exception:
        return {"git_commit": None, "git_dirty": None}


def provenance(seed=None) -> dict:
    """Environment + revision header embedded in every artifact."""
    import platform

    import jax
    dev = jax.devices()[0]
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": dev.device_kind,
        "n_devices": jax.device_count(),
        "python": platform.python_version(),
        "platform": platform.platform(),
        "seed": seed,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        **git_revision(),
    }
