"""Monotonic-clock tracer with a bounded ring buffer (DESIGN.md §10).

The serving stack is instrumented with three record kinds:

* ``span``    — a timed phase (``name`` ∈ `schema.PHASES`) with ``ts``
  (seconds since the tracer epoch), ``dur``, and optional attribution
  fields: ``dispatch_s`` (host time until the jitted call returned —
  dispatch is asynchronous on every jax backend) and ``wait_s`` (the
  ``block_until_ready``/host-transfer wait for the device result).
  ``dur - wait_s`` is therefore host time, of which ``dispatch_s`` is
  the jit-call share — the split that decides "dispatch-bound or
  compute-bound" per phase.
* ``event``   — an instantaneous per-request lifecycle point
  (``name`` ∈ `schema.LIFECYCLE`: submit → admit → first_token →
  retire, plus rollback), carrying ``uid`` and usually ``slot``.
* ``counter`` — a sampled value series (e.g. the KV quantization-quality
  counters from `engine.kvcache.kv_quality_counters`).

The buffer is a fixed-capacity deque: once full, the OLDEST records drop
(``dropped`` counts them), so a long soak keeps the most recent window
instead of growing without bound. A disabled tracer is falsy — callers
hold ``None`` (or a falsy tracer) and guard every instrumentation site
with one branch, which is the whole disabled-mode cost.

Exporters: `to_jsonl` (one header record + one record per line — the
format `launch.trace_report` and `schema.validate_events` consume) and
`to_chrome` (Chrome ``trace.json``, loadable in Perfetto / chrome://
tracing: one track per slot, one per engine phase).
"""
from __future__ import annotations

import collections
import contextlib
import json
import time

from repro.obs.atomic import atomic_write_text

SCHEMA_VERSION = 1

#: Chrome-trace thread ids: slots get 1 + slot, un-slotted lifecycle
#: events a "requests" track, un-slotted phase spans one track per phase
#: name (stable order from schema.PHASES), counters their own track.
#: These are *minimum* tids — `chrome_trace` shifts them above the
#: highest slot tid, so engines with >= 59 slots don't alias the slot
#: tracks onto the requests/counters/phase tracks.
_TID_REQUESTS = 60
_TID_COUNTERS = 61
_TID_PHASE0 = 64


class Tracer:
    """Span/event/counter recorder. All timestamps come from ``clock``
    (host-monotonic; the engine passes its own clock so trace time and
    engine metrics share one axis).

    ``enabled=False`` makes the tracer falsy and every record call a
    no-op — engines normalize a falsy tracer to ``None`` so the serving
    hot path pays exactly one predictable branch per site.
    """

    def __init__(self, capacity: int = 1 << 16, clock=time.perf_counter,
                 enabled: bool = True, meta: dict | None = None):
        self.clock = clock
        self.enabled = enabled
        self.capacity = int(capacity)
        self.t0 = clock()
        self.events: collections.deque = collections.deque(
            maxlen=self.capacity)
        self.dropped = 0
        self.meta = dict(meta or {})

    def __bool__(self) -> bool:
        return self.enabled

    # ------------------------------------------------------- recording --
    def _push(self, rec: dict) -> None:
        if len(self.events) == self.capacity:
            self.dropped += 1                  # deque drops the oldest
        self.events.append(rec)

    def now(self) -> float:
        return self.clock()

    def begin(self) -> float:
        """Timestamp helper for the begin/`span_end` pair — records
        nothing (so a span abandoned on an exception costs nothing)."""
        return self.clock()

    def span_end(self, name: str, t_begin: float, **fields) -> None:
        """Record a span from ``t_begin`` (a `begin`/clock timestamp) to
        now. Extra ``fields`` ride along (slot/uid/step/dispatch_s/...)."""
        if not self.enabled:
            return
        self._push({"kind": "span", "name": name,
                    "ts": t_begin - self.t0,
                    "dur": self.clock() - t_begin, **fields})

    @contextlib.contextmanager
    def span(self, name: str, **fields):
        t_begin = self.clock()
        try:
            yield
        finally:
            self.span_end(name, t_begin, **fields)

    def event(self, name: str, **fields) -> None:
        if not self.enabled:
            return
        self._push({"kind": "event", "name": name,
                    "ts": self.clock() - self.t0, **fields})

    def counter(self, name: str, value, **fields) -> None:
        """``value``: a number or a flat dict of numbers (one series per
        key in the Chrome export)."""
        if not self.enabled:
            return
        self._push({"kind": "counter", "name": name,
                    "ts": self.clock() - self.t0, "value": value, **fields})

    # ------------------------------------------------------- exporting --
    def header(self) -> dict:
        return {"kind": "header", "schema": SCHEMA_VERSION,
                "capacity": self.capacity, "dropped": self.dropped,
                **self.meta}

    def records(self):
        """Header + buffered records, oldest first."""
        yield self.header()
        yield from self.events

    def to_jsonl(self, path: str) -> int:
        """Write the JSONL event log atomically (tmp + fsync + rename —
        a crash mid-export never truncates the artifact); returns the
        record count (header included)."""
        lines = [json.dumps(rec, default=float) for rec in self.records()]
        atomic_write_text(path, "\n".join(lines) + "\n" if lines else "")
        return len(lines)

    def to_chrome(self, path: str) -> None:
        atomic_write_text(
            path, json.dumps(chrome_trace(list(self.records()))))


def load_jsonl(path: str) -> list[dict]:
    """Load a `to_jsonl` event log (header record first)."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def _chrome_tid(rec: dict, phase_tids: dict, tid_requests: int,
                tid_phase0: int) -> int:
    if rec.get("slot") is not None:
        return 1 + int(rec["slot"])
    if rec["kind"] == "span":
        return phase_tids.setdefault(rec["name"],
                                     tid_phase0 + len(phase_tids))
    return tid_requests


def chrome_trace(records: list[dict]) -> dict:
    """Chrome trace-event JSON (Perfetto-loadable) from trace records:
    one track per slot (slot-attributed spans + lifecycle instants), one
    track per un-slotted engine phase, one counter track. Times in µs.

    Slot tids are ``1 + slot``, so the fixed requests/counters/phase
    tids would alias slot tracks at >= 59 slots; the non-slot tids are
    therefore shifted above the highest slot seen in ``records``."""
    max_slot = -1
    for rec in records:
        if (rec.get("kind") in ("span", "event", "counter")
                and rec.get("slot") is not None):
            max_slot = max(max_slot, int(rec["slot"]))
    tid_requests = max(_TID_REQUESTS, max_slot + 2)
    tid_counters = tid_requests + (_TID_COUNTERS - _TID_REQUESTS)
    tid_phase0 = tid_requests + (_TID_PHASE0 - _TID_REQUESTS)
    out = []
    phase_tids: dict[str, int] = {}
    for rec in records:
        kind = rec.get("kind")
        if kind not in ("span", "event", "counter"):
            continue
        ts_us = rec["ts"] * 1e6
        args = {k: v for k, v in rec.items()
                if k not in ("kind", "name", "ts", "dur", "value")}
        if kind == "span":
            out.append({"ph": "X", "pid": 0,
                        "tid": _chrome_tid(rec, phase_tids, tid_requests,
                                           tid_phase0),
                        "name": rec["name"], "ts": ts_us,
                        "dur": rec["dur"] * 1e6, "args": args})
        elif kind == "event":
            out.append({"ph": "i", "s": "t", "pid": 0,
                        "tid": _chrome_tid(rec, phase_tids, tid_requests,
                                           tid_phase0),
                        "name": rec["name"], "ts": ts_us, "args": args})
        else:                                   # counter
            val = rec.get("value")
            series = (val if isinstance(val, dict) else {"value": val})
            series = {k: v for k, v in series.items()
                      if isinstance(v, (int, float))}
            if series:
                out.append({"ph": "C", "pid": 0, "tid": tid_counters,
                            "name": rec["name"], "ts": ts_us,
                            "args": series})
    names = [(1 + s, f"slot {s}") for s in range(max_slot + 1)]
    names += [(tid_requests, "requests"), (tid_counters, "counters")]
    names += [(tid, f"phase:{name}") for name, tid in phase_tids.items()]
    meta = [{"ph": "M", "pid": 0, "tid": tid, "name": "thread_name",
             "args": {"name": label}} for tid, label in names]
    meta.append({"ph": "M", "pid": 0, "name": "process_name",
                 "args": {"name": "repro-engine"}})
    return {"traceEvents": meta + out, "displayTimeUnit": "ms"}
