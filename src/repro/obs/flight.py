"""Always-on flight recorder + incident bundles.

The tracer (PR 6) is default-off and the metrics registry (PR 7) is a
point-in-time aggregate; neither answers "what happened in the 200 steps
before the engine quarantined slot 3" on a box where nobody thought to
pass ``--trace``. The flight recorder is the black box: a small bounded
ring of coarse per-step records that is *always* recording, cheap enough
to leave on (overhead gated at <= max(1%, noise) by serve_bench, same
bar as the registry).

One record per engine step, one flat dict per record:

  step        engine step index (monotone)
  ts          seconds since recorder start
  step_s      step wall-clock seconds
  decode_s    wall of the decode/verify dispatch inside the step (coarse
              dispatch+device time; host-side work is step_s - decode_s;
              the fine dispatch/wait split needs --trace)
  draft_s     wall of the draft pass (spec mode; 0.0 otherwise)
  queue       admission queue depth at end of step
  backlog     queued prefill tokens (admission set-point signal)
  occupied    slots holding a request
  decoding    slots actively decoding at step start
  rung        degradation rung (0 = full fidelity)
  retries     cumulative injected-step retries
  quarantined cumulative requests retired as "failed"
  accept      scheduler speculative-acceptance EWMA (None w/o spec)
  spec_off    True when the ladder has suspended speculation this step
  clip_frac   latest KV clip-fraction sample (None until first sample)
  span_frac   latest KV outlier-span sample (None until first sample)
  uids        uids active in slots this step

Incident bundles snapshot the ring plus everything else a postmortem
needs (metrics, journal tail, fingerprint, provenance, request docs)
into a directory written with the PR 9 tmp+fsync+rename protocol — a
crash mid-dump never leaves a half bundle.
"""
from __future__ import annotations

import collections
import json
import os
import time
from typing import Any, Callable, Deque, Dict, List, Optional

from .atomic import atomic_dir

__all__ = [
    "FLIGHT_SCHEMA",
    "BUNDLE_SCHEMA",
    "FlightRecorder",
    "write_incident_bundle",
    "load_incident_bundle",
    "tail_lines",
]

FLIGHT_SCHEMA = 1
BUNDLE_SCHEMA = 1

#: Files every bundle must contain (beyond MANIFEST.json).
BUNDLE_FILES = (
    "trigger.json",
    "flight.json",
    "metrics.json",
    "fingerprint.json",
    "provenance.json",
    "requests.json",
)


class FlightRecorder:
    """Bounded ring of per-step records; always on, never exported unless
    an incident (or the operator) asks for the window."""

    def __init__(self, capacity: int = 512,
                 clock: Callable[[], float] = time.perf_counter,
                 meta: Optional[Dict[str, Any]] = None):
        if capacity < 1:
            raise ValueError(f"flight capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.clock = clock
        self.meta = dict(meta or {})
        self.t0 = clock()
        self.records: Deque[Dict[str, Any]] = collections.deque(
            maxlen=self.capacity)
        self.n_recorded = 0

    def record(self, **fields: Any) -> Dict[str, Any]:
        """Append one per-step record; returns it (for the detector sweep)."""
        rec = {"ts": round(self.clock() - self.t0, 6)}
        rec.update(fields)
        self.records.append(rec)
        self.n_recorded += 1
        return rec

    @property
    def dropped(self) -> int:
        return self.n_recorded - len(self.records)

    def window(self) -> List[Dict[str, Any]]:
        """Oldest-to-newest copy of the retained ring."""
        return list(self.records)

    def header(self) -> Dict[str, Any]:
        return {"schema": FLIGHT_SCHEMA, "capacity": self.capacity,
                "recorded": self.n_recorded, "dropped": self.dropped,
                **self.meta}


def tail_lines(path: str, n: int = 200) -> List[str]:
    """Last ``n`` lines of a text file ('' -> []); missing file -> []."""
    try:
        with open(path) as f:
            lines = f.read().splitlines()
    except OSError:
        return []
    return lines[-n:] if n >= 0 else lines


def write_incident_bundle(incident_dir: str, name: str,
                          docs: Dict[str, Any]) -> str:
    """Atomically write one incident bundle directory.

    ``docs`` maps file names to content: ``.json`` values are serialized
    with ``json.dump``; ``.jsonl`` values must be lists of pre-rendered
    lines. A MANIFEST.json listing every file is written last and
    fsynced, then the whole directory is renamed into place — the PR 9
    snapshot protocol, so a bundle either exists completely or not at
    all. Returns the final bundle path.
    """
    os.makedirs(incident_dir, exist_ok=True)
    final = os.path.join(os.path.abspath(incident_dir), name)
    with atomic_dir(final) as tmp:
        files = []
        for fname, content in docs.items():
            fpath = os.path.join(tmp, fname)
            with open(fpath, "w") as f:
                if fname.endswith(".jsonl"):
                    for line in content:
                        f.write(line.rstrip("\n") + "\n")
                else:
                    json.dump(content, f, indent=1, sort_keys=True,
                              default=str)
            files.append(fname)
        manifest = {"schema": BUNDLE_SCHEMA, "name": name,
                    "files": sorted(files)}
        mpath = os.path.join(tmp, "MANIFEST.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
    return final


def load_incident_bundle(path: str) -> Dict[str, Any]:
    """Load a bundle directory into ``{file name: parsed content}``.

    Raises ``ValueError`` on a structurally broken bundle (missing
    manifest, wrong schema, listed file absent or unparseable) so
    ``incident_report --validate`` can turn it into a nonzero exit.
    """
    mpath = os.path.join(path, "MANIFEST.json")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except OSError as e:
        raise ValueError(f"bundle manifest missing: {mpath} ({e})")
    except json.JSONDecodeError as e:
        raise ValueError(f"bundle manifest corrupt: {mpath} ({e})")
    if manifest.get("schema") != BUNDLE_SCHEMA:
        raise ValueError(
            f"bundle schema {manifest.get('schema')!r} != {BUNDLE_SCHEMA}")
    out: Dict[str, Any] = {"MANIFEST.json": manifest}
    for fname in manifest.get("files", []):
        fpath = os.path.join(path, fname)
        try:
            with open(fpath) as f:
                if fname.endswith(".jsonl"):
                    out[fname] = [json.loads(ln) for ln in f
                                  if ln.strip()]
                else:
                    out[fname] = json.load(f)
        except OSError as e:
            raise ValueError(f"bundle file missing: {fname} ({e})")
        except json.JSONDecodeError as e:
            raise ValueError(f"bundle file corrupt: {fname} ({e})")
    for fname in BUNDLE_FILES:
        if fname not in out:
            raise ValueError(f"bundle lacks required file: {fname}")
    return out
