"""Always-on metrics registry for the serving stack (DESIGN.md §11).

The tracer (`obs.tracer`) answers "where did THIS run spend its time"
after the fact, at a profiling cost (sync points, host transfers). This
module is the production half of observability: monotonic counters,
gauges, and fixed-bucket histograms cheap enough to leave on for every
request ever served. Design constraints, in order:

* **Bounded memory.** Every instrument is O(1): counters/gauges hold one
  float, histograms hold a fixed bucket-count vector plus exact
  ``count``/``sum``. Nothing grows with the number of observations, so a
  week-long soak holds the same bytes as a smoke test.
* **Cheap increments.** The hot path of each instrument is a couple of
  Python attribute ops — no locks, no allocation, no formatting. The
  engine's decode hot path is asserted to stay within the serve-bench
  noise floor (≤1%) with metrics on vs off. Single-threaded increments
  are lock-free by construction; the GIL makes the individual ``+=``
  safe from reader threads (a racy read sees a slightly stale value,
  never a torn one).
* **Two export surfaces.** ``to_prometheus()`` renders the standard
  text exposition format (``*_total`` counters, ``*_bucket{le=...}``
  cumulative histograms) for scrapers; ``snapshot()`` returns a plain
  dict for `Engine.metrics()` / JSONL snapshots, and `SnapshotWriter`
  appends timestamped snapshots (with the shared provenance header from
  `obs.provenance`) to a JSONL file on a fixed interval.

Instruments are get-or-create by name — asking twice returns the same
object — so layers (engine, scheduler, spec) can resolve their handles
independently against one shared registry.
"""
from __future__ import annotations

import bisect
import json
import math
import time
from typing import Optional, Sequence

from repro.obs.atomic import atomic_write_text

#: Default histogram buckets for latency-in-seconds instruments:
#: log-spaced from 100 µs to 10 s (engine steps on the dev box sit
#: around 1–10 ms; TTFT under load reaches seconds). Upper bounds;
#: +Inf is implicit.
LATENCY_BUCKETS_S = (1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3,
                     1e-2, 2.5e-2, 5e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
                     10.0)

#: Default buckets for queue-depth-like counts.
DEPTH_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0)

#: Buckets for crash-recovery durations (engine_restore_duration_s):
#: coarser and wider than step latencies — a restore pays npz decompress
#: + checksum verification + journal replay, and on a cold box can reach
#: tens of seconds.
RESTORE_BUCKETS_S = (1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 0.1, 0.25,
                     0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


def _fmt(v: float) -> str:
    """Prometheus float formatting: integers render bare, +Inf as the
    literal the exposition format specifies."""
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


class Counter:
    """Monotonic counter. `inc` only — a decreasing counter is a bug
    (Prometheus rate() would interpret it as a process restart)."""

    __slots__ = ("name", "help", "value")
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease ({n})")
        self.value += n


class Gauge:
    """Point-in-time value (queue depth, slot occupancy, EWMA)."""

    __slots__ = ("name", "help", "value")
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value: Optional[float] = None      # unset until first set()

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value = (self.value or 0.0) + n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)


class Histogram:
    """Fixed-bucket histogram with exact count/sum.

    ``buckets`` are upper bounds (ascending); an implicit +Inf bucket
    catches the tail, so `observe` never loses a sample. Memory is the
    bucket vector — independent of observation count. ``percentile``
    interpolates within the winning bucket (the standard
    histogram_quantile estimate): exact enough for dashboards, while the
    engine keeps exact percentiles for its own metrics dict via
    `obs.summary` over raw lists where those already exist.
    """

    __slots__ = ("name", "help", "buckets", "counts", "count", "sum")
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = LATENCY_BUCKETS_S):
        self.name = name
        self.help = help
        self.buckets = tuple(float(b) for b in buckets)
        if list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError(f"histogram {name}: buckets must be "
                             f"strictly ascending, got {buckets}")
        self.counts = [0] * (len(self.buckets) + 1)   # + the +Inf bucket
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.count += 1
        self.sum += v

    def percentile(self, q: float) -> Optional[float]:
        """Estimated q-th percentile (0–100); None when empty. Linear
        interpolation inside the winning bucket; the +Inf bucket clamps
        to the last finite bound (an under-estimate, loudly coarse)."""
        if not self.count:
            return None
        rank = q / 100.0 * self.count
        acc = 0
        lo = 0.0
        for i, c in enumerate(self.counts):
            hi = self.buckets[i] if i < len(self.buckets) else \
                self.buckets[-1]
            if acc + c >= rank and c:
                frac = (rank - acc) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            acc += c
            lo = hi
        return self.buckets[-1]


class MetricsRegistry:
    """Named instrument store. Get-or-create semantics: the same name
    always returns the same instrument (kind mismatches raise — two
    layers silently sharing a name across kinds is always a bug)."""

    def __init__(self, namespace: str = "repro"):
        self.namespace = namespace
        self._metrics: dict[str, object] = {}

    def _get(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, requested {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = LATENCY_BUCKETS_S) \
            -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def __iter__(self):
        return iter(self._metrics.values())

    def __len__(self) -> int:
        return len(self._metrics)

    def names(self) -> tuple:
        """Registered instrument names (un-namespaced, registration
        order) — the instrument-presence assertion surface (the chaos
        smoke checks the fault-tolerance counters exist by name here
        and in the rendered Prometheus text)."""
        return tuple(self._metrics)

    # ------------------------------------------------------- exporting --
    def snapshot(self) -> dict:
        """Plain-dict view: counters/gauges map to their value,
        histograms to ``{count, sum, buckets: {le: cumulative_count}}``
        — the shape `Engine.metrics()` embeds and `SnapshotWriter`
        serializes."""
        out = {}
        for m in self._metrics.values():
            if m.kind == "histogram":
                cum, cum_counts = 0, {}
                for i, c in enumerate(m.counts):
                    cum += c
                    le = m.buckets[i] if i < len(m.buckets) else math.inf
                    cum_counts[_fmt(le)] = cum
                out[m.name] = {"count": m.count, "sum": m.sum,
                               "buckets": cum_counts}
            else:
                out[m.name] = m.value
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format, one block per instrument.
        Counters get the ``_total`` suffix convention; histograms emit
        cumulative ``_bucket{le=...}`` series plus ``_sum``/``_count``.
        Unset gauges are omitted (absent ≠ zero)."""
        lines = []
        ns = self.namespace
        for m in self._metrics.values():
            if m.kind == "gauge" and m.value is None:
                continue            # whole block: absent series, no TYPE
            full = f"{ns}_{m.name}" if ns else m.name
            if m.kind == "counter" and not full.endswith("_total"):
                full += "_total"
            if m.help:
                lines.append(f"# HELP {full} {m.help}")
            lines.append(f"# TYPE {full} {m.kind}")
            if m.kind == "histogram":
                cum = 0
                for i, c in enumerate(m.counts):
                    cum += c
                    le = m.buckets[i] if i < len(m.buckets) else math.inf
                    lines.append(f'{full}_bucket{{le="{_fmt(le)}"}} {cum}')
                lines.append(f"{full}_sum {_fmt(m.sum)}")
                lines.append(f"{full}_count {m.count}")
            elif m.value is not None:
                lines.append(f"{full} {_fmt(m.value)}")
        return "\n".join(lines) + "\n"


#: Process-default registry for callers without an engine (scripts,
#: notebooks). Engines mint their OWN registry by default so concurrent
#: engines/tests never cross-count; pass one explicitly to share.
_DEFAULT: Optional[MetricsRegistry] = None


def default_registry() -> MetricsRegistry:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = MetricsRegistry()
    return _DEFAULT


class RegistryQuantProbe:
    """`kernels.act_quant.set_quality_probe` adapter: mirrors each
    observed activation-quantizer call's saturation/occupancy into
    registry instruments instead of (or alongside) the tracer, so the
    clip-fraction drift signal from `obs.quality` is continuously
    watchable — the SplitQuant no-clipping claim as a live gauge rather
    than a trace-only counter. Duck-types `quality.ActQuantProbe`'s
    ``observe`` signature."""

    def __init__(self, registry: MetricsRegistry, prefix: str = "act"):
        from repro.obs.quality import code_stats
        self._code_stats = code_stats
        self.calls = registry.counter(
            f"{prefix}_quant_observations_total",
            "observed activation-quantizer kernel calls")
        self.clip = registry.gauge(
            f"{prefix}_quant_clip_frac",
            "fraction of codes pinned at qmin/qmax in the last "
            "observed call (upper bound on true clipping)")
        self.occ = registry.gauge(
            f"{prefix}_quant_occupancy",
            "code-range occupancy of the last observed call")

    def __bool__(self) -> bool:        # set_quality_probe keeps truthy
        return True

    def observe(self, q, scale=None, *, layer=None) -> dict:
        cs = self._code_stats(q)
        self.calls.inc()
        if cs["clip_frac"] is not None:
            self.clip.set(cs["clip_frac"])
            self.occ.set(cs["occupancy"])
        return cs


class SnapshotWriter:
    """Periodic JSONL metrics snapshots.

    Line 1 is a header record carrying the shared provenance dict
    (`obs.provenance.provenance` — the same header every BENCH_*.json
    embeds, so a snapshot stream is attributable to a jax version /
    device / git revision without side-channel context). Each subsequent
    line is ``{"kind": "snapshot", "seq", "ts", "metrics": ...}``.
    ``maybe_write`` is rate-limited by ``interval_s`` so the serve loop
    can call it every step; ``write`` forces one (the final flush).

    Snapshot lines are buffered and the whole file is rewritten through
    the atomic tmp+fsync+rename helper on every (rate-limited) write —
    a crash mid-write leaves the previous complete log, never a
    torn tail. The buffer is bounded by the ring of snapshots a serve
    run produces (one per ``interval_s``), the same order of magnitude
    the log itself occupies on disk.
    """

    def __init__(self, path: str, registry: MetricsRegistry,
                 interval_s: float = 1.0, clock=time.perf_counter,
                 provenance: Optional[dict] = None):
        self.path = path
        self.registry = registry
        self.interval_s = interval_s
        self.clock = clock
        self.t0 = clock()
        self._last: Optional[float] = None
        self.seq = 0
        if provenance is None:
            from repro.obs.provenance import provenance as _prov
            provenance = _prov()
        self._lines = [json.dumps({"kind": "header", "schema": 1,
                                   "provenance": provenance})]
        self._flush()

    def _flush(self) -> None:
        atomic_write_text(self.path, "\n".join(self._lines) + "\n")

    def write(self) -> int:
        """Append one snapshot now (atomic whole-file rewrite); returns
        its seq number."""
        rec = {"kind": "snapshot", "seq": self.seq,
               "ts": self.clock() - self.t0,
               "metrics": self.registry.snapshot()}
        self._lines.append(json.dumps(rec, default=float))
        self._flush()
        self._last = self.clock()
        self.seq += 1
        return rec["seq"]

    def maybe_write(self) -> bool:
        """Snapshot if ``interval_s`` has elapsed since the last one
        (first call always writes). Returns whether it wrote."""
        now = self.clock()
        if self._last is not None and now - self._last < self.interval_s:
            return False
        self.write()
        return True


def load_snapshots(path: str) -> tuple[dict, list[dict]]:
    """Load a `SnapshotWriter` JSONL file: ``(header, snapshots)`` — the
    provenance header record, then the snapshot records in write order."""
    with open(path) as f:
        recs = [json.loads(line) for line in f if line.strip()]
    if not recs or recs[0].get("kind") != "header":
        raise ValueError(f"{path}: not a metrics snapshot log "
                         f"(missing header record)")
    return recs[0], [r for r in recs[1:] if r.get("kind") == "snapshot"]
