"""Shared empty-guarded summary math.

`Engine.metrics()`, `benchmarks/serve_bench.py` and
`benchmarks/spec_bench.py` each used to hand-roll the same
``np.percentile``-with-empty-guard and mean-with-empty-guard logic (and
two of them carried identical token-agreement loops); this module is the
single home so a percentile convention change lands everywhere at once.
Everything returns ``None`` on empty input — metrics dicts serialize
``None``, never NaN.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np


def pct(values, q: float) -> Optional[float]:
    """Percentile with empty guard: ``None`` when there are no samples."""
    a = np.asarray(values, np.float64)
    return float(np.percentile(a, q)) if a.size else None


def mean(values) -> Optional[float]:
    a = np.asarray(values, np.float64)
    return float(a.mean()) if a.size else None


def summarize(values, percentiles: Sequence[float] = (50, 95)) -> dict:
    """``{"count", "mean", "p50", "p95", ...}`` with None-on-empty values
    (``p50``/``p95`` keys follow the requested ``percentiles``)."""
    a = np.asarray(values, np.float64)
    out = {"count": int(a.size), "mean": mean(a)}
    for q in percentiles:
        out[f"p{q:g}"] = pct(a, q)
    return out


def token_agreement(a, b) -> Optional[float]:
    """Mean per-request fraction of position-wise equal tokens between two
    finished-request lists (objects with ``.out`` token lists). The
    greedy-equivalence metric every benchmark tracks."""
    per = [mean([x == y for x, y in zip(ra.out, rb.out)]) or 0.0
           for ra, rb in zip(a, b)]
    return mean(per)
