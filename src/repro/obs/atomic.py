"""Crash-safe artifact writes: the tmp + fsync + rename protocol.

Hoisted from ``engine/recovery.py`` (PR 9) so every exporter in the stack
— trace JSONL, chrome traces, metrics snapshots, engine snapshots,
incident bundles — shares one durability story:

  * files: write to ``<final>.tmp`` in the same directory, flush, fsync,
    then ``os.replace`` onto the final name. A crash mid-export leaves
    either the old artifact or the new one, never a truncated hybrid.
  * directories: build the whole tree under ``<final>.tmp``, fsync the
    last file written (the manifest), then ``os.rename`` the directory.
    POSIX renames are atomic within a filesystem, so a half-written
    bundle is never visible under the final name.
"""
from __future__ import annotations

import contextlib
import os
import shutil
from typing import Iterator

__all__ = ["atomic_write_text", "atomic_dir"]


def atomic_write_text(path: str, data: str) -> None:
    """Atomically replace ``path`` with ``data`` (tmp + fsync + rename)."""
    final = os.path.abspath(path)
    tmp = final + ".tmp"
    with open(tmp, "w") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)


@contextlib.contextmanager
def atomic_dir(path: str) -> Iterator[str]:
    """Context manager yielding a tmp directory that atomically replaces
    ``path`` on clean exit. On exception the tmp tree is removed and the
    final name is untouched."""
    final = os.path.abspath(path)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    try:
        yield tmp
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
