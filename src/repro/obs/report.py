"""Trace aggregation: phase breakdown, dispatch-vs-device attribution,
and per-request waterfalls — shared by `launch.trace_report` (the CLI),
`Engine.metrics()` (traced engines embed the breakdown), and the
benchmark phase-attribution sections in BENCH_serve/spec.json.
"""
from __future__ import annotations

from .summary import mean, pct


def spans(records, name=None):
    return [r for r in records if r.get("kind") == "span"
            and (name is None or r.get("name") == name)]


def phase_breakdown(records) -> dict:
    """Aggregate span records into the per-phase timeline summary.

    ``step`` spans are the denominator (total measured wall-clock step
    time); every other phase nests inside a step, and the phases are
    non-overlapping by construction (engine instrumentation brackets
    disjoint regions), so ``coverage`` = attributed / step-total is the
    fraction of step wall the taxonomy explains — the acceptance bar is
    ≥ 0.9. Per phase: total/count/mean plus the ``dispatch_s`` (host
    time inside the jit call) and ``wait_s`` (device wait) attribution,
    with ``host_s = total − device wait`` (host incl. dispatch).
    """
    per: dict[str, dict] = {}
    step_total, step_count = 0.0, 0
    for r in spans(records):
        if r["name"] == "step":
            step_total += r["dur"]
            step_count += 1
            continue
        d = per.setdefault(r["name"], {"total_s": 0.0, "count": 0,
                                       "dispatch_s": 0.0,
                                       "device_wait_s": 0.0})
        d["total_s"] += r["dur"]
        d["count"] += 1
        d["dispatch_s"] += r.get("dispatch_s", 0.0)
        d["device_wait_s"] += r.get("wait_s", 0.0)
    attributed = 0.0
    for d in per.values():
        d["mean_s"] = d["total_s"] / d["count"]
        d["host_s"] = d["total_s"] - d["device_wait_s"]
        d["frac_of_step"] = (d["total_s"] / step_total if step_total
                             else None)
        attributed += d["total_s"]
    dispatch = sum(d["dispatch_s"] for d in per.values())
    wait = sum(d["device_wait_s"] for d in per.values())
    return {
        "phases": per,
        "steps": step_count,
        "step_total_s": step_total,
        "attributed_s": attributed,
        "coverage": attributed / step_total if step_total else None,
        # the dispatch-bound question, answered: host time inside jitted
        # calls (tracing + lowering + enqueue) vs device-result wait vs
        # other host work (accept loops, scheduler, numpy staging)
        "dispatch_s": dispatch,
        "device_wait_s": wait,
        "other_host_s": attributed - dispatch - wait,
        "dispatch_frac": dispatch / attributed if attributed else None,
        "device_wait_frac": wait / attributed if attributed else None,
    }


def request_waterfalls(records) -> list[dict]:
    """Per-request lifecycle rows (uid order): submit/admit/first-token/
    retire timestamps with the derived queued / prefill+first-token /
    decode segments a waterfall plots."""
    reqs: dict[int, dict] = {}
    for r in records:
        if r.get("kind") != "event" or r.get("uid") is None:
            continue
        row = reqs.setdefault(int(r["uid"]), {"uid": int(r["uid"])})
        name = r["name"]
        if name == "submit":
            row["t_submit"] = r["ts"]
            row["prompt_len"] = r.get("prompt_len")
            row["budget"] = r.get("budget")
        elif name == "admit":
            row["t_admit"] = r["ts"]
            row["slot"] = r.get("slot")
        elif name == "first_token":
            row["t_first_token"] = r["ts"]
        elif name == "retire":
            row["t_retire"] = r["ts"]
            row["reason"] = r.get("reason")
            row["n_out"] = r.get("n_out")

    def seg(row, a, b):
        return (row[b] - row[a] if a in row and b in row else None)
    for row in reqs.values():
        row["queued_s"] = seg(row, "t_submit", "t_admit")
        row["prefill_s"] = seg(row, "t_admit", "t_first_token")
        row["decode_s"] = seg(row, "t_first_token", "t_retire")
        row["total_s"] = seg(row, "t_submit", "t_retire")
    return [reqs[u] for u in sorted(reqs)]


def lifecycle_summary(records) -> dict:
    """Aggregate waterfall segments (the per-request view of the same
    trace the phase breakdown views per-step)."""
    rows = request_waterfalls(records)

    def agg(key):
        vals = [r[key] for r in rows if r.get(key) is not None]
        return {"mean": mean(vals), "p50": pct(vals, 50),
                "p95": pct(vals, 95)}
    reasons: dict[str, int] = {}
    for r in rows:
        if r.get("reason"):
            reasons[r["reason"]] = reasons.get(r["reason"], 0) + 1
    return {"requests": len(rows), "queued_s": agg("queued_s"),
            "prefill_s": agg("prefill_s"), "decode_s": agg("decode_s"),
            "total_s": agg("total_s"), "retire_reasons": reasons}
