"""Default-off observability for the serving stack (DESIGN.md §10).

`tracer.Tracer` records spans/events/counters into a bounded ring buffer
and exports JSONL + Chrome ``trace.json``; `schema` is the phase/
lifecycle vocabulary and validator; `report` aggregates traces into the
phase-breakdown / waterfall views; `summary` is the shared
percentile-with-empty-guard math every metrics consumer reuses;
`quality` holds the quantization-quality counters; `metrics` is the
always-on registry (counters/gauges/histograms, Prometheus + JSONL
snapshot export, DESIGN.md §11) and `provenance` the shared artifact
header. `flight` is the always-on bounded per-step flight recorder and
incident-bundle writer, `detect` the anomaly-detector catalog that
triggers bundles, and `atomic` the shared tmp+fsync+rename protocol
every exporter writes through (DESIGN.md §14).
"""
from repro.obs.atomic import atomic_dir, atomic_write_text
from repro.obs.detect import DETECTORS, AnomalyDetector, Firing
from repro.obs.flight import (FlightRecorder, load_incident_bundle,
                              tail_lines, write_incident_bundle)
from repro.obs.metrics import (DEPTH_BUCKETS, LATENCY_BUCKETS_S, Counter,
                               Gauge, Histogram, MetricsRegistry,
                               RegistryQuantProbe, SnapshotWriter,
                               default_registry, load_snapshots)
from repro.obs.provenance import provenance
from repro.obs.quality import ActQuantProbe, code_stats, span_stats
from repro.obs.report import (lifecycle_summary, phase_breakdown,
                              request_waterfalls)
from repro.obs.schema import LIFECYCLE, PHASES, RETIRE_REASONS, \
    validate_events
from repro.obs.summary import mean, pct, summarize, token_agreement
from repro.obs.tracer import SCHEMA_VERSION, Tracer, chrome_trace, \
    load_jsonl

__all__ = [
    "Tracer", "SCHEMA_VERSION", "chrome_trace", "load_jsonl",
    "PHASES", "LIFECYCLE", "RETIRE_REASONS", "validate_events",
    "phase_breakdown", "request_waterfalls", "lifecycle_summary",
    "pct", "mean", "summarize", "token_agreement",
    "ActQuantProbe", "code_stats", "span_stats",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "SnapshotWriter", "RegistryQuantProbe", "default_registry",
    "load_snapshots", "LATENCY_BUCKETS_S", "DEPTH_BUCKETS",
    "provenance",
    "atomic_write_text", "atomic_dir",
    "FlightRecorder", "write_incident_bundle", "load_incident_bundle",
    "tail_lines", "AnomalyDetector", "Firing", "DETECTORS",
]
