"""Quantization-quality counters (host-side, numpy).

SplitQuant keeps low-bit error down by giving every sub-channel chunk its
own range — so the runtime questions that matter are exactly the ones the
calibration pass answers offline (`calib/stats.py`): how often do codes
saturate, how much of the code range does a chunk actually occupy (a
static scale that leaves half the levels unused has drifted), and which
chunks are range outliers (OCS/OverQ's motivating measurement, taken live
instead of on a calibration set). These helpers compute those three
counters from quantizer OUTPUTS — int8 codes and (scale, zero) arrays —
so the jitted kernels stay untouched; the observed wrappers in
`kernels/act_quant.py` and `engine.kvcache.kv_quality_counters` feed
them, and the engine samples the latter into the trace as a ``counter``
record every ``trace_kv_every`` steps.
"""
from __future__ import annotations

import numpy as np

#: log2(chunk span / per-layer median span) bucket edges for the
#: outlier-chunk histogram: [<¼×, ¼–½×, ½–1×, 1–2×, 2–4×, 4–8×, >8×]
OUTLIER_LOG2_EDGES = (-2.0, -1.0, 0.0, 1.0, 2.0, 3.0)


def code_stats(q, bits: int = 8) -> dict:
    """Saturation + occupancy from int8 codes alone.

    ``clip_frac``: fraction of codes pinned at qmin/qmax (values at the
    endpoint are *possibly* clipped — an upper bound on true clipping,
    and the quantity that trends up when a static scale drifts narrow).
    ``occupancy``: (max − min code) / (levels) — how much of the code
    range the data spans (trends DOWN when a static scale drifts wide).
    """
    q = np.asarray(q)
    qmin, qmax = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    n = q.size
    if n == 0:
        return {"n": 0, "clip_frac": None, "lo_clip_frac": None,
                "hi_clip_frac": None, "occupancy": None}
    lo = float(np.count_nonzero(q == qmin)) / n
    hi = float(np.count_nonzero(q == qmax)) / n
    occ = float(int(q.max()) - int(q.min())) / float(2 ** bits - 1)
    return {"n": int(n), "clip_frac": lo + hi, "lo_clip_frac": lo,
            "hi_clip_frac": hi, "occupancy": occ}


def span_stats(spans, ref_spans=None) -> dict:
    """Chunk-range statistics from per-chunk spans (α − β, any shape).

    ``occupancy_vs_ref``: mean(span / ref_span) — dynamic ranges measured
    against the static calibrated ranges they would be replaced by (> 1
    means live data exceeds the recipe: clipping; ≪ 1 means the recipe
    wastes levels). ``outlier_hist``: counts of log2(span / median span)
    in `OUTLIER_LOG2_EDGES` buckets — the "which chunks are hot" OCS
    histogram.
    """
    raw = np.asarray(spans, np.float64).ravel()
    mask = np.isfinite(raw) & (raw > 0)
    spans = raw[mask]
    out: dict = {"chunks": int(spans.size)}
    if spans.size == 0:
        out.update(span_median=None, span_max=None, outlier_hist=None,
                   occupancy_vs_ref=None)
        return out
    med = float(np.median(spans))
    out["span_median"] = med
    out["span_max"] = float(spans.max())
    ratio = np.log2(spans / med) if med > 0 else np.zeros_like(spans)
    edges = (-np.inf,) + OUTLIER_LOG2_EDGES + (np.inf,)
    hist, _ = np.histogram(ratio, bins=np.asarray(edges))
    out["outlier_hist"] = [int(c) for c in hist]
    out["occupancy_vs_ref"] = None
    if ref_spans is not None:
        ref = np.asarray(ref_spans, np.float64).ravel()
        if ref.size == 1:
            ref = np.broadcast_to(ref, raw.shape)
        if ref.size == raw.size:                # same pre-filter layout
            ref = ref[mask]
            ok = np.isfinite(ref) & (ref > 0)
            if ok.any():
                out["occupancy_vs_ref"] = float(
                    np.mean(spans[ok] / ref[ok]))
    return out


def scale_to_span(scale, bits: int = 8):
    """Invert eq. (2): S = levels / span ⇒ span = levels / S."""
    scale = np.asarray(scale, np.float64)
    levels = float(2 ** bits - 1)
    return np.where(scale > 0, levels / np.where(scale > 0, scale, 1.0),
                    0.0)


class ActQuantProbe:
    """Accumulates activation-quantizer quality across kernel calls.

    The observed wrappers in `kernels.act_quant` feed every call's codes
    (and dynamic scales, when present) here; `summary()` folds them into
    one counter dict, and ``tracer`` (optional) gets a live ``counter``
    record per observation. Weighted by element count so big calls
    dominate, as they do in error terms.
    """

    def __init__(self, tracer=None, name: str = "act_quant",
                 bits: int = 8):
        self.tracer = tracer if tracer else None
        self.name = name
        self.bits = bits
        self.calls = 0
        self._elems = 0
        self._clip_w = 0.0          # clip_frac weighted by elements
        self._occ_w = 0.0           # occupancy weighted by elements
        self._spans: list[np.ndarray] = []

    def observe(self, q, scale=None, *, layer=None) -> dict:
        cs = code_stats(q, self.bits)
        self.calls += 1
        n = cs["n"]
        if n:
            self._elems += n
            self._clip_w += cs["clip_frac"] * n
            self._occ_w += cs["occupancy"] * n
        if scale is not None:
            self._spans.append(
                scale_to_span(scale, self.bits).ravel())
        if self.tracer:
            self.tracer.counter(
                self.name,
                {"clip_frac": cs["clip_frac"],
                 "occupancy": cs["occupancy"]},
                layer=layer)
        return cs

    def summary(self) -> dict:
        out = {"calls": self.calls, "elements": self._elems,
               "clip_frac": (self._clip_w / self._elems
                             if self._elems else None),
               "occupancy": (self._occ_w / self._elems
                             if self._elems else None)}
        if self._spans:
            out.update(span_stats(np.concatenate(self._spans)))
        return out
