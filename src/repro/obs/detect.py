"""Anomaly detectors over live engine signals.

A sweep runs once per step boundary over the flight record just written
(``AnomalyDetector.sweep``); event-shaped anomalies that happen *inside*
a step (retry, quarantine, ``IntegrityError``, ``InjectedCrash``) are
posted with ``note`` and drained by the same sweep so every firing is
step-stamped. Each detector fires at most once per ``cooldown_steps`` —
a fault storm produces one incident, not one per step.

Catalog (name → signal → default threshold):

  step_latency_spike  step_s vs rolling EWMA baseline; fires when
                      step_s > latency_factor × baseline after
                      warmup_steps baseline samples. The EWMA is fed
                      from the start, so jit-compile spikes during
                      warmup inflate the baseline instead of firing.
  accept_collapse     scheduler acceptance EWMA drops below
                      accept_floor after having been >= 2×floor —
                      speculation is burning draft passes for nothing.
  kv_clip_spike       KV clip-fraction sample exceeds clip_abs or jumps
                      by > clip_jump over the previous sample — the
                      paper's eq. 1–3 outlier pathology getting worse
                      at runtime.
  queue_runaway       admission queue depth exceeds the configured set
                      point (engine max_queue) — overload is outrunning
                      admission control.
  rung_ascent         degradation rung increased this step.
  step_retry          a step failed and was retried (posted by the
                      engine with the faulted uid when attributable).
  quarantine          a request was retired as "failed" after
                      exhausting retries (posted with the uid).
  integrity_error     artifact validation failed during restore/load
                      (posted with the reason).
  injected_crash      the chaos injector killed the step loop (posted
                      by the supervisor on restart).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

__all__ = ["DETECTORS", "Firing", "AnomalyDetector"]

#: Every detector name this module can emit (incident_report validates
#: triggers against this catalog).
DETECTORS = (
    "step_latency_spike",
    "accept_collapse",
    "kv_clip_spike",
    "queue_runaway",
    "rung_ascent",
    "step_retry",
    "quarantine",
    "integrity_error",
    "injected_crash",
)

#: Detectors posted via note() rather than derived from the sweep.
EVENT_DETECTORS = ("step_retry", "quarantine", "integrity_error",
                   "injected_crash")


@dataclass
class Firing:
    detector: str
    step: int
    reason: str
    uid: Optional[int] = None
    value: Optional[float] = None

    def to_dict(self) -> Dict[str, Any]:
        return {"detector": self.detector, "step": self.step,
                "reason": self.reason, "uid": self.uid,
                "value": self.value}


class AnomalyDetector:
    """Stateful sweep over per-step flight records + posted events."""

    def __init__(self, cooldown_steps: int = 50, *,
                 latency_factor: float = 6.0,
                 warmup_steps: int = 8,
                 baseline_alpha: float = 0.2,
                 accept_floor: float = 0.2,
                 clip_abs: float = 0.5,
                 clip_jump: float = 0.25,
                 queue_set_point: Optional[int] = None):
        if cooldown_steps < 1:
            raise ValueError(
                f"cooldown_steps must be >= 1, got {cooldown_steps}")
        self.cooldown_steps = int(cooldown_steps)
        self.latency_factor = float(latency_factor)
        self.warmup_steps = int(warmup_steps)
        self.baseline_alpha = float(baseline_alpha)
        self.accept_floor = float(accept_floor)
        self.clip_abs = float(clip_abs)
        self.clip_jump = float(clip_jump)
        self.queue_set_point = queue_set_point
        # Rolling state.
        self._lat_ewma: Optional[float] = None
        self._lat_n = 0
        self._accept_armed = False
        self._prev_clip: Optional[float] = None
        self._prev_rung = 0
        self._step = -1
        self._last_fired: Dict[str, int] = {}
        self._pending: List[Firing] = []
        self.n_fired = 0

    # ---------------------------------------------------------- events
    def note(self, detector: str, *, reason: str = "",
             uid: Optional[int] = None,
             value: Optional[float] = None,
             step: Optional[int] = None) -> None:
        """Post an event-shaped anomaly; drained by the next sweep (or
        immediately via drain() for out-of-step events like crashes)."""
        if detector not in DETECTORS:
            raise ValueError(f"unknown detector {detector!r}")
        at = self._step + 1 if step is None else int(step)
        self._pending.append(Firing(detector, at, reason, uid=uid,
                                    value=value))

    # ----------------------------------------------------------- sweep
    def sweep(self, rec: Dict[str, Any]) -> List[Firing]:
        """Evaluate one flight record; returns cooldown-filtered firings
        (posted events first — they are the precise signal, the derived
        detectors are the echo)."""
        self._step = step = int(rec.get("step", self._step + 1))
        raw: List[Firing] = list(self._pending)
        self._pending.clear()

        step_s = rec.get("step_s")
        if step_s is not None:
            if (self._lat_n >= self.warmup_steps
                    and self._lat_ewma is not None and self._lat_ewma > 0
                    and step_s > self.latency_factor * self._lat_ewma):
                raw.append(Firing(
                    "step_latency_spike", step,
                    f"step wall {step_s:.4f}s > {self.latency_factor:g}x "
                    f"rolling baseline {self._lat_ewma:.4f}s",
                    value=float(step_s)))
            a = self.baseline_alpha
            self._lat_ewma = (float(step_s) if self._lat_ewma is None
                              else (1 - a) * self._lat_ewma + a * float(step_s))
            self._lat_n += 1

        accept = rec.get("accept")
        if accept is not None:
            if accept >= 2.0 * self.accept_floor:
                self._accept_armed = True
            elif self._accept_armed and accept < self.accept_floor:
                self._accept_armed = False
                raw.append(Firing(
                    "accept_collapse", step,
                    f"spec acceptance EWMA {accept:.3f} fell below "
                    f"{self.accept_floor:g}", value=float(accept)))

        clip = rec.get("clip_frac")
        if clip is not None:
            jumped = (self._prev_clip is not None
                      and clip - self._prev_clip > self.clip_jump)
            if clip > self.clip_abs or jumped:
                base = (f" (was {self._prev_clip:.3f})"
                        if self._prev_clip is not None else "")
                raw.append(Firing(
                    "kv_clip_spike", step,
                    f"KV clip fraction {clip:.3f}{base}",
                    value=float(clip)))
            self._prev_clip = float(clip)

        queue = rec.get("queue")
        if (queue is not None and self.queue_set_point is not None
                and self.queue_set_point > 0
                and queue > self.queue_set_point):
            raw.append(Firing(
                "queue_runaway", step,
                f"queue depth {queue} > admission set point "
                f"{self.queue_set_point}", value=float(queue)))

        rung = rec.get("rung")
        if rung is not None:
            if rung > self._prev_rung:
                raw.append(Firing(
                    "rung_ascent", step,
                    f"degradation rung {self._prev_rung} -> {rung}",
                    value=float(rung)))
            self._prev_rung = int(rung)

        return self._admit(raw)

    def drain(self) -> List[Firing]:
        """Cooldown-filter pending posted events without a step record —
        for anomalies outside the step loop (crash on restart,
        IntegrityError during restore)."""
        raw = list(self._pending)
        self._pending.clear()
        return self._admit(raw)

    def _admit(self, raw: List[Firing]) -> List[Firing]:
        out: List[Firing] = []
        for f in raw:
            last = self._last_fired.get(f.detector)
            if last is not None and f.step - last < self.cooldown_steps:
                continue
            self._last_fired[f.detector] = f.step
            self.n_fired += 1
            out.append(f)
        return out
