"""Trace event schema: the phase taxonomy, lifecycle vocabulary, and a
dependency-free validator (CI's trace smoke runs it against the JSONL a
traced serve run emits — see DESIGN.md §10 for the prose contract).

Phase taxonomy (``span`` names) — each engine step tiles into these:

* ``step``            — the whole `Engine.step()` (the coverage
                        denominator; every other phase nests inside it)
* ``prefill_oneshot`` — legacy dense per-request prefill + slot write
* ``prefill_chunk``   — one fused chunked-prefill dispatch (slot, uid,
                        pos_start, n)
* ``draft``           — the speculative draft pass over all slots
                        (aggregated per-iteration dispatch/wait fields)
* ``verify``          — ONE slot's fused verify dispatch + device wait +
                        accept-length computation
* ``rollback``        — target + draft cache rollback for one slot
* ``accept_commit``   — host-side token commit loop (spec and plain
                        decode share the name; eos/budget retire runs
                        inside it)
* ``decode``          — one batched plain decode dispatch + device wait
* ``kv_sample``       — the periodic KV quality-counter sample (its
                        cache→host transfer is traced-mode-only cost)

Lifecycle vocabulary (``event`` names): ``submit``, ``admit``,
``first_token``, ``retire`` (with ``reason``), ``rollback``,
``cancel`` (the Engine.cancel call site; the matching retire carries
reason "cancelled"), ``degrade`` (a degradation-ladder rung change —
engine-scoped, so it carries ``rung``/``pressure`` instead of a uid),
``snapshot`` / ``restore`` (crash-safety boundaries, DESIGN.md §13 —
engine-scoped like ``degrade``; the request journal shares this schema,
so a merged crash + recovery journal validates as one trace).

Retire reasons split into the NORMAL terminals (eos / budget / max_len /
zero_budget) and the POLICY terminals introduced by fault tolerance
(DESIGN.md §12): ``cancelled`` (client withdrew), ``deadline_exceeded``
(TTFT or total-wall deadline passed at a step boundary), ``shed``
(admission control or ladder rung 3 dropped it unserved), ``failed``
(quarantined after exhausting step retries, or force-failed by the
drain watchdog). Together they partition every submission: each request
retires exactly once with exactly one reason (the chaos harness'
core invariant, tests/test_faults.py).
"""
from __future__ import annotations

PHASES = ("step", "prefill_oneshot", "prefill_chunk", "draft", "verify",
          "rollback", "accept_commit", "decode", "kv_sample")

LIFECYCLE = ("submit", "admit", "first_token", "retire", "rollback",
             "cancel", "degrade", "snapshot", "restore")

RETIRE_REASONS = ("eos", "budget", "max_len", "zero_budget",
                  "cancelled", "deadline_exceeded", "shed", "failed")

KINDS = ("header", "span", "event", "counter")

#: per-kind required fields (beyond "kind")
_REQUIRED = {
    "header": ("schema",),
    "span": ("name", "ts", "dur"),
    "event": ("name", "ts"),
    "counter": ("name", "ts", "value"),
}


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate_events(records: list[dict]) -> list[str]:
    """Validate a record list (as loaded from `tracer.load_jsonl`).
    Returns a list of human-readable errors — empty means valid."""
    from .tracer import SCHEMA_VERSION

    errs = []
    if not records:
        return ["empty trace (no header record)"]
    head = records[0]
    if head.get("kind") != "header":
        errs.append(f"record 0: expected header, got {head.get('kind')!r}")
    elif head.get("schema") != SCHEMA_VERSION:
        errs.append(f"header: schema {head.get('schema')!r} != "
                    f"{SCHEMA_VERSION}")
    for i, rec in enumerate(records):
        kind = rec.get("kind")
        if kind not in KINDS:
            errs.append(f"record {i}: unknown kind {kind!r}")
            continue
        for f in _REQUIRED[kind]:
            if f not in rec:
                errs.append(f"record {i} ({kind}): missing field {f!r}")
        if kind == "header":
            if i != 0:
                errs.append(f"record {i}: header not first")
            continue
        if not _is_num(rec.get("ts")) or rec.get("ts", 0) < 0:
            errs.append(f"record {i} ({kind}): bad ts {rec.get('ts')!r}")
        if kind == "span":
            if rec.get("name") not in PHASES:
                errs.append(f"record {i}: unknown phase {rec.get('name')!r}")
            if not _is_num(rec.get("dur")) or rec.get("dur", 0) < 0:
                errs.append(f"record {i}: bad dur {rec.get('dur')!r}")
            for f in ("dispatch_s", "wait_s"):
                if f in rec and (not _is_num(rec[f]) or rec[f] < 0):
                    errs.append(f"record {i}: bad {f} {rec[f]!r}")
        elif kind == "event":
            name = rec.get("name")
            if name not in LIFECYCLE:
                errs.append(f"record {i}: unknown lifecycle event {name!r}")
            if name in ("submit", "admit", "first_token", "retire",
                        "cancel") \
                    and not isinstance(rec.get("uid"), int):
                errs.append(f"record {i} ({name}): missing/bad uid")
            if name == "retire" \
                    and rec.get("reason") not in RETIRE_REASONS:
                errs.append(f"record {i}: bad retire reason "
                            f"{rec.get('reason')!r}")
        elif kind == "counter":
            val = rec.get("value")
            if not (_is_num(val) or (isinstance(val, dict)
                                     and all(_is_num(v) or v is None
                                             or isinstance(v, (list, str))
                                             for v in val.values()))):
                errs.append(f"record {i}: bad counter value {val!r}")
    return errs
