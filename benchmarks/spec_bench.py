"""Self-speculative decoding benchmark → BENCH_spec.json.

Serves one decode-heavy mixed-length workload through the engine twice —
plain greedy (spec_k=0) and speculative (spec_k>0) at EQUAL batch — for a
ladder of SplitQuant draft fidelities, and reports per config:

  * the acceptance-rate histogram (verify calls that accepted exactly a
    draft tokens, a in [0, spec_k]) plus draft/verify token counters;
  * tokens/s vs the non-speculative engine (the tracked speedup), and
  * greedy agreement with the non-speculative run (must be 100% — the
    accept rule is lossless; anything else is a bug, see
    tests/test_spec.py).

The headline draft is a mixed <=2.9-avg-bit QuantRecipe (attention
projections at 4 bits, everything else at 2 — the SplitQuant
faithfulness-per-byte sweet spot the calibration benchmark established),
loaded through the real `engine.spec.load_draft_params` recipe path. The
ladder (INT4, INT8, self-draft upper bound) shows acceptance rising with
draft fidelity; on RANDOM-INIT weights low-bit drafts diverge far more
than on trained checkpoints (the paper's recovery results are post-
training), so treat the absolute acceptance here as a lower bound and
the self-draft row as the harness ceiling. The expected >=1.3x
tokens/s applies when measured acceptance >= 0.7; the number is
reported either way.

    PYTHONPATH=src python benchmarks/spec_bench.py            # full
    PYTHONPATH=src python benchmarks/spec_bench.py --smoke    # CI-sized
"""
import argparse
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.calib import QuantRecipe  # noqa: E402
from repro.configs import get_arch  # noqa: E402
from repro.core import QuantConfig, QuantPolicy, quantize_tree  # noqa: E402
from repro.engine import Engine, EngineConfig  # noqa: E402
from repro.models import get_model  # noqa: E402
from repro.obs import token_agreement  # noqa: E402

from run import provenance  # noqa: E402

SEED = 11


def make_workload(rng, n_requests, vocab, new_tokens):
    """Short prompts, long generations: speculative decoding attacks the
    DECODE wall, so the workload keeps slots mid-generation ~all the
    time (prefill treatment is identical across configs anyway)."""
    return [(rng.integers(0, vocab, size=int(rng.integers(4, 12))),
             new_tokens) for _ in range(n_requests)]


def allocated_avg_bits(params, per_path) -> float:
    """Parameter-weighted average of the ASSIGNED bit-widths (the number
    the calibration benchmark tracks — codebook/scale overhead is
    reported separately as deployed bytes)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    sizes = {"/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path): leaf.size for path, leaf in flat}
    num = den = 0
    for p, d in per_path.items():
        num += d["bits"] * sizes[p]
        den += sizes[p]
    return num / den


def run_engine(cfg, params, workload, ecfg, draft=None, repeats=1):
    """Best-of-N (greedy: identical outputs across repeats, fastest run
    is the steady-state sample)."""
    best = None
    for _ in range(repeats):
        eng = Engine(cfg, params, ecfg, draft_params=draft)
        for p, b in workload:
            eng.submit(p.copy(), max_new_tokens=b)
        t0 = time.perf_counter()
        fin = eng.drain()
        wall = time.perf_counter() - t0
        m = eng.metrics()
        m["wall_s"] = wall
        m["tokens_per_s"] = m["total_tokens"] / wall
        if best is None or m["tokens_per_s"] > best[1]["tokens_per_s"]:
            best = (fin, m)
    return best


# greedy-token agreement (shared helper: repro.obs.summary)
agreement = token_agreement


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=None)
    ap.add_argument("--spec-k", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests/repeats, drops "
                         "the INT8 ladder point)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_spec.json"))
    args = ap.parse_args()
    requests = args.requests or (6 if args.smoke else 16)
    new_tokens = args.new_tokens or (24 if args.smoke else 48)
    repeats = args.repeats or (1 if args.smoke else 3)

    cfg = get_arch(args.arch).reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    rng = np.random.default_rng(SEED)
    workload = make_workload(rng, requests, cfg.vocab, new_tokens)

    # ---- draft ladder -----------------------------------------------
    # headline: mixed <=2.9-avg-bit recipe (attention 4-bit, rest 2-bit),
    # loaded through the real QuantRecipe path the engine uses
    _, probe = quantize_tree(key, params, QuantPolicy(
        cfg=QuantConfig(bits=2)))
    mixed_over = {p: {"bits": 4} for p in probe["per_path"]
                  if "/attn/" in p and p.endswith(("wq", "wk"))}
    qp_mixed, rep_mixed = quantize_tree(
        key, params, QuantPolicy(cfg=QuantConfig(bits=2)),
        overrides=mixed_over)
    mixed_bits = allocated_avg_bits(params, rep_mixed["per_path"])
    assert mixed_bits <= 2.9, mixed_bits
    drafts = {}
    with tempfile.TemporaryDirectory() as recipe_dir:
        QuantRecipe(
            name=f"{cfg.name}-spec-draft", arch=cfg.name,
            policies={p: {"bits": d["bits"], "k": d["k"],
                          "method": d["method"]}
                      for p, d in rep_mixed["per_path"].items()},
            meta={"avg_bits": mixed_bits}).save(recipe_dir)
        from repro.engine.spec import load_draft_params
        drafts["mixed2.9"] = (load_draft_params(recipe_dir, params, cfg),
                              mixed_bits, rep_mixed["deployed_bytes"])
    for bits in (4,) if args.smoke else (4, 8):
        qp, rep = quantize_tree(key, params, QuantPolicy(
            cfg=QuantConfig(bits=bits)))
        drafts[f"int{bits}"] = (qp, float(bits), rep["deployed_bytes"])
    drafts["self"] = (params, 32.0, probe["orig_bytes"])

    ecfg0 = EngineConfig(n_slots=args.slots, max_len=args.max_len,
                         prefill_bucket=8, kv_mode="int8")
    ecfgS = EngineConfig(**{**ecfg0.__dict__, "spec_k": args.spec_k})
    print(f"workload: {requests} requests x {new_tokens} tokens, "
          f"{args.slots} slots, spec_k={args.spec_k}, kv=int8")

    # warm every jit bucket (decode, prefill chunks, verify window) so
    # measured walls compare steady state, not XLA compiles
    warm = workload[:min(3, len(workload))]
    run_engine(cfg, params, warm, ecfg0)
    run_engine(cfg, params, warm, ecfgS, draft=drafts["self"][0])

    base_out, base = run_engine(cfg, params, workload, ecfg0,
                                repeats=repeats)
    print(f"spec_k=0 baseline: {base['tokens_per_s']:8.1f} tok/s "
          f"({base['total_tokens']} tokens, {base['wall_s']:.2f}s)")

    configs = {}
    for name, (dp, bits, dbytes) in drafts.items():
        out, m = run_engine(cfg, params, workload, ecfgS, draft=dp,
                            repeats=repeats)
        agree = agreement(out, base_out)
        configs[name] = {
            "draft_avg_bits": bits,
            "draft_deployed_bytes": int(dbytes),
            "tokens_per_s": m["tokens_per_s"],
            "speedup_vs_nonspec": m["tokens_per_s"] / base["tokens_per_s"],
            "acceptance_rate": m["acceptance_rate"],
            "accept_hist": m["accept_hist"],
            "tokens_per_verify_mean": m["tokens_per_verify_mean"],
            "draft_proposed": m["draft_proposed"],
            "draft_accepted": m["draft_accepted"],
            "draft_steps": m["draft_steps"],
            "verify_calls": m["verify_calls"],
            "verify_tokens": m["verify_tokens"],
            "spec_steps": m["spec_steps"],
            "greedy_agreement_vs_nonspec": agree,
            "wall_s": m["wall_s"],
        }
        c = configs[name]
        print(f"{name:>9}: {c['tokens_per_s']:8.1f} tok/s "
              f"({c['speedup_vs_nonspec']:.2f}x), acceptance "
              f"{c['acceptance_rate']:.1%} "
              f"({c['tokens_per_verify_mean']:.2f} tokens/verify, hist "
              f"{c['accept_hist']}), agreement {agree:.1%}")
        assert agree == 1.0, (name, agree)   # the accept rule is lossless

    # ---- traced phase attribution of the headline spec config --------
    # One traced run of the mixed2.9 draft answers WHERE the spec step's
    # wall goes: draft vs verify vs rollback vs host dispatch (the
    # ROADMAP's "is verify dispatch-bound?" question). Coverage of the
    # per-step phase spans must account for >=90% of stepped wall —
    # anything less means an uninstrumented phase is eating time.
    traced_cfg = EngineConfig(**{**ecfgS.__dict__, "trace": True})
    dp_head = drafts["mixed2.9"][0]
    run_engine(cfg, params, warm, traced_cfg, draft=dp_head)  # warm
    _, traced = run_engine(cfg, params, workload, traced_cfg,
                           draft=dp_head, repeats=repeats)
    pa = traced["phase_attribution"]
    ph = pa["phases"]
    step_total = max(pa["step_total_s"], 1e-12)

    def _tot(name):
        return ph.get(name, {}).get("total_s", 0.0)
    trace = {
        "config": "mixed2.9",
        "traced_tokens_per_s": traced["tokens_per_s"],
        "coverage": pa["coverage"],
        "steps": pa["steps"],
        "step_total_s": pa["step_total_s"],
        # the four-way split the ISSUE tracks: draft / verify / rollback
        # / host-dispatch shares of attributed step time
        "draft_frac_of_step": _tot("draft") / step_total,
        "verify_frac_of_step": _tot("verify") / step_total,
        "rollback_frac_of_step": _tot("rollback") / step_total,
        "dispatch_frac": pa["dispatch_frac"],
        "device_wait_frac": pa["device_wait_frac"],
        "phase_attribution": pa,
    }
    assert pa["coverage"] is None or pa["coverage"] >= 0.9, \
        f"spec phase coverage {pa['coverage']} < 0.9 of step wall"
    print(f"trace(mixed2.9): coverage {pa['coverage']:.1%}, "
          f"draft {trace['draft_frac_of_step']:.0%} / verify "
          f"{trace['verify_frac_of_step']:.0%} / rollback "
          f"{trace['rollback_frac_of_step']:.0%} of step wall; "
          f"host dispatch {pa['dispatch_frac']:.0%} / device wait "
          f"{pa['device_wait_frac']:.0%} of attributed time")

    head = configs["mixed2.9"]
    result = {
        "provenance": provenance(seed=SEED),
        "arch": cfg.name,
        "requests": requests,
        "new_tokens": new_tokens,
        "slots": args.slots,
        "max_len": args.max_len,
        "spec_k": args.spec_k,
        "smoke": args.smoke,
        "nonspec": {k: base[k] for k in
                    ("tokens_per_s", "total_tokens", "wall_s",
                     "decode_steps")},
        "configs": configs,
        "trace": trace,
        # the tracked headline pair: a <=2.9-avg-bit draft's acceptance
        # and its tokens/s vs the non-speculative engine at equal batch
        # (>=1.3x expected once acceptance >= 0.7 — random-init weights
        # land far below that; report either way)
        "headline_draft_avg_bits": head["draft_avg_bits"],
        "headline_acceptance_rate": head["acceptance_rate"],
        "headline_speedup_vs_nonspec": head["speedup_vs_nonspec"],
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, default=str)
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
