"""Serving throughput: continuous-batching engine vs wave-synchronous
server on a mixed-length request workload.

The workload is adversarial for wave batching: most requests want a few
tokens, a minority want many. In a wave, every batch slot is held until
the wave's longest member finishes; the engine retires and refills slots
per step, so the long tail no longer stalls short requests.

The INT8 cache is additionally served two ways: the legacy
materialize-then-attend read (dequantize the whole slot cache per decode
step; now behind `fused_attn=False` — the engine default flipped to
fused) and the fused dequant-in-kernel read
(`repro.kernels.decode_attention`) — the fused-vs-materialized delta and
per-decode-step latency percentiles are tracked per PR. `--max-len`
defaults to 512 so the cache is deep enough for the read path to
dominate the step.

A mixed prefill+decode SOAK config additionally serves a long-prompt
workload two ways: legacy ONE-SHOT prefill (every admission blocks the
step for a whole prompt's prefill — the stall baseline) vs CHUNKED fused
prefill (`prefill_chunk` tokens per step, quantize-in-kernel slot
writes, `kernels/prefill_attention.py`). It reports TTFT p50/p95, the
p95 of full-step latency among steps that did prefill work (decode-step
latency under concurrent prefill — the admission-stall metric), and
chunked-vs-one-shot tokens/s + greedy agreement.

    PYTHONPATH=src python benchmarks/serve_bench.py --requests 24

Emits BENCH_serve.json next to this file (tokens/s, per-step p50/p95,
TTFT, speedups, soak percentiles, and greedy token agreement across
every pair of paths) so the perf trajectory accumulates.
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from loadgen import (CLASSES, find_knee, make_open_loop_workload,  # noqa: E402
                     request_slo, slo_summary)
from run import provenance  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.engine import (Engine, EngineConfig,  # noqa: E402
                          admission_set_point)
from repro.models import get_model  # noqa: E402
from repro.obs import token_agreement  # noqa: E402
from repro.runtime.serve_loop import Request, ServeConfig, Server  # noqa: E402


def make_workload(rng, n_requests, vocab, long_every=6,
                  short_tokens=16, long_tokens=96):
    """Mixed lengths: mostly short prompts/generations, every `long_every`-th
    request is a long one (the wave-stalling tail). Generation lengths are
    sized so decode dominates the wall at max_len 512 — the serving regime
    the fused cache read targets (admissions amortize over ~30 steps)."""
    reqs = []
    for i in range(n_requests):
        is_long = (i % long_every) == long_every - 1
        plen = int(rng.integers(24, 48)) if is_long else int(rng.integers(4, 12))
        budget = long_tokens if is_long else short_tokens
        reqs.append((rng.integers(0, vocab, size=plen), budget))
    return reqs


def make_soak_workload(rng, n_requests, vocab, long_prompt=(144, 208),
                       short_prompt=(4, 10), long_tokens=16,
                       short_tokens=48):
    """Concurrent prefill+decode stress: every other request carries a
    LONG prompt (one-shot prefill of it stalls the whole step for the
    prompt length) while the short requests in neighboring slots are
    mid-generation — the regime chunked prefill targets. Queue depth is
    kept above the slot count so admissions keep happening while slots
    decode."""
    reqs = []
    for i in range(n_requests):
        if i % 2:
            plen = int(rng.integers(*long_prompt))
            budget = long_tokens
        else:
            plen = int(rng.integers(*short_prompt))
            budget = short_tokens
        reqs.append((rng.integers(0, vocab, size=plen), budget))
    return reqs


def run_soak(cfg, params, workload, max_len, slots, prefill_chunk,
             repeats=1):
    """One soak config: INT8 cache, fused decode (the engine defaults),
    one-shot (prefill_chunk=0) or chunked prefill."""
    ecfg = EngineConfig(n_slots=slots, max_len=max_len, kv_mode="int8",
                        prefill_bucket=16, prefill_chunk=prefill_chunk)
    return run_engine(cfg, params, workload, ecfg, repeats)


def run_wave(srv, workload, repeats=1):
    """Best-of-`repeats`, same treatment as `run_engine` — comparing a
    best-of-N engine against a single wave sample would bias the tracked
    speedup upward on a noisy box. `srv` is constructed ONCE by the
    caller: `Server.__init__` jits its decode per instance, so a fresh
    Server per repeat would put XLA compile time inside every wave wall
    while the engine repeats hit the process-wide jit cache."""
    best = None
    for _ in range(repeats):
        reqs = [Request(i, p.copy(), max_new_tokens=b)
                for i, (p, b) in enumerate(workload)]
        t0 = time.perf_counter()
        out = srv.serve(reqs)
        wall = time.perf_counter() - t0
        total = sum(len(r.out) for r in out)
        m = {"wall_s": wall, "total_tokens": total,
             "tokens_per_s": total / wall}
        if best is None or m["tokens_per_s"] > best[1]["tokens_per_s"]:
            best = (out, m)
    return best


def run_engine(cfg, params, workload, ecfg, repeats=1):
    """Best-of-`repeats` run (greedy decoding: outputs are identical
    across repeats, so the fastest run is the steady-state measurement —
    sub-second walls on a shared box otherwise measure scheduler noise)."""
    best = None
    for _ in range(repeats):
        eng = Engine(cfg, params, ecfg)
        for p, b in workload:
            eng.submit(p, max_new_tokens=b)
        t0 = time.perf_counter()
        fin = eng.drain()
        wall = time.perf_counter() - t0
        m = eng.metrics()
        m["wall_s"] = wall
        m["tokens_per_s"] = m["total_tokens"] / wall
        if best is None or m["tokens_per_s"] > best[1]["tokens_per_s"]:
            best = (fin, m)
    return best


def run_open_loop(cfg, params, arrivals, ecfg):
    """One open-loop point: submit each request at its SCHEDULED wall
    time while the engine steps regardless — the submission rate is an
    independent variable, unlike the closed-loop runs above where it
    implicitly tracks the service rate. Arrivals may carry robustness
    fields (loadgen §12 options): per-request deadlines pass through to
    ``Engine.submit`` and scheduled client cancellations fire at their
    wall times via ``Engine.cancel``. Returns (slo_summary, metrics);
    SLO judging covers every request that the engine FINISHED for any
    reason — shed / cancelled / expired requests simply never attain
    (they produced no timely tokens), which is exactly how an external
    client would score them.
    """
    eng = Engine(cfg, params, ecfg)
    by_uid = {}
    cancels = []                               # (cancel_t, uid), sorted
    i, n = 0, len(arrivals)
    t0 = time.perf_counter()
    while i < n or not eng.sched.idle:
        now = time.perf_counter() - t0
        while i < n and arrivals[i].t <= now:
            a = arrivals[i]
            uid = eng.submit(a.prompt, max_new_tokens=a.max_new_tokens,
                             cls=a.cls, ttft_deadline_s=a.ttft_deadline_s,
                             deadline_s=a.deadline_s)
            by_uid[uid] = a
            if a.cancel_t is not None:
                cancels.append((a.cancel_t, uid))
            # backdate to the SCHEDULED arrival: when the engine was busy
            # stepping past this arrival's time, the request has already
            # been "waiting" since then — charging the queue from the
            # submit call instead would hide exactly the queueing delay
            # the open-loop method exists to measure. Look the request up
            # by uid: under a bounded queue an overload victim is
            # finished (shed) during submit, so it may live in
            # `finished`, or — shed-oldest/by-class — not be queue[-1].
            req = next((r for r in reversed(eng.sched.queue)
                        if r.uid == uid), None) \
                or next(r for r in reversed(eng.sched.finished)
                        if r.uid == uid)
            req.t_submit = t0 + a.t
            i += 1
        while cancels and cancels[0][0] <= now:
            eng.cancel(cancels.pop(0)[1])
        if eng.sched.idle:
            # nothing in flight: sleep toward the next event — arrival
            # or scheduled cancel — (capped so late-running generations
            # never oversleep a burst). i < n is guaranteed here (else
            # the loop condition would have exited).
            nxt = arrivals[i].t
            if cancels:
                nxt = min(nxt, cancels[0][0])
            time.sleep(min(max(nxt - now, 0.0), 0.02))
            continue
        eng.step()
    wall = time.perf_counter() - t0
    fin = sorted(eng.sched.finished, key=lambda r: r.uid)
    judged = [request_slo(by_uid[r.uid], r) for r in fin]
    m = eng.metrics()
    return slo_summary(judged, wall), m


def run_recovery_bench(cfg, params, vocab, n, seed, slots, max_len):
    """Seeded crash/recovery measurement (DESIGN.md §13): replay a
    loadgen schedule whose appended ``crash_t`` draws pick the crash
    moment, serve it with the journal + periodic snapshots armed, "die"
    at the first step boundary past the scheduled crash time (stop
    stepping — the durable state is exactly what a SIGKILL would leave),
    then recover in a fresh engine and drain. Reports restore latency,
    how much work survived in the snapshot vs re-prefilled from the
    journal, and token identity of the combined outputs against an
    uncrashed reference run of the same schedule."""
    import shutil
    import tempfile
    sched = make_open_loop_workload(seed, n, vocab, float("inf"),
                                    crash_rate=1.0)
    crash_t = min(a.crash_t for a in sched)
    ecfg = EngineConfig(n_slots=slots, max_len=max_len, kv_mode="int8",
                        prefill_bucket=16)

    def submit_all(eng):
        for a in sched:
            eng.submit(a.prompt, max_new_tokens=a.max_new_tokens,
                       cls=a.cls)

    ref = Engine(cfg, params, ecfg)
    submit_all(ref)
    ref_out = {r.uid: list(r.out) for r in ref.drain()}

    tmp = tempfile.mkdtemp(prefix="recovery_bench_")
    jpath = os.path.join(tmp, "journal.jsonl")
    spath = os.path.join(tmp, "snap")
    try:
        wcfg = EngineConfig(**{**ecfg.__dict__, "journal_path": jpath,
                               "snapshot_path": spath,
                               "snapshot_every": 2})
        eng = Engine(cfg, params, wcfg)
        submit_all(eng)
        t0 = time.perf_counter()
        crashed_at_step = None
        while not eng.sched.idle:
            eng.step()
            if time.perf_counter() - t0 >= crash_t:
                crashed_at_step = len(eng.step_s)
                break
        eng2 = Engine(cfg, params, EngineConfig(
            **{**wcfg.__dict__, "journal_resume": True}))
        t1 = time.perf_counter()
        info = eng2.recover(spath, jpath)
        restore_s = time.perf_counter() - t1
        fin = {r.uid: list(r.out) for r in eng2.drain()}
        combined = {uid: list(rec["out"])
                    for uid, rec in info["retired"].items()}
        combined.update(fin)
        return {
            "requests": n,
            "seed": seed,
            "crash_t_s": crash_t,
            "crashed_at_step": crashed_at_step,
            "snapshot_every": 2,
            "restore_duration_s": restore_s,
            "n_restored_from_snapshot": info["n_restored"],
            "n_requeued_from_journal": info["n_requeued"],
            "n_retired_pre_crash": len(info["retired"]),
            "token_identical_vs_uncrashed":
                sorted(combined) == sorted(ref_out)
                and all(combined[u] == ref_out[u] for u in ref_out),
        }
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--repeats", type=int, default=3,
                    help="best-of-N runs per engine config")
    ap.add_argument("--soak-requests", type=int, default=10,
                    help="requests in the mixed prefill+decode soak "
                         "(0 disables the soak)")
    ap.add_argument("--soak-prefill-chunk", type=int, default=96,
                    help="prompt-token budget per step for the chunked "
                         "soak config (too-small budgets pay a dispatch "
                         "per bucket-rounded chunk and under-fill the "
                         "whole-chunk-or-nothing budget; ~4x the "
                         "prefill_bucket is the sweet spot on the CI box)")
    ap.add_argument("--open-loop-requests", type=int, default=24,
                    help="requests per open-loop sweep point (0 disables "
                         "the open-loop SLO section)")
    ap.add_argument("--open-loop-rates", default="1,2,4,8,inf",
                    help="comma-separated base Poisson rates (req/s) to "
                         "sweep; 'inf' is the all-at-once closed-loop "
                         "limit that guarantees a measured saturation "
                         "knee even when the finite rates all keep up")
    ap.add_argument("--open-loop-seed", type=int, default=7,
                    help="loadgen seed — same seed reproduces the exact "
                         "arrival schedule, class draws, and prompts")
    ap.add_argument("--slo-threshold", type=float, default=0.9,
                    help="attainment level defining the saturation knee")
    ap.add_argument("--recovery-requests", type=int, default=8,
                    help="requests in the seeded crash/recovery "
                         "measurement (restore latency, survivor "
                         "counts, token identity vs an uncrashed "
                         "reference; 0 disables the section)")
    ap.add_argument("--recovery-seed", type=int, default=13,
                    help="loadgen seed for the crash schedule — same "
                         "seed reproduces the arrivals AND the "
                         "appended crash_t draws")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: caps requests/repeats/soak so the "
                         "bench (including the tracing-overhead section) "
                         "finishes in minutes — for the trace smoke job, "
                         "not for tracked numbers")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serve.json"))
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 8)
        args.repeats = 1
        args.soak_requests = min(args.soak_requests, 4)
        args.max_len = min(args.max_len, 256)
        args.open_loop_requests = min(args.open_loop_requests, 8)
        args.open_loop_rates = "2,inf"
        args.recovery_requests = min(args.recovery_requests, 6)

    cfg = get_arch(args.arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    workload = make_workload(rng, args.requests, cfg.vocab)
    n_long = sum(1 for _, b in workload if b > 16)
    print(f"workload: {len(workload)} requests ({n_long} long-tail), "
          f"{args.slots} slots")

    scfg = ServeConfig(max_batch=args.slots, max_new_tokens=64,
                       max_len=args.max_len)
    # prefill_chunk pinned to 0 (one-shot): the engine default flipped to
    # chunked, but this bench's tracked engine-vs-wave and fused-vs-
    # materialized numbers are decode-path comparisons whose prefill
    # treatment must stay fixed across PRs — the soak below measures the
    # chunked-vs-oneshot delta explicitly
    ecfg = EngineConfig(n_slots=args.slots, max_len=args.max_len,
                        prefill_bucket=16, prefill_chunk=0)

    # fused_attn defaults ON now — the materialized read is the explicit
    # oracle config, the fused one is the engine default
    ecfg8 = EngineConfig(**{**ecfg.__dict__, "kv_mode": "int8",
                            "fused_attn": False})
    ecfg8f = EngineConfig(**{**ecfg8.__dict__, "fused_attn": True})

    # warm the (process-shared) jit caches on a throwaway pass so wall
    # times compare steady state, not compilation. One representative per
    # PREFILL BUCKET shape (the engine's own bucketing): a warmup that
    # misses a bucket leaves an XLA compile inside somebody's measured
    # wall time.
    from repro.engine.engine import bucket_len
    reps = {}
    for p, b in workload:
        reps.setdefault(bucket_len(len(p), ecfg.prefill_bucket,
                                   args.max_len), (p, 8))
    warm = list(reps.values())
    srv = Server(cfg, params, scfg)
    run_wave(srv, warm)
    for w in (ecfg, ecfg8, ecfg8f):
        run_engine(cfg, params, warm, w)

    wave_out, wave = run_wave(srv, workload, args.repeats)
    eng_out, eng = run_engine(cfg, params, workload, ecfg, args.repeats)
    eng8_out, eng8 = run_engine(cfg, params, workload, ecfg8, args.repeats)
    eng8f_out, eng8f = run_engine(cfg, params, workload, ecfg8f,
                                  args.repeats)

    # greedy-token agreement checks (shared helper: repro.obs.summary)
    agreement = token_agreement
    agree_engine_wave = agreement(eng_out, wave_out)
    agree_int8_fp = agreement(eng8_out, eng_out)
    agree_fused = agreement(eng8f_out, eng8_out)

    # ---- tracing: disabled-mode overhead vs run-to-run noise, and the
    # traced phase attribution. Tracing defaults OFF and must cost only a
    # branch — quantified by re-running the default-config (untraced)
    # measurement and comparing the delta (= the box's noise floor)
    # against the already-measured eng8f run of the same config.
    _, eng8f_rerun = run_engine(cfg, params, workload, ecfg8f,
                                args.repeats)
    a, b = eng8f["tokens_per_s"], eng8f_rerun["tokens_per_s"]
    noise_frac = abs(a - b) / max(a, b)
    traced_cfg = EngineConfig(**{**ecfg8f.__dict__, "trace": True})
    run_engine(cfg, params, warm, traced_cfg)           # warm traced path
    _, traced = run_engine(cfg, params, workload, traced_cfg,
                           args.repeats)
    pa = traced["phase_attribution"]
    trace = {
        "untraced_tokens_per_s": a,
        "untraced_rerun_tokens_per_s": b,
        "noise_frac": noise_frac,
        "traced_tokens_per_s": traced["tokens_per_s"],
        # enabled-mode cost (sync points + record pushes) — a PROFILING
        # mode number, reported, not asserted
        "traced_overhead_frac": 1.0 - traced["tokens_per_s"] / max(a, b),
        "coverage": pa["coverage"],
        "dispatch_frac": pa["dispatch_frac"],
        "device_wait_frac": pa["device_wait_frac"],
        "phase_attribution": pa,
    }
    # disabled-mode overhead must be noise: the two untraced runs are
    # the same binary + config, so any systematic gap IS measurement
    # noise — a generous 1.5x bound catches only real regressions
    # (e.g. instrumentation accidentally on the hot path) without
    # flaking on a busy CI box
    assert max(a, b) / min(a, b) < 1.5, \
        f"untraced serve throughput unstable: {a:.1f} vs {b:.1f} tok/s"
    assert pa["coverage"] is None or pa["coverage"] >= 0.9, \
        f"phase coverage {pa['coverage']} < 0.9 of step wall"

    # ---- mixed prefill+decode soak: one-shot stall baseline vs chunked
    soak = None
    if args.soak_requests:
        soak_wl = make_soak_workload(rng, args.soak_requests, cfg.vocab)
        for pc in (0, args.soak_prefill_chunk):     # warm all jit buckets
            run_soak(cfg, params, soak_wl, args.max_len, args.slots, pc)
        # INTERLEAVED best-of-N: the tracked chunked-vs-oneshot ratios
        # compare the two configs, so back-to-back repeat pairs keep a
        # noisy box from loading one side's repeats into a bad regime
        stall_out = stall = chunk_out = chunk = None
        for _ in range(args.repeats):
            so, sm = run_soak(cfg, params, soak_wl, args.max_len,
                              args.slots, 0)
            co, cm = run_soak(cfg, params, soak_wl, args.max_len,
                              args.slots, args.soak_prefill_chunk)
            if stall is None or sm["tokens_per_s"] > stall["tokens_per_s"]:
                stall_out, stall = so, sm
            if chunk is None or cm["tokens_per_s"] > chunk["tokens_per_s"]:
                chunk_out, chunk = co, cm
        pick = ("tokens_per_s", "ttft_p50_s", "ttft_p95_s",
                "decode_step_p95_s", "step_p95_s",
                "step_with_prefill_p95_s", "steps_with_prefill",
                "prefill_chunks", "wall_s")
        soak = {
            "requests": len(soak_wl),
            "prefill_chunk": args.soak_prefill_chunk,
            "oneshot": {k: stall[k] for k in pick},
            "chunked": {k: chunk[k] for k in pick},
            "speedup_chunked_vs_oneshot_tokens_per_s":
                chunk["tokens_per_s"] / stall["tokens_per_s"],
            # THE stall metric: p95 full-step latency among steps that did
            # prefill work — one-shot pays a whole prompt there, chunked
            # pays at most the chunk budget. None when a (smoke-sized)
            # run never overlapped prefill with live decoders.
            "step_with_prefill_p95_improvement":
                stall["step_with_prefill_p95_s"]
                / chunk["step_with_prefill_p95_s"]
                if stall["step_with_prefill_p95_s"] is not None
                and chunk["step_with_prefill_p95_s"] is not None else None,
            "greedy_agreement_chunked_vs_oneshot":
                agreement(chunk_out, stall_out),
        }

    # ---- metrics registry overhead: the registry is ALWAYS ON (unlike
    # the tracer, which is a profiling mode), so its hot-path cost must
    # be indistinguishable from run-to-run noise. Same config twice —
    # metrics on (the eng8f run above, registry default-enabled) vs
    # EngineConfig(metrics=False) — and the gap is asserted under
    # max(1%, the noise floor measured between the two untraced runs).
    ecfg8f_off = EngineConfig(**{**ecfg8f.__dict__, "metrics": False})
    run_engine(cfg, params, warm, ecfg8f_off)        # same jit cache, but
    # INTERLEAVED best-of-N pairs (min 3): the on/off delta is ~0.1% by
    # microbenchmark (tests/test_metrics.py), far under the box's noise,
    # so the two sides must sample the same machine regime — reusing the
    # earlier eng8f wall from a different moment of the run measures the
    # box, not the registry
    m_on = m_off = None
    for _ in range(max(args.repeats, 3)):
        _, mo = run_engine(cfg, params, workload, ecfg8f)
        _, mf = run_engine(cfg, params, workload, ecfg8f_off)
        if m_on is None or mo["tokens_per_s"] > m_on["tokens_per_s"]:
            m_on = mo
        if m_off is None or mf["tokens_per_s"] > m_off["tokens_per_s"]:
            m_off = mf
    on_tps, off_tps = m_on["tokens_per_s"], m_off["tokens_per_s"]
    mx_overhead_frac = 1.0 - on_tps / off_tps
    # bound = max(1%, 3 × measured noise): noise_frac comes from a SINGLE
    # pair of identical runs, which understates tail noise — the same
    # 3σ-style widening check_regression.py applies to its relative
    # gates (a 1.6% "overhead" reading on a 1.5%-noisy box is the box,
    # not the registry; the ~0.1% true registry cost is microbenchmarked
    # in tests/test_metrics.py)
    mx_bound = max(0.01, 3.0 * noise_frac)
    metrics_overhead = {
        "metrics_on_tokens_per_s": on_tps,
        "metrics_off_tokens_per_s": off_tps,
        "overhead_frac": mx_overhead_frac,
        "bound_frac": mx_bound,
    }
    assert mx_overhead_frac <= mx_bound, (
        f"always-on metrics registry costs {mx_overhead_frac:.2%} of "
        f"decode throughput ({on_tps:.1f} vs {off_tps:.1f} tok/s) — above "
        f"both the 1% budget and 3x the {noise_frac:.2%} noise floor; "
        f"something landed on the hot path outside the `if mx:` guards")

    # ---- flight recorder overhead: like the registry, the flight ring
    # (obs/flight.py) is ALWAYS ON — one per-step record dict + deque
    # append — so it gets the identical interleaved on/off treatment and
    # the identical <= max(1%, 3x noise) bound. The on side is the
    # default config (flight enabled); off is EngineConfig(flight=False).
    ecfg8f_fr_off = EngineConfig(**{**ecfg8f.__dict__, "flight": False})
    run_engine(cfg, params, warm, ecfg8f_fr_off)
    f_on = f_off = None
    for _ in range(max(args.repeats, 3)):
        _, fo = run_engine(cfg, params, workload, ecfg8f)
        _, ff = run_engine(cfg, params, workload, ecfg8f_fr_off)
        if f_on is None or fo["tokens_per_s"] > f_on["tokens_per_s"]:
            f_on = fo
        if f_off is None or ff["tokens_per_s"] > f_off["tokens_per_s"]:
            f_off = ff
    fr_on_tps, fr_off_tps = f_on["tokens_per_s"], f_off["tokens_per_s"]
    fr_overhead_frac = 1.0 - fr_on_tps / fr_off_tps
    fr_bound = max(0.01, 3.0 * noise_frac)
    flight_recorder = {
        "flight_on_tokens_per_s": fr_on_tps,
        "flight_off_tokens_per_s": fr_off_tps,
        "overhead_frac": fr_overhead_frac,
        "bound_frac": fr_bound,
    }
    assert fr_overhead_frac <= fr_bound, (
        f"always-on flight recorder costs {fr_overhead_frac:.2%} of "
        f"decode throughput ({fr_on_tps:.1f} vs {fr_off_tps:.1f} tok/s) "
        f"— above both the 1% budget and 3x the {noise_frac:.2%} noise "
        f"floor; the per-step record grew beyond one dict + ring append")

    # ---- open-loop SLO sweep: offered load is the independent variable;
    # each point replays a seeded Poisson+burst schedule against the
    # default serving config and judges every request against its class
    # SLO (loadgen.CLASSES). The sweep must contain a measured saturation
    # knee — the 'inf' endpoint (everything at t=0) guarantees one.
    open_loop = None
    if args.open_loop_requests:
        rates = [float(r) for r in args.open_loop_rates.split(",")]
        ol_ecfg = EngineConfig(n_slots=args.slots, max_len=args.max_len,
                               kv_mode="int8", prefill_bucket=16)
        # the 'inf' endpoint gets a 2x-deep queue: it exists to measure
        # saturation, and a fast box can drain n requests before the
        # FCFS tail blows its TTFT SLO — doubling the backlog keeps the
        # closed-loop limit saturating on any box, so the sweep always
        # contains its knee
        schedules = {r: make_open_loop_workload(
            args.open_loop_seed,
            args.open_loop_requests * (1 if np.isfinite(r) else 2),
            cfg.vocab, r)
            for r in rates}
        # warm every prefill bucket the sweep's prompts will hit (class
        # draws differ per rate — the arrival process consumes a
        # rate-dependent number of rng draws — so take the union)
        ol_reps = {}
        for sched in schedules.values():
            for arr in sched:
                ol_reps.setdefault(
                    bucket_len(len(arr.prompt), ol_ecfg.prefill_bucket,
                               args.max_len), (arr.prompt, 8))
        run_engine(cfg, params, list(ol_reps.values()), ol_ecfg)
        points = []
        olms = {}
        for r in rates:
            slo, olm = run_open_loop(cfg, params, schedules[r], ol_ecfg)
            olms[r] = (slo, olm)
            pt = {
                "rate_rps": r,
                # mean effective arrival rate of the MMPP-2 (bursts at
                # 4x the base rate for 25% of wall time)
                "offered_rps": r * (1 + (4.0 - 1) * 0.25),
                "queue_depth_at_submit_p95":
                    olm["queue_depth_at_submit_p95"],
                "admit_latency_p95_s": olm["admit_latency_p95_s"],
                **slo,
            }
            points.append(pt)
            att = pt["slo_attainment"]
            print(f"open-loop rate {r:>5g} rps: attainment "
                  f"{'n/a' if att is None else f'{att:.0%}'}, goodput "
                  f"{pt['goodput_tokens_per_s']:.1f} tok/s, admit p95 "
                  f"{(pt['admit_latency_p95_s'] or 0) * 1e3:.1f} ms")
        knee = find_knee(points, args.slo_threshold)
        inter = [{"offered_rps": p["offered_rps"], "slo_attainment":
                  p["per_class"]["interactive"]["slo_attainment"]}
                 for p in points]
        open_loop = {
            "seed": args.open_loop_seed,
            "requests_per_point": args.open_loop_requests,
            "burst_factor": 4.0,
            "burst_fraction": 0.25,
            "slo_threshold": args.slo_threshold,
            "classes": CLASSES,
            "points": points,
            "knee": knee,
            "knee_interactive": find_knee(inter, args.slo_threshold),
        }

        # ---- overload comparison (DESIGN.md §12): one seeded schedule
        # at a SUSTAINED finite rate well past the knee (4x the last
        # SLO-attaining base rate), run twice — shed OFF (unbounded FCFS
        # queue) vs the full robustness stack ON (bounded queue sized
        # from the freshly measured knee depth, shed-by-class victims,
        # degradation ladder). Sustained matters: the 'inf' burst drains
        # in under the batch class's lenient TTFT SLO, so nothing there
        # is ever doomed and shedding can only discard attaining work —
        # past-knee *steady* load is where the unbounded queue grows
        # without bound and late admissions blow their SLOs while a
        # bounded queue keeps every admitted request inside the
        # measured-OK regime. Shedding converts doomed queueing into
        # goodput, so goodput_on >= goodput_off is the tracked (and
        # gated) invariant.
        finite = [p["rate_rps"] for p in points
                  if np.isfinite(p["rate_rps"])]
        ok = [p["rate_rps"] for p in points
              if np.isfinite(p["rate_rps"]) and (knee or {}).get(
                  "last_ok_offered_rps") == p["offered_rps"]]
        base_rate = ok[0] if ok else (max(finite) if finite else None)
        if base_rate is not None:
            # the knee only brackets saturation between its last finite
            # rate and 'inf', so "4x the knee" may still be under true
            # sustained capacity on a fast box — escalate (doubling,
            # shed-off probe each time) until the unbounded-queue run
            # actually drops below the SLO threshold; that measured-
            # saturating rate is the overload point both sides replay
            over_rate = 4.0 * base_rate
            for _ in range(5):
                over_sched = make_open_loop_workload(
                    args.open_loop_seed, args.open_loop_requests * 2,
                    cfg.vocab, over_rate)
                reps = {}
                for arr in over_sched:  # warm unseen prefill buckets
                    reps.setdefault(
                        bucket_len(len(arr.prompt),
                                   ol_ecfg.prefill_bucket,
                                   args.max_len), (arr.prompt, 8))
                run_engine(cfg, params, list(reps.values()), ol_ecfg)
                slo_off, olm_off = run_open_loop(cfg, params, over_sched,
                                                 ol_ecfg)
                if (slo_off["slo_attainment"] or 0) < args.slo_threshold:
                    break
                over_rate *= 2.0
            set_point = admission_set_point(open_loop) \
                or max(2, 2 * args.slots)
            on_ecfg = EngineConfig(**{
                **ol_ecfg.__dict__, "max_queue": set_point,
                "overload_policy": "shed-by-class", "degrade": True})
            slo_on, olm_on = run_open_loop(cfg, params, over_sched,
                                           on_ecfg)
            g_on = slo_on["goodput_tokens_per_s"] or 0.0
            g_off = slo_off["goodput_tokens_per_s"] or 0.0

            def _side(slo, olm):
                return {"slo_attainment": slo["slo_attainment"],
                        "goodput_tokens_per_s":
                            slo["goodput_tokens_per_s"],
                        "throughput_tokens_per_s":
                            slo["throughput_tokens_per_s"],
                        "retire_reasons": olm["retire_reasons"],
                        "requests_shed": olm.get("requests_shed", 0),
                        "degradation_transitions":
                            olm.get("degradation_transitions", 0)}
            open_loop["overload"] = {
                "requests": len(over_sched),
                "rate_rps": over_rate,
                "offered_rps": over_rate * (1 + (4.0 - 1) * 0.25),
                "max_queue": set_point,
                "overload_policy": "shed-by-class",
                "degrade": True,
                "shed_off": _side(slo_off, olm_off),
                "shed_on": _side(slo_on, olm_on),
                "goodput_ratio_shed_on_vs_off":
                    (g_on / g_off) if g_off > 0 else None,
            }
            ratio = open_loop["overload"]["goodput_ratio_shed_on_vs_off"]
            n_shed = open_loop["overload"]["shed_on"]["requests_shed"]
            print(f"overload ({over_rate:g} rps sustained, max_queue="
                  f"{set_point}): goodput shed-on {g_on:.1f} vs "
                  f"shed-off {g_off:.1f} tok/s (ratio "
                  f"{'n/a' if ratio is None else f'{ratio:.2f}x'}), "
                  f"shed {n_shed} requests")

    # ---- crash/recovery (DESIGN.md §13): seeded crash schedule, journal
    # + snapshot recovery, restore latency, token identity vs uncrashed.
    # Not gated by check_regression (recovery latency on a shared box is
    # noisy); the token_identical_vs_uncrashed bool is the number that
    # matters and IS asserted here.
    recovery = None
    if args.recovery_requests:
        recovery = run_recovery_bench(cfg, params, cfg.vocab,
                                      args.recovery_requests,
                                      args.recovery_seed, args.slots,
                                      args.max_len)
        assert recovery["token_identical_vs_uncrashed"], (
            f"crash/recovery bench diverged from the uncrashed "
            f"reference: {recovery}")

    def slim(m):
        # registry snapshots are live-export payloads, not tracked bench
        # numbers — keep BENCH_serve.json diffable across PRs
        return {k: v for k, v in m.items() if k != "registry"}

    result = {
        "provenance": provenance(seed=7),
        "arch": cfg.name,
        "requests": len(workload),
        "slots": args.slots,
        "max_len": args.max_len,
        "wave": wave,
        "engine": slim(eng),
        "engine_int8_kv": slim(eng8),
        "engine_int8_kv_fused": slim(eng8f),
        "speedup_tokens_per_s": eng["tokens_per_s"] / wave["tokens_per_s"],
        "speedup_fused_vs_materialized_int8":
            eng8f["tokens_per_s"] / eng8["tokens_per_s"],
        "greedy_agreement_engine_vs_wave": agree_engine_wave,
        "greedy_agreement_int8kv_vs_fp": agree_int8_fp,
        "greedy_agreement_fused_vs_materialized": agree_fused,
        "trace": trace,
        "metrics_overhead": metrics_overhead,
        "flight_recorder": flight_recorder,
        "soak": soak,
        "open_loop": open_loop,
        "recovery": recovery,
    }

    def steps(m):
        if m.get("decode_step_p50_s") is None:
            return ""
        return (f", step p50 {m['decode_step_p50_s']*1e3:.2f} ms "
                f"p95 {m['decode_step_p95_s']*1e3:.2f} ms")

    print(f"wave    : {wave['tokens_per_s']:8.1f} tok/s "
          f"({wave['total_tokens']} tokens, {wave['wall_s']:.2f}s)")
    print(f"engine  : {eng['tokens_per_s']:8.1f} tok/s "
          f"({eng['total_tokens']} tokens, {eng['wall_s']:.2f}s, "
          f"util {eng['slot_utilization']:.0%}{steps(eng)})")
    print(f"engine8 : {eng8['tokens_per_s']:8.1f} tok/s "
          f"(INT8 KV materialized, {eng8['kv_bytes_per_token']:.0f} "
          f"B/token/layer vs {eng['kv_bytes_per_token']:.0f}{steps(eng8)})")
    print(f"engine8f: {eng8f['tokens_per_s']:8.1f} tok/s "
          f"(INT8 KV fused read{steps(eng8f)})")
    print(f"speedup : engine/wave {result['speedup_tokens_per_s']:.2f}x, "
          f"fused/materialized "
          f"{result['speedup_fused_vs_materialized_int8']:.2f}x")
    print(f"greedy agreement: engine=wave {agree_engine_wave:.1%}, "
          f"int8=fp {agree_int8_fp:.1%}, fused=materialized "
          f"{agree_fused:.1%}")
    print(f"trace   : untraced {a:.1f}/{b:.1f} tok/s "
          f"(noise {noise_frac:.1%}), traced "
          f"{trace['traced_tokens_per_s']:.1f} tok/s (overhead "
          f"{trace['traced_overhead_frac']:.1%}), coverage "
          f"{pa['coverage']:.1%}, dispatch {pa['dispatch_frac']:.0%} / "
          f"wait {pa['device_wait_frac']:.0%}")
    if soak:
        s1, s2 = soak["oneshot"], soak["chunked"]

        def ms(x):
            return f"{x*1e3:.1f} ms" if x is not None else "n/a"
        print(f"soak oneshot: {s1['tokens_per_s']:8.1f} tok/s, ttft p50 "
              f"{ms(s1['ttft_p50_s'])} p95 {ms(s1['ttft_p95_s'])}, "
              f"step-with-prefill p95 {ms(s1['step_with_prefill_p95_s'])}")
        print(f"soak chunked: {s2['tokens_per_s']:8.1f} tok/s, ttft p50 "
              f"{ms(s2['ttft_p50_s'])} p95 {ms(s2['ttft_p95_s'])}, "
              f"step-with-prefill p95 {ms(s2['step_with_prefill_p95_s'])} "
              f"(chunk {soak['prefill_chunk']})")
        imp = soak["step_with_prefill_p95_improvement"]
        print(f"soak: step-with-prefill p95 "
              f"{'n/a' if imp is None else f'{imp:.2f}x'} better "
              f"chunked, tokens/s "
              f"{soak['speedup_chunked_vs_oneshot_tokens_per_s']:.2f}x, "
              f"greedy agreement "
              f"{soak['greedy_agreement_chunked_vs_oneshot']:.1%}")
    print(f"metrics : on {on_tps:.1f} / off {off_tps:.1f} tok/s "
          f"(overhead {mx_overhead_frac:.2%} <= bound "
          f"{metrics_overhead['bound_frac']:.2%})")
    print(f"flight  : on {fr_on_tps:.1f} / off {fr_off_tps:.1f} tok/s "
          f"(overhead {fr_overhead_frac:.2%} <= bound "
          f"{flight_recorder['bound_frac']:.2%})")
    if open_loop:
        k = open_loop["knee"]
        if k is None:
            print(f"open-loop: no saturation knee found (attainment "
                  f"never dropped below {args.slo_threshold:.0%} — "
                  f"raise the sweep's top rate)")
        else:
            lo = k["last_ok_offered_rps"]
            print(f"open-loop knee: attainment holds >= "
                  f"{k['threshold']:.0%} up to "
                  f"{'n/a' if lo is None else f'{lo:g} rps'} offered, "
                  f"saturates at {k['first_saturated_offered_rps']:g} rps "
                  f"({k['first_saturated_attainment']:.0%})")
    if recovery:
        print(f"recovery: crashed at step {recovery['crashed_at_step']} "
              f"(t={recovery['crash_t_s']*1e3:.0f} ms), restore "
              f"{recovery['restore_duration_s']*1e3:.1f} ms, "
              f"{recovery['n_restored_from_snapshot']} restored / "
              f"{recovery['n_requeued_from_journal']} re-enqueued / "
              f"{recovery['n_retired_pre_crash']} pre-crash retires, "
              f"token-identical "
              f"{recovery['token_identical_vs_uncrashed']}")
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, default=str)
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
