"""Serving throughput: continuous-batching engine vs wave-synchronous
server on a mixed-length request workload.

The workload is adversarial for wave batching: most requests want a few
tokens, a minority want many. In a wave, every batch slot is held until
the wave's longest member finishes; the engine retires and refills slots
per step, so the long tail no longer stalls short requests.

    PYTHONPATH=src python benchmarks/serve_bench.py --requests 24

Emits BENCH_serve.json next to this file (tokens/s, TTFT, speedup, and
the INT8-KV vs fp token agreement) so the perf trajectory accumulates.
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.engine import Engine, EngineConfig  # noqa: E402
from repro.models import get_model  # noqa: E402
from repro.runtime.serve_loop import Request, ServeConfig, Server  # noqa: E402


def make_workload(rng, n_requests, vocab, long_every=6,
                  short_tokens=8, long_tokens=64):
    """Mixed lengths: mostly short prompts/generations, every `long_every`-th
    request is a long one (the wave-stalling tail)."""
    reqs = []
    for i in range(n_requests):
        is_long = (i % long_every) == long_every - 1
        plen = int(rng.integers(24, 48)) if is_long else int(rng.integers(4, 12))
        budget = long_tokens if is_long else short_tokens
        reqs.append((rng.integers(0, vocab, size=plen), budget))
    return reqs


def run_wave(cfg, params, workload, scfg):
    srv = Server(cfg, params, scfg)
    reqs = [Request(i, p.copy(), max_new_tokens=b)
            for i, (p, b) in enumerate(workload)]
    t0 = time.perf_counter()
    out = srv.serve(reqs)
    wall = time.perf_counter() - t0
    total = sum(len(r.out) for r in out)
    return out, {"wall_s": wall, "total_tokens": total,
                 "tokens_per_s": total / wall}


def run_engine(cfg, params, workload, ecfg):
    eng = Engine(cfg, params, ecfg)
    for p, b in workload:
        eng.submit(p, max_new_tokens=b)
    t0 = time.perf_counter()
    fin = eng.drain()
    wall = time.perf_counter() - t0
    m = eng.metrics()
    m["wall_s"] = wall
    m["tokens_per_s"] = m["total_tokens"] / wall
    return fin, m


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serve.json"))
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    workload = make_workload(rng, args.requests, cfg.vocab)
    n_long = sum(1 for _, b in workload if b > 8)
    print(f"workload: {len(workload)} requests ({n_long} long-tail), "
          f"{args.slots} slots")

    scfg = ServeConfig(max_batch=args.slots, max_new_tokens=64,
                       max_len=args.max_len)
    ecfg = EngineConfig(n_slots=args.slots, max_len=args.max_len,
                        prefill_bucket=16)

    # warm both jit caches on a throwaway pass so wall times compare steady
    # state, not compilation
    warm = workload[: args.slots]
    run_wave(cfg, params, warm, scfg)
    run_engine(cfg, params, warm, ecfg)
    run_engine(cfg, params, warm,
               EngineConfig(**{**ecfg.__dict__, "kv_mode": "int8"}))

    wave_out, wave = run_wave(cfg, params, workload, scfg)
    eng_out, eng = run_engine(cfg, params, workload, ecfg)
    eng8_out, eng8 = run_engine(
        cfg, params, workload,
        EngineConfig(**{**ecfg.__dict__, "kv_mode": "int8"}))

    # greedy-token agreement checks
    def agreement(a, b):
        per = [np.mean([x == y for x, y in zip(ra.out, rb.out)])
               for ra, rb in zip(a, b)]
        return float(np.mean(per))

    agree_engine_wave = agreement(eng_out, wave_out)
    agree_int8_fp = agreement(eng8_out, eng_out)

    result = {
        "arch": cfg.name,
        "requests": len(workload),
        "slots": args.slots,
        "wave": wave,
        "engine": {k: v for k, v in eng.items()},
        "engine_int8_kv": {k: v for k, v in eng8.items()},
        "speedup_tokens_per_s": eng["tokens_per_s"] / wave["tokens_per_s"],
        "greedy_agreement_engine_vs_wave": agree_engine_wave,
        "greedy_agreement_int8kv_vs_fp": agree_int8_fp,
    }
    print(f"wave    : {wave['tokens_per_s']:8.1f} tok/s "
          f"({wave['total_tokens']} tokens, {wave['wall_s']:.2f}s)")
    print(f"engine  : {eng['tokens_per_s']:8.1f} tok/s "
          f"({eng['total_tokens']} tokens, {eng['wall_s']:.2f}s, "
          f"util {eng['slot_utilization']:.0%})")
    print(f"engine8 : {eng8['tokens_per_s']:8.1f} tok/s "
          f"(INT8 KV, {eng8['kv_bytes_per_token']:.0f} B/token/layer vs "
          f"{eng['kv_bytes_per_token']:.0f})")
    print(f"speedup : {result['speedup_tokens_per_s']:.2f}x   "
          f"greedy agreement engine=wave {agree_engine_wave:.1%}, "
          f"int8=fp {agree_int8_fp:.1%}")
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, default=str)
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
