"""Seeded open-loop load generator: Poisson + burst arrivals with
request classes and SLO definitions.

Closed-loop benches (submit everything, drain) self-throttle: the
submission rate automatically matches the engine's service rate, so
queueing collapse is invisible — the engine always looks "keeping up"
because the bench waits for it. An OPEN-loop process submits on a
schedule that does not care how the engine is doing; offered load is an
independent variable, and the latency-vs-load curve shows exactly where
queueing delay departs from the service floor (the saturation knee).

The arrival process is a two-state Markov-modulated Poisson process
(MMPP-2): a base Poisson rate, punctuated by burst episodes at
``burst_factor`` × that rate, with exponentially distributed episode
durations. Bursts are what kill SLOs in production — a plain Poisson
stream at the same mean rate hides the transient queue spikes admission
control has to survive. Poisson memorylessness makes the state-boundary
handling exact: crossing an episode boundary just redraws the next gap
at the new rate.

Everything derives from ONE ``numpy.random.default_rng(seed)``: same
seed ⇒ byte-identical arrival times, class draws, prompts, and budgets
(asserted in tests/test_metrics.py) — so a BENCH open-loop section is
reproducible and two engine configs can be compared on the *same*
arrival sequence.

SLO model (per request class): TTFT ≤ ``ttft_slo_s`` AND mean
time-per-output-token after the first ≤ ``tpot_slo_s``. A request
*attains* its SLO when both hold; **goodput** is tokens/s counted over
SLO-attaining requests only (throughput that arrives too late to matter
is not good). The per-class attainment-vs-offered-load curve and its
knee land in BENCH_serve.json (serve_bench --open-loop).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

#: Request-class mix: mostly latency-sensitive interactive traffic with
#: a minority of long batch jobs (the wave-stalling tail, now with a
#: looser SLO instead of no SLO). ``weight`` is the class draw
#: probability; prompt/new_tokens are inclusive-exclusive rng ranges.
CLASSES: dict[str, dict] = {
    "interactive": {"weight": 0.8, "prompt": (4, 12),
                    "new_tokens": (8, 24),
                    "ttft_slo_s": 0.30, "tpot_slo_s": 0.020},
    "batch": {"weight": 0.2, "prompt": (24, 64),
              "new_tokens": (32, 64),
              "ttft_slo_s": 2.00, "tpot_slo_s": 0.050},
}


@dataclasses.dataclass
class Arrival:
    """One scheduled request: arrival time (s since schedule start),
    class name, prompt token ids, and generation budget — plus the
    optional fault-tolerance fields (DESIGN.md §12): a scheduled
    client-side cancellation time and per-request deadlines the engine
    enforces at step boundaries. All None by default so schedules
    generated without the robustness options stay byte-identical to
    pre-§12 ones."""

    t: float
    cls: str
    prompt: np.ndarray
    max_new_tokens: int
    #: absolute schedule time (same axis as ``t``) at which the client
    #: cancels this request; None = never
    cancel_t: Optional[float] = None
    #: wall-clock deadlines relative to submission (Engine.submit
    #: kwargs); None = no deadline
    ttft_deadline_s: Optional[float] = None
    deadline_s: Optional[float] = None
    #: absolute schedule time at which the serving PROCESS is scheduled
    #: to crash (DESIGN.md §13) — process-level, unlike the per-request
    #: fields above: the harness arms the engine's crash injector at the
    #: first step boundary past this time. None = no crash scheduled
    crash_t: Optional[float] = None


def poisson_burst_times(rng: np.random.Generator, n: int, rate: float,
                        burst_factor: float = 4.0,
                        burst_fraction: float = 0.25,
                        mean_burst_s: float = 0.5) -> np.ndarray:
    """n arrival times of an MMPP-2: Poisson at ``rate`` in the normal
    state, ``rate * burst_factor`` inside bursts; episode lengths are
    exponential with mean ``mean_burst_s`` (burst) and the normal-state
    mean chosen so ``burst_fraction`` of wall time is bursty. The mean
    offered rate is therefore rate * (1 + (burst_factor-1) *
    burst_fraction). ``rate=inf`` degenerates to all-at-t=0 (the
    closed-loop limit, useful as the sweep's saturating endpoint)."""
    if not np.isfinite(rate):
        return np.zeros(n, np.float64)
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    mean_normal_s = mean_burst_s * (1.0 - burst_fraction) \
        / max(burst_fraction, 1e-9)
    times = np.empty(n, np.float64)
    t = 0.0
    bursty = False
    # time remaining in the current episode; exponential draws keep the
    # whole schedule a pure function of the rng stream
    left = rng.exponential(mean_normal_s)
    for i in range(n):
        while True:
            r = rate * (burst_factor if bursty else 1.0)
            gap = rng.exponential(1.0 / r)
            if gap < left:                   # arrival inside the episode
                t += gap
                left -= gap
                times[i] = t
                break
            # crossed an episode boundary: advance to it, flip state,
            # redraw (exact — exponential gaps are memoryless)
            t += left
            bursty = not bursty
            left = rng.exponential(mean_burst_s if bursty
                                   else mean_normal_s)
    return times


def make_open_loop_workload(seed: int, n: int, vocab: int, rate: float,
                            classes: Optional[dict] = None,
                            burst_factor: float = 4.0,
                            burst_fraction: float = 0.25,
                            cancel_rate: float = 0.0,
                            cancel_after_s: tuple = (0.05, 0.5),
                            deadlines: bool = False,
                            deadline_factor: float = 8.0,
                            crash_rate: float = 0.0,
                            crash_after_s: tuple = (0.02, 0.3)) \
        -> list[Arrival]:
    """The full deterministic schedule: arrival times + class draws +
    prompts + budgets from one seeded rng. Same (seed, n, vocab, rate,
    …) ⇒ identical schedule, byte for byte.

    Robustness options (DESIGN.md §12), both default-off so the base
    schedule is unchanged byte for byte (the extra rng draws happen
    AFTER the base draws, so enabling them never perturbs arrival
    times, prompts, or budgets of the same seed):

    * ``cancel_rate`` — each request independently gets a scheduled
      client cancellation with this probability, at a uniform delay in
      ``cancel_after_s`` after its arrival (disconnects cluster shortly
      after submit: the user gave up waiting).
    * ``deadlines`` — stamp per-request TTFT/total deadlines derived
      from the class SLOs: ``ttft_deadline_s = ttft_slo_s ×
      deadline_factor`` and ``deadline_s`` adds the budgeted decode
      time at the TPOT SLO, also × factor. Deterministic (no rng) —
      deadline enforcement changes which requests FINISH, and seeding
      that through the schedule would conflate policy with workload.
    * ``crash_rate`` — each request independently marks a scheduled
      PROCESS crash with this probability, at a uniform delay in
      ``crash_after_s`` after its arrival (DESIGN.md §13). Drawn after
      the cancel draws, so every lower-numbered option's stream — and
      the base schedule — stays byte-identical whether crashes are
      scheduled or not."""
    classes = classes or CLASSES
    rng = np.random.default_rng(seed)
    times = poisson_burst_times(rng, n, rate, burst_factor,
                                burst_fraction)
    names = list(classes)
    weights = np.asarray([classes[c]["weight"] for c in names],
                         np.float64)
    weights = weights / weights.sum()
    draws = rng.choice(len(names), size=n, p=weights)
    out = []
    for i in range(n):
        spec = classes[names[draws[i]]]
        plen = int(rng.integers(*spec["prompt"]))
        budget = int(rng.integers(*spec["new_tokens"]))
        out.append(Arrival(t=float(times[i]), cls=names[draws[i]],
                           prompt=rng.integers(0, vocab, size=plen,
                                               dtype=np.int64),
                           max_new_tokens=budget))
    if cancel_rate > 0:
        # drawn after (and only after) the base schedule: same-seed
        # byte-identity of the base fields is preserved for any
        # cancel_rate, including comparing cancel-on vs cancel-off runs
        # on the same arrivals
        hit = rng.uniform(size=n) < cancel_rate
        delay = rng.uniform(cancel_after_s[0], cancel_after_s[1], size=n)
        for i, a in enumerate(out):
            if hit[i]:
                a.cancel_t = a.t + float(delay[i])
    if crash_rate > 0:
        # drawn after the cancel draws (which are after the base
        # schedule): appending keeps every earlier field byte-identical
        # for the same seed regardless of crash_rate — a crash/recovery
        # run and its uncrashed reference share one arrival sequence
        hit = rng.uniform(size=n) < crash_rate
        delay = rng.uniform(crash_after_s[0], crash_after_s[1], size=n)
        for i, a in enumerate(out):
            if hit[i]:
                a.crash_t = a.t + float(delay[i])
    if deadlines:
        for a in out:
            spec = classes[a.cls]
            a.ttft_deadline_s = spec["ttft_slo_s"] * deadline_factor
            a.deadline_s = (spec["ttft_slo_s"] + a.max_new_tokens
                            * spec["tpot_slo_s"]) * deadline_factor
    return out


def request_slo(arr: Arrival, req, classes: Optional[dict] = None) \
        -> dict:
    """Judge one finished engine request against its class SLO. ``req``
    needs ``.ttft`` / ``.t_first_token`` / ``.t_done`` / ``.out`` (the
    engine's EngineRequest surface)."""
    spec = (classes or CLASSES)[arr.cls]
    ttft = req.ttft
    n_out = len(req.out)
    tpot = None
    if req.t_first_token is not None and req.t_done is not None \
            and n_out > 1:
        tpot = (req.t_done - req.t_first_token) / (n_out - 1)
    ttft_ok = ttft is not None and ttft <= spec["ttft_slo_s"]
    # single-token requests have no decode cadence to judge
    tpot_ok = tpot is None or tpot <= spec["tpot_slo_s"]
    return {"cls": arr.cls, "ttft_s": ttft, "tpot_s": tpot,
            "tokens": n_out, "attained": bool(ttft_ok and tpot_ok)}


def slo_summary(judged: list[dict], wall_s: float,
                classes: Optional[dict] = None) -> dict:
    """Aggregate per-class SLO attainment + goodput from `request_slo`
    rows. Percentile math via obs.summary (None-on-empty preserved)."""
    from repro.obs.summary import mean, pct
    classes = classes or CLASSES
    out: dict = {"per_class": {}}
    for cls in classes:
        rows = [j for j in judged if j["cls"] == cls]
        ttfts = [j["ttft_s"] for j in rows if j["ttft_s"] is not None]
        tpots = [j["tpot_s"] for j in rows if j["tpot_s"] is not None]
        att = [j["attained"] for j in rows]
        good = sum(j["tokens"] for j in rows if j["attained"])
        out["per_class"][cls] = {
            "requests": len(rows),
            "ttft_slo_s": classes[cls]["ttft_slo_s"],
            "tpot_slo_s": classes[cls]["tpot_slo_s"],
            "ttft_p50_s": pct(ttfts, 50),
            "ttft_p95_s": pct(ttfts, 95),
            "tpot_p95_s": pct(tpots, 95),
            "slo_attainment": mean(att),
            "goodput_tokens": good,
            "goodput_tokens_per_s": good / wall_s if wall_s > 0 else None,
        }
    total_tokens = sum(j["tokens"] for j in judged)
    good_tokens = sum(j["tokens"] for j in judged if j["attained"])
    out["requests"] = len(judged)
    out["slo_attainment"] = mean([j["attained"] for j in judged])
    out["total_tokens"] = total_tokens
    out["goodput_tokens_per_s"] = good_tokens / wall_s if wall_s > 0 \
        else None
    out["throughput_tokens_per_s"] = total_tokens / wall_s \
        if wall_s > 0 else None
    return out


def find_knee(points: list[dict], threshold: float = 0.9,
              key: str = "slo_attainment") -> Optional[dict]:
    """Locate the saturation knee in an offered-load sweep: the first
    point (ascending offered load) whose ``key`` drops below
    ``threshold``, paired with the last point still above it. None when
    the engine never saturates (raise the sweep's top rate)."""
    pts = sorted(points, key=lambda p: p["offered_rps"])
    below = next((p for p in pts
                  if p[key] is not None and p[key] < threshold), None)
    if below is None:
        return None
    above = [p for p in pts if p["offered_rps"] < below["offered_rps"]
             and p[key] is not None and p[key] >= threshold]
    return {
        "threshold": threshold,
        "last_ok_offered_rps": above[-1]["offered_rps"] if above else None,
        "last_ok_attainment": above[-1][key] if above else None,
        "first_saturated_offered_rps": below["offered_rps"],
        "first_saturated_attainment": below[key],
    }
