"""Benchmark runner — one entry per paper table/figure + framework metrics.
Prints ``name,us_per_call,derived`` CSV rows.

  table1        paper Table 1 (BERT-Tiny accuracy grid) — reduced epochs
                here for CI speed; examples/reproduce_bert_tiny.py runs the
                full version.
  range_stats   paper §4 mechanism: per-cluster scale-factor gains
  kernel        fused dequant-matmul micro (µs + deployed bytes)
  quantize_cost preprocessing cost of SplitQuant itself (paper: one-off)
  roofline      summary fractions from the dry-run artifacts (if present)
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


# Shared provenance header for every BENCH_*.json artifact — the
# definition moved in-package (repro.obs.provenance) so serving code and
# metrics snapshots embed the same header; re-exported here because the
# benches import it as `from run import provenance`.
from repro.obs.provenance import provenance  # noqa: E402, F401


def bench_table1():
    from table1 import run_table1
    t0 = time.perf_counter()
    res = run_table1(epochs=2, n_samples=1500, verbose=False)
    dt = (time.perf_counter() - t0) * 1e6
    for ds, row in res.items():
        gap2 = row["int2_splitquant"] - row["int2_baseline"]
        gap8 = row["int8_splitquant"] - row["int8_baseline"]
        print(f"table1_{ds},{dt/2:.0f},"
              f"fp32={row['fp32']:.3f};int2_gain={gap2:+.3f};"
              f"int8_gain={gap8:+.3f}")
        assert gap2 > gap8 - 1e-3, "INT2 gain should dominate INT8 gain"


def bench_range_stats():
    from range_stats import run
    t0 = time.perf_counter()
    _, med = run(verbose=False)
    dt = (time.perf_counter() - t0) * 1e6
    print(f"range_stats,{dt:.0f},median_scale_gain={med:.1f}x")


def bench_kernel():
    from kernel_bench import run
    rows = run(verbose=False)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def bench_quantize_cost():
    from repro.core import QuantConfig, QuantPolicy, quantize_tree
    from repro.configs import get_arch
    from repro.models import get_model
    cfg = get_arch("stablelm-1.6b").reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    t0 = time.perf_counter()
    qp, rep = quantize_tree(key, params, QuantPolicy(cfg=QuantConfig(bits=2)))
    jax.block_until_ready(jax.tree.leaves(qp)[0])
    dt = (time.perf_counter() - t0) * 1e6
    n = sum(l.size for l in jax.tree.leaves(params))
    print(f"quantize_cost,{dt:.0f},{n/1e6:.1f}M_params;"
          f"{rep['deployed_bytes']/rep['orig_bytes']:.3f}_size_ratio")


def bench_roofline():
    from roofline import load_results, roofline_row
    for tag in ("", "opt"):
        rows = [roofline_row(r) for r in load_results("16x16", tag)]
        ok = [r for r in rows if r and r["status"] == "ok"]
        label = tag or "baseline"
        if not ok:
            print(f"roofline_{label},0,no_dryrun_artifacts")
            continue
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        best = max(ok, key=lambda r: r["roofline_fraction"])
        import statistics
        med = statistics.median(r["roofline_fraction"] for r in ok)
        print(f"roofline_{label}_best,0,{best['arch']}x{best['shape']}="
              f"{best['roofline_fraction']:.4f}")
        print(f"roofline_{label}_median,0,{med:.4f}")


def main() -> None:
    sys.path.insert(0, os.path.dirname(__file__))
    print("name,us_per_call,derived")
    bench_kernel()
    bench_quantize_cost()
    bench_range_stats()
    bench_roofline()
    bench_table1()


if __name__ == "__main__":
    main()
