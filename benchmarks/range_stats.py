"""Range-narrowing microbenchmark (paper §4's mechanism): per quantized
tensor, compare the single-scale range (α-β) against the three per-cluster
ranges, and the resulting scale-factor gain S_c / S_single.

This is the paper's *mechanism* check, independent of end accuracy: the
k-means split should shrink the bulk cluster's range by ≥2× whenever
outliers are present, which is exactly what lifts the quantization
resolution of the 99% of weights in the middle cluster.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import QuantConfig, splitquant_tensor
from repro.models import get_model


def run(arch="stablelm-1.6b", bits=2, plant_outliers=True, verbose=True):
    cfg = get_arch(arch).reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    rows = []
    for path, leaf in flat:
        ks = jax.tree_util.keystr(path)
        if leaf.ndim < 2 or "norm" in ks or "embed" in ks:
            continue
        w = leaf.reshape(-1, leaf.shape[-1]) if leaf.ndim > 2 else leaf
        if plant_outliers:
            w = w.at[0, 0].set(float(jnp.abs(w).max()) * 8)
        sq = splitquant_tensor(key, w, QuantConfig(bits=bits), k=3)
        single_span = float(w.max() - w.min())
        gains = []
        for c in range(3):
            m = np.asarray(sq.cid) == c
            if m.sum() == 0:
                continue
            span_c = float(np.asarray(w)[m].max() - np.asarray(w)[m].min())
            gains.append(single_span / max(span_c, 1e-12))
        rows.append((ks, single_span, gains))
        if verbose:
            g = ", ".join(f"{x:.1f}×" for x in gains)
            print(f"{ks:45s} span {single_span:7.3f}  scale gains [{g}]")
    med = np.median([max(g) for _, _, g in rows if g])
    if verbose:
        print(f"\nmedian best-cluster scale gain: {med:.1f}×")
    return rows, med


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    run()
