"""Noise-aware perf-regression gate over the committed BENCH_*.json
baselines.

    PYTHONPATH=src python benchmarks/check_regression.py            # fresh
    PYTHONPATH=src python benchmarks/check_regression.py --smoke    # self-check

Every PR regenerates BENCH files; this script is the CI tripwire that
turns "the numbers moved" into an exit code. Two modes:

* default: compare fresh BENCH files in ``--fresh-dir`` against the
  committed baselines in ``--baseline-dir`` gate by gate; exit 1 on any
  regression. Relative gates (throughput, speedups, step latency) get a
  noise-aware tolerance: ``max(--tol, 3 × trace.noise_frac)``, where
  ``noise_frac`` is the run-to-run delta serve_bench measures between
  two identical untraced runs — a CI box that is 1.6% noisy gets a
  ~5% gate, not a flaky 1% one. Floor gates (greedy agreement, trace
  coverage) are absolute: correctness metrics have no noise excuse.

* ``--smoke``: self-check for CI — the committed baselines compared
  against THEMSELVES must pass (exit 0 path exercised), and a
  synthetically degraded copy (throughput halved, agreement broken)
  must be flagged (exit 1 path exercised). Runs in milliseconds with no
  model execution, so every CI run proves the gate can actually fire —
  a regression gate that silently stopped failing is worse than none.

Gates live in ``GATES`` below — add one line when a new tracked number
lands in a BENCH file. A gate whose path is missing from the baseline is
skipped (older baselines predate the metric); missing from the FRESH
file is a failure (a tracked metric silently vanished).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import tempfile
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Gate:
    """One tracked number: ``kind`` is "higher" (regression when fresh
    drops below baseline by more than the tolerance), "lower" (latency:
    regression when fresh rises above), or "floor" (absolute: regression
    when fresh < ``floor`` regardless of the baseline)."""

    file: str
    path: str                  # dot-separated into the JSON tree
    kind: str                  # "higher" | "lower" | "floor"
    floor: Optional[float] = None


GATES = [
    # serving: the headline engine-vs-wave and fused-read numbers
    Gate("BENCH_serve.json", "speedup_tokens_per_s", "higher"),
    Gate("BENCH_serve.json", "speedup_fused_vs_materialized_int8",
         "higher"),
    Gate("BENCH_serve.json", "engine_int8_kv_fused.tokens_per_s",
         "higher"),
    Gate("BENCH_serve.json", "engine_int8_kv_fused.decode_step_p95_s",
         "lower"),
    Gate("BENCH_serve.json",
         "soak.speedup_chunked_vs_oneshot_tokens_per_s", "higher"),
    # correctness floors — greedy equivalence is exact by construction
    Gate("BENCH_serve.json", "greedy_agreement_engine_vs_wave",
         "floor", floor=0.999),
    Gate("BENCH_serve.json", "greedy_agreement_fused_vs_materialized",
         "floor", floor=0.999),
    Gate("BENCH_serve.json", "soak.greedy_agreement_chunked_vs_oneshot",
         "floor", floor=0.999),
    Gate("BENCH_serve.json", "trace.coverage", "floor", floor=0.9),
    # overload robustness (DESIGN.md §12): shedding batch-class work
    # past the knee must never cost SLO-attaining tokens — the ratio is
    # an absolute floor (admission control that loses goodput is worse
    # than none), the shed-on goodput itself tracks noise-aware
    Gate("BENCH_serve.json",
         "open_loop.overload.goodput_ratio_shed_on_vs_off",
         "floor", floor=1.0),
    Gate("BENCH_serve.json",
         "open_loop.overload.shed_on.goodput_tokens_per_s", "higher"),
    # flight recorder (DESIGN.md §14): always-on like the metrics
    # registry, so the flight-on throughput tracks noise-aware and the
    # measured overhead must stay under serve_bench's own in-run bound
    Gate("BENCH_serve.json", "flight_recorder.flight_on_tokens_per_s",
         "higher"),
    # calibration: static-scale decode win + first-token faithfulness
    Gate("BENCH_calib.json", "static_kv_decode.static_speedup",
         "higher"),
    Gate("BENCH_calib.json",
         "static_kv_decode.greedy_agreement_first3_tokens",
         "floor", floor=0.999),
    # speculative decoding: int8 draft acceptance + lossless guarantee
    Gate("BENCH_spec.json", "configs.int8.acceptance_rate", "higher"),
    Gate("BENCH_spec.json", "configs.int8.greedy_agreement_vs_nonspec",
         "floor", floor=0.999),
    Gate("BENCH_spec.json", "configs.self.acceptance_rate",
         "floor", floor=0.999),
]

_MISSING = object()


def get(tree: dict, path: str):
    cur = tree
    for seg in path.split("."):
        if not isinstance(cur, dict) or seg not in cur:
            return _MISSING
        cur = cur[seg]
    return _MISSING if cur is None else cur


def noise_frac(tree: dict) -> float:
    """The file's own measured run-to-run noise (serve_bench records it
    under trace.noise_frac); 0 for files that don't measure one."""
    v = get(tree, "trace.noise_frac")
    return float(v) if v is not _MISSING else 0.0


def check_file(name: str, base: dict, fresh: dict, tol: float) \
        -> list[str]:
    """All gate failures for one BENCH file (empty list = pass)."""
    fails = []
    # the gate must survive whichever run was noisier
    eff_tol = max(tol, 3.0 * max(noise_frac(base), noise_frac(fresh)))
    for g in GATES:
        if g.file != name:
            continue
        f = get(fresh, g.path)
        if g.kind == "floor":
            if f is _MISSING:
                if get(base, g.path) is _MISSING:
                    continue                      # predates the metric
                fails.append(f"{name}:{g.path} vanished from fresh run")
            elif float(f) < g.floor:
                fails.append(f"{name}:{g.path} = {float(f):.4f} below "
                             f"floor {g.floor}")
            continue
        b = get(base, g.path)
        if b is _MISSING:
            continue                              # baseline predates it
        if f is _MISSING:
            fails.append(f"{name}:{g.path} vanished from fresh run")
            continue
        b, f = float(b), float(f)
        if g.kind == "higher" and f < b * (1.0 - eff_tol):
            fails.append(f"{name}:{g.path} regressed {b:.4g} -> {f:.4g} "
                         f"({f / b - 1.0:+.1%}, tol {eff_tol:.1%})")
        elif g.kind == "lower" and f > b * (1.0 + eff_tol):
            fails.append(f"{name}:{g.path} regressed {b:.4g} -> {f:.4g} "
                         f"({f / b - 1.0:+.1%}, tol {eff_tol:.1%})")
    return fails


def compare_dirs(baseline_dir: str, fresh_dir: str, tol: float) \
        -> tuple[list[str], int]:
    """(failures, n_gates_checked) across every gated BENCH file present
    in the baseline dir."""
    fails, checked = [], 0
    for name in sorted({g.file for g in GATES}):
        bpath = os.path.join(baseline_dir, name)
        fpath = os.path.join(fresh_dir, name)
        if not os.path.exists(bpath):
            continue                    # this repo doesn't track it yet
        if not os.path.exists(fpath):
            fails.append(f"{name}: fresh file missing from {fresh_dir}")
            continue
        with open(bpath) as fh:
            base = json.load(fh)
        with open(fpath) as fh:
            fresh = json.load(fh)
        checked += sum(1 for g in GATES if g.file == name)
        fails.extend(check_file(name, base, fresh, tol))
    return fails, checked


def degrade(tree: dict) -> dict:
    """Synthetically regress every gated number in a BENCH tree: halve
    "higher" metrics, double "lower" ones, break floors — the --smoke
    proof that the gate fires on a real regression."""
    out = json.loads(json.dumps(tree))            # deep copy
    for g in GATES:
        cur = out
        segs = g.path.split(".")
        for seg in segs[:-1]:
            if not isinstance(cur, dict) or seg not in cur \
                    or cur[seg] is None:
                cur = None
                break
            cur = cur[seg]
        if not isinstance(cur, dict) or segs[-1] not in cur \
                or cur[segs[-1]] is None:
            continue
        v = float(cur[segs[-1]])
        cur[segs[-1]] = {"higher": v * 0.5, "lower": v * 2.0,
                         "floor": (g.floor or 1.0) * 0.5}[g.kind]
    return out


def smoke(baseline_dir: str, tol: float) -> int:
    """Self-check: baselines vs themselves must PASS, a degraded copy
    must FAIL. Exit 0 only when both hold."""
    fails, checked = compare_dirs(baseline_dir, baseline_dir, tol)
    if not checked:
        print("smoke: no gated BENCH files found — nothing to protect")
        return 1
    if fails:
        print(f"smoke FAIL: committed baselines do not pass their own "
              f"gates ({len(fails)}):")
        for f in fails:
            print(f"  {f}")
        return 1
    print(f"smoke: {checked} gates pass against committed baselines")
    with tempfile.TemporaryDirectory() as tmp:
        for name in sorted({g.file for g in GATES}):
            p = os.path.join(baseline_dir, name)
            if not os.path.exists(p):
                continue
            with open(p) as fh:
                tree = json.load(fh)
            with open(os.path.join(tmp, name), "w") as fh:
                json.dump(degrade(tree), fh)
        dfails, _ = compare_dirs(baseline_dir, tmp, tol)
    if not dfails:
        print("smoke FAIL: synthetically degraded BENCH files were NOT "
              "flagged — the gate cannot fire")
        return 1
    print(f"smoke: degraded copies flagged {len(dfails)} regressions "
          f"(gate can fire), e.g.:")
    for f in dfails[:4]:
        print(f"  {f}")
    return 0


def main(argv=None) -> int:
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
    ap = argparse.ArgumentParser(
        description="noise-aware BENCH_*.json regression gate")
    ap.add_argument("--baseline-dir", default=root,
                    help="committed baselines (default: repo root)")
    ap.add_argument("--fresh-dir", default=root,
                    help="freshly generated BENCH files to judge")
    ap.add_argument("--tol", type=float, default=0.10,
                    help="relative tolerance floor; the effective gate "
                         "is max(tol, 3x the measured noise_frac)")
    ap.add_argument("--smoke", action="store_true",
                    help="self-check: baselines pass, degraded copies "
                         "fail — proves the gate fires without running "
                         "any model")
    args = ap.parse_args(argv)
    if args.smoke:
        return smoke(args.baseline_dir, args.tol)
    fails, checked = compare_dirs(args.baseline_dir, args.fresh_dir,
                                  args.tol)
    if fails:
        print(f"REGRESSION: {len(fails)} of {checked} gates failed:")
        for f in fails:
            print(f"  {f}")
        return 1
    print(f"ok: {checked} gates pass "
          f"({args.fresh_dir} vs {args.baseline_dir})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
