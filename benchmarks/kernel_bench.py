"""Kernel microbenchmark (paper §6 size/speed discussion): the fused
cluster-dequant matmul vs a dense bf16 matmul.

On this CPU container the Pallas TPU kernel only runs in interpret mode
(not representative), so wall-time is measured for the XLA-fused jnp path;
the structural metrics (deployed bytes, HBM-traffic ratio) are the
TPU-relevant output. Timings are µs/call, median of `reps`.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import QuantConfig, splitquant_tensor
from repro.kernels import ops


def _time(fn, *args, reps=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def run(M=256, K=2048, N=2048, bits=4, verbose=True):
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (K, N), dtype=jnp.float32) * 0.05
    x = jax.random.normal(key, (M, K), dtype=jnp.float32)
    sq = splitquant_tensor(key, w, QuantConfig(bits=bits), k=3)
    qp, cp, recip, shift = ops.pack_for_kernel(sq)

    dense = jax.jit(lambda x, w: x @ w)
    fused = jax.jit(lambda x: ops.quantized_matmul(
        x, qp, cp, recip, shift, bits=bits, k=3))

    t_dense = _time(dense, x, w)
    t_fused = _time(fused, x)
    dense_bytes = w.size * 4
    packed_bytes = sq.nbytes_deployed()
    rows = [
        ("dense_matmul", t_dense, f"{dense_bytes/2**20:.1f}MiB weights"),
        (f"splitquant_int{bits}_fused", t_fused,
         f"{packed_bytes/2**20:.2f}MiB weights "
         f"({dense_bytes/packed_bytes:.1f}x smaller)"),
    ]
    if verbose:
        for r in rows:
            print(f"{r[0]},{r[1]:.1f},{r[2]}")
    return rows


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    run()
