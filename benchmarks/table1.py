"""Paper Table 1 reproduction: BERT-Tiny × {emotion-like 6-way, spam-like
binary} × {FP32, INT2/4/8} × {baseline PTQ, SplitQuant}.

Offline constraint: the HF checkpoints + DAIR.AI/UCI datasets
are not downloadable, so the repro is *structural*: same model family, two
synthetic classification tasks calibrated to the paper's FP32 accuracy
regime (~0.90 6-way, ~0.98 binary), same quantization grid and comparison.
The validated claim is the paper's causal one: SplitQuant recovers low-bit
accuracy, with the effect shrinking as bits grow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import QuantConfig, QuantPolicy, dequantize_tree, quantize_tree
from repro.data.classification import ClsDataset, batches, emotion_like, spam_like
from repro.models import bert_tiny
from repro.optim import adamw


def train_bert(ds: ClsDataset, *, epochs=4, batch_size=32, lr=3e-4, seed=0):
    cfg = get_arch("bert-tiny")
    key = jax.random.PRNGKey(seed)
    params = bert_tiny.init(key, cfg, ds.n_classes, max_len=ds.seq_len)
    steps = (ds.tokens.shape[0] // batch_size) * epochs
    opt_cfg = adamw.OptConfig(lr=lr, total_steps=steps, warmup_steps=50,
                              weight_decay=0.01)
    opt = adamw.init(opt_cfg, params)

    @jax.jit
    def step(p, o, b):
        (l, m), g = jax.value_and_grad(
            lambda pp: bert_tiny.loss_fn(pp, cfg, b), has_aux=True)(p)
        p, o, _ = adamw.update(opt_cfg, o, p, g)
        return p, o, l

    for b in batches(ds, batch_size, seed=seed, epochs=epochs):
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, loss = step(params, opt, b)
    return cfg, params


def evaluate(cfg, params, ds: ClsDataset, *, batch_size=100,
             act_cfg: QuantConfig | None = None, act_chunks=1) -> float:
    correct = total = 0
    for b in batches(ds, batch_size, train=False):
        jb = {k: jnp.asarray(v) for k, v in b.items()}
        logits = bert_tiny.forward(params, cfg, jb, act_quant=act_cfg,
                                   act_chunks=act_chunks)
        pred = np.asarray(jnp.argmax(logits, -1))
        correct += int((pred == b["labels"]).sum())
        total += len(b["labels"])
    return correct / total


def quantized_accuracy(cfg, params, ds, bits: int, method: str,
                       seed=0, quantize_acts=False) -> float:
    """Weight (+bias) PTQ, optionally with §4.2 activation quantization:
    method="splitquant" uses 3-chunk split activation ranges,
    "baseline" uses one whole-tensor dynamic range."""
    policy = QuantPolicy(cfg=QuantConfig(bits=bits), method=method, k=3)
    qp, _ = quantize_tree(jax.random.PRNGKey(seed), params, policy)
    act_cfg = None
    act_chunks = 1
    if quantize_acts:
        act_cfg = QuantConfig(bits=max(bits, 8))   # W{b}A8 convention
        act_chunks = 3 if method == "splitquant" else 1
    return evaluate(cfg, dequantize_tree(qp), ds, act_cfg=act_cfg,
                    act_chunks=act_chunks)


def run_table1(*, epochs=8, n_samples=4000, seed=0, verbose=True,
               quantize_acts=False) -> dict:
    results = {}
    for name, maker in (("emotion", emotion_like), ("spam", spam_like)):
        ds = maker(n_samples=n_samples, seed=seed)
        # train/test split 80/20
        n_tr = int(0.8 * n_samples)
        tr = ClsDataset(ds.name, ds.n_classes, ds.seq_len,
                        ds.tokens[:n_tr], ds.labels[:n_tr], ds.mask[:n_tr])
        te = ClsDataset(ds.name, ds.n_classes, ds.seq_len,
                        ds.tokens[n_tr:], ds.labels[n_tr:], ds.mask[n_tr:])
        cfg, params = train_bert(tr, epochs=epochs, seed=seed)
        row = {"fp32": evaluate(cfg, params, te)}
        for bits in (2, 4, 8):
            row[f"int{bits}_baseline"] = quantized_accuracy(
                cfg, params, te, bits, "baseline", seed,
                quantize_acts=quantize_acts)
            row[f"int{bits}_splitquant"] = quantized_accuracy(
                cfg, params, te, bits, "splitquant", seed,
                quantize_acts=quantize_acts)
        results[name] = row
        if verbose:
            print(f"\n== {name} (FP32 {row['fp32']:.3f}) ==")
            for bits in (2, 4, 8):
                b_, s_ = row[f"int{bits}_baseline"], row[f"int{bits}_splitquant"]
                print(f"  INT{bits}: baseline {b_:.3f}  splitquant {s_:.3f}"
                      f"  diff {100 * (s_ - b_):+.1f}%p")
    return results


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    run_table1()
