"""Roofline analysis: read the dry-run JSONs and derive the three terms per
(arch × shape × mesh) — EXPERIMENTS.md §Roofline is generated from this.

Hardware model (TPU v5e, per assignment):
    peak compute   197 TFLOP/s bf16 per chip
    HBM bandwidth  819 GB/s per chip
    ICI link       ~50 GB/s per link

Terms (seconds, per device):
    compute    = dot_flops / PEAK_FLOPS
    memory     = dot_bytes / HBM_BW        (dot operand/output traffic proxy)
    collective = collective_bytes / ICI_BW (per-device bytes over one link)

MODEL_FLOPS (useful-work floor): 6·N·D for training, 2·N·D for prefill,
2·N·B for one decode step (N = active params). The ratio
MODEL_FLOPS / HLO dot FLOPs exposes remat + GSPMD-redundancy waste.
"""
from __future__ import annotations

import functools
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


@functools.lru_cache(maxsize=None)
def param_counts(arch: str) -> tuple[float, float]:
    """(total, active) parameter counts via eval_shape (no allocation)."""
    import jax
    from repro.configs import get_arch
    from repro.models import get_model
    cfg = get_arch(arch)
    model = get_model(cfg)
    shapes = jax.eval_shape(functools.partial(model.init, cfg=cfg),
                            jax.random.PRNGKey(0))
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    total = active = 0.0
    for path, leaf in flat:
        keystr = jax.tree_util.keystr(path)
        n = 1.0
        for d in leaf.shape:
            n *= d
        total += n
        if leaf.ndim >= 4 and "moe" in keystr:       # per-expert weights
            active += n * cfg.top_k / max(cfg.n_experts, 1)
        else:
            active += n
    return total, active


def model_flops_per_dev(arch: str, shape: dict, n_dev: int) -> float:
    from repro.configs import SHAPES
    shp = SHAPES[shape]
    total, active = param_counts(arch)
    # exclude embeddings from the matmul-work count? Keep them: lm_head is
    # a real matmul; embed lookup is not. Approximation noted.
    if shp.kind == "train":
        toks = shp.global_batch * shp.seq_len
        return 6.0 * active * toks / n_dev
    if shp.kind == "prefill":
        toks = shp.global_batch * shp.seq_len
        return 2.0 * active * toks / n_dev
    return 2.0 * active * shp.global_batch / n_dev    # decode: one token/seq


def load_results(mesh: str = "16x16", tag: str = "") -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        r = json.load(open(f))
        fname = os.path.basename(f)
        want = f"_{mesh}{('_' + tag) if tag else ''}.json"
        if not fname.endswith(want):
            continue
        if tag == "" and len(fname.replace(f"_{mesh}.json", "").split("_")) \
                != len(f"{r['arch']}_{r['shape']}".split("_")):
            continue
        out.append(r)
    return out


def roofline_row(r: dict) -> dict | None:
    if r["status"] != "ok":
        return {"arch": r["arch"], "shape": r["shape"], "status": r["status"],
                "reason": r.get("reason", r.get("error", ""))[:90]}
    n_dev = r["n_devices"]
    t_c = r["dot_flops"] / PEAK_FLOPS
    t_m = r["dot_bytes"] / HBM_BW
    coll = r["collectives"].get("total_bytes_tpu",
                                r["collectives"]["total_bytes"])
    t_x = coll / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops_per_dev(r["arch"], r["shape"], n_dev)
    return {
        "arch": r["arch"], "shape": r["shape"], "status": "ok",
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": mf / r["dot_flops"] if r["dot_flops"] else 0.0,
        "roofline_fraction": (
            # fraction of peak the step would achieve, bounded by the
            # dominant term: useful_flops_time / max(term)
            (mf / PEAK_FLOPS) / max(t_c, t_m, t_x, 1e-12)),
        "temp_gb": (r["memory"]["temp_bytes"] or 0) / 2**30,
    }


def table(mesh: str = "16x16", tag: str = "") -> str:
    rows = [roofline_row(r) for r in load_results(mesh, tag)]
    hdr = (f"| arch | shape | compute s | memory s | collective s | "
           f"dominant | useful ratio | roofline frac | temp GB/dev |\n"
           f"|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for x in rows:
        if x is None:
            continue
        if x["status"] != "ok":
            lines.append(f"| {x['arch']} | {x['shape']} | — | — | — | "
                         f"SKIP | — | — | — |")
            continue
        lines.append(
            f"| {x['arch']} | {x['shape']} | {x['compute_s']:.3f} | "
            f"{x['memory_s']:.3f} | {x['collective_s']:.3f} | "
            f"**{x['dominant']}** | {x['useful_ratio']:.3f} | "
            f"{x['roofline_fraction']:.4f} | {x['temp_gb']:.0f} |")
    return hdr + "\n".join(lines)


def main():
    print("# single-pod (16x16)")
    print(table("16x16"))
    print()
    print("# multi-pod (2x16x16)")
    print(table("2x16x16"))


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
