"""Calibration-subsystem benchmark → BENCH_calib.json.

Two claims, matching the repro.calib design goals:

(a) **Mixed precision under a byte budget** (BERT-Tiny, the paper's test
    vehicle): per-layer sensitivity + greedy allocation produce a mixed
    INT2/4/8 assignment that beats the best *uniform* bit-width fitting
    the same deployed-byte budget. Curve points: the uniform-INT2 budget,
    the INT2/INT4 midpoint (where uniform has no answer but mixed does),
    and the uniform-INT4 budget.

(b) **Static activation scales on the decode hot path** (engine, INT8 KV
    cache): per-layer scales calibrated offline replace the per-step
    min/max reduce. Throughput must match or beat dynamic scales, with
    decode logits still within the INT8 tolerance of the fp cache.

    PYTHONPATH=src python benchmarks/calib_bench.py            # full
    PYTHONPATH=src python benchmarks/calib_bench.py --smoke    # CI-sized
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.calib import (best_uniform_within, collect_kv_stats,  # noqa: E402
                         greedy_allocate, kv_static_scales,
                         layer_sensitivity, sensitivity_summary,
                         uniform_bytes)
from repro.configs import get_arch  # noqa: E402
from repro.core import QuantConfig, QuantPolicy, dequantize_tree, \
    quantize_tree  # noqa: E402
from repro.data.classification import ClsDataset, batches, \
    emotion_like  # noqa: E402
from repro.engine import Engine, EngineConfig  # noqa: E402
from repro.models import bert_tiny, get_model  # noqa: E402

from run import provenance  # noqa: E402
from table1 import evaluate, train_bert  # noqa: E402

INT8_LOGIT_TOL = 0.05      # tests/test_engine.py decode-logit tolerance
# Static (per-layer, calibrated) ranges are globally ~2.5x wider than the
# per-token dynamic ranges (measured: per-token span ≈ 0.4x global span),
# so the static-scale logit bound scales accordingly. Greedy decode tokens
# still match the dynamic path exactly on short horizons (asserted in
# tests/test_engine.py); mean |Δlogit| stays within the INT8 tolerance.
STATIC_LOGIT_TOL = 2.5 * INT8_LOGIT_TOL


# ------------------------------------------------- (a) accuracy vs budget --
def accuracy_vs_budget(*, epochs: int, n_samples: int, seed: int = 0,
                       bits_list=(2, 4, 8)) -> dict:
    ds = emotion_like(n_samples=n_samples, seed=seed)
    n_tr = int(0.8 * n_samples)
    tr = ClsDataset(ds.name, ds.n_classes, ds.seq_len,
                    ds.tokens[:n_tr], ds.labels[:n_tr], ds.mask[:n_tr])
    te = ClsDataset(ds.name, ds.n_classes, ds.seq_len,
                    ds.tokens[n_tr:], ds.labels[n_tr:], ds.mask[n_tr:])
    cfg, params = train_bert(tr, epochs=epochs, seed=seed)
    fp32_acc = evaluate(cfg, params, te)

    calib_batch = next(batches(tr, min(256, n_tr), train=False))
    t0 = time.perf_counter()
    table = layer_sensitivity(
        jax.random.PRNGKey(seed + 1), cfg, params,
        lambda p, b: bert_tiny.forward(p, cfg, b), calib_batch,
        bits_list=bits_list)
    t_sens = time.perf_counter() - t0

    key = jax.random.PRNGKey(seed + 2)

    def acc_of(tree):
        return evaluate(cfg, dequantize_tree(tree), te)

    uniform = {}
    for bits in bits_list:
        qp, rep = quantize_tree(key, params, QuantPolicy(
            cfg=QuantConfig(bits=bits)))
        uniform[bits] = {"acc": acc_of(qp), "bytes": rep["deployed_bytes"]}

    b_lo = uniform_bytes(table, bits_list[0])
    b_hi = uniform_bytes(table, 4) if 4 in bits_list else \
        uniform_bytes(table, bits_list[-1])
    curve = []
    for name, budget in (("int2_budget", b_lo),
                         ("midpoint_budget", (b_lo + b_hi) // 2),
                         ("int4_budget", b_hi)):
        alloc = greedy_allocate(table, budget, metric="kl")
        qp, rep = quantize_tree(key, params, QuantPolicy(),
                                overrides=alloc["overrides"])
        mixed_acc = acc_of(qp)
        bu = best_uniform_within(table, budget)
        bu_acc = uniform[bu]["acc"] if bu is not None else None
        curve.append({
            "name": name,
            "budget_bytes": int(budget),
            "mixed_acc": mixed_acc,
            "mixed_bytes": int(rep["deployed_bytes"]),
            "avg_bits": alloc["avg_bits"],
            "assignment": alloc["assignment"],
            "best_uniform_bits_within_budget": bu,
            "best_uniform_acc": bu_acc,
            "mixed_minus_uniform": (mixed_acc - bu_acc
                                    if bu_acc is not None else None),
        })
    return {
        "dataset": ds.name,
        "n_train": n_tr, "n_test": n_samples - n_tr,
        "fp32_acc": fp32_acc,
        "uniform": {str(b): v for b, v in uniform.items()},
        "sensitivity_seconds": t_sens,
        "sensitivity_top": sensitivity_summary(table, bits=bits_list[0])[:5],
        "curve": curve,
        "mixed_beats_uniform_at_equal_budget": any(
            c["mixed_minus_uniform"] is not None
            and c["mixed_minus_uniform"] > 0 for c in curve),
    }


# ------------------------------------- (b) static vs dynamic decode scales --
def make_workload(rng, n_requests, vocab, budget=16):
    return [(rng.integers(0, vocab, size=int(rng.integers(4, 12))), budget)
            for _ in range(n_requests)]


def run_engine(cfg, params, workload, ecfg, kv_scales=None):
    eng = Engine(cfg, params, ecfg, kv_scales=kv_scales)
    for p, b in workload:
        eng.submit(p, max_new_tokens=b)
    t0 = time.perf_counter()
    fin = eng.drain()
    wall = time.perf_counter() - t0
    m = eng.metrics()
    m["wall_s"] = wall
    m["tokens_per_s"] = m["total_tokens"] / wall
    return fin, m


def static_vs_dynamic_decode(*, arch="stablelm-1.6b", requests=16,
                             repeats=3, seed=0) -> dict:
    cfg = get_arch(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)

    # calibration prompts cover the decode position range (longer S than
    # serving prompts — RoPE'd K ranges are position-dependent)
    calib = [rng.integers(0, cfg.vocab, size=(4, 48)) for _ in range(4)]
    scales = kv_static_scales(collect_kv_stats(cfg, params, calib,
                                               qchunks=4))

    # -- decode-logit agreement: identical prefill written to fp / dynamic /
    #    static caches, one batched decode step over each
    from repro.engine.kvcache import init_slot_cache, write_prefill
    from repro.models import transformer
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(3, 14)))
               for _ in range(2)]

    def decode_logits(mode, kv_scales=None):
        cache = init_slot_cache(cfg, 2, 48, mode=mode, kv_scales=kv_scales)
        toks, pos = [], []
        for slot, p in enumerate(prompts):
            logits, pc = model.prefill(
                params, cfg, {"tokens": jnp.asarray(p)[None]})
            cache = write_prefill(cache, slot, pc, len(p))
            toks.append(int(jnp.argmax(logits[0, -1])))
            pos.append(len(p))
        logits, _ = transformer.decode_step_slots(
            params, cfg, cache, jnp.asarray(toks, jnp.int32)[:, None],
            jnp.asarray(pos, jnp.int32))
        return np.asarray(logits[:, -1])

    lf = decode_logits("fp")
    ld = decode_logits("int8")
    ls = decode_logits("int8", kv_scales=scales)
    dyn_diff = float(np.max(np.abs(ld - lf)))
    sta_diff = float(np.max(np.abs(ls - lf)))
    sta_mean_diff = float(np.mean(np.abs(ls - lf)))

    # -- behavioral check: greedy tokens on a short horizon (before chaotic
    #    drift) must match the dynamic path exactly
    # prefill_chunk pinned to 0 (one-shot): this benchmark tracks the
    # static-vs-dynamic SCALE effect across PRs, so the prefill path must
    # stay fixed even as the engine default flips (cf. serve_bench's pin)
    short = make_workload(rng, 6, cfg.vocab, budget=3)
    ecfg3 = EngineConfig(n_slots=3, max_len=64, prefill_bucket=8,
                        kv_mode="int8", prefill_chunk=0)
    fin_d3, _ = run_engine(cfg, params, short, ecfg3)
    fin_s3, _ = run_engine(cfg, params, short, ecfg3, kv_scales=scales)
    first3_agree = float(np.mean([
        np.mean([a == b for a, b in zip(rd.out, rs.out)])
        for rd, rs in zip(fin_d3, fin_s3)]))

    # -- throughput: same workload, dynamic vs static scales (best of N)
    workload = make_workload(rng, requests, cfg.vocab)
    ecfg = EngineConfig(n_slots=4, max_len=64, prefill_bucket=8,
                        kv_mode="int8", prefill_chunk=0)
    run_engine(cfg, params, workload[:4], ecfg)                   # warm
    run_engine(cfg, params, workload[:4], ecfg, kv_scales=scales)  # warm
    dyn_best, sta_best = 0.0, 0.0
    agree = None
    for _ in range(repeats):
        fin_d, md = run_engine(cfg, params, workload, ecfg)
        fin_s, ms = run_engine(cfg, params, workload, ecfg,
                               kv_scales=scales)
        dyn_best = max(dyn_best, md["tokens_per_s"])
        sta_best = max(sta_best, ms["tokens_per_s"])
        agree = float(np.mean([
            np.mean([a == b for a, b in zip(rd.out, rs.out)])
            for rd, rs in zip(fin_d, fin_s)]))
    return {
        "arch": cfg.name,
        "requests": requests,
        "dynamic_tokens_per_s": dyn_best,
        "static_tokens_per_s": sta_best,
        "static_speedup": sta_best / dyn_best,
        "static_matches_or_beats_dynamic": sta_best >= 0.95 * dyn_best,
        "kv_bytes_per_token_dynamic": md["kv_bytes_per_token"],
        "kv_bytes_per_token_static": ms["kv_bytes_per_token"],
        "greedy_agreement_static_vs_dynamic": agree,
        "greedy_agreement_first3_tokens": first3_agree,
        "max_logit_diff_dynamic_vs_fp": dyn_diff,
        "max_logit_diff_static_vs_fp": sta_diff,
        "mean_logit_diff_static_vs_fp": sta_mean_diff,
        "int8_logit_tolerance": INT8_LOGIT_TOL,
        "static_logit_tolerance": STATIC_LOGIT_TOL,
        "static_max_within_static_tolerance": sta_diff <= STATIC_LOGIT_TOL,
        "static_mean_within_int8_tolerance": sta_mean_diff <= INT8_LOGIT_TOL,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (minutes, looser statistics)")
    ap.add_argument("--epochs", type=int, default=None)
    ap.add_argument("--samples", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_calib.json"))
    args = ap.parse_args()

    # smoke keeps the full pipeline but shrinks training enough for CI;
    # below ~4 epochs the model is too untrained for sensitivity to rank
    # layers meaningfully and the curve turns into seed noise
    epochs = args.epochs or (4 if args.smoke else 8)
    samples = args.samples or (1600 if args.smoke else 4000)
    requests = args.requests or (8 if args.smoke else 16)

    print(f"== (a) mixed-precision accuracy vs byte budget "
          f"(bert-tiny, {samples} samples, {epochs} epochs) ==")
    acc = accuracy_vs_budget(epochs=epochs, n_samples=samples)
    print(f"fp32 {acc['fp32_acc']:.3f} | uniform " + "  ".join(
        f"INT{b}: {v['acc']:.3f} ({v['bytes']/1024:.0f} KiB)"
        for b, v in acc["uniform"].items()))
    for c in acc["curve"]:
        bu = c["best_uniform_bits_within_budget"]
        print(f"  {c['name']:>16}: mixed {c['mixed_acc']:.3f} "
              f"(avg {c['avg_bits']:.2f} bits, "
              f"{c['mixed_bytes']/1024:.0f} KiB) vs best uniform "
              f"INT{bu} {c['best_uniform_acc']:.3f}  "
              f"Δ {100*c['mixed_minus_uniform']:+.1f}%p")

    print(f"\n== (b) static vs dynamic KV scales (decode path) ==")
    kv = static_vs_dynamic_decode(requests=requests,
                                  repeats=2 if args.smoke else 3)
    print(f"dynamic {kv['dynamic_tokens_per_s']:.1f} tok/s | static "
          f"{kv['static_tokens_per_s']:.1f} tok/s "
          f"({kv['static_speedup']:.2f}x; first-3-token agreement "
          f"{kv['greedy_agreement_first3_tokens']:.1%}, full-horizon "
          f"{kv['greedy_agreement_static_vs_dynamic']:.1%})")
    print(f"|Δlogit| vs fp: dynamic max "
          f"{kv['max_logit_diff_dynamic_vs_fp']:.4f} (tol "
          f"{INT8_LOGIT_TOL}); static max "
          f"{kv['max_logit_diff_static_vs_fp']:.4f} (tol "
          f"{STATIC_LOGIT_TOL}), mean "
          f"{kv['mean_logit_diff_static_vs_fp']:.4f}")

    result = {"provenance": provenance(seed=0), "smoke": args.smoke,
              "bert_tiny_budget": acc, "static_kv_decode": kv}
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, default=str)
    print(f"\nwrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
