"""Multi-device numeric correctness (subprocess with 8 forced host devices
— XLA device count locks at first jax init, so these cannot run in the
main pytest process):

  * tshard ring decode attention == single-device decode logits,
  * sharded quantized serve step == unsharded,
  * tp_dense == dense under a real (2,4) mesh.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.slow
def test_tshard_ring_decode_matches_dense():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.attention import attend, tshard_decode_attend
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        key = jax.random.PRNGKey(0)
        B, T, Hq, Hkv, D = 4, 32, 8, 2, 16
        q = jax.random.normal(key, (B, 1, Hq, D))
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, Hkv, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hkv, D))
        kv_pos = jnp.where(jnp.arange(T) < 20, jnp.arange(T), -1)
        q_pos = jnp.asarray([19])
        ref = attend(q, k, v, q_pos, kv_pos, causal=True)
        with mesh:
            ring = jax.jit(lambda *a: tshard_decode_attend(*a))(
                q, k, v, q_pos, kv_pos)
        err = float(jnp.abs(ring - ref).max())
        assert err < 1e-4, err
        print("ring-decode ok", err)
    """)
    assert "ring-decode ok" in out


@pytest.mark.slow
def test_sharded_quantized_serve_matches_unsharded():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_arch
        from repro.models import get_model
        from repro.core import QuantConfig, QuantPolicy, quantize_tree
        from repro.launch.shardings import param_shardings, cache_shardings
        cfg = get_arch("chatglm3-6b").reduced()   # GQA kv=2 < tp
        model = get_model(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init(key, cfg)
        qp, _ = quantize_tree(key, params, QuantPolicy(cfg=QuantConfig(bits=4)))
        toks = jax.random.randint(key, (8, 8), 0, cfg.vocab)
        logits0, cache = model.prefill(qp, cfg, {"tokens": toks}, max_len=16)
        ref, _ = model.decode_step(qp, cfg, cache, toks[:, :1], jnp.int32(8))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh:
            p_sh = param_shardings(qp, mesh, fsdp=False)
            c_sh = cache_shardings(cache, mesh)
            qp_s = jax.device_put(qp, p_sh)
            cache_s = jax.device_put(cache, c_sh)
            got, _ = jax.jit(lambda p, c, t: model.decode_step(
                p, cfg, c, t, jnp.int32(8), tshard=True))(
                qp_s, cache_s, toks[:, :1])
        err = float(jnp.abs(got - ref).max())
        rel = err / (float(jnp.abs(ref).max()) + 1e-9)
        assert rel < 1e-3, (err, rel)
        print("sharded serve ok", rel)
    """)
    assert "sharded serve ok" in out


@pytest.mark.slow
def test_tp_dense_matches_dense():
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.models.common import tp_dense, dense
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (8, 6, 32))
        w = jax.random.normal(jax.random.fold_in(key, 1), (32, 16))
        with mesh:
            got = jax.jit(lambda x, w: tp_dense(x, w))(x, w)
        ref = dense(x, w)
        err = float(jnp.abs(got - ref).max())
        assert err < 1e-4, err
        print("tp_dense ok", err)
    """)
    assert "tp_dense ok" in out


@pytest.mark.slow
def test_sharded_train_step_matches_unsharded_loss():
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.configs import get_arch
        from repro.models import get_model
        from repro.launch.shardings import param_shardings, batch_shardings
        cfg = get_arch("moonshot-v1-16b-a3b").reduced()
        model = get_model(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init(key, cfg)
        batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab),
                 "labels": jax.random.randint(key, (8, 16), 0, cfg.vocab)}
        ref, _ = model.loss_fn(params, cfg, batch)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh:
            p_sh = param_shardings(params, mesh)
            b_sh = batch_shardings(batch, mesh)
            p = jax.device_put(params, p_sh)
            b = jax.device_put(batch, b_sh)
            got, _ = jax.jit(lambda p, b: model.loss_fn(
                p, cfg, b, moe_blocks=2))(p, b)
        err = abs(float(got) - float(ref))
        assert err < 5e-3, (float(got), float(ref))
        print("sharded train ok", err)
    """)
    assert "sharded train ok" in out


@pytest.mark.slow
def test_elastic_restart_across_mesh_shapes():
    """Fault-tolerance + elasticity: checkpoint on a (1,8) mesh, restore
    and continue on a (2,4) mesh — checkpoints are mesh-independent."""
    out = run_sub("""
        import tempfile, jax, jax.numpy as jnp
        from repro.configs import get_arch
        from repro.models import get_model
        from repro.optim import adamw
        from repro.checkpoint import ckpt
        from repro.launch.shardings import param_shardings, batch_shardings, opt_shardings
        from repro.data import DataConfig, synthetic_lm_batch

        cfg = get_arch("stablelm-1.6b").reduced()
        model = get_model(cfg)
        key = jax.random.PRNGKey(0)
        opt_cfg = adamw.OptConfig(lr=1e-3, warmup_steps=0)
        dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8)

        def make_step(mesh, p_sh, o_sh, b_sh):
            def step(p, o, b):
                (l, _), g = jax.value_and_grad(
                    lambda pp: model.loss_fn(pp, cfg, b), has_aux=True)(p)
                p, o, _ = adamw.update(opt_cfg, o, p, g)
                return p, o, l
            return jax.jit(step, in_shardings=(p_sh, o_sh, b_sh))

        with tempfile.TemporaryDirectory() as d:
            mesh1 = jax.make_mesh((1, 8), ("data", "model"))
            with mesh1:
                params = model.init(key, cfg)
                p_sh = param_shardings(params, mesh1)
                params = jax.device_put(params, p_sh)
                opt = jax.device_put(adamw.init(opt_cfg, params),
                                     opt_shardings(adamw.init(opt_cfg, params), p_sh, mesh1))
                b_sh = batch_shardings(synthetic_lm_batch(dc, 0), mesh1)
                step = make_step(mesh1, p_sh, opt_shardings(opt, p_sh, mesh1), b_sh)
                for s in range(3):
                    params, opt, loss = step(params, opt, jax.device_put(synthetic_lm_batch(dc, s), b_sh))
                ckpt.save(d, 3, (params, opt))
                loss_mesh1 = float(step(params, opt, jax.device_put(synthetic_lm_batch(dc, 3), b_sh))[2])

            # "new fleet": different mesh shape
            mesh2 = jax.make_mesh((2, 4), ("data", "model"))
            with mesh2:
                like = (model.init(key, cfg), adamw.init(opt_cfg, model.init(key, cfg)))
                p_sh2 = param_shardings(like[0], mesh2)
                o_sh2 = opt_shardings(like[1], p_sh2, mesh2)
                (params2, opt2), st = ckpt.restore(d, like, shardings=(p_sh2, o_sh2))
                assert st == 3
                b_sh2 = batch_shardings(synthetic_lm_batch(dc, 0), mesh2)
                step2 = make_step(mesh2, p_sh2, o_sh2, b_sh2)
                loss_mesh2 = float(step2(params2, opt2, jax.device_put(synthetic_lm_batch(dc, 3), b_sh2))[2])
        err = abs(loss_mesh1 - loss_mesh2)
        assert err < 1e-3, (loss_mesh1, loss_mesh2)
        print("elastic restart ok", err)
    """)
    assert "elastic restart ok" in out
