"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting output shapes + finiteness, and decode-vs-full-context
logit equivalence for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_arch
from repro.models import get_model

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def make_batch(cfg, key, S=S, with_labels=True):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    if with_labels:
        batch["labels"] = toks
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.n_prefix_embeds, 1152))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq,
                                                  cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def models():
    out = {}
    for name in ASSIGNED:
        cfg = get_arch(name).reduced()
        model = get_model(cfg)
        out[name] = (cfg, model, model.init(KEY, cfg))
    return out


@pytest.mark.parametrize("name", ASSIGNED)
def test_forward_shapes_and_finite(models, name):
    cfg, model, params = models[name]
    batch = make_batch(cfg, KEY)
    out = model.forward(params, cfg, batch)
    logits = out[0]
    exp_S = S + (cfg.n_prefix_embeds if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ASSIGNED)
def test_train_step_decreases_loss(models, name):
    """Two SGD steps on one batch must reduce the loss (gradients flow)."""
    cfg, model, params = models[name]
    batch = make_batch(cfg, KEY)

    def loss(p):
        return model.loss_fn(p, cfg, batch)[0]

    l0, g = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(l0))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                         for x in jax.tree.leaves(g)))
    lr = 0.05 / (float(gnorm) + 1e-6)      # normalized step ⇒ guaranteed descent
    p2 = jax.tree.map(lambda p, gg: p - lr * gg.astype(p.dtype), params, g)
    l1 = loss(p2)
    assert float(l1) < float(l0)


@pytest.mark.parametrize("name", ASSIGNED)
def test_decode_matches_full_context(models, name):
    cfg, model, params = models[name]
    S0, S1 = 8, 12
    key = jax.random.PRNGKey(42)
    toks = jax.random.randint(key, (B, S1), 0, cfg.vocab)
    bf = {"tokens": toks}
    bp = {"tokens": toks[:, :S0]}
    off = 0
    if cfg.family == "vlm":
        pe = jax.random.normal(key, (B, cfg.n_prefix_embeds, 1152))
        bf["patch_embeds"] = pe
        bp["patch_embeds"] = pe
        off = cfg.n_prefix_embeds
    if cfg.family == "audio":
        fr = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
        bf["frames"] = fr
        bp["frames"] = fr
    full = model.forward(params, cfg, bf)[0]
    if cfg.family == "ssm":
        _, cache = model.prefill(params, cfg, bp)
    else:
        _, cache = model.prefill(params, cfg, bp, max_len=S1 + off)
    errs = []
    for t in range(S0, S1):
        lg, cache = model.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                      jnp.int32(t + off))
        errs.append(np.abs(np.asarray(lg[:, 0]) -
                           np.asarray(full[:, t + off])).max())
    assert max(errs) < 1e-3, f"{name}: decode/full mismatch {max(errs)}"


def test_windowed_attention_matches_explicit_mask():
    """Griffin's ring-buffer local attention == dense attention with a
    window mask."""
    from repro.models.attention import attend
    key = jax.random.PRNGKey(1)
    Bq, T, H, D, W = 1, 12, 2, 8, 4
    q = jax.random.normal(key, (Bq, T, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (Bq, T, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (Bq, T, H, D))
    pos = jnp.arange(T)
    out_w = attend(q, k, v, pos, pos, causal=True, window=W)
    # manual windowed softmax
    s = jnp.einsum("bshd,bthd->bhst", q * D ** -0.5, k)
    m = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - W)
    s = jnp.where(m[None, None], s, -1e30)
    ref = jnp.einsum("bhst,bthd->bshd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out_w), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_chunked_attention_matches_dense():
    from repro.models.attention import attend
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (2, 16, 4, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 16, 2, 8))
    pos = jnp.arange(16)
    dense = attend(q, k, v, pos, pos, causal=True)
    chunked = attend(q, k, v, pos, pos, causal=True, kv_chunk=4)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               rtol=1e-4, atol=1e-5)


def test_moe_no_drop_regime_exact():
    """At T ≤ 512 the MoE must not drop tokens: output == dense mixture."""
    from repro.models.ffn import apply_moe, init_moe
    cfg = get_arch("moonshot-v1-16b-a3b").reduced()
    p = init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))
    out, aux = apply_moe(p, x, cfg)
    # dense reference: full mixture over selected experts
    T = 16
    xt = x.reshape(T, cfg.d_model)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, eidx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for t in range(T):
        acc = jnp.zeros(cfg.d_model)
        for j in range(cfg.top_k):
            e = int(eidx[t, j])
            h = jax.nn.silu(xt[t] @ p["w_gate"][e]) * (xt[t] @ p["w_up"][e])
            acc += gate[t, j] * (h @ p["w_down"][e])
        ref = ref.at[t].set(acc)
    if "shared" in p:
        from repro.models.ffn import apply_ffn
        ref = ref + apply_ffn(p["shared"], xt, "swiglu")
    np.testing.assert_allclose(np.asarray(out.reshape(T, -1)),
                               np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_rglru_associative_scan_matches_sequential():
    from repro.models.griffin import _rg_lru, _init_rec
    cfg = get_arch("recurrentgemma-9b").reduced()
    p = _init_rec(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 10, 128))
    h0 = jnp.zeros((2, 128))
    out, h_last = _rg_lru(p, x, h0)
    # sequential reference
    xf = np.asarray(x, np.float64)
    rt = np.asarray(jax.nn.sigmoid(x @ p["rg_lru_wa"] + p["rg_lru_ba"]))
    it = np.asarray(jax.nn.sigmoid(x @ p["rg_lru_wx"] + p["rg_lru_bx"]))
    lam = np.asarray(jax.nn.softplus(p["rg_lru_lambda"]))
    h = np.zeros((2, 128))
    for t in range(10):
        a = np.exp(-8.0 * lam * rt[:, t])
        b = np.sqrt(np.maximum(1 - a ** 2, 0)) * (it[:, t] * xf[:, t])
        h = a * h + b
    np.testing.assert_allclose(np.asarray(out[:, -1]), h, rtol=1e-4,
                               atol=1e-5)
