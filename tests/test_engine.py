"""Continuous-batching engine: scheduler lifecycle, slot-cache numerics
(INT8 KV vs fp), and end-to-end greedy equivalence against both a naive
per-request decode loop and the wave-synchronous baseline server."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.engine import Engine, EngineConfig, EngineRequest, Scheduler
from repro.engine.kvcache import dequantize_kv, init_slot_cache, quantize_kv
from repro.models import get_model
from repro.runtime.serve_loop import Request, ServeConfig, Server

KEY = jax.random.PRNGKey(0)
MAX_LEN = 48
NEW_TOKENS = 6


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("stablelm-1.6b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(3, 14)))
               for _ in range(7)]
    return cfg, model, params, prompts


def naive_generate(model, cfg, params, prompt, n_tokens):
    """Per-request greedy reference: B=1 prefill + decode loop."""
    logits, cache = model.prefill(
        params, cfg, {"tokens": jnp.asarray(prompt)[None]}, max_len=MAX_LEN)
    tok = int(jnp.argmax(logits[0, -1]))
    out = [tok]
    pos = len(prompt)
    for _ in range(n_tokens - 1):
        logits, cache = model.decode_step(
            params, cfg, cache, jnp.asarray([[tok]], jnp.int32),
            jnp.int32(pos))
        tok = int(jnp.argmax(logits[0, -1]))
        out.append(tok)
        pos += 1
    return out


# ------------------------------------------------------------ scheduler ---
def test_scheduler_fcfs_admit_retire():
    s = Scheduler(n_slots=2, clock=lambda: 0.0)
    reqs = [s.submit(EngineRequest(uid=i, prompt=[0], max_new_tokens=4))
            for i in range(5)]
    placed = s.admit()
    assert [(slot, r.uid) for slot, r in placed] == [(0, 0), (1, 1)]
    assert s.admit() == []                        # pool full
    assert len(s.queue) == 3
    s.retire(0)
    assert reqs[0].done and s.slots[0] is None
    placed = s.admit()
    assert [(slot, r.uid) for slot, r in placed] == [(0, 2)]   # FCFS refill
    for slot in list(s.active_slots()):
        s.retire(slot)
    while not s.idle:
        for slot, _ in s.admit():
            s.retire(slot)
    assert sorted(r.uid for r in s.finished) == [0, 1, 2, 3, 4]
    assert s.n_admitted == 5


def test_engine_mixed_lengths_and_eos(setup):
    """Admission/retire under mixed prompt lengths, per-request budgets and
    a forced eos: every request terminates, slots are reused."""
    cfg, model, params, prompts = setup
    # pick an eos id the greedy model actually emits for one request so the
    # early-stop path runs (probe the reference first)
    ref0 = naive_generate(model, cfg, params, prompts[0], 4)
    eos = ref0[2]                                  # stops request 0 early
    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, max_len=MAX_LEN, max_new_tokens=8, eos_id=eos,
        prefill_bucket=8))
    budgets = [8, 3, 8, 5, 8, 2, 8]
    for p, b in zip(prompts, budgets):
        eng.submit(p, max_new_tokens=b)
    fin = eng.drain()
    assert len(fin) == len(prompts)
    assert [r.uid for r in fin] == list(range(len(prompts)))
    for r, b in zip(fin, budgets):
        assert r.done and 0 < len(r.out) <= b
        assert eos not in r.out                    # eos never emitted
        assert r.ttft is not None and r.t_done is not None
    # with 7 requests through 2 slots, the pool must have been recycled
    assert eng.sched.n_admitted == 7
    assert eng.metrics()["queue_depth_max"] >= 3


# -------------------------------------------------------------- numerics ---
def test_kv_quant_roundtrip_error_bounded():
    """INT8 chunked-range quantization reconstructs K/V head-vectors to
    ~range/255 absolute error per chunk."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(5, 3, 4, 64)).astype(np.float32))
    # inject per-chunk outliers: separate ranges must localize the damage
    x = x.at[..., 0].mul(50.0)
    q, scale, zero = quantize_kv(x, qchunks=4)
    xr = dequantize_kv(q, scale, zero)
    xc = np.asarray(x).reshape(5, 3, 4, 4, 16)
    step = (xc.max(-1) - xc.min(-1)) / 255.0       # per-chunk quant step
    err = np.abs(np.asarray(xr - x)).reshape(5, 3, 4, 4, 16).max(-1)
    # value rounding (step/2) + zero-point rounding (step/2) ⇒ ≤ 1 step
    assert np.all(err <= step + 1e-6)
    # the outlier chunk must not inflate the other chunks' error
    assert err[..., 1:].max() < 0.04


def test_int8_kv_decode_logits_close(setup):
    """Decode logits read from the INT8 KV cache stay within a tight bound
    of the fp cache path — identical prefill state written to both caches,
    one `decode_step_slots` over each."""
    from repro.engine.kvcache import write_prefill
    from repro.models import transformer

    cfg, model, params, prompts = setup

    def decode_logits(kv_mode):
        cache = init_slot_cache(cfg, 2, MAX_LEN, mode=kv_mode)
        toks, pos = [], []
        for slot, p in enumerate(prompts[:2]):
            logits, pc = model.prefill(
                params, cfg, {"tokens": jnp.asarray(p)[None]})
            cache = write_prefill(cache, slot, pc, len(p))
            toks.append(int(jnp.argmax(logits[0, -1])))
            pos.append(len(p))
        logits, _ = transformer.decode_step_slots(
            params, cfg, cache, jnp.asarray(toks, jnp.int32)[:, None],
            jnp.asarray(pos, jnp.int32))
        return np.asarray(logits[:, -1])

    lf = decode_logits("fp")
    lq = decode_logits("int8")
    # stated tolerance: max |Δlogit| ≤ 0.05 for INT8 KV at reduced scale
    assert np.max(np.abs(lf - lq)) <= 0.05, np.max(np.abs(lf - lq))


# ------------------------------------------------------------ end-to-end ---
def test_engine_matches_naive_reference(setup):
    cfg, model, params, prompts = setup
    ref = [naive_generate(model, cfg, params, p, NEW_TOKENS)
           for p in prompts]
    eng = Engine(cfg, params, EngineConfig(
        n_slots=3, max_len=MAX_LEN, max_new_tokens=NEW_TOKENS,
        prefill_bucket=8))
    for p in prompts:
        eng.submit(p)
    fin = eng.drain()
    assert [r.out for r in fin] == ref


def test_engine_matches_wave_server_greedy(setup):
    """Token-for-token greedy equivalence with the wave baseline on MIXED
    prompt lengths — exercises both the engine's per-request prefill and
    the wave server's left-pad masking."""
    cfg, model, params, prompts = setup
    srv = Server(cfg, params, ServeConfig(
        max_batch=3, max_new_tokens=NEW_TOKENS, max_len=MAX_LEN))
    wave = srv.serve([Request(i, p.copy()) for i, p in enumerate(prompts)])
    eng = Engine(cfg, params, EngineConfig(
        n_slots=3, max_len=MAX_LEN, max_new_tokens=NEW_TOKENS,
        prefill_bucket=8))
    for p in prompts:
        eng.submit(p)
    fin = eng.drain()
    assert [r.out for r in fin] == [r.out for r in wave]


def test_int8_engine_first_tokens_match(setup):
    """INT8 KV drifts over long generations, but the first greedy tokens
    must match the fp path (prefill is exact; decode reads dequantized)."""
    cfg, model, params, prompts = setup

    def run(kv_mode):
        eng = Engine(cfg, params, EngineConfig(
            n_slots=3, max_len=MAX_LEN, max_new_tokens=2,
            prefill_bucket=8, kv_mode=kv_mode))
        for p in prompts:
            eng.submit(p)
        return [r.out[0] for r in eng.drain()]

    assert run("int8") == run("fp")


# --------------------------------------------- static calibration scales ---
@pytest.fixture(scope="module")
def kv_scales(setup):
    """Static KV scales calibrated on long random prompts (position
    coverage past the serving prompts — RoPE'd K ranges grow with pos)."""
    from repro.calib import collect_kv_stats, kv_static_scales
    cfg, model, params, prompts = setup
    rng = np.random.default_rng(0)
    calib = [rng.integers(0, cfg.vocab, size=(4, MAX_LEN)) for _ in range(4)]
    return kv_static_scales(collect_kv_stats(cfg, params, calib, qchunks=4))


def test_static_kv_decode_logits_close(setup, kv_scales):
    """Static-scale decode logits vs the fp cache: bounded by 2.5x the
    dynamic INT8 tolerance (calibrated global ranges are ~2.5x wider than
    per-token dynamic ranges — measured per-token span ≈ 0.4x global), and
    the MEAN |Δlogit| stays within the dynamic tolerance itself."""
    from repro.engine.kvcache import write_prefill
    from repro.models import transformer

    cfg, model, params, prompts = setup

    def decode_logits(kv_mode, scales=None):
        cache = init_slot_cache(cfg, 2, MAX_LEN, mode=kv_mode,
                                kv_scales=scales)
        toks, pos = [], []
        for slot, p in enumerate(prompts[:2]):
            logits, pc = model.prefill(
                params, cfg, {"tokens": jnp.asarray(p)[None]})
            cache = write_prefill(cache, slot, pc, len(p))
            toks.append(int(jnp.argmax(logits[0, -1])))
            pos.append(len(p))
        logits, _ = transformer.decode_step_slots(
            params, cfg, cache, jnp.asarray(toks, jnp.int32)[:, None],
            jnp.asarray(pos, jnp.int32))
        return np.asarray(logits[:, -1])

    lf = decode_logits("fp")
    ls = decode_logits("int8", kv_scales)
    diff = np.abs(ls - lf)
    assert np.max(diff) <= 2.5 * 0.05, np.max(diff)
    assert np.mean(diff) <= 0.05, np.mean(diff)


def test_static_kv_greedy_tokens_match_dynamic(setup, kv_scales):
    """Behavioral contract: the admission token (prefill-exact) AND the
    first cache-reading decode token must match the dynamic-scale engine
    exactly (longer horizons drift chaotically for BOTH int8 paths)."""
    cfg, model, params, prompts = setup

    def run(scales):
        eng = Engine(cfg, params, EngineConfig(
            n_slots=3, max_len=MAX_LEN, max_new_tokens=2,
            prefill_bucket=8, kv_mode="int8"), kv_scales=scales)
        for p in prompts:
            eng.submit(p)
        return [r.out for r in eng.drain()]

    assert run(kv_scales) == run(None)


def test_static_cache_skips_scale_storage(setup, kv_scales):
    """Static mode stores per-layer scale constants, not per-entry arrays:
    fewer bytes per cached token, and the scale leaves never grow with
    slots or sequence length."""
    cfg, model, params, prompts = setup
    dyn = init_slot_cache(cfg, 4, MAX_LEN, mode="int8")
    sta = init_slot_cache(cfg, 4, MAX_LEN, mode="int8", kv_scales=kv_scales)
    assert sta.static and not dyn.static
    assert sta.bytes_per_token() < dyn.bytes_per_token()
    assert sta.k_scale.shape[1:3] == (1, 1)
    assert dyn.k_scale.shape[1:3] == (4, MAX_LEN)
    with pytest.raises(ValueError, match="static kv_scales"):
        init_slot_cache(cfg, 4, MAX_LEN, mode="fp", kv_scales=kv_scales)


def test_serve_from_recipe_without_kmeans(setup, kv_scales, tmp_path,
                                          monkeypatch):
    """A recipe + pre-quantized checkpoint must serve with NO k-means at
    startup (quantization ran offline) and with static KV scales."""
    from repro.calib import QuantRecipe
    from repro.checkpoint import ckpt
    from repro.core import QuantConfig, QuantPolicy, quantize_tree
    from repro.launch.serve import load_recipe_params

    cfg, model, params, prompts = setup
    qp, report = quantize_tree(KEY, params, QuantPolicy(
        cfg=QuantConfig(bits=2)))
    ckpt.save(str(tmp_path / "ckpt"), 0, qp)
    QuantRecipe(name="t", arch="stablelm-1.6b",
                policies={p: {"bits": d["bits"], "k": d["k"],
                              "method": d["method"]}
                          for p, d in report["per_path"].items()},
                kv_scales=kv_scales, ckpt_dir="ckpt").save(str(tmp_path))

    import repro.core.kmeans as kmeans_mod
    import repro.core.splitquant as splitquant_mod

    def boom(*a, **kw):
        raise AssertionError("k-means ran during recipe serving")

    monkeypatch.setattr(kmeans_mod, "kmeans_1d", boom)
    monkeypatch.setattr(splitquant_mod, "kmeans_1d", boom)
    served_params, rec, scales = load_recipe_params(str(tmp_path), params)
    assert scales is not None
    eng = Engine(cfg, served_params, EngineConfig(
        n_slots=2, max_len=MAX_LEN, max_new_tokens=2, prefill_bucket=8,
        kv_mode="int8"), kv_scales=scales)
    for p in prompts[:2]:
        eng.submit(p)
    fin = eng.drain()
    assert all(len(r.out) == 2 for r in fin)
    m = eng.metrics()
    assert m["kv_static_scales"] is True


# ------------------------------------------------------ metrics + trace ---
def test_metrics_empty_engine(setup):
    """metrics() on a never-stepped engine: all-zero counters and None
    (not NaN/crash) for every percentile/mean with no samples."""
    cfg, model, params, prompts = setup
    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_len=MAX_LEN,
                                           prefill_bucket=8))
    m = eng.metrics()
    assert m["n_finished"] == 0 and m["total_tokens"] == 0
    assert m["tokens_per_s"] is None
    assert m["ttft_p95_s"] is None and m["ttft_mean_s"] is None
    assert m["decode_step_p50_s"] is None
    assert m["step_with_prefill_p95_s"] is None
    assert m["steps_with_prefill"] == 0
    # untraced engines never grow trace keys
    assert "phase_attribution" not in m and "trace_records" not in m


def test_metrics_spec_counters_only_when_spec(setup):
    cfg, model, params, prompts = setup
    base = EngineConfig(n_slots=2, max_len=MAX_LEN, max_new_tokens=3,
                        prefill_bucket=8, kv_mode="int8")
    eng = Engine(cfg, params, base)
    eng.submit(prompts[0])
    eng.drain()
    m = eng.metrics()
    for k in ("spec_k", "acceptance_rate", "accept_hist", "verify_calls"):
        assert k not in m
    spec_cfg = EngineConfig(**{**base.__dict__, "spec_k": 2})
    engS = Engine(cfg, params, spec_cfg, draft_params=params)
    engS.submit(prompts[0])
    engS.drain()
    mS = engS.metrics()
    assert mS["spec_k"] == 2 and mS["verify_calls"] > 0
    assert len(mS["accept_hist"]) == 3            # a in [0, spec_k]


def test_metrics_step_with_prefill_none_without_concurrent_decode(setup):
    """step_with_prefill_p95_s covers steps where prefill ran WHILE other
    slots decoded; a single-request engine never overlaps the two."""
    cfg, model, params, prompts = setup
    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_len=MAX_LEN,
                                           max_new_tokens=3,
                                           prefill_bucket=8))
    eng.submit(prompts[0])
    eng.drain()
    m = eng.metrics()
    assert m["n_finished"] == 1
    assert m["steps_with_prefill"] == 0
    assert m["step_with_prefill_p95_s"] is None
    assert m["step_p95_s"] is not None            # steps did happen


def test_traced_engine_end_to_end(setup, tmp_path):
    """EngineConfig(trace=True): valid schema, finish reasons, lifecycle
    events for every request, >=90% step-wall phase coverage, and
    identical greedy tokens to the untraced engine."""
    from repro.obs import validate_events

    cfg, model, params, prompts = setup
    base = EngineConfig(n_slots=2, max_len=MAX_LEN, max_new_tokens=4,
                        prefill_bucket=8, kv_mode="int8")
    fin0 = [r.out for r in _drained(Engine(cfg, params, base), prompts[:4])]
    traced_cfg = EngineConfig(**{**base.__dict__, "trace": True,
                                 "trace_kv_every": 2})
    eng = Engine(cfg, params, traced_cfg)
    fin = _drained(eng, prompts[:4])
    assert [r.out for r in fin] == fin0           # tracing never resteers
    assert all(r.finish_reason in ("budget", "eos", "max_len")
               for r in fin)
    records = list(eng.tracer.records())
    assert validate_events(records) == []
    events = {r["name"] for r in records if r.get("kind") == "event"}
    assert {"submit", "admit", "first_token", "retire"} <= events
    uids = {r["uid"] for r in records
            if r.get("kind") == "event" and r["name"] == "retire"}
    assert uids == {r.uid for r in fin}
    assert any(r.get("kind") == "counter" and r["name"] == "kv_quality"
               for r in records)                  # trace_kv_every fired
    m = eng.metrics()
    pa = m["phase_attribution"]
    assert pa["coverage"] >= 0.9
    assert m["trace_records"] == len(eng.tracer.events)
    # exporters round-trip from a live engine
    path = str(tmp_path / "t.jsonl")
    eng.tracer.to_jsonl(path)
    from repro.obs import load_jsonl
    assert validate_events(load_jsonl(path)) == []


def _drained(eng, prompts):
    for p in prompts:
        eng.submit(p)
    return eng.drain()
