"""Fault-tolerant serving (DESIGN.md §12): admission control, deadlines
and cancellation, step retry with rollback, the degradation ladder, and
the seeded chaos harness.

The load-bearing property here is the CHAOS test: under a seeded storm
of injected step exceptions, corrupted tokens, stragglers, and poisoned
requests, (a) every submitted request retires exactly once with a
schema retire reason, (b) the drained engine holds no residual slot
state (kvcache.occupied_slots == []), and (c) the SURVIVORS' outputs
are token-identical to an unfaulted run — retry-after-rollback re-derives
bit-identical greedy tokens from the unchanged committed prefix, across
fp / int8-dynamic / int8-static KV caches.
"""
import os
import sys
import time

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.engine import (DegradationLadder, Engine, EngineConfig,
                          EngineRequest, FaultInjector, FaultSpec,
                          Scheduler, SubmitError, admission_set_point,
                          occupied_slots)
from repro.models import get_model
from repro.obs.schema import RETIRE_REASONS

sys.path.append(os.path.join(os.path.dirname(__file__), "..",
                             "benchmarks"))

import loadgen  # noqa: E402

KEY = jax.random.PRNGKey(0)
MAX_LEN = 48
NORMAL_REASONS = ("eos", "budget", "max_len", "zero_budget")


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("stablelm-1.6b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(3, 14)))
               for _ in range(7)]
    return cfg, model, params, prompts


@pytest.fixture(scope="module")
def kv_scales(setup):
    from repro.calib import collect_kv_stats, kv_static_scales
    cfg, model, params, prompts = setup
    rng = np.random.default_rng(0)
    calib = [rng.integers(0, cfg.vocab, size=(4, MAX_LEN))
             for _ in range(4)]
    return kv_static_scales(collect_kv_stats(cfg, params, calib,
                                             qchunks=4))


class FakeClock:
    """Manually advanced clock — deadline/watchdog semantics must be
    testable without real sleeps."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# =================================================== fault spec / injector
def test_fault_spec_parse():
    s = FaultSpec.parse("exception=0.1,nan=0.05,seed=3,max=7,slow=0.2,"
                        "slow_s=0.001,poison=0.5")
    assert s.step_exception_rate == 0.1
    assert s.nan_logits_rate == 0.05
    assert s.seed == 3 and s.max_faults == 7
    assert s.slow_step_rate == 0.2 and s.slow_step_s == 0.001
    assert s.poison_rate == 0.5
    with pytest.raises(ValueError, match="unknown fault spec key"):
        FaultSpec.parse("bogus=1")
    with pytest.raises(ValueError, match="not k=v"):
        FaultSpec.parse("exception")


def test_injector_deterministic():
    """Same spec ⇒ identical fault sequence — the property that makes a
    chaos run reproducible (and survivor identity assertable)."""
    spec = FaultSpec(seed=11, step_exception_rate=0.3, slow_step_rate=0.2,
                     nan_logits_rate=0.5, poison_rate=0.4)

    def storm():
        inj = FaultInjector(spec)
        marks = [inj.note_submit(u) for u in range(8)]
        draws = [inj.draw_step() for _ in range(30)]
        toks = np.arange(4, dtype=np.int64)
        corr = [inj.corrupt_tokens(toks, [0, 1, 2, 3],
                                   {s: s for s in range(4)}).tolist()
                for _ in range(5)]
        return marks, draws, corr, inj.counts()

    assert storm() == storm()


def test_injector_max_faults_budget():
    inj = FaultInjector(FaultSpec(seed=0, step_exception_rate=1.0,
                                  max_faults=3))
    kinds = [inj.draw_step() for _ in range(10)]
    assert kinds[:3] == ["exception"] * 3
    assert kinds[3:] == [None] * 7
    assert inj.injected_total() == 3


# ====================================================== degradation ladder
def test_ladder_thresholds_validated():
    with pytest.raises(ValueError, match="strictly ascending"):
        DegradationLadder((3, 2, 1))
    with pytest.raises(ValueError, match="strictly ascending"):
        DegradationLadder((1, 1, 2))


def test_ladder_hysteresis():
    lad = DegradationLadder((2, 4, 8), patience=2)
    assert lad.target(0) == 0 and lad.target(3) == 1 and lad.target(9) == 3
    # one burst step does NOT move the rung (patience=2)
    assert lad.update(5) == 0
    assert lad.update(0) == 0          # burst over — counter reset
    assert lad.update(5) == 0
    assert lad.update(5) == 2          # sustained ⇒ jump to target rung
    assert lad.n_transitions == 1
    # descent needs 2x patience consecutive low-pressure steps
    assert lad.update(0) == 2
    assert lad.update(0) == 2
    assert lad.update(0) == 2
    assert lad.update(0) == 0
    assert lad.n_transitions == 2


# ===================================================== admission control
def _req(uid, cls=None):
    return EngineRequest(uid=uid, prompt=[0], max_new_tokens=4, cls=cls)


def test_overload_reject_new():
    s = Scheduler(n_slots=1, clock=lambda: 0.0, max_queue=2,
                  overload_policy="reject-new")
    for u in range(4):
        s.submit(_req(u))
    assert [r.uid for r in s.queue] == [0, 1]
    shed = [r for r in s.finished if r.finish_reason == "shed"]
    assert sorted(r.uid for r in shed) == [2, 3]
    assert s.n_shed == 2
    assert all(r.done for r in shed)


def test_overload_shed_oldest():
    s = Scheduler(n_slots=1, clock=lambda: 0.0, max_queue=2,
                  overload_policy="shed-oldest")
    for u in range(4):
        s.submit(_req(u))
    # each overflow evicts the head: arrivals 2 and 3 displace 0 and 1
    assert [r.uid for r in s.queue] == [2, 3]
    assert sorted(r.uid for r in s.finished) == [0, 1]


def test_overload_shed_by_class():
    s = Scheduler(n_slots=1, clock=lambda: 0.0, max_queue=3,
                  overload_policy="shed-by-class")
    s.submit(_req(0, cls="interactive"))
    s.submit(_req(1, cls="batch"))
    s.submit(_req(2, cls="batch"))
    s.submit(_req(3, cls="interactive"))   # evicts oldest batch (uid 1)
    assert [r.uid for r in s.queue] == [0, 2, 3]
    s.submit(_req(4, cls="interactive"))   # evicts remaining batch (uid 2)
    assert [r.uid for r in s.queue] == [0, 3, 4]
    s.submit(_req(5, cls="interactive"))   # no batch left ⇒ reject-new
    assert [r.uid for r in s.queue] == [0, 3, 4]
    assert sorted(r.uid for r in s.finished) == [1, 2, 5]
    assert all(r.finish_reason == "shed" for r in s.finished)


def test_overload_unbounded_by_default():
    s = Scheduler(n_slots=1, clock=lambda: 0.0)
    for u in range(50):
        s.submit(_req(u))
    assert len(s.queue) == 50 and not s.finished


def test_shed_queued_to_prefers_batch():
    s = Scheduler(n_slots=1, clock=lambda: 0.0)
    s.submit(_req(0, cls="interactive"))
    s.submit(_req(1, cls="batch"))
    s.submit(_req(2, cls="interactive"))
    s.submit(_req(3, cls="batch"))
    assert s.shed_queued_to(1) == 3
    assert [r.uid for r in s.queue] == [2]    # batch first, then FCFS head
    assert sorted(r.uid for r in s.finished) == [0, 1, 3]


def test_admit_defers_classes():
    s = Scheduler(n_slots=2, clock=lambda: 0.0)
    s.submit(_req(0, cls="batch"))
    s.submit(_req(1, cls="interactive"))
    placed = s.admit(defer=("batch",))
    assert [r.uid for _, r in placed] == [1]
    assert [r.uid for r in s.queue] == [0]    # kept its queue position
    placed = s.admit()                        # rung dropped: admits normally
    assert [r.uid for _, r in placed] == [0]


def test_admission_set_point():
    ol = {"knee": {"last_ok_offered_rps": 14.0},
          "points": [{"offered_rps": 7.0, "queue_depth_at_submit_p95": 1.0},
                     {"offered_rps": 14.0,
                      "queue_depth_at_submit_p95": 3.2}]}
    assert admission_set_point(ol) == 7           # ceil(3.2 * 2.0)
    assert admission_set_point(ol, slack=1.0) == 4
    assert admission_set_point(ol, slack=0.1, floor=2) == 2
    assert admission_set_point(None) is None
    assert admission_set_point({"knee": None, "points": []}) is None
    assert admission_set_point({"knee": {"last_ok_offered_rps": None}}) \
        is None
    # older BENCH files lack the depth signal
    assert admission_set_point(
        {"knee": {"last_ok_offered_rps": 2.0},
         "points": [{"offered_rps": 2.0}]}) is None


# ================================================== submit-time validation
def test_submit_validation(setup):
    cfg, model, params, prompts = setup
    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_len=MAX_LEN,
                                           prefill_bucket=8))
    with pytest.raises(SubmitError) as e:
        eng.submit(np.zeros(0, np.int64))
    assert e.value.code == "empty_prompt"
    with pytest.raises(SubmitError) as e:
        eng.submit(prompts[0], max_new_tokens=-1)
    assert e.value.code == "bad_budget"
    with pytest.raises(SubmitError) as e:
        eng.submit(prompts[0], max_new_tokens=MAX_LEN)
    assert e.value.code == "too_long"
    assert isinstance(e.value, ValueError)        # catchable as ValueError
    # nothing malformed entered the queue, and valid work still flows
    assert eng.sched.n_submitted == 0 and not eng.sched.queue
    eng.submit(prompts[0], max_new_tokens=4)
    assert len(eng.drain()) == 1


# ======================================================== cancellation
def test_cancel_queued_and_slotted(setup):
    cfg, model, params, prompts = setup
    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, max_len=MAX_LEN, max_new_tokens=8, prefill_bucket=8,
        prefill_chunk=0))
    uids = [eng.submit(p) for p in prompts[:5]]
    eng.step()                         # uids 0,1 slotted; 2,3,4 queued
    assert eng.cancel(uids[3]) is True           # queued victim
    assert eng.cancel(uids[0]) is True           # slotted victim
    assert eng.cancel(999) is False              # unknown uid
    assert eng.cancel(uids[3]) is False          # idempotent: already done
    by_uid = {r.uid: r for r in eng.sched.finished}
    assert by_uid[uids[3]].finish_reason == "cancelled"
    assert by_uid[uids[0]].finish_reason == "cancelled"
    # the freed slot is immediately reusable — drain finishes everyone
    fin = eng.drain()
    assert sorted(r.uid for r in fin) == sorted(uids)
    reasons = {r.uid: r.finish_reason for r in fin}
    survivors = [u for u in uids if u not in (uids[0], uids[3])]
    assert all(reasons[u] in NORMAL_REASONS for u in survivors)
    assert eng.metrics()["requests_cancelled"] == 2
    assert occupied_slots(eng.cache) == []


def test_cancel_mid_chunked_prefill(setup):
    """Cancelling a slot that is mid-chunked-prefill must free the slot,
    the cache row, AND the prefill bookkeeping — the state most easily
    leaked (the slot is occupied but invisible to decode)."""
    cfg, model, params, prompts = setup
    rng = np.random.default_rng(9)
    long_prompt = rng.integers(0, cfg.vocab, size=40)
    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, max_len=MAX_LEN, max_new_tokens=8, prefill_bucket=8,
        prefill_chunk=8))
    # a short request keeps one slot DECODING, so the chunk budget
    # throttles the long prompt (a decode-idle engine would fast-path
    # the whole prompt in one step and never be observably mid-prefill)
    eng.submit(prompts[1])
    uid = eng.submit(long_prompt)
    eng.step()
    # the 40-token prompt streams <= 8 tokens/step: still mid-prefill
    assert eng.sched.prefill_slots(), "precondition: slot mid-prefill"
    slot = eng.sched.prefill_slots()[0]
    assert eng.cancel(uid) is True
    assert not eng.sched.prefill_slots()
    assert eng.sched.slots[slot] is None
    assert eng.sched.finished[0].finish_reason == "cancelled"
    # the freed slot admits and serves new work correctly
    uid2 = eng.submit(prompts[0], max_new_tokens=4)
    fin = eng.drain()
    by_uid = {r.uid: r for r in fin}
    assert by_uid[uid2].finish_reason in NORMAL_REASONS
    assert len(by_uid[uid2].out) > 0
    assert occupied_slots(eng.cache) == []


# ========================================================== deadlines
def test_total_deadline_slotted(setup):
    cfg, model, params, prompts = setup
    clk = FakeClock()
    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, max_len=MAX_LEN, max_new_tokens=16, prefill_bucket=8),
        clock=clk)
    uid = eng.submit(prompts[0], deadline_s=5.0)
    u2 = eng.submit(prompts[1])                   # no deadline: untouched
    eng.step()
    assert not eng.sched.finished                 # within deadline
    clk.t = 6.0
    eng.step()                                    # sweep fires
    done = {r.uid: r for r in eng.sched.finished}
    assert done[uid].finish_reason == "deadline_exceeded"
    assert u2 not in done
    fin = eng.drain()
    assert {r.uid: r.finish_reason for r in fin}[u2] in NORMAL_REASONS
    assert eng.metrics()["retire_reasons"]["deadline_exceeded"] == 1


def test_ttft_deadline_queued(setup):
    """A queued request whose TTFT deadline lapses retires without ever
    consuming a slot; one that got its first token in time is immune to
    the TTFT (but not the total) deadline."""
    cfg, model, params, prompts = setup
    clk = FakeClock()
    eng = Engine(cfg, params, EngineConfig(
        n_slots=1, max_len=MAX_LEN, max_new_tokens=12, prefill_bucket=8),
        clock=clk)
    u_slot = eng.submit(prompts[0], ttft_deadline_s=2.0)
    u_queue = eng.submit(prompts[1], ttft_deadline_s=2.0)
    eng.step()                   # u_slot admitted + first token at t=0
    clk.t = 3.0
    eng.step()
    done = {r.uid: r for r in eng.sched.finished}
    assert done[u_queue].finish_reason == "deadline_exceeded"
    assert u_slot not in done    # first token arrived before the deadline
    fin = eng.drain()
    assert {r.uid: r.finish_reason for r in fin}[u_slot] in NORMAL_REASONS


# ==================================================== drain watchdog
def test_drain_watchdog_stall(setup):
    cfg, model, params, prompts = setup
    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_len=MAX_LEN,
                                           prefill_bucket=8))
    uids = [eng.submit(p, max_new_tokens=4) for p in prompts[:3]]
    eng.step = lambda: []                         # wedged engine
    fin = eng.drain(stall_steps=3)
    assert sorted(r.uid for r in fin) == sorted(uids)
    assert all(r.finish_reason == "failed" for r in fin)
    assert eng.sched.idle and occupied_slots(eng.cache) == []


def test_drain_watchdog_timeout(setup):
    cfg, model, params, prompts = setup
    clk = FakeClock()
    eng = Engine(cfg, params, EngineConfig(n_slots=2, max_len=MAX_LEN,
                                           prefill_bucket=8), clock=clk)
    uid = eng.submit(prompts[0], max_new_tokens=4)

    def wedged_step():
        clk.t += 1.0             # wall advances, nothing else moves
        return []

    eng.step = wedged_step
    fin = eng.drain(timeout_s=2.5)
    assert [r.uid for r in fin] == [uid]
    assert fin[0].finish_reason == "failed"


# ================================================== chaos property test
CHAOS_SPEC = FaultSpec(seed=5, step_exception_rate=0.15,
                       nan_logits_rate=0.10, slow_step_rate=0.05,
                       slow_step_s=0.0005, poison_rate=0.25,
                       max_faults=60)


@pytest.mark.parametrize("kv_mode", ["fp", "int8", "int8-static"])
def test_chaos_storm_invariants(setup, kv_scales, kv_mode):
    """THE §12 acceptance property: under a seeded storm of transient
    exceptions, corrupted tokens, stragglers, and poisoned requests —
    with chunked prefill running concurrently — every request retires
    exactly once with a schema reason, the drained slot pool is empty,
    and survivors' outputs are token-identical to an unfaulted engine."""
    cfg, model, params, prompts = setup
    scales = kv_scales if kv_mode == "int8-static" else None
    mode = "int8" if kv_mode.startswith("int8") else "fp"
    # uid 4 is the seed's poisoned submission — give it a real decode
    # budget so quarantine is exercised; uid 1 keeps the budget-1 edge
    # (first token from prefill logits, never decodes)
    budgets = [6, 1, 6, 4, 3, 6, 5]

    def run(fault_spec):
        eng = Engine(cfg, params, EngineConfig(
            n_slots=3, max_len=MAX_LEN, prefill_bucket=8, prefill_chunk=8,
            kv_mode=mode, fault_spec=fault_spec), kv_scales=scales)
        for p, b in zip(prompts, budgets):
            eng.submit(p, max_new_tokens=b)
        return eng, eng.drain()

    ref_eng, ref = run(None)
    eng, fin = run(CHAOS_SPEC)

    # (a) exactly-once retire with schema reasons
    assert sorted(r.uid for r in fin) == list(range(len(prompts)))
    assert all(r.done for r in fin)
    assert all(r.finish_reason in RETIRE_REASONS for r in fin)
    # (b) no residual engine state: slots, queue, prefill marks, cache
    assert eng.sched.idle and not eng.sched.prefill_slots()
    assert occupied_slots(eng.cache) == []
    # (c) survivors are token-identical to the unfaulted run
    ref_out = {r.uid: r.out for r in ref}
    survivors = [r for r in fin if r.finish_reason in NORMAL_REASONS]
    assert survivors, "storm killed everyone — rates too hot to test (c)"
    for r in survivors:
        assert r.out == ref_out[r.uid], \
            f"uid {r.uid} diverged after retries ({kv_mode})"
    # the storm must actually have exercised retry + quarantine. The
    # injector is seeded, so replaying its submit-time draws predicts
    # exactly which uids were poisoned; every poisoned request that
    # DECODES (budget > 1 — the first token comes from prefill logits,
    # before the corrupting decode path) must have been quarantined
    m = eng.metrics()
    assert m["step_retries"] > 0
    assert m["faults_injected"]["step_exceptions"] > 0
    probe = FaultInjector(CHAOS_SPEC)
    poisoned = [u for u in range(len(prompts)) if probe.note_submit(u)]
    assert poisoned, "seed produced no poisoned submission — adjust spec"
    must_fail = {u for u in poisoned if budgets[u] > 1}
    failed = {r.uid for r in fin if r.finish_reason == "failed"}
    assert must_fail <= failed, \
        f"poisoned uids {must_fail - failed} escaped quarantine"
    # the unfaulted reference saw zero retries (retry machinery is
    # always on but must never fire on healthy decode output)
    assert ref_eng.metrics()["step_retries"] == 0


def test_poisoned_request_quarantined_alone(setup):
    """poison_rate=1: every request corrupts every attempt — all must
    quarantine as 'failed' (bounded retries), none may wedge the drain."""
    cfg, model, params, prompts = setup
    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, max_len=MAX_LEN, prefill_bucket=8, max_retries=1,
        fault_spec=FaultSpec(seed=0, poison_rate=1.0)))
    for p in prompts[:3]:
        eng.submit(p, max_new_tokens=6)
    t0 = time.perf_counter()
    fin = eng.drain()
    assert time.perf_counter() - t0 < 60.0
    assert all(r.finish_reason == "failed" for r in fin)
    assert len(fin) == 3
    assert occupied_slots(eng.cache) == []


# ============================================ degradation ladder end-to-end
def test_degrade_ladder_output_identical(setup):
    """A spec-enabled engine pushed through the full ladder (spec off →
    defer batch → shed) still emits token-identical outputs for every
    request it finishes normally, and records the rung transitions."""
    cfg, model, params, prompts = setup
    budgets = [6, 4, 6, 3, 6, 4, 5]

    def run(degrade):
        eng = Engine(cfg, params, EngineConfig(
            n_slots=2, max_len=MAX_LEN, prefill_bucket=8, spec_k=2,
            degrade=degrade, degrade_thresholds=(1, 2, 3),
            degrade_patience=1), draft_params=params)
        for i, (p, b) in enumerate(zip(prompts, budgets)):
            eng.submit(p, max_new_tokens=b,
                       cls="batch" if i % 2 else "interactive")
        return eng, eng.drain()

    base_eng, base = run(False)
    eng, fin = run(True)
    m = eng.metrics()
    assert m["degradation_transitions"] > 0
    # 7 requests / 2 slots with thresholds (1,2,3): pressure reaches
    # rung 3 ⇒ some queued work was shed
    assert m["requests_shed"] > 0
    # rung >= 1 steps routed the spec engine through plain decode
    assert m["spec_suspended_steps"] > 0
    base_out = {r.uid: r.out for r in base}
    for r in fin:
        if r.finish_reason in NORMAL_REASONS:
            assert r.out == base_out[r.uid]
    assert sorted(r.uid for r in fin) == list(range(len(prompts)))
    assert occupied_slots(eng.cache) == []


# ========================================================== metrics surface
def test_robustness_metrics_exported(setup):
    """The §12 counters land in the Prometheus exposition: shed,
    cancelled, deadline, retries, and the rung gauge (rendered even at
    rung 0 — a dashboard must distinguish 'healthy' from 'absent')."""
    cfg, model, params, prompts = setup
    clk = FakeClock()
    eng = Engine(cfg, params, EngineConfig(
        n_slots=1, max_len=MAX_LEN, max_new_tokens=4, prefill_bucket=8,
        max_queue=2, overload_policy="reject-new", degrade=True,
        fault_spec=FaultSpec(seed=0)), clock=clk)
    uids = [eng.submit(p, deadline_s=50.0) for p in prompts[:4]]
    eng.step()
    eng.cancel(uids[1])
    clk.t = 100.0
    eng.step()                                    # deadline sweep
    eng.drain()
    text = eng.registry.to_prometheus()
    for name in ("repro_sched_requests_shed_total",
                 "repro_sched_requests_cancelled_total",
                 "repro_engine_deadline_exceeded_total",
                 "repro_engine_step_retries_total",
                 "repro_engine_degradation_rung"):
        assert name in text, f"{name} missing from exposition"
    snap = eng.registry.snapshot()
    assert snap["sched_requests_shed"] >= 1       # 4 submits into bound 2
    assert snap["sched_requests_cancelled"] == 1
    assert snap["engine_deadline_exceeded"] >= 1
    assert snap["engine_degradation_rung"] == 0   # drained: back to healthy


# ============================================================= loadgen
def test_loadgen_robustness_fields_byte_identical():
    """Enabling cancels/deadlines must not perturb the base schedule:
    the extra rng draws happen after the base draws, so same-seed
    arrival times, classes, prompts, and budgets stay byte-identical."""
    CLASSES = loadgen.CLASSES
    make_open_loop_workload = loadgen.make_open_loop_workload
    base = make_open_loop_workload(11, 20, 1000, 4.0)
    robo = make_open_loop_workload(11, 20, 1000, 4.0, cancel_rate=0.3,
                                   deadlines=True, crash_rate=0.2)
    assert len(base) == len(robo) == 20
    for a, b in zip(base, robo):
        assert a.t == b.t and a.cls == b.cls
        assert a.max_new_tokens == b.max_new_tokens
        np.testing.assert_array_equal(a.prompt, b.prompt)
        assert a.cancel_t is None and a.ttft_deadline_s is None
        assert a.crash_t is None
    # cancels: seeded, after arrival, within the delay window — and
    # byte-identical whether or not the LATER crash draws are enabled
    # (crash draws append after cancel draws in the stream)
    cancel_only = make_open_loop_workload(11, 20, 1000, 4.0,
                                          cancel_rate=0.3, deadlines=True)
    assert [b.cancel_t for b in robo] == \
        [b.cancel_t for b in cancel_only]
    cancelled = [b for b in robo if b.cancel_t is not None]
    assert 0 < len(cancelled) < 20
    for b in cancelled:
        assert b.t + 0.05 <= b.cancel_t <= b.t + 0.5
    # crash schedule: seeded, after arrival, within the delay window
    crashes = [b for b in robo if b.crash_t is not None]
    assert 0 < len(crashes) < 20
    for b in crashes:
        assert b.t + 0.02 <= b.crash_t <= b.t + 0.3
    # deadlines: deterministic from the class SLOs
    for b in robo:
        spec = CLASSES[b.cls]
        assert b.ttft_deadline_s == spec["ttft_slo_s"] * 8.0
        assert b.deadline_s == (spec["ttft_slo_s"] + b.max_new_tokens
                                * spec["tpot_slo_s"]) * 8.0
    # and the robustness draws themselves are seed-reproducible
    again = make_open_loop_workload(11, 20, 1000, 4.0, cancel_rate=0.3,
                                    deadlines=True, crash_rate=0.2)
    assert [b.cancel_t for b in robo] == [b.cancel_t for b in again]
    assert [b.crash_t for b in robo] == [b.crash_t for b in again]
