"""Sharding rules + HLO analyzer tests (single real device; the full-mesh
path is exercised by launch/dryrun.py which forces 512 host devices)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import QuantConfig, QuantPolicy, quantize_tree
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_local_mesh
from repro.launch.shardings import (batch_shardings, param_shardings,
                                    spec_for_param)
from repro.models import get_model

KEY = jax.random.PRNGKey(0)


class FakeMesh:
    shape = {"data": 16, "model": 16}
    axis_names = ("data", "model")


def test_spec_rules():
    m = FakeMesh()
    up = spec_for_param("layers/attn/wq",
                        jnp.zeros((4, 4096, 2048)), m)
    assert up == jax.sharding.PartitionSpec(None, "data", "model")
    down = spec_for_param("layers/ffn/w_down",
                          jnp.zeros((4, 8192, 4096)), m)
    assert down == jax.sharding.PartitionSpec(None, "model", "data")
    emb = spec_for_param("embed", jnp.zeros((32000, 4096)), m)
    assert emb == jax.sharding.PartitionSpec("model", "data")
    bias = spec_for_param("layers/ffn/b_up", jnp.zeros((4, 8192)), m)
    assert bias == jax.sharding.PartitionSpec(None, None)
    exp = spec_for_param("moe_layers/moe/w_gate",
                         jnp.zeros((4, 64, 2048, 1408)), m)
    assert exp == jax.sharding.PartitionSpec(None, "model", "data", None)


def test_divisibility_fallback():
    m = FakeMesh()
    odd = spec_for_param("layers/attn/wk", jnp.zeros((4, 4096, 384)), m)
    assert odd[-1] == "model"          # 384 % 16 == 0
    odd2 = spec_for_param("layers/attn/wk", jnp.zeros((4, 4096, 100)), m)
    assert odd2[-1] is None            # 100 % 16 != 0 → replicate


def test_param_shardings_cover_quantized_leaves():
    mesh = make_local_mesh()
    cfg = get_arch("stablelm-1.6b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    qp, _ = quantize_tree(KEY, params, QuantPolicy(cfg=QuantConfig(bits=4)))
    sh = param_shardings(qp, mesh)
    # structure matches exactly
    jax.tree.map(lambda a, b: None, qp, sh)


def test_sharded_train_step_runs_on_local_mesh():
    """End-to-end jit with in_shardings on the 1×N local mesh."""
    from repro.optim import adamw
    from repro.launch.shardings import opt_shardings
    mesh = make_local_mesh()
    cfg = get_arch("stablelm-1.6b").reduced()
    model = get_model(cfg)
    with mesh:
        params = model.init(KEY, cfg)
        p_sh = param_shardings(params, mesh)
        params = jax.device_put(params, p_sh)
        opt_cfg = adamw.OptConfig(lr=1e-3)
        opt_state = adamw.init(opt_cfg, params)
        o_sh = opt_shardings(opt_state, p_sh, mesh)
        batch = {"tokens": jax.random.randint(KEY, (4, 16), 0, cfg.vocab),
                 "labels": jax.random.randint(KEY, (4, 16), 0, cfg.vocab)}
        b_sh = batch_shardings(batch, mesh)

        def step(p, o, b):
            (l, _), g = jax.value_and_grad(
                lambda pp, bb: model.loss_fn(pp, cfg, bb),
                has_aux=True)(p, b)
            return adamw.update(opt_cfg, o, p, g)[0:2] + (l,)

        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh))
        p2, o2, loss = fn(params, opt_state, batch)
        assert bool(jnp.isfinite(loss))


def test_hlo_analyzer_scan_trip_counts():
    def f(x, w):
        def body(x, _):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, None, length=10)
        return x
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    r = analyze(txt)
    expected = 10 * 2 * 64 * 128 * 128
    assert abs(r["dot_flops"] - expected) / expected < 1e-6


def test_hlo_analyzer_nested_scan():
    def f(x, w):
        def outer(x, _):
            def inner(x, _):
                return x @ w, None
            x, _ = jax.lax.scan(inner, x, None, length=3)
            return x, None
        x, _ = jax.lax.scan(outer, x, None, length=5)
        return x
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    r = analyze(txt)
    expected = 15 * 2 * 32 * 64 * 64
    assert abs(r["dot_flops"] - expected) / expected < 1e-6


def test_hlo_analyzer_counts_unlooped_dots():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    txt = jax.jit(f).lower(a, b).compile().as_text()
    r = analyze(txt)
    assert abs(r["dot_flops"] - 2 * 128 * 256 * 64) < 1e-6 * 2**21
