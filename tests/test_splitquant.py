"""Core SplitQuant properties: the paper's mathematical-equivalence claim,
resolution improvement, outlier preservation, stacked (scan) layouts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import (QuantConfig, baseline_quant_tensor,
                        split_activation_fake_quant, splitquant_tensor)

KEY = jax.random.PRNGKey(0)


def outlier_weight(key, shape, scale=0.05, outliers=((0, 0, 3.0),)):
    w = jax.random.normal(key, shape) * scale
    for i, j, v in outliers:
        w = w.at[i, j].set(v)
    return w


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("k", [1, 2, 3, 4])
def test_split_layers_sum_equals_dequant(bits, k):
    """Paper Fig. 2: Σ_c Ŵ_c == Ŵ exactly (mathematical equivalence)."""
    w = outlier_weight(KEY, (64, 48))
    sq = splitquant_tensor(KEY, w, QuantConfig(bits=bits), k=k)
    total = sum(sq.split_layers())
    np.testing.assert_array_equal(np.asarray(total),
                                  np.asarray(sq.dequantize()))


def test_split_masks_are_disjoint_and_cover():
    w = outlier_weight(KEY, (32, 32))
    sq = splitquant_tensor(KEY, w, QuantConfig(bits=2), k=3)
    cid = np.asarray(sq.cid)
    assert set(np.unique(cid)) <= {0, 1, 2}


@pytest.mark.parametrize("bits", [2, 4])
def test_splitquant_beats_baseline_with_outliers(bits):
    """The paper's headline claim at low bits: splitting preserves both the
    outliers and the bulk resolution."""
    key = jax.random.PRNGKey(7)
    w = jax.random.normal(key, (128, 128)) * 0.02
    w = w.at[0, 0].set(5.0).at[3, 3].set(-4.0).at[7, 1].set(4.5)
    cfg = QuantConfig(bits=bits)
    sq = splitquant_tensor(key, w, cfg, k=3)
    bl = baseline_quant_tensor(w, cfg)
    mse_sq = float(jnp.mean((w - sq.dequantize()) ** 2))
    mse_bl = float(jnp.mean((w - bl.dequantize()) ** 2))
    assert mse_sq < mse_bl
    # outlier reconstruction: splitquant must be dramatically closer
    assert abs(float(sq.dequantize()[0, 0]) - 5.0) < \
        abs(float(bl.dequantize()[0, 0]) - 5.0)


def test_outliers_not_clipped_unlike_percentile():
    key = jax.random.PRNGKey(8)
    w = jax.random.normal(key, (128, 128)) * 0.02
    w = w.at[0, 0].set(5.0)
    cfg = QuantConfig(bits=4, percentile=0.99)
    pc = baseline_quant_tensor(w, cfg)
    sq = splitquant_tensor(key, w, QuantConfig(bits=4), k=3)
    # percentile clip saturates the outlier far from 5.0
    assert abs(float(pc.dequantize()[0, 0]) - 5.0) > 3.0
    assert abs(float(sq.dequantize()[0, 0]) - 5.0) < 0.5


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 8]))
def test_scale_factors_increase(seed, bits):
    """§4: each split layer's scale S_c ≥ the unsplit scale (resolution
    never decreases; strictly increases when ranges narrow)."""
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (64, 64))
    cfg = QuantConfig(bits=bits)
    sq = splitquant_tensor(key, w, cfg, k=3)
    bl = baseline_quant_tensor(w, cfg)
    assert float(jnp.min(sq.scale)) >= float(bl.scale[0]) * 0.999


def test_stacked_matches_per_slice():
    """Stacked (vmapped) quantization == quantizing each slice separately."""
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (4, 32, 24))
    cfg = QuantConfig(bits=4)
    stacked = splitquant_tensor(key, w, cfg, k=3, stack_dims=1)
    keys = jax.random.split(key, 4)
    for i in range(4):
        single = splitquant_tensor(keys[i], w[i], cfg, k=3)
        np.testing.assert_array_equal(np.asarray(stacked.q[i]),
                                      np.asarray(single.q))
        np.testing.assert_allclose(np.asarray(stacked.dequantize()[i]),
                                   np.asarray(single.dequantize()),
                                   rtol=1e-6)


def test_stacked_slice_dequantizes_like_whole():
    """Slicing leaves along the stack axis (what lax.scan does) and
    dequantizing per slice == dequantizing the whole stacked tensor."""
    import dataclasses
    key = jax.random.PRNGKey(4)
    w = jax.random.normal(key, (3, 16, 8))
    sq = splitquant_tensor(key, w, QuantConfig(bits=2), k=3, stack_dims=1)
    whole = np.asarray(sq.dequantize())
    for i in range(3):
        part = dataclasses.replace(sq, q=sq.q[i], cid=sq.cid[i],
                                   scale=sq.scale[i], zero=sq.zero[i])
        np.testing.assert_allclose(np.asarray(part.dequantize()), whole[i],
                                   rtol=1e-6)


def test_activation_split_matches_manual_chunks():
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (4, 96))
    cfg = QuantConfig(bits=8)
    out = split_activation_fake_quant(x, cfg, n_chunks=3)
    assert out.shape == x.shape
    # per-chunk ranges ⇒ error within each chunk bounded by its own span
    for c in range(3):
        xc = np.asarray(x[:, c * 32:(c + 1) * 32])
        oc = np.asarray(out[:, c * 32:(c + 1) * 32])
        step = (xc.max() - xc.min()) / 255
        assert np.abs(oc - xc).max() <= step + 1e-5


def test_activation_split_improves_resolution_with_outlier():
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (2, 96)) * 0.1
    x = x.at[0, 0].set(100.0)          # outlier in chunk 0
    cfg = QuantConfig(bits=4)
    split = split_activation_fake_quant(x, cfg, n_chunks=3)
    whole = split_activation_fake_quant(x, cfg, n_chunks=1)
    # chunks 1,2 (no outlier) must be far better with the split
    err_s = np.abs(np.asarray(split[:, 32:]) - np.asarray(x[:, 32:])).max()
    err_w = np.abs(np.asarray(whole[:, 32:]) - np.asarray(x[:, 32:])).max()
    assert err_s < err_w / 4


def test_indivisible_width_still_splits():
    """Regression: an axis not divisible by n_chunks must use uneven
    (array_split) chunks, NOT silently degrade to one range — §4.2 was
    effectively disabled for d=128 with the default 3 chunks."""
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (2, 97)) * 0.1
    x = x.at[0, 0].set(100.0)          # outlier lands in chunk 0 ([0:33))
    cfg = QuantConfig(bits=4)
    out = split_activation_fake_quant(x, cfg, n_chunks=3)
    assert out.shape == x.shape
    # chunks 1-2 ([33:97)) must keep fine resolution despite the outlier —
    # impossible if the whole 97-wide axis shared one range
    whole = split_activation_fake_quant(x, cfg, n_chunks=1)
    err_s = np.abs(np.asarray(out[:, 33:]) - np.asarray(x[:, 33:])).max()
    err_w = np.abs(np.asarray(whole[:, 33:]) - np.asarray(x[:, 33:])).max()
    assert err_s < err_w / 4
    # uneven boundaries follow jnp.array_split semantics: 33 + 32 + 32
    from repro.core import activation_chunk_bounds
    assert activation_chunk_bounds(97, 3) == [0, 33, 65, 97]


def test_more_chunks_than_width_clamps():
    x = jnp.ones((2, 2))
    out = split_activation_fake_quant(x, QuantConfig(bits=8), n_chunks=5)
    assert out.shape == x.shape


def test_deployed_bytes_accounting():
    w = jnp.zeros((128, 128))
    sq = splitquant_tensor(KEY, w, QuantConfig(bits=2), k=3)
    n = 128 * 128
    expected = (2 * n + 2 * n) // 8 + sq.scale.nbytes + sq.zero.nbytes
    assert sq.nbytes_deployed() == expected
    bl = baseline_quant_tensor(w, QuantConfig(bits=2))
    assert bl.nbytes_deployed() < sq.nbytes_deployed()
