"""Pallas activation split-quantize kernel (paper §4.2) vs jnp oracle —
bits × shapes × chunk-count sweep, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.kernels.act_quant import (act_split_quantize,
                                     act_split_quantize_ref, dequantize_act)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("shape,chunks", [((256, 96), 3), ((512, 384), 3),
                                          ((256, 128), 1), ((256, 130), 2)])
def test_kernel_matches_ref(bits, shape, chunks):
    x = jax.random.normal(KEY, shape) * 2
    x = x.at[0, 0].set(50.0)                       # outlier in chunk 0
    qk, sk, zk = act_split_quantize(x, bits=bits, n_chunks=chunks,
                                    interpret=True)
    qr, sr, zr = act_split_quantize_ref(x, bits=bits, n_chunks=chunks)
    # codes may differ on exact .5 rounding boundaries — compare dequant
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(zk), np.asarray(zr), rtol=1e-6)
    xk = dequantize_act(qk, sk, zk)
    xr = dequantize_act(qr, sr, zr)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr), rtol=1e-4,
                               atol=1e-4)


def test_split_isolates_outlier_chunk():
    """§4.2: an outlier in chunk 0 must not hurt chunks 1-2 resolution."""
    x = jax.random.normal(KEY, (256, 96)) * 0.1
    x = x.at[0, 0].set(100.0)
    q3, s3, z3 = act_split_quantize(x, bits=4, n_chunks=3, interpret=True)
    q1, s1, z1 = act_split_quantize(x.reshape(256, 96), bits=4, n_chunks=1,
                                    interpret=True)
    x3 = dequantize_act(q3, s3, z3)
    x1 = dequantize_act(q1, s1, z1)
    err3 = np.abs(np.asarray(x3[:, 32:]) - np.asarray(x[:, 32:])).max()
    err1 = np.abs(np.asarray(x1[:, 32:]) - np.asarray(x[:, 32:])).max()
    assert err3 < err1 / 4


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 8]))
def test_roundtrip_bounded_property(seed, bits):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (256, 96)) * jax.random.uniform(
        jax.random.fold_in(key, 1), minval=0.1, maxval=10)
    q, s, z = act_split_quantize(x, bits=bits, n_chunks=3, interpret=True)
    xd = dequantize_act(q, s, z)
    # per-(row, chunk) error bounded by that chunk's own step size
    xc = np.asarray(x).reshape(256, 3, 32)
    xdc = np.asarray(xd).reshape(256, 3, 32)
    step = (xc.max(-1) - xc.min(-1)) / (2 ** bits - 1)
    err = np.abs(xdc - xc).max(-1)
    assert (err <= step + 1e-4).all()
