"""Calibration subsystem: stats collection, sensitivity, greedy
allocation, recipe (de)serialization, per-path quantize_tree overrides,
quantized-checkpoint roundtrip, and the static act-quant kernel."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.calib import (QuantRecipe, act_static_scales, best_uniform_within,
                         collect_act_stats, collect_kv_stats,
                         greedy_allocate, kv_static_scales,
                         layer_sensitivity, uniform_bytes)
from repro.checkpoint import ckpt
from repro.configs import get_arch
from repro.core import (QuantConfig, QuantPolicy, SplitQuantTensor,
                        activation_chunk_bounds, quantize_tree,
                        resolve_policy)
from repro.models import bert_tiny, get_model

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def bert():
    cfg = get_arch("bert-tiny")
    params = bert_tiny.init(KEY, cfg, n_classes=4, max_len=24)
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(1, cfg.vocab, size=(16, 24),
                                    dtype=np.int32),
             "mask": np.ones((16, 24), np.int32)}
    return cfg, params, batch


@pytest.fixture(scope="module")
def lm():
    cfg = get_arch("stablelm-1.6b").reduced()
    params = get_model(cfg).init(KEY, cfg)
    return cfg, params


# ------------------------------------------------ percentile normalization --
def test_percentile_default_single_path():
    """Regression: method="percentile" with an unset percentile must fall
    back to 0.99 through the same code path as an explicit value."""
    pol = QuantPolicy(cfg=QuantConfig(bits=4, percentile=None),
                      method="percentile")
    assert resolve_policy(pol).cfg.percentile == 0.99
    explicit = QuantPolicy(cfg=QuantConfig(bits=4, percentile=0.95),
                           method="percentile")
    assert resolve_policy(explicit).cfg.percentile == 0.95
    # baseline never clips, even if a percentile was set on the config
    base = QuantPolicy(cfg=QuantConfig(bits=4, percentile=0.95),
                       method="baseline")
    assert resolve_policy(base).cfg.percentile is None


def test_percentile_tree_equals_explicit_default():
    w = {"layers": {"ffn": {"w_up": jax.random.normal(KEY, (64, 32))}}}
    q_none, _ = quantize_tree(KEY, w, QuantPolicy(
        cfg=QuantConfig(bits=4, percentile=None), method="percentile"))
    q_99, _ = quantize_tree(KEY, w, QuantPolicy(
        cfg=QuantConfig(bits=4, percentile=0.99), method="percentile"))
    np.testing.assert_array_equal(
        np.asarray(q_none["layers"]["ffn"]["w_up"].q),
        np.asarray(q_99["layers"]["ffn"]["w_up"].q))


# --------------------------------------------------------- tree overrides --
def test_quantize_tree_honors_per_path_overrides():
    w = {"layers": {"attn": {"wq": jax.random.normal(KEY, (32, 32))},
                    "ffn": {"w_up": jax.random.normal(KEY, (32, 64)),
                            "w_down": jax.random.normal(KEY, (64, 32))}}}
    overrides = {"layers/attn/wq": {"bits": 2, "k": 2},
                 "layers/ffn/w_up": {"bits": 8},
                 "layers/ffn/w_down": {"method": "none"}}
    qt, report = quantize_tree(KEY, w, QuantPolicy(cfg=QuantConfig(bits=4)),
                               overrides=overrides)
    wq = qt["layers"]["attn"]["wq"]
    assert (wq.bits, wq.k) == (2, 2)
    assert qt["layers"]["ffn"]["w_up"].bits == 8
    # method "none" leaves the leaf dense
    assert not isinstance(qt["layers"]["ffn"]["w_down"], SplitQuantTensor)
    assert report["per_path"]["layers/attn/wq"]["bits"] == 2
    assert "layers/ffn/w_down" in report["skipped"]


def test_quantize_tree_rejects_unknown_override_paths():
    w = {"ffn": {"w": jax.random.normal(KEY, (32, 32))}}
    with pytest.raises(ValueError, match="matched no quantizable leaf"):
        quantize_tree(KEY, w, QuantPolicy(), overrides={"nope": {"bits": 2}})
    with pytest.raises(ValueError, match="unknown override keys"):
        quantize_tree(KEY, w, QuantPolicy(),
                      overrides={"ffn/w": {"bitz": 2}})


# ------------------------------------------------------------- act stats ---
def test_collect_act_stats_shapes_and_bounds(bert):
    cfg, params, batch = bert
    half = {k: v[:8] for k, v in batch.items()}
    stats = collect_act_stats(cfg, params, [half, batch], n_chunks=3)
    assert stats.n_batches == 2
    L = cfg.n_layers
    for site in bert_tiny.ACT_SITES:
        d = stats.sites[site]
        assert d["min"].shape == (L,) and d["chunk_min"].shape == (L, 3)
        assert np.all(d["min"] <= d["max"])
        assert np.all(d["chunk_min"] >= d["min"][:, None] - 1e-6)
        assert np.all(d["chunk_max"] <= d["max"][:, None] + 1e-6)
        assert np.all(d["p_lo"] >= d["min"]) and np.all(d["p_hi"] <= d["max"])
    scales = act_static_scales(stats)
    for site in bert_tiny.ACT_SITES:
        assert scales[site]["scale"].shape == (L, 3)
        assert np.all(scales[site]["scale"] > 0)


def test_activation_chunk_bounds_uneven():
    assert activation_chunk_bounds(97, 3) == [0, 33, 65, 97]
    assert activation_chunk_bounds(96, 3) == [0, 32, 64, 96]
    assert activation_chunk_bounds(5, 8) == [0, 1, 2, 3, 4, 5]


# --------------------------------------------------- sensitivity + budget ---
def test_sensitivity_and_allocation(bert):
    cfg, params, batch = bert
    table = layer_sensitivity(
        KEY, cfg, params, lambda p, b: bert_tiny.forward(p, cfg, b),
        batch, bits_list=(2, 8))
    assert table, "no quantizable groups found"
    for path, row in table.items():
        pb = row["per_bits"]
        assert set(pb) == {2, 8}
        # more bits can only help on the calibration objective
        assert pb[8]["mse"] <= pb[2]["mse"] + 1e-9
        assert pb[2]["bytes"] < pb[8]["bytes"]

    b_lo, b_hi = uniform_bytes(table, 2), uniform_bytes(table, 8)
    # at the minimum budget everything stays at 2 bits
    lo = greedy_allocate(table, b_lo)
    assert set(lo["assignment"].values()) == {2} and lo["feasible"]
    # at the max budget everything is upgraded (every upgrade has gain>=0;
    # allow ties where a group's error is already 0)
    hi = greedy_allocate(table, b_hi)
    assert hi["total_bytes"] <= b_hi
    # midpoint: mixed assignment within budget, uniform can only do 2 bits
    mid = greedy_allocate(table, (b_lo + b_hi) // 2)
    assert b_lo <= mid["total_bytes"] <= (b_lo + b_hi) // 2
    assert best_uniform_within(table, (b_lo + b_hi) // 2) == 2
    assert 2 <= mid["avg_bits"] <= 8
    # infeasible budget: minimum assignment returned, flagged
    broke = greedy_allocate(table, b_lo - 1)
    assert not broke["feasible"]
    assert set(broke["assignment"].values()) == {2}
    # overrides are consumable by quantize_tree
    qt, report = quantize_tree(KEY, params, QuantPolicy(),
                               overrides=mid["overrides"])
    got = {p: d["bits"] for p, d in report["per_path"].items()}
    assert got == mid["assignment"]


# ------------------------------------------------------- recipe roundtrip ---
def test_recipe_json_npz_roundtrip(lm):
    cfg, params = lm
    rng = np.random.default_rng(0)
    calib = [rng.integers(0, cfg.vocab, size=(2, 12)) for _ in range(2)]
    kv = kv_static_scales(collect_kv_stats(cfg, params, calib, qchunks=4))
    rec = QuantRecipe(
        name="unit", arch="stablelm-1.6b",
        policies={"layers/attn/wq": {"bits": 2, "k": 3,
                                     "method": "splitquant"}},
        kv_scales=kv, kv_qchunks=4,
        act_scales={"ffn_in": {"scale": np.ones((2, 3), np.float32),
                               "zero": np.zeros((2, 3), np.float32)}},
        ckpt_dir="ckpt", meta={"budget": 1234})
    with tempfile.TemporaryDirectory() as d:
        rec.save(d)
        got = QuantRecipe.load(d)
    assert got.name == rec.name and got.arch == rec.arch
    assert got.policies == rec.policies
    assert got.kv_qchunks == 4 and got.ckpt_dir == "ckpt"
    assert got.meta["budget"] == 1234
    for kk, v in rec.kv_scales.items():
        np.testing.assert_array_equal(got.kv_scales[kk], v)
    np.testing.assert_array_equal(got.act_scales["ffn_in"]["scale"],
                                  rec.act_scales["ffn_in"]["scale"])


# -------------------------------------------- quantized ckpt meta roundtrip --
def test_ckpt_quantized_roundtrip_preserves_meta(lm):
    cfg, params = lm
    qp, _ = quantize_tree(KEY, params, QuantPolicy(
        cfg=QuantConfig(bits=2), k=3))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 5, qp)
        # restore into a PLAIN fp32 tree: quantized leaves must come back
        # as SplitQuantTensors with their saved meta (no k-means rerun)
        restored, step = ckpt.restore(d, params)
        # and restoring into a quantized `like` must also work
        restored2, _ = ckpt.restore(d, qp)
    assert step == 5
    is_sqt = lambda l: isinstance(l, SplitQuantTensor)
    orig = jax.tree_util.tree_leaves(qp, is_leaf=is_sqt)
    got = jax.tree_util.tree_leaves(restored, is_leaf=is_sqt)
    got2 = jax.tree_util.tree_leaves(restored2, is_leaf=is_sqt)
    n_q = 0
    for a, b, c in zip(orig, got, got2):
        if not is_sqt(a):
            continue
        n_q += 1
        for b_i in (b, c):
            assert is_sqt(b_i)
            assert (b_i.bits, b_i.k) == (a.bits, a.k)
            assert b_i.orig_shape == a.orig_shape
            assert jnp.dtype(b_i.orig_dtype) == jnp.dtype(a.orig_dtype)
            np.testing.assert_array_equal(np.asarray(a.dequantize()),
                                          np.asarray(b_i.dequantize()))
    assert n_q > 0


# ------------------------------------------------------ static act kernel ---
@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("width", [96, 97, 128])
def test_static_act_kernel_matches_ref(bits, width):
    """Divisible and uneven (array_split) widths — 128 is the BERT-Tiny
    d_model the calibration stats are actually collected with."""
    from repro.kernels.act_quant import (act_split_quantize_static,
                                         act_split_quantize_static_ref,
                                         dequantize_act)
    x = jax.random.normal(KEY, (256, width)) * 2
    scale = jnp.asarray([1.3, 0.7, 2.1])
    zero = jnp.asarray([0.5, -1.25, 3.0])      # fractional static zeros
    qk = act_split_quantize_static(x, scale, zero, bits=bits,
                                   interpret=True)
    qr = act_split_quantize_static_ref(x, scale, zero, bits=bits)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(dequantize_act(qk, scale, zero)),
                               np.asarray(dequantize_act(qr, scale, zero)),
                               atol=1e-5)
    qmax = 2 ** (bits - 1) - 1
    assert int(qk.max()) <= qmax and int(qk.min()) >= -(qmax + 1)


def test_static_act_kernel_single_launch(monkeypatch):
    """The chunk-id-map form issues exactly ONE pallas_call regardless of
    chunking — uneven widths used to launch one kernel per chunk."""
    from repro.kernels import act_quant

    calls = []
    orig = act_quant.pl.pallas_call

    def counting(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(act_quant.pl, "pallas_call", counting)
    for width in (96, 97):                          # even and uneven
        x = jax.random.normal(KEY, (256, width))
        scale = jnp.asarray([1.3, 0.7, 2.1])
        zero = jnp.asarray([0.5, -1.25, 3.0])
        calls.clear()
        # __wrapped__ bypasses the jit cache so the trace (and therefore
        # the pallas_call count) happens on every invocation
        q = act_quant.act_split_quantize_static.__wrapped__(
            x, scale, zero, bits=8, interpret=True)
        ref = act_quant.act_split_quantize_static_ref(x, scale, zero,
                                                      bits=8)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(ref))
        assert len(calls) == 1, (width, len(calls))


def test_static_act_kernel_consumes_recipe_scales(bert):
    """End-to-end: scales calibrated by collect_act_stats on BERT-Tiny
    (uneven 128/3 chunks) feed straight into the static kernel."""
    from repro.kernels.act_quant import (act_split_quantize_static,
                                         dequantize_act)
    cfg, params, batch = bert
    stats = collect_act_stats(cfg, params, [batch], n_chunks=3)
    scales = act_static_scales(stats)["ffn_in"]
    layer = 0
    s = jnp.asarray(scales["scale"][layer])
    z = jnp.asarray(scales["zero"][layer])
    x = jax.random.normal(KEY, (256, cfg.d_model))
    q = act_split_quantize_static(x, s, z, bits=8, interpret=True)
    xd = dequantize_act(q, s, z)
    # reconstruction bounded by each chunk's calibrated step (values inside
    # the calibrated range; x ~ N(0,1) is well inside the activation range)
    assert xd.shape == x.shape
    step = 1.0 / np.asarray(s)
    from repro.core import activation_chunk_bounds
    bounds = activation_chunk_bounds(cfg.d_model, 3)
    for c, (lo, hi) in enumerate(zip(bounds, bounds[1:])):
        inside = np.abs(np.asarray(x[:, lo:hi])) < 2.0
        err = np.abs(np.asarray(xd[:, lo:hi]) - np.asarray(x[:, lo:hi]))
        assert err[inside].max() <= step[c] + 1e-5
