"""Property tests for 1-D k-means with greedy k-means++ init (paper §4.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import kmeans_1d


def test_recovers_separated_clusters():
    key = jax.random.PRNGKey(0)
    x = jnp.concatenate([
        -10 + 0.1 * jax.random.normal(key, (200,)),
        0.1 * jax.random.normal(jax.random.fold_in(key, 1), (500,)),
        10 + 0.1 * jax.random.normal(jax.random.fold_in(key, 2), (200,)),
    ])
    res = kmeans_1d(key, x, k=3)
    np.testing.assert_allclose(np.asarray(res.centroids), [-10, 0, 10],
                               atol=0.2)


def test_centroids_sorted_and_assignments_nearest():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (512,)) * 3
    res = kmeans_1d(key, x, k=3)
    c = np.asarray(res.centroids)
    assert (np.diff(c) >= 0).all()
    a = np.asarray(res.assignments)
    d = (np.asarray(x)[:, None] - c[None, :]) ** 2
    np.testing.assert_array_equal(a, d.argmin(1))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 3, 4]))
def test_cost_not_worse_than_single_cluster(seed, k):
    """k-means cost must be ≤ the k=1 (mean) cost — the paper's whole
    premise: splitting narrows ranges."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (256,)) * jax.random.uniform(
        jax.random.fold_in(key, 1), minval=0.1, maxval=10.0)
    res = kmeans_1d(key, x, k=k)
    cost1 = float(jnp.sum((x - jnp.mean(x)) ** 2))
    assert float(res.cost) <= cost1 + 1e-3


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_deterministic(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (128,))
    r1 = kmeans_1d(key, x, k=3)
    r2 = kmeans_1d(key, x, k=3)
    np.testing.assert_array_equal(np.asarray(r1.centroids),
                                  np.asarray(r2.centroids))


def test_all_identical_points():
    key = jax.random.PRNGKey(0)
    x = jnp.full((64,), 2.5)
    res = kmeans_1d(key, x, k=3)
    assert np.isfinite(np.asarray(res.centroids)).all()
    assert float(res.cost) < 1e-6


def test_cluster_ranges_narrower_than_total():
    """The quantization-relevant property: per-cluster (max-min) < global."""
    key = jax.random.PRNGKey(2)
    x = jnp.concatenate([jax.random.normal(key, (900,)),
                         20 + jax.random.normal(key, (50,)),
                         -20 + jax.random.normal(key, (50,))])
    res = kmeans_1d(key, x, k=3)
    xs = np.asarray(x)
    total = xs.max() - xs.min()
    for c in range(3):
        m = np.asarray(res.assignments) == c
        if m.any():
            assert xs[m].max() - xs[m].min() < total * 0.6
