"""Unit + property tests for the uniform quantizer (paper §3 eqs. 1-6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import QuantConfig, dequantize, fake_quant, qparams, quantize, value_range

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_code_range(bits):
    cfg = QuantConfig(bits=bits)
    x = jnp.linspace(-5, 7, 1000)
    beta, alpha = value_range(x)
    s, z = qparams(beta, alpha, cfg)
    q = quantize(x, s, z, cfg)
    assert int(q.min()) >= cfg.qmin
    assert int(q.max()) <= cfg.qmax
    # extremes map to extremes (full range used)
    assert int(q.min()) == cfg.qmin
    assert int(q.max()) == cfg.qmax


def test_paper_formula_int8():
    """S = (2^b - 1)/(α - β), Z = -2^(b-1) - INT(S·β)."""
    cfg = QuantConfig(bits=8)
    beta, alpha = jnp.float32(-1.0), jnp.float32(3.0)
    s, z = qparams(beta, alpha, cfg)
    assert np.isclose(float(s), 255.0 / 4.0)
    assert np.isclose(float(z), -128 - round(255.0 / 4.0 * -1.0))


def test_symmetric_zero_point():
    cfg = QuantConfig(bits=8, symmetric=True)
    s, z = qparams(jnp.float32(-2.0), jnp.float32(1.0), cfg)
    assert float(z) == 0.0
    # zero maps to zero exactly under symmetric quantization
    q = quantize(jnp.zeros(4), s, z, cfg)
    x = dequantize(q, s, z)
    np.testing.assert_allclose(np.asarray(x), 0.0)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32),
                min_size=2, max_size=256),
       st.sampled_from([2, 4, 8]))
def test_roundtrip_error_bound(vals, bits):
    """|x - x̂| ≤ (α-β)/(2^b - 1) for in-range x (half-step rounding ⇒ one
    full step is a safe bound, covering the clip at the code edges)."""
    x = jnp.asarray(vals, jnp.float32)
    cfg = QuantConfig(bits=bits)
    xq = fake_quant(x, cfg)
    span = float(jnp.max(x) - jnp.min(x))
    step = span / (2 ** bits - 1) if span > 0 else 0.0
    err = np.abs(np.asarray(xq) - np.asarray(x)).max()
    assert err <= step + 1e-4 * max(1.0, span)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_monotonic(seed):
    """Quantization must preserve ordering (monotone non-decreasing)."""
    key = jax.random.PRNGKey(seed)
    x = jnp.sort(jax.random.normal(key, (64,)) * 10)
    cfg = QuantConfig(bits=4)
    beta, alpha = value_range(x)
    s, z = qparams(beta, alpha, cfg)
    q = np.asarray(quantize(x, s, z, cfg))
    assert (np.diff(q) >= 0).all()


def test_percentile_clips_outlier():
    x = jnp.concatenate([jnp.linspace(-1, 1, 999), jnp.asarray([1e4])])
    beta, alpha = value_range(x, percentile=0.99)
    assert float(alpha) < 10.0
    assert float(beta) >= -1.0


def test_degenerate_range():
    cfg = QuantConfig(bits=2)
    x = jnp.full((16,), 3.14)
    xq = fake_quant(x, cfg)
    assert np.isfinite(np.asarray(xq)).all()


def test_per_channel_beats_per_tensor():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64, 32)) * jnp.linspace(0.01, 10, 32)
    pt = fake_quant(w, QuantConfig(bits=4))
    pc = fake_quant(w, QuantConfig(bits=4, per_channel=True),
                    axis=(0,))
    err_pt = float(jnp.mean((w - pt) ** 2))
    err_pc = float(jnp.mean((w - pc) ** 2))
    assert err_pc < err_pt
