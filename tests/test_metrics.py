"""Always-on metrics registry (obs.metrics), loadgen determinism, the
BENCH regression gate, and the trace-report drop warning (DESIGN.md §11).

The registry's contract is different from the tracer's: it is ON in
production, so these tests pin the things that keep it safe to leave on
— bounded memory (fixed buckets, no per-sample storage), get-or-create
instrument identity, None-until-set gauges, exact count/sum, and a
hot-path cost measured in nanoseconds. The loadgen tests pin the other
contract this PR leans on: one seed ⇒ one exact arrival schedule, so
open-loop BENCH sections are reproducible and configs comparable.
"""
import json
import os
import sys
import time

import numpy as np
import pytest

from repro.obs.metrics import (DEPTH_BUCKETS, MetricsRegistry,
                               RegistryQuantProbe, SnapshotWriter,
                               load_snapshots)

sys.path.append(os.path.join(os.path.dirname(__file__), "..",
                             "benchmarks"))

import check_regression  # noqa: E402
import loadgen  # noqa: E402


# ------------------------------------------------------------ registry ---
def test_counter_inc_and_negative_guard():
    r = MetricsRegistry()
    c = r.counter("toks", "tokens")
    assert c.value == 0
    c.inc()
    c.inc(5)
    assert c.value == 6
    with pytest.raises(ValueError):
        c.inc(-1)                     # counters are monotonic by contract


def test_gauge_none_until_set():
    r = MetricsRegistry()
    g = r.gauge("depth", "queue depth")
    assert g.value is None            # never-set gauges export nothing
    g.set(3)
    g.inc()
    g.dec(2)
    assert g.value == 2


def test_histogram_exact_count_sum_and_percentile():
    r = MetricsRegistry()
    h = r.histogram("lat", "latency", buckets=(0.001, 0.01, 0.1))
    assert h.percentile(50) is None   # None-on-empty, like obs.summary
    for v in (0.0005, 0.002, 0.003, 0.05, 2.0):     # incl. +Inf bucket
        h.observe(v)
    assert h.count == 5
    assert h.sum == pytest.approx(0.0005 + 0.002 + 0.003 + 0.05 + 2.0)
    p50 = h.percentile(50)
    assert 0.001 <= p50 <= 0.01      # median sample sits in that bucket
    snap = r.snapshot()["lat"]
    assert snap["count"] == 5
    assert snap["buckets"]["+Inf"] == 5              # cumulative


def test_get_or_create_identity_and_kind_mismatch():
    r = MetricsRegistry()
    assert r.counter("x", "d") is r.counter("x", "d")
    with pytest.raises(TypeError):
        r.gauge("x", "d")             # same name, different kind


def test_prometheus_text_format():
    r = MetricsRegistry()
    r.counter("steps", "engine steps").inc(3)
    r.gauge("depth", "queue depth").set(2)
    r.gauge("never_set", "stays unexported")
    h = r.histogram("lat_seconds", "latency", buckets=(0.01, 0.1))
    h.observe(0.005)
    h.observe(0.05)
    text = r.to_prometheus()
    assert "repro_steps_total 3" in text             # counter suffix
    assert "repro_depth 2" in text
    assert "never_set" not in text                   # unset gauge omitted
    assert 'repro_lat_seconds_bucket{le="0.01"} 1' in text
    assert 'repro_lat_seconds_bucket{le="+Inf"} 2' in text  # cumulative
    assert "repro_lat_seconds_count 2" in text
    assert "# TYPE repro_steps_total counter" in text


def test_snapshot_writer_interval_and_provenance(tmp_path):
    t = [0.0]
    r = MetricsRegistry()
    c = r.counter("n", "count")
    path = str(tmp_path / "metrics.jsonl")
    w = SnapshotWriter(path, r, interval_s=1.0, clock=lambda: t[0])
    c.inc()
    assert w.maybe_write()            # first call always writes
    t[0] = 0.5
    assert not w.maybe_write()        # inside the interval
    t[0] = 1.6
    c.inc()
    assert w.maybe_write()
    header, snaps = load_snapshots(path)
    assert header["kind"] == "header"
    assert "jax_version" in header["provenance"]     # shared artifact
    assert [s["metrics"]["n"] for s in snaps] == [1, 2]
    assert snaps[0]["seq"] == 0 and snaps[1]["seq"] == 1


def test_quant_probe_updates_registry():
    r = MetricsRegistry()
    probe = RegistryQuantProbe(r)
    assert probe                      # truthy: act_quant probe contract
    q = np.asarray([[-128, 0, 127, 5]], np.int8)
    probe.observe(q, layer="l0")
    snap = r.snapshot()
    assert snap["act_quant_observations_total"] == 1
    assert snap["act_quant_clip_frac"] == pytest.approx(0.5)


def test_registry_hot_path_is_cheap():
    """The registry is always on, so its per-event cost must be orders
    of magnitude under a decode step (~2 ms on the CI box). 20 µs/op is
    ~100x what the primitives measure — the bound only catches
    catastrophes (locks, allocation per observe), never box noise."""
    r = MetricsRegistry()
    c = r.counter("c", "d")
    h = r.histogram("h", "d")
    n = 20_000
    t0 = time.perf_counter()
    for _ in range(n):
        c.inc()
        h.observe(0.002)
    per_op = (time.perf_counter() - t0) / (2 * n)
    assert per_op < 20e-6, f"registry op costs {per_op * 1e6:.1f} us"


# ------------------------------------------------------------- loadgen ---
def test_loadgen_same_seed_identical_schedule():
    a = loadgen.make_open_loop_workload(7, 48, 500, 2.0)
    b = loadgen.make_open_loop_workload(7, 48, 500, 2.0)
    assert [x.t for x in a] == [x.t for x in b]
    assert [x.cls for x in a] == [x.cls for x in b]
    assert [x.max_new_tokens for x in a] == [x.max_new_tokens for x in b]
    assert all((x.prompt == y.prompt).all() for x, y in zip(a, b))
    c = loadgen.make_open_loop_workload(8, 48, 500, 2.0)
    assert [x.t for x in a] != [x.t for x in c]


def test_loadgen_arrival_times_well_formed():
    rng = np.random.default_rng(0)
    times = loadgen.poisson_burst_times(rng, 64, 4.0)
    assert (np.diff(times) > 0).all() and times[0] > 0
    assert (loadgen.poisson_burst_times(rng, 5, float("inf")) == 0).all()
    with pytest.raises(ValueError):
        loadgen.poisson_burst_times(rng, 5, 0.0)


class _FakeReq:
    def __init__(self, ttft, tpot, n):
        self.ttft = ttft
        self.t_first_token = 1.0
        self.t_done = 1.0 + tpot * (n - 1)
        self.out = [0] * n


def test_slo_judgement_and_summary_deterministic():
    wl = loadgen.make_open_loop_workload(7, 32, 500, 2.0)
    # half the requests blow their TTFT SLO by construction
    judged = [loadgen.request_slo(
        a, _FakeReq(10.0 if i % 2 else 0.01, 0.001, 8))
        for i, a in enumerate(wl)]
    s1 = loadgen.slo_summary(judged, wall_s=10.0)
    s2 = loadgen.slo_summary(list(judged), wall_s=10.0)
    assert s1 == s2                           # same rows -> same section
    assert s1["slo_attainment"] == pytest.approx(0.5)
    assert s1["goodput_tokens_per_s"] < s1["throughput_tokens_per_s"]
    for cls in loadgen.CLASSES:
        assert s1["per_class"][cls]["ttft_slo_s"] == \
            loadgen.CLASSES[cls]["ttft_slo_s"]
    empty = loadgen.slo_summary([], wall_s=0.0)
    assert empty["slo_attainment"] is None    # None-on-empty preserved
    assert empty["goodput_tokens_per_s"] is None


def test_find_knee():
    pts = [{"offered_rps": r, "slo_attainment": a}
           for r, a in [(8, 0.2), (1, 1.0), (2, 0.95), (4, 0.6)]]
    k = loadgen.find_knee(pts, threshold=0.9)
    assert k["last_ok_offered_rps"] == 2
    assert k["first_saturated_offered_rps"] == 4
    assert loadgen.find_knee(
        [{"offered_rps": 1, "slo_attainment": 1.0}]) is None


# ----------------------------------------------------- scheduler signals ---
def test_scheduler_queueing_signals_without_tracer():
    from repro.engine import EngineRequest, Scheduler
    t = [0.0]
    s = Scheduler(n_slots=1, clock=lambda: t[0])     # no tracer, no registry
    s.submit(EngineRequest(uid=0, prompt=[0]))
    t[0] = 0.25
    s.submit(EngineRequest(uid=1, prompt=[0]))
    assert s.queue_depth_submit == [1, 2]            # depth each submit saw
    s.admit()                                        # uid 0 -> slot, 0.25s
    t[0] = 1.0
    s.retire(0)
    s.admit()                                        # uid 1 waited 0.75s
    assert s.admit_latency_s == pytest.approx([0.25, 0.75])


def test_scheduler_acceptance_ewma():
    from repro.engine import Scheduler
    s = Scheduler(n_slots=1, clock=lambda: 0.0)
    assert s.accept_ewma is None
    s.note_spec(0, proposed=4, accepted=4)
    assert s.accept_ewma == pytest.approx(1.0)
    s.note_spec(0, proposed=4, accepted=0)
    assert s.accept_ewma == pytest.approx(0.9)       # alpha 0.1
    s.note_spec(0, proposed=0, accepted=0)           # w=1: no signal
    assert s.accept_ewma == pytest.approx(0.9)


# ------------------------------------------------------- engine end-to-end ---
@pytest.fixture(scope="module")
def served():
    import jax
    from repro.configs import get_arch
    from repro.engine import Engine, EngineConfig
    from repro.models import get_model
    cfg = get_arch("stablelm-1.6b").reduced()
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params, Engine, EngineConfig


def _run(cfg, params, Engine, EngineConfig, **kw):
    rng = np.random.default_rng(5)
    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, max_len=48, prefill_bucket=8, **kw))
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab, size=int(rng.integers(3, 9))),
                   max_new_tokens=5)
    t0 = time.perf_counter()
    fin = eng.drain()
    return eng, fin, time.perf_counter() - t0


def test_engine_registry_tracks_run(served):
    eng, fin, _ = _run(*served, kv_mode="int8")
    m = eng.metrics()
    snap = m["registry"]
    total = sum(len(r.out) for r in fin)
    assert snap["engine_tokens_generated"] == total
    assert snap["sched_requests_submitted"] == 3
    assert snap["sched_requests_retired"] == 3
    assert snap["engine_steps"] > 0
    assert snap["engine_step_seconds"]["count"] == snap["engine_steps"]
    # always-on queueing percentiles in metrics() (None-on-empty math)
    assert m["admit_latency_p95_s"] is not None
    assert m["queue_depth_at_submit_p95"] >= 1
    # gauges settled to the drained state
    assert snap["engine_slot_occupancy"] == 0.0
    assert snap["engine_tokens_in_flight"] == 0
    text = eng.registry.to_prometheus()
    assert f"repro_engine_tokens_generated_total {total}" in text


def test_engine_metrics_off_leaves_no_registry(served):
    eng, _, _ = _run(*served, metrics=False)
    m = eng.metrics()
    assert eng.registry is None
    assert "registry" not in m
    # the always-on scheduler lists still feed the percentile fields
    assert m["admit_latency_p95_s"] is not None


def test_engine_metrics_overhead_bounded(served):
    """Registry on vs off over the same tiny workload: the delta must be
    lost in the noise. The 1.5x wall bound is deliberately generous —
    the real ≤1% assertion runs in serve_bench on long walls; a unit
    test on sub-second walls can only catch the registry accidentally
    doing device syncs or O(history) work per step."""
    *_, on_wall = _run(*served)
    *_, off_wall = _run(*served, metrics=False)
    assert on_wall < off_wall * 1.5, (on_wall, off_wall)


# ------------------------------------------------------- regression gate ---
def _mini_bench():
    return {
        "speedup_tokens_per_s": 8.0,
        "greedy_agreement_engine_vs_wave": 1.0,
        "greedy_agreement_fused_vs_materialized": 1.0,
        "engine_int8_kv_fused": {"tokens_per_s": 1000.0,
                                 "decode_step_p95_s": 0.002},
        "trace": {"noise_frac": 0.016, "coverage": 0.99},
        "soak": {"speedup_chunked_vs_oneshot_tokens_per_s": 1.1,
                 "greedy_agreement_chunked_vs_oneshot": 1.0},
    }


def test_check_regression_passes_identical(tmp_path):
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    for d in (base, fresh):
        d.mkdir()
        (d / "BENCH_serve.json").write_text(json.dumps(_mini_bench()))
    assert check_regression.main(
        ["--baseline-dir", str(base), "--fresh-dir", str(fresh)]) == 0


def test_check_regression_flags_degraded(tmp_path, capsys):
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    base.mkdir()
    fresh.mkdir()
    (base / "BENCH_serve.json").write_text(json.dumps(_mini_bench()))
    bad = _mini_bench()
    bad["engine_int8_kv_fused"]["tokens_per_s"] = 500.0   # halved
    bad["greedy_agreement_fused_vs_materialized"] = 0.8   # broken floor
    (fresh / "BENCH_serve.json").write_text(json.dumps(bad))
    assert check_regression.main(
        ["--baseline-dir", str(base), "--fresh-dir", str(fresh)]) == 1
    out = capsys.readouterr().out
    assert "tokens_per_s" in out and "floor" in out


def test_check_regression_noise_aware_tolerance(tmp_path):
    """A drop inside 3x the measured noise floor must NOT trip the gate."""
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    base.mkdir()
    fresh.mkdir()
    b = _mini_bench()
    b["trace"]["noise_frac"] = 0.05                  # noisy box: 15% gate
    f = json.loads(json.dumps(b))
    f["engine_int8_kv_fused"]["tokens_per_s"] = 880.0        # -12%
    (base / "BENCH_serve.json").write_text(json.dumps(b))
    (fresh / "BENCH_serve.json").write_text(json.dumps(f))
    assert check_regression.main(
        ["--baseline-dir", str(base), "--fresh-dir", str(fresh)]) == 0


def test_check_regression_missing_fresh_metric_fails(tmp_path):
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    base.mkdir()
    fresh.mkdir()
    (base / "BENCH_serve.json").write_text(json.dumps(_mini_bench()))
    gone = _mini_bench()
    del gone["speedup_tokens_per_s"]                 # tracked metric vanished
    (fresh / "BENCH_serve.json").write_text(json.dumps(gone))
    assert check_regression.main(
        ["--baseline-dir", str(base), "--fresh-dir", str(fresh)]) == 1


def test_check_regression_smoke_self_check():
    """The CI entry point: committed baselines pass their own gates AND
    degraded copies are provably flagged."""
    root = os.path.join(os.path.dirname(__file__), "..")
    if not os.path.exists(os.path.join(root, "BENCH_serve.json")):
        pytest.skip("no committed baselines in this checkout")
    assert check_regression.main(["--smoke"]) == 0


# ------------------------------------------------- trace report warning ---
def _trace_file(tmp_path, capacity, spans):
    from repro.obs import Tracer
    tr = Tracer(capacity=capacity)
    for _ in range(spans):
        t = tr.begin()
        tr.span_end("decode", t, slots=1)
    path = str(tmp_path / "trace.jsonl")
    tr.to_jsonl(path)
    return path


def test_trace_report_warns_on_drops(tmp_path, capsys):
    from repro.launch import trace_report
    path = _trace_file(tmp_path, capacity=4, spans=12)
    assert trace_report.main([path]) == 0
    out = capsys.readouterr().out
    assert "WARNING" in out and "DROPPED" in out
    assert "trace_capacity" in out                   # the actionable fix


def test_trace_report_quiet_without_drops(tmp_path, capsys):
    from repro.launch import trace_report
    path = _trace_file(tmp_path, capacity=64, spans=12)
    assert trace_report.main([path]) == 0
    assert "DROPPED" not in capsys.readouterr().out
