"""Chunked RWKV6 WKV kernel vs sequential oracle: shape/decay sweeps in
interpret mode + the state-carry property the model relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.kernels.wkv_chunked import wkv_chunked, wkv_chunked_jnp, wkv_ref

KEY = jax.random.PRNGKey(0)


def make_inputs(key, BH, T, K, V, decay_scale=2.0):
    ks = [jax.random.fold_in(key, i) for i in range(5)]
    r = jax.random.normal(ks[0], (BH, T, K))
    k = jax.random.normal(ks[1], (BH, T, K))
    v = jax.random.normal(ks[2], (BH, T, V))
    dec = jax.random.normal(ks[3], (BH, T, K)) * decay_scale - 1
    w = jnp.exp(-jnp.exp(dec))
    u = jax.random.normal(ks[4], (BH, K)) * 0.5
    return r, k, v, w, u


@pytest.mark.parametrize("shape", [(2, 32, 16, 16), (4, 64, 32, 32),
                                   (1, 128, 64, 64)])
@pytest.mark.parametrize("chunk", [8, 16])
def test_kernel_matches_sequential(shape, chunk):
    BH, T, K, V = shape
    r, k, v, w, u = make_inputs(KEY, BH, T, K, V)
    ref = wkv_ref(r, k, v, w, u)
    out = wkv_chunked(r, k, v, w, u, chunk=chunk, interpret=True)
    sc = float(jnp.abs(ref).max())
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4 * sc)


def test_extreme_decay_no_nan():
    """w underflowing to 0 (very strong decay) must stay finite."""
    BH, T, K, V = 2, 32, 16, 16
    r, k, v, _, u = make_inputs(KEY, BH, T, K, V)
    w = jnp.full((BH, T, K), 1e-45)            # denormal → flushed to 0
    out = wkv_chunked(r, k, v, w, u, chunk=16, interpret=True)
    assert bool(jnp.all(jnp.isfinite(out)))
    ref = wkv_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_state_carry_equals_contiguous():
    """Running two halves with the carried state == one contiguous run."""
    BH, T, K, V = 2, 64, 16, 16
    r, k, v, w, u = make_inputs(KEY, BH, T, K, V)
    full, s_full = wkv_chunked_jnp(r, k, v, w, u, chunk=16)
    h = T // 2
    y1, s1 = wkv_chunked_jnp(r[:, :h], k[:, :h], v[:, :h], w[:, :h], u,
                             chunk=16)
    y2, s2 = wkv_chunked_jnp(r[:, h:], k[:, h:], v[:, h:], w[:, h:], u,
                             chunk=16, s0=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([1.0, 2.0, 4.0]))
def test_chunked_jnp_property(seed, decay_scale):
    key = jax.random.PRNGKey(seed)
    r, k, v, w, u = make_inputs(key, 2, 32, 8, 8, decay_scale)
    ref = wkv_ref(r, k, v, w, u)
    out, _ = wkv_chunked_jnp(r, k, v, w, u, chunk=8)
    sc = float(jnp.abs(ref).max()) + 1e-6
    assert float(jnp.abs(out - ref).max()) / sc < 1e-4


def test_model_chunked_matches_decode_path():
    """rwkv6 forward at T=32 (chunked) must agree with 32 sequential
    decode steps (the scan path)."""
    from repro.configs import get_arch
    from repro.models import rwkv6
    cfg = get_arch("rwkv6-3b").reduced()
    params = rwkv6.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab)
    full, _ = rwkv6.forward(params, cfg, {"tokens": toks})
    state = rwkv6.init_state(cfg, 2, jnp.float32)
    outs = []
    for t in range(32):
        lg, state = rwkv6.decode_step(params, cfg, state, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    step_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits), np.asarray(full),
                               rtol=2e-3, atol=2e-3)
