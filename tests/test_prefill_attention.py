"""Fused chunked-prefill kernel + engine state machine:

* kernel parity (jnp sweep and Pallas interpret mode) vs the
  assemble-then-`attend` oracle for fp / int8-dynamic / int8-static,
  including the decode-parking garbage row the cache mask must exclude;
* epilogue codes bit-identical to `quantize_kv` / `quantize_kv_static`
  (chunked and one-shot prefill fill the cache with the same bytes);
* chunked `prefill_chunk_slots` vs legacy `prefill` + `write_prefill`
  cache contents;
* engine-level token-for-token greedy equality (ragged chunk boundaries,
  chunk sizes 1 / 16 / not-dividing-S) for fp, int8-dynamic and
  int8-static caches;
* a slot mid-prefill stays invisible to decode (emits nothing, and a
  concurrently decoding request's tokens are untouched);
* the fused chunked path never materializes a dense fp prefill cache
  (`engine.FP_PREFILL_MATERIALIZATIONS` hook);
* non-transformer prefill signatures fail loudly on kwargs they cannot
  honor instead of silently swallowing them.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.engine import Engine, EngineConfig
from repro.engine.kvcache import (dequantize_kv, init_slot_cache,
                                  quantize_kv, quantize_kv_static,
                                  write_prefill)
from repro.kernels.prefill_attention import prefill_attention
from repro.models import get_model, transformer
from repro.models.attention import attend

KEY = jax.random.PRNGKey(0)


def make_case(seed, T=32, Hq=4, Hkv=2, D=16, prior=9, Sq=8):
    rng = np.random.default_rng(seed)
    f = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32))
    q = f(Sq, Hq, D)
    k_new, v_new = f(Sq, Hkv, D), f(Sq, Hkv, D)
    k_all, v_all = f(T, Hkv, D), f(T, Hkv, D)
    kv_pos = np.full(T, -1, np.int32)
    kv_pos[:prior] = np.arange(prior)
    # the engine's decode ride-along parks mid-prefill slots at their
    # next-unwritten position: a garbage row marked valid at kv_pos ==
    # pos_start, which the cache mask (kv_pos < pos_start) must exclude
    kv_pos[prior] = prior
    return q, k_new, v_new, k_all, v_all, jnp.asarray(kv_pos), rng


def reference(q, kd, vd, k_new, v_new, prior, length, pos_start):
    """Assemble [dequantized prior rows] + [chunk fp K/V] and run the
    dense masked `attend` oracle at the chunk's absolute positions."""
    kf = jnp.concatenate([kd[:prior], k_new[:length]], 0)[None]
    vf = jnp.concatenate([vd[:prior], v_new[:length]], 0)[None]
    kp = jnp.arange(prior + length, dtype=jnp.int32)[None]
    qpos = (pos_start + jnp.arange(q.shape[0], dtype=jnp.int32))[None]
    return attend(q[None], kf, vf, qpos, kp)[0]


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["jnp", "pallas-interpret"])
@pytest.mark.parametrize("mode", ["fp", "int8-dyn", "int8-static"])
def test_kernel_parity(mode, use_pallas):
    C, prior, Sq, length = 4, 9, 8, 5
    q, k_new, v_new, k_all, v_all, kv_pos, rng = make_case(0)
    kw = dict(kv_chunk=8, use_pallas=use_pallas, interpret=use_pallas)
    if mode == "fp":
        o, aux = prefill_attention(q, k_new, v_new, k_all, v_all, kv_pos,
                                   prior, length, mode="fp", **kw)
        kd, vd = k_all, v_all
        assert aux == ()
    elif mode == "int8-dyn":
        qk, ks, kz = quantize_kv(k_all, C)
        qv, vs, vz = quantize_kv(v_all, C)
        o, aux = prefill_attention(q, k_new, v_new, qk, qv, kv_pos, prior,
                                   length, k_scale=ks, k_zero=kz,
                                   v_scale=vs, v_zero=vz, mode="int8", **kw)
        kd, vd = dequantize_kv(qk, ks, kz), dequantize_kv(qv, vs, vz)
        # epilogue codes + scales must be bit-identical to quantize_kv —
        # the bytes write_prefill would have produced
        rqk, rks, rkz = quantize_kv(k_new, C)
        rqv, rvs, rvz = quantize_kv(v_new, C)
        for got, want in zip(aux, (rqk, rqv, rks, rkz, rvs, rvz)):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        ss = jnp.asarray(1.0 + rng.uniform(size=(2, C)).astype(np.float32))
        zz = jnp.asarray(rng.normal(size=(2, C)).astype(np.float32))
        qk = quantize_kv_static(k_all, ss, zz)
        qv = quantize_kv_static(v_all, ss, zz)
        o, aux = prefill_attention(q, k_new, v_new, qk, qv, kv_pos, prior,
                                   length, k_scale=ss, k_zero=zz,
                                   v_scale=ss, v_zero=zz, mode="int8",
                                   per_entry_scales=False, **kw)
        kd, vd = dequantize_kv(qk, ss, zz), dequantize_kv(qv, ss, zz)
        np.testing.assert_array_equal(
            np.asarray(aux[0]), np.asarray(quantize_kv_static(k_new, ss, zz)))
        np.testing.assert_array_equal(
            np.asarray(aux[1]), np.asarray(quantize_kv_static(v_new, ss, zz)))
    ref = reference(q, kd, vd, k_new, v_new, prior, length, prior)
    np.testing.assert_allclose(np.asarray(o)[:length],
                               np.asarray(ref)[:length], atol=2e-4)


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["jnp", "pallas-interpret"])
def test_kernel_empty_cache_is_pure_causal_prefill(use_pallas):
    """pos_start=0 (first chunk of a fresh slot): the whole cache sweep is
    dead and the result is plain causal self-attention over the chunk."""
    q, k_new, v_new, k_all, v_all, _, _ = make_case(1)
    kv_pos = jnp.full(k_all.shape[0], -1, jnp.int32)
    Sq = q.shape[0]
    o, _ = prefill_attention(q, k_new, v_new, k_all, v_all, kv_pos, 0, Sq,
                             mode="fp", kv_chunk=8, use_pallas=use_pallas,
                             interpret=use_pallas)
    qpos = jnp.arange(Sq, dtype=jnp.int32)[None]
    ref = attend(q[None], k_new[None], v_new[None], qpos, qpos[0])[0]
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref), atol=2e-4)


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("stablelm-1.6b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(3, 30)))
               for _ in range(6)]
    return cfg, model, params, prompts


@pytest.mark.parametrize("kv_mode", ["fp", "int8"])
def test_chunk_slots_matches_write_prefill(setup, kv_mode):
    """`prefill_chunk_slots` loop vs legacy one-shot `prefill` +
    `write_prefill` on the same slot: identical kv_pos rows, bit-identical
    layer-0 codes (layer-0 K/V see only embeddings, so chunking cannot
    perturb them), near-identical cache values at every layer, and the
    same greedy first token."""
    cfg, model, params, prompts = setup
    prompt = prompts[-1][:19]
    S, T, slot = len(prompt), 48, 1
    legacy = init_slot_cache(cfg, 2, T, mode=kv_mode)
    logits, pc = model.prefill(params, cfg,
                               {"tokens": jnp.asarray(prompt)[None]})
    legacy = write_prefill(legacy, slot, pc, S)
    first_legacy = int(jnp.argmax(logits[0, -1]))

    chunked = init_slot_cache(cfg, 2, T, mode=kv_mode)
    pos, chunk = 0, 8
    while pos < S:
        n = min(chunk, S - pos)
        toks = np.zeros((1, chunk), np.int32)      # right-padded chunk
        toks[0, :n] = prompt[pos:pos + n]
        last, chunked = transformer.prefill_chunk_slots(
            params, cfg, chunked, jnp.asarray(toks), jnp.int32(slot),
            jnp.int32(pos), jnp.int32(n))
        pos += n
    np.testing.assert_array_equal(np.asarray(chunked.kv_pos),
                                  np.asarray(legacy.kv_pos))
    np.testing.assert_array_equal(np.asarray(chunked.k[0, slot, :S]),
                                  np.asarray(legacy.k[0, slot, :S]))
    if kv_mode == "int8":
        km_c = dequantize_kv(chunked.k, chunked.k_scale, chunked.k_zero)
        km_l = dequantize_kv(legacy.k, legacy.k_scale, legacy.k_zero)
    else:
        km_c, km_l = chunked.k, legacy.k
    # later layers see attention over the (quantized) prior instead of the
    # legacy all-fp prefill — bounded by the INT8 read noise
    np.testing.assert_allclose(np.asarray(km_c[:, slot, :S]),
                               np.asarray(km_l[:, slot, :S]), atol=0.05)
    assert int(jnp.argmax(last[0])) == first_legacy


def run_engine(cfg, params, prompts, *, prefill_chunk, kv_mode="int8",
               scales=None, tokens=4, slots=2, max_len=48):
    eng = Engine(cfg, params, EngineConfig(
        n_slots=slots, max_len=max_len, max_new_tokens=tokens,
        prefill_bucket=8, kv_mode=kv_mode, prefill_chunk=prefill_chunk),
        kv_scales=scales)
    for p in prompts:
        eng.submit(p)
    return [r.out for r in eng.drain()]


@pytest.mark.parametrize("kv_mode", ["fp", "int8"])
@pytest.mark.parametrize("chunk", [1, 16, 7])
def test_engine_chunked_matches_oneshot(setup, kv_mode, chunk):
    """Token-for-token greedy equality between chunked fused prefill and
    the legacy one-shot path, across chunk sizes that divide, exceed, and
    ragged-split the prompts."""
    cfg, model, params, prompts = setup
    base = run_engine(cfg, params, prompts, prefill_chunk=0,
                      kv_mode=kv_mode)
    got = run_engine(cfg, params, prompts, prefill_chunk=chunk,
                     kv_mode=kv_mode)
    assert got == base


def test_chunk_boundaries_are_load_independent(setup):
    """Chunks are never split to fit leftover step budget, so a request's
    chunk decomposition — and therefore its exact generation (an int8
    cache makes boundary placement numerically visible: tokens after a
    boundary attend the quantized prefix) — is identical whether it
    prefills alone or under contention."""
    cfg, model, params, prompts = setup
    rng = np.random.default_rng(23)
    wl = [rng.integers(0, cfg.vocab, size=int(s))
          for s in (9, 27, 8, 30, 4, 26)]
    together = run_engine(cfg, params, wl, prefill_chunk=8, tokens=12,
                          slots=3, max_len=64)
    solo = [run_engine(cfg, params, [p], prefill_chunk=8, tokens=12,
                       slots=1, max_len=64)[0] for p in wl]
    assert together == solo


def test_engine_chunked_static_scales(setup):
    from repro.calib import collect_kv_stats, kv_static_scales
    cfg, model, params, prompts = setup
    rng = np.random.default_rng(0)
    calib = [rng.integers(0, cfg.vocab, size=(4, 48)) for _ in range(2)]
    scales = kv_static_scales(collect_kv_stats(cfg, params, calib,
                                               qchunks=4))
    base = run_engine(cfg, params, prompts, prefill_chunk=0, scales=scales)
    got = run_engine(cfg, params, prompts, prefill_chunk=16, scales=scales)
    assert got == base


def test_midprefill_slot_invisible_to_decode(setup):
    """A slot mid-prefill must not decode (no tokens, not in
    active_slots), and a concurrently decoding request must generate
    exactly what it would have generated without the prefilling neighbor."""
    cfg, model, params, prompts = setup
    rng = np.random.default_rng(11)
    short = rng.integers(0, cfg.vocab, size=4)
    long = rng.integers(0, cfg.vocab, size=28)

    solo = run_engine(cfg, params, [short], prefill_chunk=4, tokens=10,
                      max_len=64)[0]

    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, max_len=64, max_new_tokens=10, prefill_bucket=8,
        kv_mode="int8", prefill_chunk=4))
    eng.submit(short)
    eng.step()                                 # admit short, 1st chunk
    eng.step()                                 # short starts decoding
    uid_long = eng.submit(long)
    saw_midprefill = 0
    while not eng.sched.idle:
        eng.step()
        pre = eng.sched.prefill_slots()
        for slot in pre:
            req = eng.sched.slots[slot]
            if req.uid == uid_long:
                saw_midprefill += 1
                assert req.out == []           # emits nothing mid-prefill
                assert slot not in eng.sched.active_slots()
    # the 28-token prompt at 4 tokens/step must have spent >= 6 steps
    # mid-prefill while the short request was decoding
    assert saw_midprefill >= 6
    fin = {r.uid: r.out for r in eng.sched.finished}
    assert fin[0] == solo                      # short request undisturbed
    assert len(fin[uid_long]) == 10            # long request completes


def test_chunked_path_never_materializes_fp_prefill_cache(setup):
    """Acceptance hook: the fused chunked path allocates no dense
    (L, S, Hkv, D) fp prefill cache; the legacy path does, once per
    admission."""
    import repro.engine.engine as eng_mod
    cfg, model, params, prompts = setup
    before = eng_mod.FP_PREFILL_MATERIALIZATIONS
    run_engine(cfg, params, prompts[:3], prefill_chunk=8)
    assert eng_mod.FP_PREFILL_MATERIALIZATIONS == before
    run_engine(cfg, params, prompts[:3], prefill_chunk=0)
    assert eng_mod.FP_PREFILL_MATERIALIZATIONS == before + 3


def test_engine_defaults_fused():
    """ROADMAP flip: decode defaults to the fused dequant-in-kernel read;
    the materializing path stays reachable as the explicit oracle."""
    assert EngineConfig().fused_attn is True
    assert EngineConfig(fused_attn=False).fused_attn is False


# ---------------------------------------- loud non-transformer prefill ---
def test_prefill_kwargs_fail_loudly():
    """whisper/rwkv6/griffin prefill must raise on kwargs they cannot
    honor instead of silently swallowing them (the old `**_` signatures
    dropped a caller's pad_mask on the floor — corrupted left-pad
    handling instead of failing)."""
    from repro.models import griffin, rwkv6, whisper
    toks = {"tokens": jnp.zeros((2, 4), jnp.int32)}
    pad = jnp.ones((2, 4), bool)
    for mod in (whisper, rwkv6, griffin):
        with pytest.raises(NotImplementedError, match="pad_mask"):
            mod.prefill(None, None, toks, pad_mask=pad)
        with pytest.raises(NotImplementedError, match="MoE"):
            mod.prefill(None, None, toks, moe_blocks=4)
