"""Pallas kernel validation: shape/dtype/bits sweeps against the pure-jnp
oracle (interpret mode on CPU), the paper's literal 3-layer form, and the
packing utilities."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import QuantConfig, splitquant_tensor
from repro.kernels import ops, ref
from repro.kernels.packing import (pack_cids, pack_codes, unpack_cids,
                                   unpack_codes)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_pack_roundtrip(bits):
    q = jax.random.randint(KEY, (64, 32), -(2 ** (bits - 1)),
                           2 ** (bits - 1)).astype(jnp.int8)
    rt = unpack_codes(pack_codes(q, bits), bits)
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(q))


def test_cid_pack_roundtrip():
    cid = jax.random.randint(KEY, (64, 32), 0, 4).astype(jnp.uint8)
    np.testing.assert_array_equal(np.asarray(unpack_cids(pack_cids(cid))),
                                  np.asarray(cid))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 8]))
def test_pack_roundtrip_property(seed, bits):
    key = jax.random.PRNGKey(seed)
    q = jax.random.randint(key, (16, 8), -(2 ** (bits - 1)),
                           2 ** (bits - 1)).astype(jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(unpack_codes(pack_codes(q, bits), bits)), np.asarray(q))


def _packed(key, K, N, bits, k=3):
    w = jax.random.normal(key, (K, N)) * 0.1
    w = w.at[0, 0].set(2.0)
    sq = splitquant_tensor(key, w, QuantConfig(bits=bits), k=k)
    return ops.pack_for_kernel(sq), sq


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("shape", [(8, 512, 256), (16, 1024, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_ref(bits, shape, dtype):
    M, K, N = shape
    (qp, cp, recip, shift), _ = _packed(KEY, K, N, bits)
    x = jax.random.normal(KEY, (M, K), dtype=dtype)
    y_ref = ref.splitquant_matmul_ref(x, qp, cp, recip, shift, bits)
    y_pal = ops.quantized_matmul(x, qp, cp, recip, shift, bits=bits, k=3,
                                 use_pallas=True, interpret=True,
                                 block_m=128, block_n=128, block_k=256)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bits", [2, 4])
def test_kernel_matches_paper_three_layer_form(bits):
    (qp, cp, recip, shift), _ = _packed(KEY, 512, 256, bits)
    x = jax.random.normal(KEY, (8, 512))
    y_paper = ref.splitquant_matmul_paper(x, qp, cp, recip, shift, bits, k=3)
    y_pal = ops.quantized_matmul(x, qp, cp, recip, shift, bits=bits, k=3,
                                 use_pallas=True, interpret=True,
                                 block_m=128, block_n=128, block_k=256)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_paper),
                               rtol=1e-4, atol=1e-4)


def test_kernel_padding_path():
    """M/N/K not multiples of the block sizes exercise the padding logic."""
    (qp, cp, recip, shift), _ = _packed(KEY, 384, 200, 4)
    x = jax.random.normal(KEY, (5, 384))
    y_ref = ref.splitquant_matmul_ref(x, qp, cp, recip, shift, 4)
    y_pal = ops.quantized_matmul(x, qp, cp, recip, shift, bits=4, k=3,
                                 use_pallas=True, interpret=True,
                                 block_m=128, block_n=128, block_k=256)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_linear_dispatch_quantized_vs_dense():
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (256, 128)) * 0.1
    sq = splitquant_tensor(key, w, QuantConfig(bits=8), k=3)
    x = jax.random.normal(key, (4, 256))
    y_q = ops.linear(x, sq)
    y_d = x @ np.asarray(sq.dequantize())
    np.testing.assert_allclose(np.asarray(y_q), y_d, rtol=1e-4, atol=1e-4)


def test_k1_baseline_through_kernel():
    """k=1 (plain PTQ) must flow through the same kernel."""
    from repro.core import baseline_quant_tensor
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (512, 256))
    bl = baseline_quant_tensor(w, QuantConfig(bits=8))
    qp, cp, recip, shift = ops.pack_for_kernel(bl)
    x = jax.random.normal(key, (8, 512))
    y = ops.quantized_matmul(x, qp, cp, recip, shift, bits=8, k=1,
                             use_pallas=True, interpret=True,
                             block_m=128, block_n=128, block_k=256)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(x @ bl.dequantize()),
                               rtol=1e-3, atol=1e-3)


def test_batched_input_reshape():
    (qp, cp, recip, shift), _ = _packed(KEY, 256, 128, 4)
    x = jax.random.normal(KEY, (2, 3, 256))
    y = ops.quantized_matmul(x, qp, cp, recip, shift, bits=4, k=3)
    assert y.shape == (2, 3, 128)
