"""Self-speculative decoding (engine/spec.py, DESIGN.md §9).

The spec parity suite:

* ENGINE-LEVEL LOSSLESSNESS — speculative greedy output is 100%
  token-identical to plain greedy decoding on a mixed-length workload at
  fp, int8-dynamic and int8-static KV, with a genuinely-rejecting
  low-bit draft (INT2 on random weights rejects almost everything, so
  rollback runs constantly) and with the self-draft upper bound;
* VERIFY == SEQUENTIAL DECODE — each verify row's argmax equals the
  token a plain decode step would have produced (the property the
  engine-level guarantee rests on);
* ROLLBACK BIT-EXACTNESS — hypothesis property over random prefix
  lengths / window sizes / accept lengths: after `rollback_slot` +
  re-decode, slot codes/scales/kv_pos are bit-identical to a
  never-speculated cache, in dynamic and static scale modes;
* LOUD FAILURES — rwkv6 / griffin / whisper raise NotImplementedError
  on the speculative path (recurrent state has no positional rollback),
  and non-greedy speculative engines are rejected;
* accounting — per-slot accepted-length bookkeeping and the flipped
  chunked-prefill default.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.engine import Engine, EngineConfig
from repro.engine.kvcache import (init_slot_cache, rollback_slot,
                                  slot_layer_write)
from repro.models import get_model

KEY = jax.random.PRNGKey(0)
MAX_LEN = 48


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("stablelm-1.6b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(3, 14)))
               for _ in range(6)]
    return cfg, model, params, prompts


@pytest.fixture(scope="module")
def draft_int2(setup):
    """A draft that genuinely disagrees with the target: INT2 splitquant
    on random-init weights accepts only a few percent of proposals, so
    the engine-identity tests exercise rejection + rollback on nearly
    every spec step (a well-matched draft would accept everything and
    never roll back)."""
    from repro.core import QuantConfig, QuantPolicy, quantize_tree
    cfg, model, params, prompts = setup
    qp, _ = quantize_tree(KEY, params, QuantPolicy(cfg=QuantConfig(bits=2)))
    return qp


@pytest.fixture(scope="module")
def kv_scales(setup):
    from repro.calib import collect_kv_stats, kv_static_scales
    cfg, model, params, prompts = setup
    rng = np.random.default_rng(0)
    calib = [rng.integers(0, cfg.vocab, size=(4, MAX_LEN)) for _ in range(4)]
    return kv_static_scales(collect_kv_stats(cfg, params, calib, qchunks=4))


def run_engine(cfg, params, prompts, *, spec_k, draft=None, kv_mode="fp",
               scales=None, tokens=8, budgets=None, eos=-1,
               prefill_chunk=0):
    eng = Engine(cfg, params, EngineConfig(
        n_slots=3, max_len=MAX_LEN, max_new_tokens=tokens, eos_id=eos,
        prefill_bucket=8, kv_mode=kv_mode, spec_k=spec_k,
        prefill_chunk=prefill_chunk), kv_scales=scales, draft_params=draft)
    for i, p in enumerate(prompts):
        eng.submit(p, max_new_tokens=None if budgets is None else budgets[i])
    return [r.out for r in eng.drain()], eng


# ------------------------------------------------------ accept rule ------
def test_accept_length_rule():
    from repro.engine.spec import accept_length
    # drafts d_1..d_{w-1} vs target rows g_1..g_w
    assert accept_length([5, 6, 7], [5, 6, 7, 9], 4) == 3    # all accepted
    assert accept_length([5, 6, 7], [5, 9, 7, 1], 4) == 1    # stop at first
    assert accept_length([5, 6, 7], [1, 6, 7, 1], 4) == 0    # miss
    assert accept_length([5], [9], 1) == 0                   # w=1: non-spec
    assert accept_length([5, 6], [5, 6, 1, 1], 3) == 2


# ------------------------------------- engine-level token identity -------
@pytest.mark.parametrize("kv_mode", ["fp", "int8", "int8-static"])
def test_spec_greedy_token_identical(setup, draft_int2, kv_scales, kv_mode):
    """THE acceptance criterion: speculative greedy == plain greedy,
    token for token, on a mixed-length workload with mixed budgets —
    windows get budget-capped (mixed spec/non-spec steps) and the INT2
    draft forces rejections + rollbacks nearly every step."""
    cfg, model, params, prompts = setup
    scales = kv_scales if kv_mode == "int8-static" else None
    mode = "int8" if kv_mode.startswith("int8") else "fp"
    budgets = [8, 3, 8, 5, 1, 8]
    base, _ = run_engine(cfg, params, prompts, spec_k=0, kv_mode=mode,
                         scales=scales, budgets=budgets)
    spec, eng = run_engine(cfg, params, prompts, spec_k=3, draft=draft_int2,
                           kv_mode=mode, scales=scales, budgets=budgets)
    assert spec == base
    m = eng.metrics()
    assert m["verify_calls"] > 0 and m["acceptance_rate"] is not None
    # the INT2 draft must actually have been rejected somewhere, or this
    # test isn't exercising rollback at all
    assert m["draft_accepted"] < m["draft_proposed"]


def test_spec_self_draft_accepts_everything(setup):
    """Upper bound: the target drafting for itself accepts every
    proposal, commits spec_k+1 tokens per full window, and still matches
    plain greedy exactly."""
    cfg, model, params, prompts = setup
    base, _ = run_engine(cfg, params, prompts, spec_k=0)
    spec, eng = run_engine(cfg, params, prompts, spec_k=3, draft=params)
    assert spec == base
    m = eng.metrics()
    assert m["acceptance_rate"] == 1.0
    # far fewer engine steps than tokens: windows commit in bulk
    assert m["spec_steps"] < sum(len(o) for o in base)


def test_spec_with_eos_mid_window(setup, draft_int2):
    """eos inside a committed window truncates the commit exactly like
    sequential decode (eos never emitted, later commits dropped)."""
    cfg, model, params, prompts = setup
    base, _ = run_engine(cfg, params, prompts, spec_k=0)
    eos = base[0][3]                      # a token greedy actually emits
    base_e, _ = run_engine(cfg, params, prompts, spec_k=0, eos=eos)
    spec_e, _ = run_engine(cfg, params, prompts, spec_k=3, draft=params,
                           eos=eos)
    assert spec_e == base_e
    assert all(eos not in o for o in spec_e)


def test_spec_with_chunked_prefill(setup, draft_int2):
    """Speculation composes with chunked fused prefill: the draft cache
    mirrors every chunk, and output still matches plain greedy (which
    itself matches one-shot — PR 4's equivalence)."""
    cfg, model, params, prompts = setup
    base, _ = run_engine(cfg, params, prompts, spec_k=0, kv_mode="int8",
                         prefill_chunk=8)
    spec, _ = run_engine(cfg, params, prompts, spec_k=4, draft=draft_int2,
                         kv_mode="int8", prefill_chunk=8)
    assert spec == base


# ------------------------------------ verify == sequential decode --------
def test_verify_rows_match_sequential_decode(setup):
    """Each verify row's argmax equals the token plain decode would have
    produced — fed the same window sequentially. This is the per-position
    property the engine-level identity rests on (and why verify attends
    its own window through the quantization round-trip)."""
    from repro.engine.kvcache import write_prefill
    from repro.models import transformer
    cfg, model, params, prompts = setup
    W = 4
    prompt = prompts[0]
    S = len(prompt)
    logits, pc = model.prefill(params, cfg,
                               {"tokens": jnp.asarray(prompt)[None]})
    window = [int(jnp.argmax(logits[0, -1]))]

    def fresh():
        cache = init_slot_cache(cfg, 1, MAX_LEN, mode="int8")
        return write_prefill(cache, 0, pc, S)

    # sequential: W decode steps, each writing its token then predicting
    cache = fresh()
    seq = []
    for j in range(W):
        lg, cache = transformer.decode_step_slots(
            params, cfg, cache, jnp.asarray([[window[j]]], jnp.int32),
            jnp.asarray([S + j], jnp.int32), fused=True)
        seq.append(int(jnp.argmax(lg[0, -1])))
        window.append(seq[-1])
    # one fused verify of the same window
    vlog, vcache = transformer.verify_step_slots(
        params, cfg, fresh(), jnp.asarray([window[:W]], jnp.int32),
        jnp.int32(0), jnp.int32(S), jnp.int32(W))
    got = [int(t) for t in np.asarray(jnp.argmax(vlog[0], axis=-1))]
    assert got == seq
    # and the verify wrote the same cache bytes the decode steps did
    np.testing.assert_array_equal(np.asarray(vcache.kv_pos),
                                  np.asarray(cache.kv_pos))
    valid = np.asarray(cache.kv_pos)[..., :, None, None] >= 0
    np.testing.assert_array_equal(
        np.where(valid, np.asarray(vcache.k), 0),
        np.where(valid, np.asarray(cache.k), 0))


# --------------------------------------------- rollback bit-exactness ----
@pytest.mark.parametrize("static", [False, True])
def test_rollback_then_redecode_bitexact(setup, kv_scales, static):
    """Hypothesis property (random prefix occupancy, window size, accept
    length): a cache that speculated a window, rolled back to the
    accepted point, and then wrote the true continuation is bit-identical
    — codes, scales, kv_pos — to a cache that never speculated."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    cfg, model, params, prompts = setup
    L, H, D = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    scales = kv_scales if static else None

    def token_kv(seed):
        r = np.random.default_rng(seed)
        return (jnp.asarray(r.normal(size=(L, H, D)).astype(np.float32)),
                jnp.asarray(r.normal(size=(L, H, D)).astype(np.float32)))

    def write_token(cache, t, seed):
        k, v = token_kv(seed)

        def body(_, xs):
            cl, kl, vl = xs
            return None, slot_layer_write(
                cl, kl[None, None], vl[None, None],
                jnp.full((1, 1), t, jnp.int32))
        _, new = jax.lax.scan(body, None, (cache, k, v))
        return new

    @settings(max_examples=12, deadline=None)
    @given(st.integers(1, 16), st.integers(1, 6), st.data())
    def prop(prefix, window, data):
        accept = data.draw(st.integers(0, window - 1))
        extra = data.draw(st.integers(0, 3))
        fresh = lambda: init_slot_cache(cfg, 1, 32, mode="int8",
                                        kv_scales=scales)
        # never-speculated reference: prefix, then the true continuation
        ref = fresh()
        for t in range(prefix + accept + extra):
            ref = write_token(ref, t, seed=t)
        # speculated: prefix; window rows where the accepted prefix
        # carries the TRUE values (accepted drafts ARE the true tokens)
        # and the rejected tail carries junk; rollback; re-decode truth
        spec = fresh()
        for t in range(prefix):
            spec = write_token(spec, t, seed=t)
        for j in range(window):
            t = prefix + j
            spec = write_token(spec, t,
                               seed=t if j < accept else 7_000 + j)
        spec = rollback_slot(spec, 0, prefix + accept)
        for j in range(extra):
            t = prefix + accept + j
            spec = write_token(spec, t, seed=t)

        np.testing.assert_array_equal(np.asarray(spec.kv_pos),
                                      np.asarray(ref.kv_pos))
        valid = np.asarray(ref.kv_pos)[0][:, :, None, None] >= 0  # (N,T,1,1)
        for f in ("k", "v"):
            np.testing.assert_array_equal(
                np.where(valid, np.asarray(getattr(spec, f))[0], 0),
                np.where(valid, np.asarray(getattr(ref, f))[0], 0))
        if not static:      # per-entry scale rows must match on valid rows
            vs = valid[..., :1]                          # (N, T, 1, 1)→C
            for f in ("k_scale", "k_zero", "v_scale", "v_zero"):
                np.testing.assert_array_equal(
                    np.where(vs, np.asarray(getattr(spec, f))[0], 0),
                    np.where(vs, np.asarray(getattr(ref, f))[0], 0))

    prop()


def test_rollback_noop_and_full(setup):
    """Edge cases: rolling back to the current length changes nothing;
    rolling back to 0 empties the slot like clear_slot."""
    cfg, model, params, prompts = setup
    cache = init_slot_cache(cfg, 2, 16, mode="int8")
    cache = dataclasses.replace(
        cache, kv_pos=cache.kv_pos.at[:, 0, :5].set(
            jnp.arange(5, dtype=jnp.int32)))
    same = rollback_slot(cache, 0, 5)
    np.testing.assert_array_equal(np.asarray(same.kv_pos),
                                  np.asarray(cache.kv_pos))
    empty = rollback_slot(cache, 0, 0)
    assert int(np.asarray(empty.kv_pos[:, 0]).max()) == -1
    # other slots untouched
    np.testing.assert_array_equal(np.asarray(empty.kv_pos[:, 1]),
                                  np.asarray(cache.kv_pos[:, 1]))


# -------------------------------------------------- loud failures --------
@pytest.mark.parametrize("arch", ["rwkv6-3b", "recurrentgemma-9b",
                                  "whisper-tiny"])
def test_unsupported_families_fail_loud(arch):
    """rwkv6 / griffin / whisper must refuse the speculative path with a
    reasoned NotImplementedError (recurrent state has no positional
    rollback) — never a silent non-speculative fallback."""
    cfg = get_arch(arch).reduced()
    model = get_model(cfg)
    with pytest.raises(NotImplementedError, match="spec_k"):
        model.verify_step_slots()
    # and the engine itself refuses to construct for these families
    with pytest.raises(NotImplementedError):
        Engine(cfg, {}, EngineConfig(n_slots=1, max_len=16, spec_k=2))


def test_spec_requires_greedy(setup):
    cfg, model, params, prompts = setup
    with pytest.raises(NotImplementedError, match="greedy"):
        Engine(cfg, params, EngineConfig(
            n_slots=1, max_len=16, spec_k=2, temperature=0.7))


# ----------------------------------------------------- accounting --------
def test_scheduler_spec_accounting(setup):
    """Per-slot accepted-length bookkeeping: totals reconcile with the
    histogram, per-slot pairs sum to the totals, and metrics surface the
    acceptance rate."""
    cfg, model, params, prompts = setup
    _, eng = run_engine(cfg, params, prompts, spec_k=3, draft=params,
                        tokens=6)
    s = eng.sched
    assert s.spec_proposed > 0
    assert sum(s.accept_hist) == s.spec_accepted
    assert len(s.accept_hist) == eng.n_verify_calls
    assert sum(p for p, _ in s.spec_by_slot) == s.spec_proposed
    assert sum(a for _, a in s.spec_by_slot) == s.spec_accepted
    m = eng.metrics()
    assert m["acceptance_rate"] == pytest.approx(
        s.spec_accepted / s.spec_proposed)
    assert sum(m["accept_hist"]) == eng.n_verify_calls
    # every committed token except each request's admission token (sampled
    # from prefill logits) came through a verify window
    assert m["total_tokens"] - m["n_finished"] <= m["verify_tokens"]


def test_prefill_chunk_default_flipped(setup):
    """ROADMAP item: chunked fused prefill is the engine default now
    (prefill_chunk=0 remains the one-shot opt-out)."""
    cfg, model, params, prompts = setup
    assert EngineConfig().prefill_chunk > 0
    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, max_len=MAX_LEN, max_new_tokens=2, prefill_bucket=8))
    for p in prompts[:2]:
        eng.submit(p)
    eng.drain()
    assert eng.n_prefill_chunks > 0 and eng.n_prefills == 0
