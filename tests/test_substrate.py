"""Substrate tests: optimizer, checkpointing, fault-tolerant loop, data
pipeline determinism, gradient compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data import DataConfig, synthetic_lm_batch
from repro.data.classification import batches, emotion_like, spam_like
from repro.optim import adamw
from repro.runtime import train_loop


def test_adamw_converges_quadratic():
    cfg = adamw.OptConfig(lr=0.1, weight_decay=0.0, total_steps=200,
                          warmup_steps=0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adamw.init(cfg, params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        params, state, _ = adamw.update(cfg, state, params, g)
    assert float(jnp.abs(params["x"]).max()) < 0.05


def test_adamw_bf16_states():
    cfg = adamw.OptConfig(state_dtype="bfloat16")
    params = {"w": jnp.ones((8, 8))}
    st = adamw.init(cfg, params)
    assert st.m["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((8, 8)) * 0.1}
    p2, st2, m = adamw.update(cfg, st, params, g)
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


def test_grad_clip():
    cfg = adamw.OptConfig(clip_norm=1.0, lr=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    st = adamw.init(cfg, params)
    g = {"w": jnp.full(4, 100.0)}
    _, _, metrics = adamw.update(cfg, st, params, g)
    assert float(metrics["grad_norm"]) > 100.0


def test_grad_compression_error_feedback():
    """int8 compression with error feedback: the *accumulated* update over
    many steps converges to the uncompressed sum (residual stays bounded)."""
    err = jnp.zeros(64)
    key = jax.random.PRNGKey(0)
    g_total = jnp.zeros(64)
    d_total = jnp.zeros(64)
    for i in range(50):
        g = jax.random.normal(jax.random.fold_in(key, i), (64,)) * 0.01
        d, err = adamw.compress_int8(g, err)
        g_total += g
        d_total += d
    # residual bounded by one quantization step
    assert float(jnp.abs(g_total - d_total).max()) < 0.01


def test_ckpt_atomic_roundtrip():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, tree)
        restored, step = ckpt.restore(d, tree)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        assert restored["b"]["c"].dtype == jnp.bfloat16


def test_ckpt_retention():
    tree = {"a": jnp.zeros(2)}
    with tempfile.TemporaryDirectory() as d:
        for s in range(6):
            ckpt.save(d, s, tree, retain=2)
        kept = sorted(os.listdir(d))
        assert len(kept) == 2
        assert ckpt.latest_step(d) == 5


def test_ckpt_tmp_dir_ignored():
    tree = {"a": jnp.zeros(2)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, tree)
        os.makedirs(os.path.join(d, "step_00000009.tmp"))
        assert ckpt.latest_step(d) == 1


def test_data_pipeline_deterministic_and_restart_safe():
    dc = DataConfig(vocab=64, seq_len=16, global_batch=4)
    b1 = synthetic_lm_batch(dc, step=10)
    b2 = synthetic_lm_batch(dc, step=10)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = synthetic_lm_batch(dc, step=11)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_data_sharding_partition():
    dc = DataConfig(vocab=64, seq_len=8, global_batch=8)
    shards = [synthetic_lm_batch(dc, 0, shard=i, n_shards=4)
              for i in range(4)]
    assert all(s["tokens"].shape == (2, 8) for s in shards)
    # distinct shards produce distinct data
    assert not np.array_equal(np.asarray(shards[0]["tokens"]),
                              np.asarray(shards[1]["tokens"]))


def test_train_loop_failure_recovery():
    params = {"w": jnp.zeros(4)}
    opt_cfg = adamw.OptConfig(lr=0.1, warmup_steps=0)
    opt_state = adamw.init(opt_cfg, params)

    def loss_fn(p, b):
        return jnp.sum((p["w"] - b["target"]) ** 2), {}

    step = train_loop.make_train_step(loss_fn, opt_cfg)
    fails = {3, 9}

    def inject(s):
        if s in fails:
            fails.discard(s)
            raise RuntimeError("boom")

    with tempfile.TemporaryDirectory() as d:
        lc = train_loop.TrainLoopConfig(total_steps=15, ckpt_dir=d,
                                        ckpt_every=2, ckpt_async=False,
                                        log_every=100)
        p, o, hist = train_loop.run(
            lc, step, params, opt_state,
            lambda s: {"target": jnp.ones(4)}, inject_failure=inject,
            log=lambda *a: None)
        assert len(hist) >= 15        # replayed steps after restore included
        assert float(hist[-1]["loss"]) < float(hist[0]["loss"])


def test_train_loop_gives_up_after_max_failures():
    params = {"w": jnp.zeros(2)}
    opt_cfg = adamw.OptConfig()
    opt_state = adamw.init(opt_cfg, params)
    step = train_loop.make_train_step(
        lambda p, b: (jnp.sum(p["w"] ** 2), {}), opt_cfg)

    def inject(s):
        raise RuntimeError("persistent failure")

    lc = train_loop.TrainLoopConfig(total_steps=5, max_failures=2,
                                    log_every=100)
    with pytest.raises(RuntimeError):
        train_loop.run(lc, step, params, opt_state, lambda s: {},
                       inject_failure=inject, log=lambda *a: None)


def test_straggler_monitor():
    m = train_loop.StragglerMonitor(factor=2.0)
    assert not m.observe(0.1)
    for _ in range(5):
        m.observe(0.1)
    assert m.observe(1.0)
    assert m.flagged == 1


def test_classification_datasets_learnable_structure():
    ds = spam_like(n_samples=200, seq_len=32)
    assert ds.tokens.shape == (200, 32)
    assert set(np.unique(ds.labels)) == {0, 1}
    ds6 = emotion_like(n_samples=200, seq_len=32)
    assert ds6.n_classes == 6
    bs = list(batches(ds, 32, train=False))
    assert len(bs) == 6
