"""Observability package: tracer ring buffer + exporters, event schema
validation, shared summary math, phase-breakdown/waterfall aggregation,
quantization-quality counters, and the observed act-quant wrappers."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import (ActQuantProbe, SCHEMA_VERSION, Tracer, chrome_trace,
                       code_stats, lifecycle_summary, load_jsonl, mean,
                       pct, phase_breakdown, request_waterfalls, span_stats,
                       summarize, token_agreement, validate_events)
from repro.obs.quality import scale_to_span


class FakeClock:
    """Deterministic monotonic clock: every call advances by ``tick``."""

    def __init__(self, tick=0.001):
        self.t = 0.0
        self.tick = tick

    def __call__(self):
        self.t += self.tick
        return self.t


# --------------------------------------------------------------- tracer ---
def test_tracer_disabled_is_falsy_and_records_nothing():
    tr = Tracer(enabled=False, clock=FakeClock())
    assert not tr
    tr.span_end("decode", tr.begin())
    tr.event("submit", uid=0)
    tr.counter("kv_quality", 1.0)
    assert len(tr.events) == 0 and tr.dropped == 0


def test_tracer_records_and_ring_buffer_drops_oldest():
    tr = Tracer(capacity=4, clock=FakeClock())
    assert tr
    for i in range(7):
        tr.event("submit", uid=i)
    assert len(tr.events) == 4
    assert tr.dropped == 3
    assert [r["uid"] for r in tr.events] == [3, 4, 5, 6]   # newest kept
    assert tr.header()["dropped"] == 3


def test_tracer_span_fields_and_timebase():
    clk = FakeClock(tick=0.5)
    tr = Tracer(clock=clk)                     # t0 = 0.5
    t0 = tr.begin()                            # 1.0
    tr.span_end("decode", t0, slots=3, dispatch_s=0.1, wait_s=0.2)
    rec = tr.events[0]
    assert rec["kind"] == "span" and rec["name"] == "decode"
    assert rec["ts"] == pytest.approx(0.5)     # t_begin - t0
    assert rec["dur"] == pytest.approx(0.5)    # one tick begin -> end
    assert rec["slots"] == 3 and rec["dispatch_s"] == 0.1


def test_tracer_span_contextmanager_records_on_exception():
    tr = Tracer(clock=FakeClock())
    with pytest.raises(RuntimeError):
        with tr.span("decode", slot=1):
            raise RuntimeError("boom")
    assert len(tr.events) == 1 and tr.events[0]["name"] == "decode"


def test_tracer_jsonl_roundtrip(tmp_path):
    tr = Tracer(clock=FakeClock(), meta={"arch": "t"})
    tr.event("submit", uid=0, prompt_len=5, budget=8)
    tr.span_end("step", tr.begin())
    path = str(tmp_path / "trace.jsonl")
    n = tr.to_jsonl(path)
    records = load_jsonl(path)
    assert n == len(records) == 3              # header + 2
    assert records[0]["kind"] == "header"
    assert records[0]["schema"] == SCHEMA_VERSION
    assert records[0]["arch"] == "t"
    assert validate_events(records) == []


def test_chrome_trace_tracks():
    tr = Tracer(clock=FakeClock())
    tr.span_end("decode", tr.begin(), slot=2)
    tr.span_end("draft", tr.begin())           # un-slotted -> phase track
    tr.event("submit", uid=0)
    tr.counter("kv_quality", {"k_clip_frac": 0.1, "hist": [1, 2]})
    ct = chrome_trace(list(tr.records()))
    evs = ct["traceEvents"]
    xs = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"decode", "draft"}
    slot_span = next(e for e in xs if e["name"] == "decode")
    assert slot_span["tid"] == 3               # 1 + slot
    assert any(e["ph"] == "i" and e["name"] == "submit" for e in evs)
    counter = next(e for e in evs if e["ph"] == "C")
    assert counter["args"] == {"k_clip_frac": 0.1}     # list filtered out
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"slot 2", "requests", "counters", "phase:draft"} <= names


def test_tracer_ring_wraparound_mixed_kinds(tmp_path):
    """Wraparound with spans, events, and counters interleaved: the ring
    drops the OLDEST records regardless of kind, the header counts them,
    and both exporters stay consistent on the surviving window."""
    tr = Tracer(capacity=6, clock=FakeClock())
    for i in range(4):                         # 12 records, 6 survive
        tr.span_end("decode", tr.begin(), slot=i % 2, step=i)
        tr.event("submit", uid=i)
        tr.counter("kv_quality", {"k_clip_frac": i / 10})
    assert len(tr.events) == 6 and tr.dropped == 6
    # survivors are the two newest span/event/counter triples, in order
    assert [r["kind"] for r in tr.events] \
        == ["span", "event", "counter"] * 2
    assert [r["uid"] for r in tr.events if r["kind"] == "event"] == [2, 3]
    assert tr.header()["dropped"] == 6
    path = str(tmp_path / "wrap.jsonl")
    assert tr.to_jsonl(path) == 7              # header + 6
    records = load_jsonl(path)
    assert records[0]["dropped"] == 6
    assert validate_events(records) == []
    ct = chrome_trace(records)
    evs = ct["traceEvents"]
    assert sum(e["ph"] == "X" for e in evs) == 2
    assert sum(e["ph"] == "i" for e in evs) == 2
    assert sum(e["ph"] == "C" for e in evs) == 2


def test_chrome_trace_tid_shift_above_wide_slot_range():
    """Slot tids are 1 + slot, so slots >= 59 would land on the fixed
    requests/counters/phase tids — chrome_trace must shift the non-slot
    tracks above the widest slot instead of aliasing them."""
    tr = Tracer(clock=FakeClock())
    tr.span_end("decode", tr.begin(), slot=59)     # 1+59 == legacy requests
    tr.span_end("decode", tr.begin(), slot=70)     # past legacy phase tids
    tr.span_end("draft", tr.begin())               # un-slotted phase track
    tr.event("submit", uid=0)                      # requests track
    tr.counter("kv_quality", {"k_clip_frac": 0.1})
    ct = chrome_trace(list(tr.records()))
    evs = ct["traceEvents"]
    slot_tids = {e["tid"] for e in evs
                 if e["ph"] == "X" and e["args"].get("slot") is not None}
    assert slot_tids == {60, 71}
    req_tid = next(e["tid"] for e in evs if e["ph"] == "i")
    ctr_tid = next(e["tid"] for e in evs if e["ph"] == "C")
    phase_tid = next(e["tid"] for e in evs
                     if e["ph"] == "X" and "slot" not in e["args"])
    assert req_tid > 71 and len({req_tid, ctr_tid, phase_tid}) == 3
    assert not slot_tids & {req_tid, ctr_tid, phase_tid}
    # thread_name metadata is one label per tid, no duplicates
    names = {}
    for e in evs:
        if e["ph"] == "M" and e["name"] == "thread_name":
            assert e["tid"] not in names, "duplicate thread_name tid"
            names[e["tid"]] = e["args"]["name"]
    assert names[60] == "slot 59" and names[71] == "slot 70"
    assert names[req_tid] == "requests"
    assert names[ctr_tid] == "counters"
    assert names[phase_tid] == "phase:draft"


# --------------------------------------------------------------- schema ---
def _valid_records():
    return [
        {"kind": "header", "schema": SCHEMA_VERSION, "capacity": 16,
         "dropped": 0},
        {"kind": "event", "name": "submit", "ts": 0.0, "uid": 0},
        {"kind": "event", "name": "admit", "ts": 0.1, "uid": 0, "slot": 1},
        {"kind": "span", "name": "step", "ts": 0.1, "dur": 0.2},
        {"kind": "span", "name": "decode", "ts": 0.15, "dur": 0.1,
         "dispatch_s": 0.02, "wait_s": 0.05},
        {"kind": "event", "name": "retire", "ts": 0.4, "uid": 0,
         "reason": "eos"},
        {"kind": "counter", "name": "kv_quality", "ts": 0.5,
         "value": {"k_clip_frac": 0.0, "hist": [1, 2], "none": None}},
    ]


def test_validate_events_accepts_valid_trace():
    assert validate_events(_valid_records()) == []


@pytest.mark.parametrize("mutate,fragment", [
    (lambda r: r.pop(0), "expected header"),
    (lambda r: r[0].update(schema=99), "schema"),
    (lambda r: r[3].update(name="warp"), "unknown phase"),
    (lambda r: r[3].update(dur=-1.0), "bad dur"),
    (lambda r: r[4].update(dispatch_s=-0.1), "bad dispatch_s"),
    (lambda r: r[5].update(reason="bored"), "bad retire reason"),
    (lambda r: r[1].pop("uid"), "missing/bad uid"),
    (lambda r: r[6].update(value=object), "bad counter value"),
    (lambda r: r.append({"kind": "mystery"}), "unknown kind"),
])
def test_validate_events_rejects(mutate, fragment):
    records = _valid_records()
    mutate(records)
    errs = validate_events(records)
    assert errs and any(fragment in e for e in errs), errs


def test_validate_events_empty():
    assert validate_events([]) == ["empty trace (no header record)"]


# -------------------------------------------------------------- summary ---
def test_summary_empty_guards():
    assert pct([], 95) is None and mean([]) is None
    s = summarize([])
    assert s == {"count": 0, "mean": None, "p50": None, "p95": None}


def test_summary_values():
    vals = [1.0, 2.0, 3.0, 4.0]
    assert mean(vals) == 2.5
    assert pct(vals, 50) == 2.5
    s = summarize(vals, percentiles=(50,))
    assert s["count"] == 4 and s["p50"] == 2.5 and "p95" not in s


def test_token_agreement():
    class R:
        def __init__(self, out):
            self.out = out
    assert token_agreement([R([1, 2])], [R([1, 2])]) == 1.0
    assert token_agreement([R([1, 2]), R([3])],
                           [R([1, 9]), R([3])]) == pytest.approx(0.75)
    assert token_agreement([R([])], [R([])]) == 0.0   # no common positions
    assert token_agreement([], []) is None


# --------------------------------------------------------------- report ---
def _step_records():
    """Two steps of 1.0 s each; phases tile 1.8 s of the 2.0 s total."""
    recs = [{"kind": "header", "schema": SCHEMA_VERSION}]
    for i in range(2):
        t = float(i)
        recs += [
            {"kind": "span", "name": "decode", "ts": t, "dur": 0.6,
             "dispatch_s": 0.4, "wait_s": 0.1},
            {"kind": "span", "name": "accept_commit", "ts": t + 0.6,
             "dur": 0.3},
            {"kind": "span", "name": "step", "ts": t, "dur": 1.0},
        ]
    return recs


def test_phase_breakdown_coverage_and_attribution():
    pb = phase_breakdown(_step_records())
    assert pb["steps"] == 2
    assert pb["step_total_s"] == pytest.approx(2.0)
    assert pb["attributed_s"] == pytest.approx(1.8)
    assert pb["coverage"] == pytest.approx(0.9)
    dec = pb["phases"]["decode"]
    assert dec["count"] == 2 and dec["total_s"] == pytest.approx(1.2)
    assert dec["frac_of_step"] == pytest.approx(0.6)
    assert dec["host_s"] == pytest.approx(1.0)         # total - wait
    assert pb["dispatch_frac"] == pytest.approx(0.8 / 1.8)
    assert pb["device_wait_frac"] == pytest.approx(0.2 / 1.8)
    assert pb["other_host_s"] == pytest.approx(0.8)
    # "step" is the denominator, never a phase row
    assert "step" not in pb["phases"]


def test_phase_breakdown_empty():
    pb = phase_breakdown([])
    assert pb["steps"] == 0 and pb["coverage"] is None
    assert pb["dispatch_frac"] is None


def test_waterfalls_and_lifecycle():
    recs = [
        {"kind": "event", "name": "submit", "ts": 0.0, "uid": 1,
         "prompt_len": 7, "budget": 4},
        {"kind": "event", "name": "admit", "ts": 0.2, "uid": 1, "slot": 0},
        {"kind": "event", "name": "first_token", "ts": 0.5, "uid": 1},
        {"kind": "event", "name": "retire", "ts": 1.0, "uid": 1,
         "reason": "budget", "n_out": 4},
        {"kind": "event", "name": "submit", "ts": 0.1, "uid": 0},
    ]
    rows = request_waterfalls(recs)
    assert [r["uid"] for r in rows] == [0, 1]          # uid order
    full = rows[1]
    assert full["queued_s"] == pytest.approx(0.2)
    assert full["prefill_s"] == pytest.approx(0.3)
    assert full["decode_s"] == pytest.approx(0.5)
    assert full["total_s"] == pytest.approx(1.0)
    assert full["slot"] == 0 and full["reason"] == "budget"
    assert rows[0]["total_s"] is None                  # never retired
    ls = lifecycle_summary(recs)
    assert ls["requests"] == 2
    assert ls["retire_reasons"] == {"budget": 1}
    assert ls["total_s"]["mean"] == pytest.approx(1.0)


# -------------------------------------------------------------- quality ---
def test_code_stats():
    q = np.array([-128, -128, 0, 50, 127], np.int8)
    cs = code_stats(q, bits=8)
    assert cs["n"] == 5
    assert cs["lo_clip_frac"] == pytest.approx(0.4)
    assert cs["hi_clip_frac"] == pytest.approx(0.2)
    assert cs["clip_frac"] == pytest.approx(0.6)
    assert cs["occupancy"] == pytest.approx(1.0)
    empty = code_stats(np.zeros((0,), np.int8))
    assert empty["n"] == 0 and empty["clip_frac"] is None


def test_span_stats_hist_and_ref():
    spans = [1.0, 1.0, 1.0, 8.01]        # one >8x-median outlier chunk
    st = span_stats(spans)
    assert st["chunks"] == 4 and st["span_median"] == 1.0
    assert st["outlier_hist"][-1] == 1                 # the 8.01 bucket
    assert sum(st["outlier_hist"]) == 4
    st = span_stats([2.0, 2.0], ref_spans=[4.0, 4.0])
    assert st["occupancy_vs_ref"] == pytest.approx(0.5)
    # non-finite / non-positive spans are filtered, pairing preserved
    st = span_stats([2.0, np.inf, 0.0], ref_spans=[1.0, 1.0, 1.0])
    assert st["chunks"] == 1 and st["occupancy_vs_ref"] == 2.0
    assert span_stats([])["span_median"] is None


def test_scale_to_span_inverts_eq2():
    span = np.array([0.5, 4.0])
    scale = 255.0 / span
    np.testing.assert_allclose(scale_to_span(scale, bits=8), span)
    assert scale_to_span(np.array([0.0]))[0] == 0.0    # degenerate guard


def test_act_quant_probe_weighting_and_tracer():
    tr = Tracer(clock=FakeClock())
    probe = ActQuantProbe(tracer=tr, bits=8)
    probe.observe(np.full(3, 127, np.int8))            # all clipped
    probe.observe(np.zeros(9, np.int8), scale=np.array([255.0 / 2.0]))
    s = probe.summary()
    assert s["calls"] == 2 and s["elements"] == 12
    assert s["clip_frac"] == pytest.approx(3 / 12)     # element-weighted
    assert s["span_median"] == pytest.approx(2.0)
    assert len(tr.events) == 2                         # live counters
    assert tr.events[0]["kind"] == "counter"
    assert validate_events(list(tr.records())) == []


# ------------------------------------------- kv_quality_counters (int8) ---
def test_kv_quality_counters():
    import dataclasses
    from repro.configs import get_arch
    from repro.engine.kvcache import init_slot_cache, kv_quality_counters
    cfg = get_arch("stablelm-1.6b").reduced()
    cache = init_slot_cache(cfg, n_slots=2, max_len=8, mode="int8",
                            qchunks=4)
    empty = kv_quality_counters(cache)
    assert empty["valid_rows"] == 0 and "k_clip_frac" not in empty
    # hand-write slot 0 positions [0, 5): random codes, unit scales —
    # stale slot-1 bytes stay masked (kv_pos = -1) and must not count
    rng = np.random.default_rng(0)
    codes = rng.integers(-128, 128, size=cache.k.shape).astype(np.int8)
    pos = np.full(cache.kv_pos.shape, -1, np.int32)
    pos[:, 0, :5] = np.arange(5)
    cache = dataclasses.replace(cache, k=jnp.asarray(codes),
                                v=jnp.asarray(codes),
                                kv_pos=jnp.asarray(pos))
    out = kv_quality_counters(cache)
    assert out["valid_rows"] == cfg.n_layers * 5
    assert out["sampled_rows"] == out["valid_rows"]
    assert 0.0 <= out["k_clip_frac"] <= 1.0
    assert 0.0 <= out["v_occupancy"] <= 1.0
    assert out["k_span_median"] > 0                    # unit scales
    assert sum(out["k_span_outlier_hist"]) > 0
    sub = kv_quality_counters(cache, max_rows=3)
    assert sub["sampled_rows"] == 3                    # subsample cap
    fp = init_slot_cache(cfg, n_slots=1, max_len=4, mode="fp")
    with pytest.raises(ValueError):
        kv_quality_counters(fp)


def test_kv_quality_counters_ref_scales():
    from repro.configs import get_arch
    from repro.engine.kvcache import (init_slot_cache, kv_quality_counters,
                                      write_prefill)
    from repro.models import get_model
    cfg = get_arch("stablelm-1.6b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.arange(5)[None] % cfg.vocab)
    _, pc = model.prefill(params, cfg, {"tokens": toks}, max_len=8)
    cache = init_slot_cache(cfg, n_slots=1, max_len=8, mode="int8",
                            qchunks=4)
    cache = write_prefill(cache, 0, pc, 5)
    C = (cfg.n_layers, cfg.n_kv_heads, 4)
    ref = {f"{n}_scale": np.full(C, 255.0 / 4.0) for n in ("k", "v")}
    out = kv_quality_counters(cache, ref_scales=ref)
    assert out["k_occupancy_vs_ref"] is not None
    assert out["k_occupancy_vs_ref"] > 0


# ------------------------------------------------ observed act wrappers ---
def test_act_quant_observed_wrappers():
    from repro.kernels.act_quant import (act_split_quantize,
                                         act_split_quantize_observed,
                                         act_split_quantize_static,
                                         act_split_quantize_static_observed,
                                         set_quality_probe)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(256, 12)),
                    jnp.float32)
    probe = ActQuantProbe()
    set_quality_probe(probe)
    try:
        q, s, z = act_split_quantize_observed(x, n_chunks=3,
                                              interpret=True)
        qs = act_split_quantize_static_observed(
            x, jnp.full((3,), 10.0), jnp.zeros(3), interpret=True)
    finally:
        set_quality_probe(None)
    # same numerics as the unobserved kernels
    q0, _, _ = act_split_quantize(x, n_chunks=3, interpret=True)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q0))
    qs0 = act_split_quantize_static(x, jnp.full((3,), 10.0),
                                    jnp.zeros(3), interpret=True)
    np.testing.assert_array_equal(np.asarray(qs), np.asarray(qs0))
    summ = probe.summary()
    assert summ["calls"] == 2
    assert summ["elements"] == 2 * x.size
    assert summ["span_median"] is not None     # dynamic call fed scales
    # probe cleared: observed call records nothing further
    act_split_quantize_observed(x, n_chunks=3, interpret=True)
    assert probe.summary()["calls"] == 2


def test_trace_report_cli(tmp_path):
    """End-to-end: synthetic trace -> JSONL -> CLI (validate + chrome)."""
    from repro.launch.trace_report import main as report_main
    tr = Tracer(clock=FakeClock())
    tr.event("submit", uid=0, prompt_len=4, budget=2)
    tr.event("admit", uid=0, slot=0, queued_s=0.001)
    t = tr.begin()
    tr.span_end("decode", t, slots=1, dispatch_s=0.0, wait_s=0.0)
    tr.event("first_token", uid=0)
    tr.event("retire", uid=0, slot=0, reason="budget", n_out=2)
    tr.span_end("step", t)
    path = str(tmp_path / "t.jsonl")
    tr.to_jsonl(path)
    chrome = str(tmp_path / "t.trace.json")
    rc = report_main([path, "--validate", "--chrome", chrome])
    assert rc == 0
    ct = json.load(open(chrome))
    assert any(e.get("ph") == "X" for e in ct["traceEvents"])
    # corrupt trace fails --validate
    bad = str(tmp_path / "bad.jsonl")
    with open(bad, "w") as f:
        f.write(json.dumps({"kind": "span", "name": "warp", "ts": 0.0,
                            "dur": 1.0}) + "\n")
    assert report_main([bad, "--validate", "--waterfalls", "0"]) == 1
